"""Synthetic long-context task corpus (training side).

Stands in for the paper's ChatQA2/Tulu/Stack mixture *and* for the
LongBench/RULER/QASPER/LongProc/MT-Bench evaluation suites (the Rust
workload generators in ``rust/src/workload/`` draw from the same task
families with disjoint seeds — distribution-level parity, pinned by the
shared format constants below).

Every family produces (context, query, answer) where the answer depends on
sparse, identifiable positions inside a distractor-filled context — which
is exactly the regime KV-eviction quality is measured in, and it makes
ground-truth-relevant positions known.

Format contract (mirrored in rust/src/workload/spec.rs):
  * records are `KEY=VAL;` with keys/values over [A-Z0-9];
  * noise is lowercase words terminated by `;`;
  * a query is the exact record prefix `KEY=`; the model answers `VAL`
    followed by EOS (exact-continuation form — pure induction);
  * few-shot pairs are `x->Y;`, final incomplete pair is the query;
  * longproc records are `<NAME:VAL>`; the instruction `!tsv;` asks for
    `NAME\tVAL;` lines in order of appearance.
"""

from __future__ import annotations

import dataclasses
import random
import string
from typing import Callable

CODE_CHARS = string.ascii_uppercase + string.digits
NOISE_WORDS = (
    "lorem ipsum dolor amet tempor incidunt labore magna aliqua erat "
    "sed diam nonumy eirmod invidunt ut vero accusam justo duo kasd "
    "gubergren clita takimata sanctus est sit elitr".split()
)
FAMILIES = ("kv", "multikv", "vt", "fewshot", "code", "qa", "cwe", "longproc", "mtbench")


@dataclasses.dataclass
class Sample:
    family: str
    context: str
    query: str
    answer: str
    turns: tuple[tuple[str, str], ...] = ()  # extra (query, answer) turns

    @property
    def prompt(self) -> str:
        return self.context + self.query


def _code(rng: random.Random, n: int = 3) -> str:
    return "".join(rng.choice(CODE_CHARS) for _ in range(n))


def _noise(rng: random.Random, n_words: int) -> str:
    return "".join(rng.choice(NOISE_WORDS) + ";" for _ in range(n_words))


def _shuffle_merge(rng: random.Random, records: list[str], noise_words: int) -> str:
    parts = records + [rng.choice(NOISE_WORDS) + ";" for _ in range(noise_words)]
    rng.shuffle(parts)
    return "".join(parts)


def gen_kv(rng: random.Random, ctx_chars: int) -> Sample:
    """Single-needle retrieval (RULER NIAH analog)."""
    key, val = _code(rng), _code(rng)
    rec = f"{key}={val};"
    noise = max(0, (ctx_chars - len(rec)) // 6)
    return Sample("kv", _shuffle_merge(rng, [rec], noise), f"{key}=", val)


def gen_multikv(rng: random.Random, ctx_chars: int, n_keys: int = 4) -> Sample:
    """Multi-needle: several keys present, one queried."""
    pairs = {}
    while len(pairs) < n_keys:
        pairs[_code(rng)] = _code(rng)
    recs = [f"{k}={v};" for k, v in pairs.items()]
    used = sum(len(r) for r in recs)
    noise = max(0, (ctx_chars - used) // 6)
    k = rng.choice(list(pairs))
    return Sample("multikv", _shuffle_merge(rng, recs, noise), f"{k}=", pairs[k])


def gen_vt(rng: random.Random, ctx_chars: int, depth: int = 3) -> Sample:
    """Variable tracking: a=V; b=a; c=b; ?c= -> V."""
    names = rng.sample(string.ascii_lowercase, depth + 4)
    val = _code(rng)
    recs = [f"{names[0]}={val};"]
    for i in range(1, depth):
        recs.append(f"{names[i]}={names[i-1]};")
    # distractor chains
    dval = _code(rng)
    recs.append(f"{names[depth]}={dval};")
    recs.append(f"{names[depth+1]}={names[depth]};")
    used = sum(len(r) for r in recs)
    noise = max(0, (ctx_chars - used) // 6)
    # order matters for chains: keep chain order, sprinkle noise between
    out, ri = [], 0
    noise_each = noise // max(1, len(recs))
    for r in recs:
        out.append(_noise(rng, noise_each))
        out.append(r)
    return Sample("vt", "".join(out), f"{names[depth-1]}=", val)


def gen_fewshot(rng: random.Random, ctx_chars: int) -> Sample:
    """In-context pattern: x->X (uppercase mapping), novel query item."""
    n_shots = max(2, min(8, ctx_chars // 24))
    items = rng.sample([w for w in NOISE_WORDS if len(w) <= 5], n_shots + 1)
    recs = [f"{w}->{w.upper()};" for w in items[:-1]]
    used = sum(len(r) for r in recs)
    noise = max(0, (ctx_chars - used) // 6)
    ctx = _noise(rng, noise // 2) + "".join(recs) + _noise(rng, noise - noise // 2)
    return Sample("fewshot", ctx, f"{items[-1]}->", items[-1].upper())


def gen_code(rng: random.Random, ctx_chars: int) -> Sample:
    """Repository-completion analog: fn NAME(ARG); ... complete one."""
    n_fns = max(2, ctx_chars // 40)
    fns = {}
    while len(fns) < n_fns:
        fns[_code(rng, 4).lower()] = _code(rng, 3).lower()
    recs = [f"fn {n}({a});" for n, a in fns.items()]
    used = sum(len(r) for r in recs)
    noise = max(0, (ctx_chars - used) // 6)
    name = rng.choice(list(fns))
    return Sample("code", _shuffle_merge(rng, recs, noise), f"fn {name}(", fns[name])


def gen_qa(rng: random.Random, ctx_chars: int) -> Sample:
    """Document-QA analog (QASPER/LongBench-QA): word facts in noise."""
    objs = rng.sample([w for w in NOISE_WORDS if len(w) <= 6], 3)
    vals = rng.sample([w for w in NOISE_WORDS if len(w) <= 6], 3)
    recs = [f"{o}={v};" for o, v in zip(objs, vals)]
    used = sum(len(r) for r in recs)
    noise = max(0, (ctx_chars - used) // 6)
    i = rng.randrange(3)
    return Sample("qa", _shuffle_merge(rng, recs, noise), f"{objs[i]}=", vals[i])


def gen_cwe(rng: random.Random, ctx_chars: int) -> Sample:
    """Common-word extraction: one word repeats far more than others."""
    target = rng.choice([w for w in NOISE_WORDS if len(w) <= 5])
    others = [w for w in NOISE_WORDS if w != target]
    reps = max(4, ctx_chars // 30)
    parts = [target + ";"] * reps + [rng.choice(others) + ";" for _ in range(max(0, ctx_chars // 8 - reps))]
    rng.shuffle(parts)
    return Sample("cwe", "".join(parts), "?max=", target)


def gen_longproc(rng: random.Random, ctx_chars: int, n_records: int = 4) -> Sample:
    """LongProc HTML->TSV analog: extract all records, in order."""
    recs = []
    while len(recs) < n_records:
        recs.append((_code(rng), _code(rng)))
    tagged = [f"<{n}:{v}>" for n, v in recs]
    used = sum(len(t) for t in tagged)
    noise = max(0, (ctx_chars - used) // 6)
    out, per = [], noise // max(1, n_records)
    for t in tagged:
        out.append(_noise(rng, per))
        out.append(t)
    answer = "".join(f"{n}\t{v};" for n, v in recs)
    return Sample("longproc", "".join(out), "!tsv;", answer)


def gen_mtbench(rng: random.Random, ctx_chars: int) -> Sample:
    """Two-turn conversation: both queries hit the shared turn-1 context."""
    pairs = {}
    while len(pairs) < 3:
        pairs[_code(rng)] = _code(rng)
    recs = [f"{k}={v};" for k, v in pairs.items()]
    used = sum(len(r) for r in recs)
    noise = max(0, (ctx_chars - used) // 6)
    ks = list(pairs)
    k1, k2 = rng.sample(ks, 2)
    return Sample(
        "mtbench",
        _shuffle_merge(rng, recs, noise),
        f"{k1}=",
        pairs[k1],
        turns=((f"{k2}=", pairs[k2]),),
    )


GENERATORS: dict[str, Callable[..., Sample]] = {
    "kv": gen_kv,
    "multikv": gen_multikv,
    "vt": gen_vt,
    "fewshot": gen_fewshot,
    "code": gen_code,
    "qa": gen_qa,
    "cwe": gen_cwe,
    "longproc": gen_longproc,
    "mtbench": gen_mtbench,
}

# Pretraining mixture (weights roughly by how much signal each family
# carries for retrieval-style attention; mirrors the paper's mixed
# instruction + pretraining-text recipe).
TRAIN_MIX = (
    ("kv", 0.22),
    ("multikv", 0.16),
    ("vt", 0.10),
    ("fewshot", 0.12),
    ("code", 0.12),
    ("qa", 0.12),
    ("cwe", 0.06),
    ("longproc", 0.06),
    ("mtbench", 0.04),
)


def sample_family(rng: random.Random) -> str:
    r = rng.random()
    acc = 0.0
    for fam, w in TRAIN_MIX:
        acc += w
        if r <= acc:
            return fam
    return TRAIN_MIX[-1][0]


def gen_sample(rng: random.Random, family: str, ctx_chars: int) -> Sample:
    return GENERATORS[family](rng, ctx_chars)


def gen_mixed(rng: random.Random, ctx_chars: int) -> Sample:
    return gen_sample(rng, sample_family(rng), ctx_chars)

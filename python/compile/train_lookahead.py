"""Driver: train every LookaheadKV variant needed by the experiment index.

    python -m compile.train_lookahead [--model lkv-tiny] [--variants main,ablation,...]

Variants (see DESIGN.md §5):
  main      — n=8, LoRA on all linear layers (paper default, scaled)
  ablation  — Table 5 grid (n x module placement), lkv-tiny only
  trainctx  — Fig. 6 context-length robustness arms, lkv-tiny only
  srcdata   — Fig. 7 source-answer training arm, lkv-tiny only
"""

from __future__ import annotations

import argparse

from . import lookahead as L
from .config import MODELS

VARIANT_GROUPS = ("main", "ablation", "trainctx", "srcdata")


def specs_for(model: str, groups: list[str]) -> list[L.LkvTrainSpec]:
    out = []
    if "main" in groups:
        out.append(L.main_spec())
    if model == "lkv-tiny":  # ablation arms only on the primary target model
        if "ablation" in groups:
            out.extend(L.ablation_specs())
        if "trainctx" in groups:
            out.extend(L.trainctx_specs())
        if "srcdata" in groups:
            out.append(L.srcdata_spec())
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lkv-tiny", choices=[m for m in MODELS if m != "lkv-draft"])
    ap.add_argument("--variants", default="main,ablation,trainctx,srcdata")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    groups = [g for g in args.variants.split(",") if g]
    for g in groups:
        if g not in VARIANT_GROUPS:
            raise SystemExit(f"unknown variant group {g!r}; choose from {VARIANT_GROUPS}")
    for spec in specs_for(args.model, groups):
        L.train_lookahead(args.model, spec, force=args.force)


if __name__ == "__main__":
    main()

"""Pretrain the target / base / draft language models on the synthetic corpus.

The serving-side evaluation needs models that actually *use* long-range
attention (otherwise eviction quality would be unmeasurable), so training
follows a length curriculum (most steps short, a tail at 512/1024 tokens to
cover the relative-distance range of the longest serving bucket) with the
answer span up-weighted in the LM loss.

Checkpoints land in ``artifacts/ckpt/<model>.npz`` with the canonical
parameter names of ``model.param_order``; a per-family held-out accuracy
report is written to ``artifacts/train_report.json``.

Usage: python -m compile.train_lm [--model lkv-tiny] [--all]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import random
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model as M, optim, tokenizer as tok
from .config import CKPT_DIR, MODELS, PROFILE, FAST, ARTIFACTS, steps as scaled

# (seq_len, batch, steps, ctx_chars_range) — step counts sized for the
# single-core CI testbed (~0.7 s/step at 192); most of the gradient budget
# goes to short sequences, with a long-range tail so relative distances up
# to the largest serving bucket are trained (RoPE logits are exactly
# relative, so only unseen *distances* matter).
CURRICULUM = (
    (192, 8, scaled(2400), (40, 150)),
    (512, 2, scaled(260), (200, 440)),
    (1024, 1, scaled(100), (500, 930)),
)
# Cheaper recipe for secondary models (draft, base).
CURRICULUM_SMALL = (
    (192, 8, scaled(1200), (40, 150)),
    (512, 2, scaled(150), (200, 440)),
    (1024, 1, scaled(60), (500, 930)),
)
ANSWER_WEIGHT = 4.0
EVAL_SAMPLES = 16


def tokenize_example(sample: data.Sample, seq: int):
    """BOS + prompt + answer + EOS, padded; returns (ids, loss_weights)."""
    pids = tok.encode(sample.prompt, bos=True)
    aids = tok.encode(sample.answer, eos=True)
    ids = (pids + aids)[:seq]
    w = [1.0] * len(pids) + [ANSWER_WEIGHT] * len(aids)
    w = w[:seq]
    n = len(ids)
    ids = ids + [tok.PAD_ID] * (seq - n)
    w = w + [0.0] * (seq - n)
    # next-token loss: weight applies to the *predicted* token (shifted)
    return np.asarray(ids, np.int32), np.asarray(w, np.float32)


def make_batch(rng: random.Random, batch: int, seq: int, ctx_range):
    ids = np.zeros((batch, seq), np.int32)
    ws = np.zeros((batch, seq), np.float32)
    for i in range(batch):
        s = data.gen_mixed(rng, rng.randint(*ctx_range))
        ids[i], ws[i] = tokenize_example(s, seq)
    return jnp.asarray(ids), jnp.asarray(ws)


def lm_loss(params, cfg, tokens, weights):
    logits = M.lm_logits(params, cfg, tokens)  # [B, S, V]
    targets = tokens[:, 1:]
    w = weights[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


@functools.partial(jax.jit, static_argnames=("cfg", "base_lr", "total"))
def train_step(params, opt, step, tokens, weights, *, cfg, base_lr, total):
    loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens, weights)
    grads, gnorm = optim.clip_by_global_norm(grads)
    lr = optim.cosine_lr(step, base_lr, total)
    params, opt = optim.adam_step(params, grads, opt, lr)
    return params, opt, loss, gnorm


def eval_accuracy(params, cfg, rng: random.Random, seq: int, ctx_range) -> dict:
    """Greedy exact-match accuracy per task family on held-out samples."""
    out = {}
    for fam in data.GENERATORS:
        hits, n = 0, 0
        prompts, answers, lens = [], [], []
        for _ in range(EVAL_SAMPLES):
            s = data.gen_sample(rng, fam, rng.randint(*ctx_range))
            pids = tok.encode(s.prompt, bos=True)
            if len(pids) >= seq - 8:
                continue
            prompts.append(np.asarray(tok.pad_to(pids, seq), np.int32))
            answers.append(s.answer)
            lens.append(len(pids))
        if not prompts:
            continue
        toks = jnp.asarray(np.stack(prompts))
        lengths = jnp.asarray(np.asarray(lens, np.int32))
        max_new = max(len(a) for a in answers) + 1
        gen = np.asarray(
            M.generate_batch(params, cfg, toks, lengths, jax.random.PRNGKey(0), max_new=max_new)
        )
        for g, ans in zip(gen, answers):
            ids = []
            for t in g:
                if t == tok.EOS_ID:
                    break
                ids.append(int(t))
            hits += tok.decode(ids) == ans
            n += 1
        out[fam] = hits / max(n, 1)
    out["avg"] = float(np.mean([v for v in out.values()]))
    return out


def save_params(cfg, params, path: str):
    names = M.param_order(cfg)
    flat = M.flatten_params(cfg, params)
    np.savez(path, **{n: np.asarray(a) for n, a in zip(names, flat)})


def load_params(cfg, path: str):
    z = np.load(path)
    flat = [jnp.asarray(z[n]) for n in M.param_order(cfg)]
    return M.unflatten_params(cfg, flat)


def train_model(name: str, seed: int = 0, force: bool = False) -> dict:
    cfg = MODELS[name]
    os.makedirs(CKPT_DIR, exist_ok=True)
    ckpt = os.path.join(CKPT_DIR, f"{name}.npz")
    report_path = os.path.join(ARTIFACTS, "train_report.json")
    report = {}
    if os.path.exists(report_path):
        report = json.load(open(report_path))
    if os.path.exists(ckpt) and not force:
        print(f"[train_lm] {name}: checkpoint exists, skipping")
        return report.get(name, {})

    rng = random.Random(seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    opt = optim.adam_init(params)
    curriculum = CURRICULUM if name == "lkv-tiny" else CURRICULUM_SMALL
    total = sum(c[2] for c in curriculum)
    gstep, t0 = 0, time.time()
    losses = []
    for seq, batch, nsteps, ctx_range in curriculum:
        for i in range(nsteps):
            tokens, weights = make_batch(rng, batch, seq, ctx_range)
            params, opt, loss, gnorm = train_step(
                params, opt, jnp.int32(gstep), tokens, weights,
                cfg=cfg, base_lr=PROFILE.lm_lr, total=total,
            )
            gstep += 1
            if gstep % 200 == 0 or gstep == total:
                losses.append([gstep, float(loss)])
                print(
                    f"[train_lm] {name} step {gstep}/{total} seq={seq} "
                    f"loss={float(loss):.4f} gnorm={float(gnorm):.2f} "
                    f"({time.time()-t0:.0f}s)"
                )

    erng = random.Random(10_000 + seed)
    acc_short = eval_accuracy(params, cfg, erng, 192, (40, 150))
    acc_long = eval_accuracy(params, cfg, erng, 1024, (500, 930))
    print(f"[train_lm] {name} acc@192={acc_short['avg']:.3f} acc@1024={acc_long['avg']:.3f}")

    save_params(cfg, params, ckpt)
    entry = {
        "params": int(cfg.param_count()),
        "loss_curve": losses,
        "acc_short": acc_short,
        "acc_long": acc_long,
        "wallclock_s": round(time.time() - t0, 1),
        "fast_mode": FAST,
    }
    report[name] = entry
    os.makedirs(ARTIFACTS, exist_ok=True)
    json.dump(report, open(report_path, "w"), indent=2)
    return entry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default=None, choices=list(MODELS))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    default = [m for m in MODELS if m != "lkv-base" or os.environ.get("LKV_WITH_BASE") == "1"]
    names = default if (args.all or not args.model) else [args.model]
    for n in names:
        train_model(n, force=args.force)


if __name__ == "__main__":
    main()

"""L2: pure-JAX LLaMA-style transformer (RMSNorm + RoPE + GQA + SwiGLU).

One parameter layout, one core forward, many heads on top:

* ``lm_logits``        — training forward (full causal, batched);
* ``prefill``          — serving prefill: KV export + last-token logits +
                         the score tensors every baseline eviction policy
                         consumes (suffix-window rows, H2O column means);
* ``prefill_lkv``      — serving prefill with appended lookahead tokens and
                         selective LoRA (paper Eq. 3), exporting the
                         Pallas-kernel importance scores;
* ``suffix_forward``   — the shared machinery behind both LookaheadKV
                         training passes (GT scores from the true response
                         Y, estimates from the lookahead tokens P);
* ``decode_step``      — single-token decode over a compacted cache with
                         in-graph cache insertion (caches stay device-side
                         across steps in the Rust engine).

Parameters are a plain dict; ``param_order`` fixes the canonical flat
ordering that ``aot.py`` writes to ``weights.npz`` and the Rust runtime
feeds positionally.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .config import OBS_WINDOW, LookaheadConfig, ModelConfig
from .kernels.lookahead_score import lkv_score_batched
from .kernels.decode_attn import decode_attn

NEG_INF = -1e9
EPS = 1e-5

# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

LAYER_FIELDS = ("attn_norm", "wq", "wk", "wv", "wo", "mlp_norm", "wgate", "wup", "wdown")


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """He-style init; weights stored input-major ([d_in, d_out])."""
    d, dh = cfg.d_model, cfg.head_dim

    def dense(key, n_in, n_out):
        return jax.random.normal(key, (n_in, n_out), jnp.float32) * (n_in**-0.5)

    keys = jax.random.split(key, 2 + cfg.n_layers)
    params = {
        "emb": jax.random.normal(keys[0], (cfg.vocab, d), jnp.float32) * 0.02,
        "final_norm": jnp.ones((d,), jnp.float32),
        "head": dense(keys[1], d, cfg.vocab),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 7)
        params["layers"].append(
            {
                "attn_norm": jnp.ones((d,), jnp.float32),
                "wq": dense(ks[0], d, cfg.q_dim),
                "wk": dense(ks[1], d, cfg.kv_dim),
                "wv": dense(ks[2], d, cfg.kv_dim),
                "wo": dense(ks[3], cfg.q_dim, d),
                "mlp_norm": jnp.ones((d,), jnp.float32),
                "wgate": dense(ks[4], d, cfg.ff),
                "wup": dense(ks[5], d, cfg.ff),
                "wdown": dense(ks[6], cfg.ff, d),
            }
        )
    return params


def param_order(cfg: ModelConfig) -> list[str]:
    """Canonical flat ordering, shared with the Rust runtime via the manifest."""
    names = ["emb"]
    for i in range(cfg.n_layers):
        names += [f"l{i}.{f}" for f in LAYER_FIELDS]
    names += ["final_norm", "head"]
    return names


def flatten_params(cfg: ModelConfig, params: dict) -> list[jnp.ndarray]:
    out = [params["emb"]]
    for layer in params["layers"]:
        out += [layer[f] for f in LAYER_FIELDS]
    out += [params["final_norm"], params["head"]]
    return out


def unflatten_params(cfg: ModelConfig, flat: list[jnp.ndarray]) -> dict:
    it = iter(flat)
    params = {"emb": next(it), "layers": []}
    for _ in range(cfg.n_layers):
        params["layers"].append({f: next(it) for f in LAYER_FIELDS})
    params["final_norm"] = next(it)
    params["head"] = next(it)
    return params


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS) * w


def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions [...,T] -> cos/sin [...,T, head_dim] (half-split convention)."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * inv  # [...,T, half]
    cos = jnp.concatenate([jnp.cos(ang), jnp.cos(ang)], axis=-1)
    sin = jnp.concatenate([jnp.sin(ang), jnp.sin(ang)], axis=-1)
    return cos, sin


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [...,T, n_heads, head_dim]; cos/sin [...,T, head_dim]."""
    half = x.shape[-1] // 2
    rot = jnp.concatenate([-x[..., half:], x[..., :half]], axis=-1)
    return x * cos[..., None, :] + rot * sin[..., None, :]


class LoraSpec(NamedTuple):
    """Selective LoRA (paper §3.1): delta applied only where row_mask is 1."""

    params: dict  # per-layer dicts: {"wq": (A, B), ...}
    row_mask: jnp.ndarray  # [T] 1.0 on lookahead rows, 0.0 elsewhere
    scale: float


def _linear(h, w, name, layer_idx, lora: Optional[LoraSpec]):
    y = h @ w
    if lora is not None and name in lora.params[layer_idx]:
        a, b = lora.params[layer_idx][name]
        y = y + ((h * lora.row_mask[:, None]) @ a) @ b * lora.scale
    return y


# --------------------------------------------------------------------------
# Core forward
# --------------------------------------------------------------------------

# Per-layer callback: reducer(layer_idx, q, k_rep, v, probs) -> aux pytree.
# q: [T, H, dh] (post-RoPE), k_rep: [T, H, dh] (GQA-expanded, post-RoPE),
# probs: [H, T, T] attention probabilities (rows = queries).
Reducer = Callable[[int, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray], dict]


def core_forward(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,  # [T, d] input embeddings
    pos_ids: jnp.ndarray,  # [T] RoPE positions
    mask: jnp.ndarray,  # [T, T] bool, True = attend
    lora: Optional[LoraSpec] = None,
    reducer: Optional[Reducer] = None,
    collect_kv: bool = False,
    collect_pre_rope: bool = False,
):
    """Runs all layers; returns (hidden [T, d], aux dict).

    aux["k"]/aux["v"]: [L, Hkv, T, dh] post-RoPE keys / values when
    collect_kv; aux["k_pre"]: [L, Hkv, T, dh] pre-RoPE keys when
    collect_pre_rope (the importance predictor's input); aux["reduced"]:
    list of reducer outputs per layer.
    """
    t = x.shape[0]
    cos, sin = rope_cos_sin(pos_ids, cfg.head_dim, cfg.rope_theta)
    add_mask = jnp.where(mask, 0.0, NEG_INF)  # [T, T]
    ks, vs, kpres, reduced = [], [], [], []
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q = _linear(h, layer["wq"], "wq", li, lora).reshape(t, cfg.n_heads, cfg.head_dim)
        k = _linear(h, layer["wk"], "wk", li, lora).reshape(t, cfg.n_kv_heads, cfg.head_dim)
        v = _linear(h, layer["wv"], "wv", li, lora).reshape(t, cfg.n_kv_heads, cfg.head_dim)
        if collect_pre_rope:
            kpres.append(jnp.transpose(k, (1, 0, 2)))  # [Hkv, T, dh]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        k_rep = jnp.repeat(k, cfg.group, axis=1)  # [T, H, dh]
        v_rep = jnp.repeat(v, cfg.group, axis=1)
        scores = jnp.einsum("shd,thd->hst", q, k_rep) / jnp.sqrt(jnp.float32(cfg.head_dim))
        probs = jax.nn.softmax(scores + add_mask[None], axis=-1)  # [H, T, T]
        attn = jnp.einsum("hst,thd->shd", probs, v_rep).reshape(t, cfg.q_dim)
        x = x + _linear(attn, layer["wo"], "wo", li, lora)
        h2 = rmsnorm(x, layer["mlp_norm"])
        gate = jax.nn.silu(_linear(h2, layer["wgate"], "wgate", li, lora))
        up = _linear(h2, layer["wup"], "wup", li, lora)
        x = x + _linear(gate * up, layer["wdown"], "wdown", li, lora)
        if collect_kv:
            ks.append(jnp.transpose(k, (1, 0, 2)))  # [Hkv, T, dh]
            vs.append(jnp.transpose(v, (1, 0, 2)))
        if reducer is not None:
            reduced.append(reducer(li, q, k_rep, v, probs))
    aux = {}
    if collect_kv:
        aux["k"] = jnp.stack(ks)  # [L, Hkv, T, dh]
        aux["v"] = jnp.stack(vs)
    if collect_pre_rope:
        aux["k_pre"] = jnp.stack(kpres)  # [L, Hkv, T, dh]
    if reducer is not None:
        aux["reduced"] = reduced
    return x, aux


def _head_logits(params: dict, hidden_row: jnp.ndarray) -> jnp.ndarray:
    return rmsnorm(hidden_row, params["final_norm"]) @ params["head"]


# --------------------------------------------------------------------------
# Training forward (batched LM)
# --------------------------------------------------------------------------


def lm_logits(params: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens [B, S] -> logits [B, S, V] (plain causal)."""

    def single(tok):
        s = tok.shape[0]
        x = params["emb"][tok]
        pos = jnp.arange(s)
        mask = pos[None, :] <= pos[:, None]
        hidden, _ = core_forward(params, cfg, x, pos, mask)
        return rmsnorm(hidden, params["final_norm"]) @ params["head"]

    return jax.vmap(single)(tokens)


# --------------------------------------------------------------------------
# Serving prefill (base): KV + logits + baseline score tensors
# --------------------------------------------------------------------------


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    length: jnp.ndarray,
    logit_pos: Optional[jnp.ndarray] = None,
    window: int = OBS_WINDOW,
):
    """tokens [S] i32, length scalar i32, logit_pos scalar i32 (default
    length-1; the SpecKV/LAQ rescore path appends draft tokens and needs
    logits at the last *prompt* position instead).

    Returns dict:
      k, v:          [L, Hkv, S, dh] post-RoPE KV for the prompt
      logits:        [V] next-token logits at position logit_pos
      window_scores: [L, H, W, S] attention rows of the last W real
                     positions (rows before `win_start` are zeroed); the
                     manifest records win_start = clamp(length-W, 0, S-W)
      h2o_scores:    [L, H, S] column means over valid rows (H2O salience)
    """
    s = tokens.shape[0]
    x = params["emb"][tokens]
    pos = jnp.arange(s)
    valid = pos < length
    mask = (pos[None, :] <= pos[:, None]) & valid[None, :] & valid[:, None]
    win_start = jnp.clip(length - window, 0, s - window)

    def reducer(li, q, k_rep, v, probs):
        probs = probs * valid[None, :, None]  # zero padded query rows
        h2o = jnp.sum(probs, axis=1) / jnp.maximum(length, 1).astype(jnp.float32)
        win = jax.lax.dynamic_slice(
            probs, (0, win_start, 0), (cfg.n_heads, window, s)
        )  # [H, W, S]
        return {"h2o": h2o, "win": win}

    hidden, aux = core_forward(params, cfg, x, pos, mask, reducer=reducer, collect_kv=True)
    if logit_pos is None:
        logit_pos = jnp.maximum(length - 1, 0)
    logits = _head_logits(params, hidden[logit_pos])
    return {
        "k": aux["k"],
        "v": aux["v"],
        "logits": logits,
        "window_scores": jnp.stack([r["win"] for r in aux["reduced"]]),
        "h2o_scores": jnp.stack([r["h2o"] for r in aux["reduced"]]),
    }


# --------------------------------------------------------------------------
# Serving prefill with the learned importance predictor (pred_scores)
# --------------------------------------------------------------------------


def init_predictor(cfg: ModelConfig, hidden: int, key: jax.Array) -> list:
    """Per-(layer, KV-head) ``Linear(dh->hidden)->ReLU->Linear(hidden->1)``
    importance-predictor modules over pre-RoPE keys. Returns an
    [L][Hkv] nested list of dicts with w1 [dh, hidden], b1 [hidden],
    w2 [hidden], b2 [] (small-normal init; stands in until a predictor
    training recipe lands)."""
    heads = []
    for _ in range(cfg.n_layers):
        layer = []
        for _ in range(cfg.n_kv_heads):
            key, k1, k2, k3, k4 = jax.random.split(key, 5)
            layer.append(
                {
                    "w1": jax.random.normal(k1, (cfg.head_dim, hidden)) * 0.02,
                    "b1": jax.random.normal(k2, (hidden,)) * 0.02,
                    "w2": jax.random.normal(k3, (hidden,)) * 0.02,
                    "b2": jax.random.normal(k4, ()) * 0.02,
                }
            )
        heads.append(layer)
    return heads


def prefill_pred(
    params: dict,
    cfg: ModelConfig,
    pred: list,
    tokens: jnp.ndarray,
    length: jnp.ndarray,
    logit_pos: Optional[jnp.ndarray] = None,
    window: int = OBS_WINDOW,
):
    """``prefill`` plus ``pred_scores [L, Hkv, S]``: every pre-RoPE key row
    scored by its (layer, KV-head) predictor MLP, padded rows zeroed —
    the AOT twin of the reference backend's streamed predictor sinks."""
    s = tokens.shape[0]
    x = params["emb"][tokens]
    pos = jnp.arange(s)
    valid = pos < length
    mask = (pos[None, :] <= pos[:, None]) & valid[None, :] & valid[:, None]
    win_start = jnp.clip(length - window, 0, s - window)

    def reducer(li, q, k_rep, v, probs):
        probs = probs * valid[None, :, None]  # zero padded query rows
        h2o = jnp.sum(probs, axis=1) / jnp.maximum(length, 1).astype(jnp.float32)
        win = jax.lax.dynamic_slice(
            probs, (0, win_start, 0), (cfg.n_heads, window, s)
        )  # [H, W, S]
        return {"h2o": h2o, "win": win}

    hidden, aux = core_forward(
        params, cfg, x, pos, mask, reducer=reducer, collect_kv=True, collect_pre_rope=True
    )
    if logit_pos is None:
        logit_pos = jnp.maximum(length - 1, 0)
    logits = _head_logits(params, hidden[logit_pos])
    k_pre = aux["k_pre"]  # [L, Hkv, S, dh]
    layers = []
    for li in range(cfg.n_layers):
        per_head = []
        for g in range(cfg.n_kv_heads):
            m = pred[li][g]
            act = jax.nn.relu(k_pre[li, g] @ m["w1"] + m["b1"])  # [S, hidden]
            per_head.append(act @ m["w2"] + m["b2"])  # [S]
        layers.append(jnp.stack(per_head))
    pred_scores = jnp.stack(layers) * valid[None, None, :]
    return {
        "k": aux["k"],
        "v": aux["v"],
        "logits": logits,
        "window_scores": jnp.stack([r["win"] for r in aux["reduced"]]),
        "h2o_scores": jnp.stack([r["h2o"] for r in aux["reduced"]]),
        "pred_scores": pred_scores,
    }


# --------------------------------------------------------------------------
# Suffix forward — shared by LookaheadKV training (GT pass & LKV pass)
# --------------------------------------------------------------------------


def suffix_forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [S] prompt tokens (padded)
    length: jnp.ndarray,  # scalar i32
    suffix_emb: jnp.ndarray,  # [n, d] embeddings appended after the prompt
    lora: Optional[dict] = None,  # lookahead LoRA params (per-layer dicts)
    lora_scale: float = 1.0,
    use_kernel: bool = False,
    collect_kv: bool = False,
):
    """Runs the model over [prompt ; suffix] with the Algorithm-2 mask:
    prompt rows are plain causal; suffix row r sees prompt cols < length
    plus suffix cols <= r. Suffix rows get RoPE positions length + r.

    Returns (scores, aux): scores [L, H, S] = per-layer/head column means
    of the suffix rows' attention over prompt columns (zero at
    cols >= length) — computed by the Pallas kernel when `use_kernel`,
    else by slicing the dense probabilities (training path, which needs
    the dense rows for backprop anyway); aux carries cross [L, H, n, S]
    (dense path only), plus k/v/last_hidden when collect_kv.
    """
    s = tokens.shape[0]
    n = suffix_emb.shape[0]
    t = s + n
    x = jnp.concatenate([params["emb"][tokens], suffix_emb], axis=0)
    pos = jnp.concatenate([jnp.arange(s), length + jnp.arange(n)])
    idx = jnp.arange(t)
    causal = idx[None, :] <= idx[:, None]
    mask = causal & ((idx[None, :] < length) | (idx[None, :] >= s))

    lora_spec = None
    if lora is not None:
        row_mask = (idx >= s).astype(jnp.float32)
        lora_spec = LoraSpec(params=lora, row_mask=row_mask, scale=lora_scale)

    def reducer(li, q, k_rep, v, probs):
        out = {}
        if use_kernel:
            # [H, n, dh] suffix queries / [H, s+n, dh] all keys -> kernel
            qh = jnp.transpose(q[s:], (1, 0, 2))
            kh = jnp.transpose(k_rep, (1, 0, 2))
            out["scores"] = lkv_score_batched(qh, kh, length, s_max=s)  # [H, S]
        else:
            cross = probs[:, s:, :s]  # [H, n, S]
            cross = cross * (jnp.arange(s)[None, None, :] < length)
            out["cross"] = cross
            out["scores"] = jnp.mean(cross, axis=1)
        return out

    hidden, aux = core_forward(
        params, cfg, x, pos, mask, lora=lora_spec, reducer=reducer, collect_kv=collect_kv
    )
    scores = jnp.stack([r["scores"] for r in aux["reduced"]])  # [L, H, S]
    extra = {}
    if not use_kernel:
        extra["cross"] = jnp.stack([r["cross"] for r in aux["reduced"]])  # [L, H, n, S]
    if collect_kv:
        extra["k"] = aux["k"][:, :, :s]  # prompt rows only
        extra["v"] = aux["v"][:, :, :s]
        extra["last_hidden"] = hidden[jnp.maximum(length - 1, 0)]
    return scores, extra


def prefill_lkv(
    params: dict,
    cfg: ModelConfig,
    lkv_emb: jnp.ndarray,  # [n_lookahead, d] learned lookahead embeddings
    lkv_lora: Optional[dict],
    lkv_cfg: LookaheadConfig,
    tokens: jnp.ndarray,
    length: jnp.ndarray,
):
    """Serving prefill with lookahead tokens (paper Fig. 1b / Algorithm 2).

    One forward pass returns everything decoding needs *plus* the learned
    importance scores — no draft generation:
      k, v [L, Hkv, S, dh], logits [V], lkv_scores [L, H, S].
    """
    scores, extra = suffix_forward(
        params,
        cfg,
        tokens,
        length,
        lkv_emb,
        lora=lkv_lora,
        lora_scale=lkv_cfg.scale,
        use_kernel=True,
        collect_kv=True,
    )
    logits = _head_logits(params, extra["last_hidden"])
    return {"k": extra["k"], "v": extra["v"], "logits": logits, "lkv_scores": scores}


# --------------------------------------------------------------------------
# Decode step (serving)
# --------------------------------------------------------------------------


def decode_step(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # scalar i32
    pos: jnp.ndarray,  # scalar i32 absolute RoPE position
    k_cache: jnp.ndarray,  # [L, Hkv, C, dh]
    v_cache: jnp.ndarray,  # [L, Hkv, C, dh]
    cache_lens: jnp.ndarray,  # [L] i32 live slots per layer (pre-insert)
    use_kernel: bool = True,
):
    """One decode step with in-graph cache insertion at `cache_lens[l]`.

    Returns dict: logits [V], k_cache/v_cache (updated), probs [L, H, C]
    (attention over the cache *after* insertion; cols >= cache_lens[l]+1
    are zero). The new token's KV is inserted first, so it always attends
    to itself. Attention runs through the Pallas decode kernel.
    """
    c = k_cache.shape[2]
    x = params["emb"][token]  # [d]
    cos, sin = rope_cos_sin(pos[None], cfg.head_dim, cfg.rope_theta)  # [1, dh]
    new_ks, new_vs, probs_all = [], [], []
    kc_out, vc_out = k_cache, v_cache
    for li, layer in enumerate(params["layers"]):
        h = rmsnorm(x, layer["attn_norm"])
        q = (h @ layer["wq"]).reshape(1, cfg.n_heads, cfg.head_dim)
        k = (h @ layer["wk"]).reshape(1, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ layer["wv"]).reshape(1, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos, sin)[0]  # [H, dh]
        k = apply_rope(k, cos, sin)[0]  # [Hkv, dh]
        v = v[0]
        kc = jax.lax.dynamic_update_slice(
            kc_out[li], k[:, None, :], (0, cache_lens[li], 0)
        )
        vc = jax.lax.dynamic_update_slice(
            vc_out[li], v[:, None, :], (0, cache_lens[li], 0)
        )
        kc_out = kc_out.at[li].set(kc)
        vc_out = vc_out.at[li].set(vc)
        if use_kernel:
            out, probs = decode_attn(q, kc, vc, cache_lens[li] + 1)
        else:  # dense fallback for build-time generation loops (jit/scan-friendly)
            from .kernels.ref import decode_attn_ref

            out, probs = decode_attn_ref(q, kc, vc, cache_lens[li] + 1)
        x = x + out.reshape(cfg.q_dim) @ layer["wo"]
        h2 = rmsnorm(x, layer["mlp_norm"])
        x = x + (jax.nn.silu(h2 @ layer["wgate"]) * (h2 @ layer["wup"])) @ layer["wdown"]
        probs_all.append(probs)
    logits = _head_logits(params, x)
    return {
        "logits": logits,
        "k_cache": kc_out,
        "v_cache": vc_out,
        "probs": jnp.stack(probs_all),  # [L, H, C]
    }


# --------------------------------------------------------------------------
# Batched generation (build-time only: training data + eval references)
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg", "max_new", "greedy"))
def generate_batch(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,  # [B, S] padded prompts
    lengths: jnp.ndarray,  # [B]
    key: jax.Array,
    *,
    max_new: int,
    greedy: bool = True,
    temperature: float = 1.0,
):
    """Full-cache greedy/temperature generation. Returns [B, max_new] i32.

    Build-time utility (training-data generation, python-side references);
    the serving path decodes in Rust through the AOT decode graphs.
    """
    b, s = tokens.shape

    def single(tok, length, k0):
        x = params["emb"][tok]
        pos = jnp.arange(s)
        mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] < length)
        hidden, aux = core_forward(params, cfg, x, pos, mask, collect_kv=True)
        cap = s + max_new
        kc = jnp.pad(aux["k"], ((0, 0), (0, 0), (0, max_new), (0, 0)))
        vc = jnp.pad(aux["v"], ((0, 0), (0, 0), (0, max_new), (0, 0)))
        logits0 = _head_logits(params, hidden[length - 1])

        def pick(logits, kk):
            if greedy:
                return jnp.argmax(logits).astype(jnp.int32)
            z = logits / jnp.maximum(temperature, 1e-4)
            return jax.random.categorical(kk, z).astype(jnp.int32)

        def step(carry, i):
            kc, vc, logits, cur_len, kk = carry
            kk, sub = jax.random.split(kk)
            tok_i = pick(logits, sub)
            res = decode_step(
                params, cfg, tok_i, cur_len, kc, vc,
                jnp.full((cfg.n_layers,), cur_len), use_kernel=False,
            )
            return (res["k_cache"], res["v_cache"], res["logits"], cur_len + 1, kk), tok_i

        (_, _, _, _, _), toks = jax.lax.scan(
            step, (kc, vc, logits0, length, k0), jnp.arange(max_new)
        )
        return toks

    keys = jax.random.split(key, b)
    return jax.vmap(single)(tokens, lengths, keys)

"""AOT lowering: JAX graphs -> HLO text + manifest + weights + goldens.

This is the compile-path boundary of the three-layer architecture. For
every (model, shape-bucket, variant) combination used by the serving
coordinator it lowers a jitted function to **HLO text** (NOT a serialized
proto — jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids) and records in ``manifest.json``
everything the Rust runtime needs: the positional argument list (weights
first, in the canonical order of ``model.param_order``; then runtime
inputs), output order/shapes, and model/tokenizer constants.

Weights are *runtime inputs* loaded by Rust from ``weights/<model>.npz``
into device buffers once per process — artifacts stay small and one graph
serves every LookaheadKV variant that shares shapes.

Usage: python -m compile.aot [--out ../artifacts] [--skip-ablations]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import lookahead as LK, model as M
from .config import (
    ARTIFACTS,
    BOS_ID,
    CKPT_DIR,
    DECODE_CAPS,
    EOS_ID,
    MODELS,
    OBS_WINDOW,
    PAD_ID,
    PREFILL_BUCKETS,
    SEP_ID,
    VOCAB_SIZE,
    LookaheadConfig,
)
from .train_lm import load_params

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lkv_weight_order(cfg, lkv_cfg: LookaheadConfig) -> list[str]:
    names = ["emb"]
    for i in range(cfg.n_layers):
        for t in lkv_cfg.lora_targets:
            names += [f"l{i}.{t}.a", f"l{i}.{t}.b"]
    return names


def lkv_flatten(lkv, cfg, lkv_cfg):
    flat = [lkv["emb"]]
    for i in range(cfg.n_layers):
        for t in lkv_cfg.lora_targets:
            a, b = lkv["lora"][i][t]
            flat += [a, b]
    return flat


def lkv_unflatten(flat, cfg, lkv_cfg):
    it = iter(flat)
    emb = next(it)
    lora = []
    for _ in range(cfg.n_layers):
        layer = {}
        for t in lkv_cfg.lora_targets:
            layer[t] = (next(it), next(it))
        lora.append(layer)
    return {"emb": emb, "lora": lora}


def pred_weight_order(cfg) -> list[str]:
    names = []
    for i in range(cfg.n_layers):
        for g in range(cfg.n_kv_heads):
            names += [f"l{i}.h{g}.w1", f"l{i}.h{g}.b1", f"l{i}.h{g}.w2", f"l{i}.h{g}.b2"]
    return names


def pred_flatten(pred, cfg):
    flat = []
    for i in range(cfg.n_layers):
        for g in range(cfg.n_kv_heads):
            m = pred[i][g]
            flat += [m["w1"], m["b1"], m["w2"], m["b2"]]
    return flat


def pred_unflatten(flat, cfg):
    it = iter(flat)
    out = []
    for _ in range(cfg.n_layers):
        layer = []
        for _ in range(cfg.n_kv_heads):
            layer.append({"w1": next(it), "b1": next(it), "w2": next(it), "b2": next(it)})
        out.append(layer)
    return out


class Builder:
    def __init__(self, out_dir: str):
        self.out = out_dir
        self.hlo_dir = os.path.join(out_dir, "hlo")
        self.w_dir = os.path.join(out_dir, "weights")
        self.g_dir = os.path.join(out_dir, "goldens")
        for d in (self.hlo_dir, self.w_dir, self.g_dir):
            os.makedirs(d, exist_ok=True)
        self.manifest = {
            "format": 1,
            "tokenizer": {
                "pad": PAD_ID, "bos": BOS_ID, "eos": EOS_ID, "sep": SEP_ID,
                "vocab": VOCAB_SIZE,
            },
            "obs_window": OBS_WINDOW,
            "prefill_buckets": list(PREFILL_BUCKETS),
            "decode_caps": list(DECODE_CAPS),
            "models": {},
            "lkv_variants": {},
            "predictors": {},
            "graphs": {},
            "goldens": {},
        }

    # -- weights -----------------------------------------------------------
    def add_model(self, name: str, cfg, params):
        order = M.param_order(cfg)
        flat = M.flatten_params(cfg, params)
        wfile = f"weights/{name}.npz"
        np.savez(os.path.join(self.out, wfile), **{n: np.asarray(a) for n, a in zip(order, flat)})
        self.manifest["models"][name] = {
            "d_model": cfg.d_model, "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads, "head_dim": cfg.head_dim, "ff": cfg.ff,
            "vocab": cfg.vocab, "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta,
            "weights": wfile, "param_names": order,
            "param_count": int(cfg.param_count()),
        }

    def add_lkv_variant(self, model: str, variant: str, cfg, lkv, lkv_cfg):
        order = lkv_weight_order(cfg, lkv_cfg)
        flat = lkv_flatten(lkv, cfg, lkv_cfg)
        wfile = f"weights/lkv_{model}_{variant}.npz"
        np.savez(os.path.join(self.out, wfile), **{n: np.asarray(a) for n, a in zip(order, flat)})
        self.manifest["lkv_variants"][f"{model}/{variant}"] = {
            "model": model, "variant": variant,
            "n_lookahead": lkv_cfg.n_lookahead,
            "lora_rank": lkv_cfg.lora_rank, "lora_alpha": lkv_cfg.lora_alpha,
            "lora_targets": list(lkv_cfg.lora_targets),
            "weights": wfile, "param_names": order,
            "trainable_params": int(LK.lkv_param_count(cfg, lkv_cfg)),
            "graph_suffix": graph_suffix(lkv_cfg),
        }

    def add_predictor(self, model: str, cfg, pred, hidden: int):
        """Importance-predictor weights for one model: per-(layer, KV-head)
        Linear(dh->hidden)->ReLU->Linear(hidden->1) over pre-RoPE keys.
        The Rust runtime rejects ``method=predictor`` for models without a
        ``predictors`` entry; an empty weights file in a synthetic manifest
        means the reference backend synthesizes the weights itself."""
        order = pred_weight_order(cfg)
        flat = pred_flatten(pred, cfg)
        wfile = f"weights/pred_{model}.npz"
        np.savez(os.path.join(self.out, wfile), **{n: np.asarray(a) for n, a in zip(order, flat)})
        per_head = cfg.head_dim * hidden + 2 * hidden + 1
        self.manifest["predictors"][model] = {
            "model": model,
            "hidden": hidden,
            "weights": wfile,
            "param_names": order,
            "trainable_params": int(cfg.n_layers * cfg.n_kv_heads * per_head),
        }

    # -- graphs ------------------------------------------------------------
    def lower(self, key: str, fn, arg_specs, input_names, output_names, meta, golden_args=None):
        """Lower fn(*args) and register. arg_specs: full positional specs;
        input_names: names for the non-weight tail (len <= len(arg_specs));
        weights occupy the head positions."""
        print(f"[aot] lowering {key}")
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = key.replace("/", "__") + ".hlo.txt"
        with open(os.path.join(self.hlo_dir, fname), "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update(
            {
                "file": f"hlo/{fname}",
                "inputs": [
                    {"name": n, "dtype": str(s.dtype), "shape": list(s.shape)}
                    for n, s in zip(input_names, arg_specs[len(arg_specs) - len(input_names):])
                ],
                "n_weight_args": len(arg_specs) - len(input_names),
                "outputs": output_names,
            }
        )
        self.manifest["graphs"][key] = entry
        if golden_args is not None:
            self._golden(key, fn, golden_args, input_names, len(arg_specs) - len(input_names))
        return entry

    def _golden(self, key: str, fn, args, input_names, n_weights):
        outs = jax.jit(fn)(*args)
        flat_outs = jax.tree_util.tree_leaves(outs)
        payload = {}
        for n, a in zip(input_names, args[n_weights:]):
            payload[f"in_{n}"] = np.asarray(a)
        for i, o in enumerate(flat_outs):
            payload[f"out_{i}"] = np.asarray(o)
        gfile = f"goldens/{key.replace('/', '__')}.npz"
        np.savez(os.path.join(self.out, gfile), **payload)
        self.manifest["goldens"][key] = gfile

    def finish(self):
        with open(os.path.join(self.out, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"[aot] wrote manifest with {len(self.manifest['graphs'])} graphs")


def graph_suffix(lkv_cfg: LookaheadConfig) -> str:
    """Graphs are shared by all variants with the same shapes/arg list."""
    mods = {tuple(): "emb", ("wq", "wv"): "qv"}.get(tuple(lkv_cfg.lora_targets), "all")
    return f"n{lkv_cfg.n_lookahead}_{mods}"


# --------------------------------------------------------------------------
# Per-model lowering
# --------------------------------------------------------------------------

PREFILL_OUTS = ["k", "v", "logits", "window_scores", "h2o_scores"]
PREFILL_PRED_OUTS = PREFILL_OUTS + ["pred_scores"]
PREFILL_LKV_OUTS = ["k", "v", "logits", "lkv_scores"]
DECODE_OUTS = ["logits", "k_cache", "v_cache", "probs"]


def lower_model(b: Builder, name: str, golden: bool, buckets=PREFILL_BUCKETS, caps=DECODE_CAPS):
    cfg = MODELS[name]
    params = load_params(cfg, os.path.join(CKPT_DIR, f"{name}.npz"))
    b.add_model(name, cfg, params)
    wspecs = [_spec(a.shape, a.dtype) for a in M.flatten_params(cfg, params)]
    n_w = len(wspecs)

    rng = np.random.default_rng(0)

    def demo_tokens(s):
        return jnp.asarray(rng.integers(0, 255, (s,)), I32)

    for s in buckets:
        def prefill_fn(*args, _s=s):
            params_ = M.unflatten_params(cfg, list(args[:n_w]))
            tokens, length, logit_pos = args[n_w:]
            out = M.prefill(params_, cfg, tokens, length, logit_pos, window=OBS_WINDOW)
            return tuple(out[k] for k in PREFILL_OUTS)

        specs = wspecs + [_spec((s,), I32), _spec((), I32), _spec((), I32)]
        golden_args = None
        if golden and s == buckets[0]:
            golden_args = M.flatten_params(cfg, params) + [
                demo_tokens(s), jnp.asarray(100, I32), jnp.asarray(99, I32)
            ]
        b.lower(
            f"{name}/prefill_base_s{s}",
            prefill_fn,
            specs,
            ["tokens", "length", "logit_pos"],
            PREFILL_OUTS,
            {"kind": "prefill_base", "model": name, "s": s, "window": OBS_WINDOW},
            golden_args,
        )

    for cap in caps:
        def decode_fn(*args, _c=cap):
            params_ = M.unflatten_params(cfg, list(args[:n_w]))
            token, pos, kc, vc, lens = args[n_w:]
            out = M.decode_step(params_, cfg, token, pos, kc, vc, lens)
            return tuple(out[k] for k in DECODE_OUTS)

        kv_shape = (cfg.n_layers, cfg.n_kv_heads, cap, cfg.head_dim)
        specs = wspecs + [
            _spec((), I32), _spec((), I32),
            _spec(kv_shape, F32), _spec(kv_shape, F32),
            _spec((cfg.n_layers,), I32),
        ]
        golden_args = None
        if golden and cap == caps[0]:
            golden_args = M.flatten_params(cfg, params) + [
                jnp.asarray(65, I32), jnp.asarray(40, I32),
                jnp.asarray(rng.normal(size=kv_shape), F32),
                jnp.asarray(rng.normal(size=kv_shape), F32),
                jnp.full((cfg.n_layers,), 40, I32),
            ]
        b.lower(
            f"{name}/decode_c{cap}",
            decode_fn,
            specs,
            ["token", "pos", "k_cache", "v_cache", "cache_lens"],
            DECODE_OUTS,
            {"kind": "decode", "model": name, "cap": cap},
            golden_args,
        )
    return cfg, params, wspecs


def lower_pred_graphs(b: Builder, name: str, cfg, params, wspecs, pred, buckets, golden: bool):
    """Predictor-augmented base prefill: the prefill_base outputs plus
    streamed per-KV-head MLP scores over pre-RoPE keys. Predictor weights
    are runtime inputs after the model weights, so a retrained predictor
    reuses the same HLO."""
    n_w = len(wspecs)
    pred_specs = [_spec(a.shape, a.dtype) for a in pred_flatten(pred, cfg)]
    n_pw = len(pred_specs)
    rng = np.random.default_rng(2)

    for s in buckets:
        def pred_fn(*args, _s=s):
            params_ = M.unflatten_params(cfg, list(args[:n_w]))
            pred_ = pred_unflatten(list(args[n_w:n_w + n_pw]), cfg)
            tokens, length, logit_pos = args[n_w + n_pw:]
            out = M.prefill_pred(
                params_, cfg, pred_, tokens, length, logit_pos, window=OBS_WINDOW
            )
            return tuple(out[k] for k in PREFILL_PRED_OUTS)

        specs = wspecs + pred_specs + [_spec((s,), I32), _spec((), I32), _spec((), I32)]
        golden_args = None
        if golden and s == buckets[0]:
            golden_args = (
                M.flatten_params(cfg, params)
                + pred_flatten(pred, cfg)
                + [
                    jnp.asarray(rng.integers(0, 255, (s,)), I32),
                    jnp.asarray(100, I32),
                    jnp.asarray(99, I32),
                ]
            )
        b.lower(
            f"{name}/prefill_pred_s{s}",
            pred_fn,
            specs,
            ["tokens", "length", "logit_pos"],
            PREFILL_PRED_OUTS,
            {
                "kind": "prefill_pred", "model": name, "s": s, "window": OBS_WINDOW,
                "n_pred_weight_args": n_pw,
            },
            golden_args,
        )


def lower_lkv_graphs(b: Builder, name: str, cfg, params, wspecs, lkv_cfg, buckets, golden: bool):
    """One graph per (shape bucket, n_lookahead, target-set); lkv weights
    are runtime inputs so trained variants with identical shapes share it."""
    n_w = len(wspecs)
    suffix = graph_suffix(lkv_cfg)
    lkv_demo = LK.init_lkv(cfg, lkv_cfg, jax.random.PRNGKey(1))
    lkv_specs = [_spec(a.shape, a.dtype) for a in lkv_flatten(lkv_demo, cfg, lkv_cfg)]
    n_lw = len(lkv_specs)
    rng = np.random.default_rng(1)

    for s in buckets:
        key = f"{name}/prefill_lkv_s{s}_{suffix}"
        if key in b.manifest["graphs"]:
            continue

        def lkv_fn(*args, _s=s):
            params_ = M.unflatten_params(cfg, list(args[:n_w]))
            lkv_ = lkv_unflatten(list(args[n_w:n_w + n_lw]), cfg, lkv_cfg)
            tokens, length = args[n_w + n_lw:]
            out = M.prefill_lkv(
                params_, cfg, lkv_["emb"],
                lkv_["lora"] if lkv_cfg.lora_targets else None,
                lkv_cfg, tokens, length,
            )
            return tuple(out[k] for k in PREFILL_LKV_OUTS)

        specs = wspecs + lkv_specs + [_spec((s,), I32), _spec((), I32)]
        golden_args = None
        if golden and s == buckets[0]:
            golden_args = (
                M.flatten_params(cfg, params)
                + lkv_flatten(lkv_demo, cfg, lkv_cfg)
                + [jnp.asarray(rng.integers(0, 255, (s,)), I32), jnp.asarray(100, I32)]
            )
        b.lower(
            key,
            lkv_fn,
            specs,
            ["tokens", "length"],
            PREFILL_LKV_OUTS,
            {
                "kind": "prefill_lkv", "model": name, "s": s,
                "n_lookahead": lkv_cfg.n_lookahead, "suffix": suffix,
                "n_lkv_weight_args": n_lw,
            },
            golden_args,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=ARTIFACTS)
    ap.add_argument("--skip-ablations", action="store_true")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    b = Builder(out)

    # Target + base models: full graph set.
    lkv_variant_files = []
    for name in ("lkv-tiny", "lkv-base"):
        if not os.path.exists(os.path.join(CKPT_DIR, f"{name}.npz")):
            print(f"[aot] {name}: no checkpoint, skipping")
            continue
        cfg, params, wspecs = lower_model(b, name, golden=(name == "lkv-tiny"))
        # Importance predictor: export weights (deterministic init until a
        # training recipe lands) and lower the pred-augmented prefill.
        pred_hidden = 64
        pred = M.init_predictor(cfg, pred_hidden, jax.random.PRNGKey(7))
        b.add_predictor(name, cfg, pred, pred_hidden)
        lower_pred_graphs(
            b, name, cfg, params, wspecs, pred, PREFILL_BUCKETS,
            golden=(name == "lkv-tiny"),
        )
        # Register every trained LookaheadKV variant for this model and
        # lower the graphs its shapes require.
        for fn in sorted(os.listdir(CKPT_DIR)):
            if not (fn.startswith(f"lkv_{name}_") and fn.endswith(".npz")):
                continue
            variant = fn[len(f"lkv_{name}_"):-len(".npz")]
            lkv, lkv_cfg = LK.load_lkv(cfg, os.path.join(CKPT_DIR, fn))
            if args.skip_ablations and variant not in ("main",):
                continue
            b.add_lkv_variant(name, variant, cfg, lkv, lkv_cfg)
            buckets = PREFILL_BUCKETS if variant in ("main", "srcdata", "ctx32", "ctx64", "ctx128") else PREFILL_BUCKETS[:2]
            lower_lkv_graphs(
                b, name, cfg, params, wspecs, lkv_cfg, buckets,
                golden=(name == "lkv-tiny" and variant == "main"),
            )
            lkv_variant_files.append(variant)

    # Draft model (SpecKV): prefill for scoring-free forward + full-cache
    # decode caps sized prompt+draft.
    if os.path.exists(os.path.join(CKPT_DIR, "lkv-draft.npz")):
        draft_caps = tuple(s + 32 for s in PREFILL_BUCKETS)
        lower_model(b, "lkv-draft", golden=False, caps=draft_caps)

    b.finish()


if __name__ == "__main__":
    main()

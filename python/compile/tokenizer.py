"""Byte-level tokenizer, mirrored exactly by ``rust/src/model/tokenizer.rs``.

Ids 0..255 are raw bytes; 256..259 are specials (PAD/BOS/EOS/SEP). The
cross-language contract is pinned by a golden file written at AOT time and
checked by a Rust unit test.
"""

from __future__ import annotations

from .config import BOS_ID, EOS_ID, PAD_ID, SEP_ID


def encode(text: str, *, bos: bool = False, eos: bool = False) -> list[int]:
    ids = list(text.encode("utf-8"))
    if bos:
        ids.insert(0, BOS_ID)
    if eos:
        ids.append(EOS_ID)
    return ids


def decode(ids: list[int]) -> str:
    data = bytes(i for i in ids if 0 <= i < 256)
    return data.decode("utf-8", errors="replace")


def pad_to(ids: list[int], length: int) -> list[int]:
    if len(ids) > length:
        raise ValueError(f"sequence of {len(ids)} tokens exceeds bucket {length}")
    return ids + [PAD_ID] * (length - len(ids))


__all__ = ["encode", "decode", "pad_to", "PAD_ID", "BOS_ID", "EOS_ID", "SEP_ID"]

"""Minimal Adam + cosine schedule (optax is unavailable offline).

Matches the paper's Table-16 recipe: Adam(0.9, 0.95), cosine decay to 0,
2% warmup, global-norm gradient clipping at 1.0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def cosine_lr(step, base_lr: float, total_steps: int, warmup_frac: float = 0.02):
    warm = max(1, int(total_steps * warmup_frac))
    step = step.astype(jnp.float32)
    warm_lr = base_lr * step / warm
    prog = jnp.clip((step - warm) / max(1, total_steps - warm), 0.0, 1.0)
    cos_lr = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warm, warm_lr, cos_lr)


def clip_by_global_norm(grads, max_norm: float = 1.0):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def adam_step(params, grads, state, lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1.0 - b1**tf)
    vhat_scale = 1.0 / (1.0 - b2**tf)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}

"""Pallas kernel: single-token decode attention over the compacted KV cache.

The serving-side payoff of eviction is that the decode working set fits in
fast memory; this kernel makes the HBM->VMEM schedule explicit. For one
decode step it computes GQA attention of the new token's queries over the
post-eviction cache and also exports the attention probabilities (used by
the coordinator for ground-truth importance tracking, Table 8, and for the
TOVA/H2O decode-time policies).

Same two-pass flash decomposition as `lookahead_score.py`:

  * pass 1: per query head, stream cache blocks along the sequential inner
    grid axis accumulating online-softmax stats (m, l) in the revisited
    output block;
  * pass 2: per (head, cache block), normalize with the stats, emit the
    probability block, and accumulate `p @ v` into the revisited output
    row.

GQA is expressed in the BlockSpec index maps: query head `h` reads KV head
`h // group`, so each KV block is fetched once per query group on real
hardware. interpret=True for CPU PJRT (see package docstring).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9
DEFAULT_BLOCK_C = 128


def _stats_kernel(dims_ref, q_ref, k_ref, m_ref, l_ref, *, bc: int):
    h = pl.program_id(0)
    j = pl.program_id(1)
    n_valid = dims_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)  # [1, dh]
    k = k_ref[0].astype(jnp.float32)  # [bc, dh]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = (q @ k.T) * scale  # [1, bc]
    cols = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    valid = cols < n_valid
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None]) * valid
    l_ref[...] = l_ref[...] * jnp.exp(m_prev - m_new) + jnp.sum(p, axis=-1)
    m_ref[...] = m_new


def _attend_kernel(dims_ref, q_ref, k_ref, v_ref, m_ref, l_ref, out_ref, probs_ref, *, bc: int):
    j = pl.program_id(1)
    n_valid = dims_ref[0]

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)  # [1, dh]
    k = k_ref[0].astype(jnp.float32)  # [bc, dh]
    v = v_ref[0].astype(jnp.float32)  # [bc, dh]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = (q @ k.T) * scale  # [1, bc]
    cols = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, bc), 1)
    valid = cols < n_valid
    p = jnp.exp(s - m_ref[...][:, None]) * valid
    p = p / l_ref[...][:, None]  # [1, bc]
    probs_ref[...] = p
    out_ref[...] += p @ v  # [1, dh]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def decode_attn(
    q: jnp.ndarray,  # [H, dh]
    k: jnp.ndarray,  # [Hkv, C, dh]
    v: jnp.ndarray,  # [Hkv, C, dh]
    n_valid,  # scalar i32: live slots (cols >= n_valid are masked)
    *,
    block_c: int = DEFAULT_BLOCK_C,
    interpret: bool = True,
):
    """Host wrapper. Returns (out [H, dh], probs [H, C])."""
    h, dh = q.shape
    hkv, c_in, _ = k.shape
    group = h // hkv
    bc = min(block_c, c_in)
    pad = (-c_in) % bc
    if pad:  # off-bucket caps (build-time generation utility); serving caps are multiples of 64
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    c = c_in + pad
    n_blocks = c // bc
    dims = jnp.asarray([n_valid], dtype=jnp.int32).reshape(1)

    whole_dims = pl.BlockSpec((1,), lambda h_, j: (0,))
    qspec = pl.BlockSpec((1, dh), lambda h_, j: (h_, 0))
    kvspec = pl.BlockSpec((1, bc, dh), lambda h_, j: (h_ // group, j, 0))
    stat = pl.BlockSpec((1,), lambda h_, j: (h_,))

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, bc=bc),
        grid=(h, n_blocks),
        in_specs=[whole_dims, qspec, kvspec],
        out_specs=[stat, stat],
        out_shape=[
            jax.ShapeDtypeStruct((h,), jnp.float32),
            jax.ShapeDtypeStruct((h,), jnp.float32),
        ],
        interpret=interpret,
    )(dims, q, k)

    out, probs = pl.pallas_call(
        functools.partial(_attend_kernel, bc=bc),
        grid=(h, n_blocks),
        in_specs=[whole_dims, qspec, kvspec, kvspec, stat, stat],
        out_specs=[
            pl.BlockSpec((1, dh), lambda h_, j: (h_, 0)),
            pl.BlockSpec((1, bc), lambda h_, j: (h_, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, dh), jnp.float32),
            jax.ShapeDtypeStruct((h, c), jnp.float32),
        ],
        interpret=interpret,
    )(dims, q, k, v, m, l)
    return out, probs[:, :c_in]

"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

These are the *definitions* the kernels must match; pytest/hypothesis sweeps
assert `assert_allclose(kernel(...), ref(...))` over shapes and seeds.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e9


def lkv_score_ref(
    q: jnp.ndarray,  # [n, dh] lookahead (or suffix) queries, post-RoPE
    k: jnp.ndarray,  # [s_tot, dh] keys: s_max prompt rows then n lookahead rows
    length,  # scalar i32: number of valid prompt tokens (<= s_max)
    s_max: int,  # static prompt bucket size
) -> jnp.ndarray:
    """Importance scores per Algorithm 2: softmax over the full visible row
    (prompt cols < length plus causally-visible lookahead cols), then the
    column mean over the n lookahead rows, restricted to prompt columns.

    Returns [s_max] with zeros at cols >= length.
    """
    n, dh = q.shape
    s_tot = k.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale  # [n, s_tot]
    cols = jnp.arange(s_tot)
    rows = jnp.arange(n)
    prompt_ok = cols[None, :] < length  # [1, s_tot]
    look_ok = (cols[None, :] >= s_max) & ((cols[None, :] - s_max) <= rows[:, None])
    valid = prompt_ok | look_ok
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * valid
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    scores = jnp.mean(p[:, :s_max], axis=0)  # [s_max]
    return jnp.where(jnp.arange(s_max) < length, scores, 0.0)


def decode_attn_ref(
    q: jnp.ndarray,  # [H, dh] single-token queries, post-RoPE
    k: jnp.ndarray,  # [Hkv, C, dh] compacted key cache
    v: jnp.ndarray,  # [Hkv, C, dh]
    n_valid,  # scalar i32: number of live cache slots
):
    """Single-query GQA attention over the compacted cache.

    Returns (out [H, dh], probs [H, C]); probs are zero at cols >= n_valid.
    """
    h, dh = q.shape
    hkv, c, _ = k.shape
    group = h // hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(dh))
    kh = jnp.repeat(k, group, axis=0)  # [H, C, dh]
    vh = jnp.repeat(v, group, axis=0)
    s = jnp.einsum("hd,hcd->hc", q.astype(jnp.float32), kh.astype(jnp.float32)) * scale
    valid = jnp.arange(c)[None, :] < n_valid
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * valid
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hc,hcd->hd", p, vh)
    return out, p

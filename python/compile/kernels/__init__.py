"""L1 Pallas kernels: the eviction hot-spots (see lookahead_score.py, decode_attn.py)."""

"""Pallas kernel: lookahead importance scores (the paper's eviction hot-spot).

Computes, for one (layer, head), the Algorithm-2 importance vector

    scores[j] = mean_i softmax_j'( q_i . k_j' / sqrt(d) )[j],   j < s_max

without ever materializing the full `n x s_tot` attention matrix in slow
memory. This is the TPU rethink of the paper's Appendix-C trick (flash
forward + eager cross-window): a **two-pass flash decomposition**:

  * pass 1 (`_stats_kernel`): stream key blocks HBM->VMEM along a
    sequential grid, maintaining the online-softmax statistics
    (running row-max `m`, running denominator `l`) for all `n` lookahead
    queries in the revisited output block (the canonical Pallas
    accumulate-in-output pattern).
  * pass 2 (`_score_kernel`): embarrassingly parallel over prompt-key
    blocks; each grid step re-computes its `n x bk` logit tile, normalizes
    with the pass-1 stats and emits the column means for its block.

VMEM per step is `n x bk` (plus the `bk x dh` key tile) -- at the paper's
scale (n=32, bk=128, fp32) that is 16 KiB of logits versus a 32 x 131072
full matrix. Lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); block sizes keep the lane dimension at 128 for the
real-TPU layout documented in EXPERIMENTS.md §Perf.

Masking rules (see `ref.lkv_score_ref`): prompt columns are valid when
`col < length`; the `n` lookahead keys sit at static columns
`[s_max, s_max + n)` and are causally visible (`col - s_max <= row`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9
DEFAULT_BLOCK_K = 128


def _masks(pid, bk: int, n: int, s_max: int, length):
    """Validity mask [n, bk] for key-block `pid` (shared by both passes)."""
    cols = pid * bk + jax.lax.broadcasted_iota(jnp.int32, (n, bk), 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (n, bk), 0)
    prompt_ok = cols < length
    look_ok = (cols >= s_max) & ((cols - s_max) <= rows)
    return prompt_ok | look_ok


def _stats_kernel(dims_ref, q_ref, k_ref, m_ref, l_ref, *, bk: int, s_max: int):
    """Pass 1: online-softmax stats over all key blocks (sequential grid)."""
    pid = pl.program_id(0)
    length = dims_ref[0]
    n = q_ref.shape[0]

    @pl.when(pid == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [n, bk]
    valid = _masks(pid, bk, n, s_max, length)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # exp of fully-masked blocks underflows to 0 via the NEG_INF fill.
    p = jnp.exp(s - m_new[:, None]) * valid
    l_new = l_prev * jnp.exp(m_prev - m_new) + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    l_ref[...] = l_new


def _score_kernel(dims_ref, q_ref, k_ref, m_ref, l_ref, out_ref, *, bk: int, s_max: int):
    """Pass 2: normalized column means for one prompt-key block."""
    pid = pl.program_id(0)
    length = dims_ref[0]
    n = q_ref.shape[0]

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [n, bk]
    valid = _masks(pid, bk, n, s_max, length)
    p = jnp.exp(s - m_ref[...][:, None]) * valid
    p = p / l_ref[...][:, None]
    out_ref[...] = jnp.sum(p, axis=0) / jnp.float32(n)


def _pad_cols(k: jnp.ndarray, bk: int) -> jnp.ndarray:
    s_tot = k.shape[0]
    pad = (-s_tot) % bk
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0)))
    return k


@functools.partial(jax.jit, static_argnames=("s_max", "block_k", "interpret"))
def lkv_score(
    q: jnp.ndarray,  # [n, dh]
    k: jnp.ndarray,  # [s_max + n, dh]
    length,  # scalar i32
    *,
    s_max: int,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    """Host wrapper: two pallas_call passes; returns scores [s_max]."""
    n, dh = q.shape
    bk = min(block_k, s_max)
    kp = _pad_cols(k, bk)  # padded cols are masked (col >= length, col < s_max fails look_ok... they are >= s_max+n so look_ok false)
    s_pad = kp.shape[0]
    dims = jnp.asarray([length], dtype=jnp.int32).reshape(1)
    n_blocks = s_pad // bk

    whole_q = pl.BlockSpec((n, dh), lambda i: (0, 0))
    kblock = pl.BlockSpec((bk, dh), lambda i: (i, 0))
    whole_stat = pl.BlockSpec((n,), lambda i: (0,))
    whole_dims = pl.BlockSpec((1,), lambda i: (0,))

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, bk=bk, s_max=s_max),
        grid=(n_blocks,),
        in_specs=[whole_dims, whole_q, kblock],
        out_specs=[whole_stat, whole_stat],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(dims, q, kp)

    n_prompt_blocks = s_max // bk
    scores = pl.pallas_call(
        functools.partial(_score_kernel, bk=bk, s_max=s_max),
        grid=(n_prompt_blocks,),
        in_specs=[whole_dims, whole_q, kblock, whole_stat, whole_stat],
        out_specs=pl.BlockSpec((bk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((s_max,), jnp.float32),
        interpret=interpret,
    )(dims, q, kp, m, l)
    return scores


def lkv_score_batched(q, k, length, *, s_max, block_k=DEFAULT_BLOCK_K, interpret=True):
    """vmap over leading (layer*head) axes: q [G,n,dh], k [G,s_tot,dh] -> [G,s_max]."""
    fn = functools.partial(lkv_score, s_max=s_max, block_k=block_k, interpret=interpret)
    return jax.vmap(lambda qq, kk: fn(qq, kk, length))(q, k)

"""Shared configuration for the LookaheadKV build pipeline.

Everything the Rust coordinator needs to know about these constants is
exported into ``artifacts/manifest.json`` by ``aot.py``; nothing here is
imported at runtime.
"""

from __future__ import annotations

import dataclasses
import os

# --------------------------------------------------------------------------
# Tokenizer (byte-level; mirrored by rust/src/model/tokenizer.rs)
# --------------------------------------------------------------------------
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
SEP_ID = 259
VOCAB_SIZE = 320  # 256 bytes + 4 specials, rounded up for alignment


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of a LLaMA-style decoder-only transformer.

    RMSNorm + RoPE + GQA + SwiGLU — the block structure of the paper's
    target models (LLaMA-3 / Qwen-3), scaled to the CPU testbed.
    """

    name: str
    vocab: int = VOCAB_SIZE
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 16
    ff: int = 192
    rope_theta: float = 10_000.0
    max_seq: int = 1184

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        d, f = self.d_model, self.ff
        per_layer = (
            2 * d  # two norms
            + d * self.q_dim  # wq
            + 2 * d * self.kv_dim  # wk, wv
            + self.q_dim * d  # wo
            + 3 * d * f  # gate, up, down
        )
        return self.vocab * d + self.n_layers * per_layer + d + d * self.vocab


# The paper's LLaMA/Qwen families, scaled. `lkv-tiny` is the primary target
# model, `lkv-base` the second family for multi-model figures, `lkv-draft`
# the small draft model used by SpecKV.
TINY = ModelConfig(name="lkv-tiny")
BASE = ModelConfig(
    name="lkv-base", d_model=80, n_layers=5, n_heads=5, n_kv_heads=1, ff=224
)
DRAFT = ModelConfig(name="lkv-draft", d_model=32, n_layers=2, n_heads=2, n_kv_heads=1, ff=96)

MODELS = {m.name: m for m in (TINY, BASE, DRAFT)}

# --------------------------------------------------------------------------
# LookaheadKV module configuration
# --------------------------------------------------------------------------
# LoRA target sets, matching the paper's Table-5 ablation axes.
LORA_NONE: tuple[str, ...] = ()
LORA_QV: tuple[str, ...] = ("wq", "wv")
LORA_ALL: tuple[str, ...] = ("wq", "wk", "wv", "wo", "wgate", "wup", "wdown")
LORA_SETS = {"emb": LORA_NONE, "qv": LORA_QV, "all": LORA_ALL}


@dataclasses.dataclass(frozen=True)
class LookaheadConfig:
    """Lookahead tokens + selective LoRA (paper §3.1)."""

    n_lookahead: int = 8  # paper: 32 @ 8B scale; 8 matches our context scale
    lora_rank: int = 4  # paper: 8
    lora_alpha: float = 16.0  # paper: 32
    lora_targets: tuple[str, ...] = LORA_ALL

    @property
    def scale(self) -> float:
        return self.lora_alpha / self.lora_rank


DEFAULT_LKV = LookaheadConfig()

# --------------------------------------------------------------------------
# Serving shape buckets (what aot.py lowers; mirrored in the manifest)
# --------------------------------------------------------------------------
PREFILL_BUCKETS = (128, 256, 512, 1024)
# Decode cache capacities: budget C + generation headroom.
DECODE_CAPS = (64, 128, 256, 640, 1152)
OBS_WINDOW = 32  # suffix observation window W exported by prefill_base
MAX_NEW_TOKENS = 96

# --------------------------------------------------------------------------
# Training profiles (override steps with env LKV_FAST=1 for smoke builds)
# --------------------------------------------------------------------------
FAST = os.environ.get("LKV_FAST", "0") == "1"


def steps(n: int) -> int:
    return max(20, n // 20) if FAST else n


@dataclasses.dataclass(frozen=True)
class TrainProfile:
    lm_steps: int = 3000
    lm_batch: int = 16
    lm_seq: int = 160
    lm_lr: float = 8e-4
    lkv_steps: int = 400
    lkv_ablation_steps: int = 120
    lkv_batch: int = 8
    lkv_lr: float = 2e-3
    max_resp: int = 32  # generated-response length for GT scores


PROFILE = TrainProfile()

ARTIFACTS = os.environ.get("LKV_ARTIFACTS", os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
CKPT_DIR = os.path.join(ARTIFACTS, "ckpt")

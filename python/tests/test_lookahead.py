"""LookaheadKV training machinery: loss properties, checkpoint round-trip,
parameter accounting (paper Table 1 analog)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import lookahead as L, model as M
from compile.config import DRAFT, LORA_SETS, LookaheadConfig

CFG = DRAFT


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


def test_kl_loss_zero_when_equal():
    s = jnp.asarray(np.random.default_rng(0).random((2, 2, 32)), jnp.float32)
    loss = L.kl_loss(s, s, jnp.int32(20), 32)
    assert abs(float(loss)) < 1e-5


def test_kl_loss_positive_and_finite():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.random((2, 2, 32)), jnp.float32)
    b = jnp.asarray(rng.random((2, 2, 32)), jnp.float32)
    loss = float(L.kl_loss(a, b, jnp.int32(20), 32))
    assert np.isfinite(loss) and loss > 0


def test_kl_loss_no_nan_with_zero_scores():
    """Masked columns and zero estimates must never produce NaN (the bug
    class fixed during bring-up)."""
    a = jnp.zeros((1, 1, 16), jnp.float32).at[0, 0, 3].set(1.0)
    b = jnp.zeros((1, 1, 16), jnp.float32)
    loss = float(L.kl_loss(a, b, jnp.int32(8), 16))
    assert np.isfinite(loss)


def test_gradients_flow(params):
    rng = np.random.default_rng(2)
    lkv_cfg = LookaheadConfig(n_lookahead=4)
    lkv = L.init_lkv(CFG, lkv_cfg, jax.random.PRNGKey(1))
    xs = jnp.asarray(rng.integers(0, 255, (2, 32)), jnp.int32)
    lens = jnp.asarray([20, 28], jnp.int32)
    gts = jnp.asarray(rng.random((2, CFG.n_layers, CFG.n_heads, 32)), jnp.float32)
    loss, grads = jax.value_and_grad(L.batch_loss)(lkv, params, CFG, lkv_cfg, xs, lens, gts)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert gnorm > 0, "gradients must reach emb and LoRA"


def test_ckpt_roundtrip(tmp_path, params):
    lkv_cfg = LookaheadConfig(n_lookahead=4, lora_targets=LORA_SETS["qv"])
    lkv = L.init_lkv(CFG, lkv_cfg, jax.random.PRNGKey(2))
    p = str(tmp_path / "lkv.npz")
    L.save_lkv(lkv, lkv_cfg, p)
    back, back_cfg = L.load_lkv(CFG, p)
    assert back_cfg.n_lookahead == 4
    assert set(back_cfg.lora_targets) == {"wq", "wv"}
    np.testing.assert_array_equal(np.asarray(back["emb"]), np.asarray(lkv["emb"]))


def test_param_count_under_half_percent():
    """Paper Table 1: <0.5% additional trainable parameters."""
    from compile.config import TINY

    for cfg in (TINY, CFG):
        n = L.lkv_param_count(cfg, LookaheadConfig())
        pct = 100.0 * n / cfg.param_count()
        # paper: <0.5% on 1B-8B models; our scaled models have tiny
        # denominators, so only sanity-bound the ratio here (the paper-scale
        # ratio is checked in bin/table1_params against LLaMA configs).
        assert pct < 12.0, f"{cfg.name}: {pct:.2f}%"
        assert n > 0


def test_emb_only_has_no_lora():
    lkv_cfg = LookaheadConfig(lora_targets=LORA_SETS["emb"])
    lkv = L.init_lkv(CFG, lkv_cfg, jax.random.PRNGKey(0))
    assert all(len(layer) == 0 for layer in lkv["lora"])

"""L1 correctness: Pallas kernels vs pure-jnp oracles, swept with
hypothesis over shapes/lengths/seeds (the core correctness signal).

When hypothesis is unavailable (offline CI images), the same property
checks run over a small fixed parameter grid instead of skipping."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # offline fallback: fixed-grid sweep below
    HAVE_HYPOTHESIS = False

from compile.kernels.decode_attn import decode_attn
from compile.kernels.lookahead_score import lkv_score
from compile.kernels.ref import decode_attn_ref, lkv_score_ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


def _check_lkv_score(n, dh, s_max, frac, seed):
    rng = np.random.default_rng(seed)
    length = max(1, int(s_max * frac))
    q = _rand(rng, n, dh)
    k = _rand(rng, s_max + n, dh)
    got = lkv_score(q, k, length, s_max=s_max)
    want = lkv_score_ref(q, k, length, s_max)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-6)


def _check_decode_attn(h, group, c, dh, frac, seed):
    if h % group:
        group = 1
    hkv = h // group
    rng = np.random.default_rng(seed)
    n_valid = max(1, int(c * frac))
    q = _rand(rng, h, dh)
    k = _rand(rng, hkv, c, dh)
    v = _rand(rng, hkv, c, dh)
    go, gp = decode_attn(q, k, v, n_valid)
    wo, wp = decode_attn_ref(q, k, v, n_valid)
    np.testing.assert_allclose(np.asarray(go), np.asarray(wo), rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(wp), rtol=3e-5, atol=3e-6)


if HAVE_HYPOTHESIS:

    @settings(**SETTINGS)
    @given(
        n=st.sampled_from([2, 4, 8, 16, 32]),
        dh=st.sampled_from([8, 16, 32]),
        s_max=st.sampled_from([64, 128, 256]),
        frac=st.floats(0.05, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_lkv_score_matches_ref(n, dh, s_max, frac, seed):
        _check_lkv_score(n, dh, s_max, frac, seed)

    @settings(**SETTINGS)
    @given(
        h=st.sampled_from([2, 4, 6]),
        group=st.sampled_from([1, 2]),
        c=st.sampled_from([64, 128, 256]),
        dh=st.sampled_from([16, 32]),
        frac=st.floats(0.02, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_decode_attn_matches_ref(h, group, c, dh, frac, seed):
        _check_decode_attn(h, group, c, dh, frac, seed)

else:

    @pytest.mark.parametrize(
        "n,dh,s_max,frac,seed",
        [(2, 8, 64, 0.5, 0), (8, 16, 128, 0.95, 1), (32, 32, 256, 0.1, 2)],
    )
    def test_lkv_score_matches_ref(n, dh, s_max, frac, seed):
        _check_lkv_score(n, dh, s_max, frac, seed)

    @pytest.mark.parametrize(
        "h,group,c,dh,frac,seed",
        [(2, 1, 64, 16, 0.5, 0), (4, 2, 128, 16, 0.9, 1), (6, 2, 256, 32, 0.05, 2)],
    )
    def test_decode_attn_matches_ref(h, group, c, dh, frac, seed):
        _check_decode_attn(h, group, c, dh, frac, seed)


def test_lkv_score_masks_padding():
    rng = np.random.default_rng(0)
    q, k = _rand(rng, 4, 16), _rand(rng, 128 + 4, 16)
    s = np.asarray(lkv_score(q, k, 40, s_max=128))
    assert (s[40:] == 0).all()
    assert s[:40].sum() > 0


def test_decode_probs_sum_to_one():
    rng = np.random.default_rng(1)
    q, k, v = _rand(rng, 4, 16), _rand(rng, 2, 64, 16), _rand(rng, 2, 64, 16)
    _, p = decode_attn(q, k, v, 17)
    np.testing.assert_allclose(np.asarray(p).sum(-1), 1.0, rtol=1e-5)
    assert (np.asarray(p)[:, 17:] == 0).all()


def test_block_size_invariance():
    rng = np.random.default_rng(2)
    q, k = _rand(rng, 8, 16), _rand(rng, 256 + 8, 16)
    a = lkv_score(q, k, 200, s_max=256, block_k=64)
    b = lkv_score(q, k, 200, s_max=256, block_k=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)

"""Corpus generators + tokenizer + AOT manifest contract."""

import json
import os
import random

import pytest

from compile import data, tokenizer as tok
from compile.config import ARTIFACTS, MODELS


def test_tokenizer_roundtrip():
    s = "K7F=Q2Z;lorem;"
    ids = tok.encode(s, bos=True, eos=True)
    assert ids[0] == tok.BOS_ID and ids[-1] == tok.EOS_ID
    assert tok.decode(ids) == s


def test_tokenizer_pad():
    assert tok.pad_to([1, 2], 4) == [1, 2, tok.PAD_ID, tok.PAD_ID]
    with pytest.raises(ValueError):
        tok.pad_to([1, 2, 3], 2)


@pytest.mark.parametrize("family", list(data.GENERATORS))
def test_generators_answer_derivable(family):
    rng = random.Random(42)
    for _ in range(10):
        s = data.gen_sample(rng, family, 150)
        assert s.answer
        assert s.prompt.endswith(s.query)
        if family in ("kv", "multikv", "qa", "code"):
            # exact-continuation: query+answer appears verbatim in context
            assert (s.query + s.answer) in s.context, s


def test_mixture_covers_all_families():
    rng = random.Random(0)
    seen = {data.sample_family(rng) for _ in range(500)}
    assert seen == set(f for f, _ in data.TRAIN_MIX)


def test_sizes_bounded():
    rng = random.Random(1)
    for _ in range(20):
        s = data.gen_mixed(rng, 100)
        assert len(s.prompt) < 400


manifest_path = os.path.join(ARTIFACTS, "manifest.json")


@pytest.mark.skipif(not os.path.exists(manifest_path), reason="artifacts not built")
def test_manifest_contract():
    m = json.load(open(manifest_path))
    assert m["tokenizer"]["pad"] == tok.PAD_ID
    assert m["tokenizer"]["bos"] == tok.BOS_ID
    for name, meta in m["models"].items():
        cfg = MODELS[name]
        assert meta["n_layers"] == cfg.n_layers
        assert meta["param_count"] == cfg.param_count()
        assert os.path.exists(os.path.join(ARTIFACTS, meta["weights"]))
    for key, g in m["graphs"].items():
        assert os.path.exists(os.path.join(ARTIFACTS, g["file"])), key
        expected = len(m["models"][g["model"]]["param_names"]) + g.get("n_lkv_weight_args", 0)
        assert g["n_weight_args"] == expected, key
    # every lkv variant's graph family exists at some bucket
    for vk, v in m["lkv_variants"].items():
        found = any(
            g["kind"] == "prefill_lkv" and g.get("suffix") == v["graph_suffix"]
            for g in m["graphs"].values()
        )
        assert found, vk

"""L2 invariants: shapes, masking, prefill/decode/LM consistency, and the
selective-LoRA guarantee (prompt rows unchanged)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.config import DEFAULT_LKV, DRAFT, LookaheadConfig

CFG = DRAFT  # smallest config keeps the suite fast


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 255, (64,)), jnp.int32)


def test_param_roundtrip(params):
    flat = M.flatten_params(CFG, params)
    assert len(flat) == len(M.param_order(CFG))
    back = M.unflatten_params(CFG, flat)
    np.testing.assert_array_equal(np.asarray(back["emb"]), np.asarray(params["emb"]))


def test_prefill_matches_lm(params, tokens):
    full = M.lm_logits(params, CFG, tokens[None])[0]
    pre = M.prefill(params, CFG, tokens, jnp.int32(50), window=8)
    np.testing.assert_allclose(
        np.asarray(pre["logits"]), np.asarray(full[49]), rtol=1e-4, atol=1e-5
    )


def test_decode_matches_lm(params, tokens):
    full = M.lm_logits(params, CFG, tokens[None])[0]
    pre = M.prefill(params, CFG, tokens, jnp.int32(50), window=8)
    res = M.decode_step(
        params, CFG, tokens[50], jnp.int32(50), pre["k"], pre["v"],
        jnp.full((CFG.n_layers,), 50, jnp.int32),
    )
    np.testing.assert_allclose(
        np.asarray(res["logits"]), np.asarray(full[50]), rtol=1e-3, atol=1e-4
    )


def test_padding_does_not_leak(params, tokens):
    """Changing tokens beyond `length` must not change outputs."""
    pre1 = M.prefill(params, CFG, tokens, jnp.int32(40), window=8)
    corrupted = tokens.at[45:].set(7)
    pre2 = M.prefill(params, CFG, corrupted, jnp.int32(40), window=8)
    np.testing.assert_allclose(np.asarray(pre1["logits"]), np.asarray(pre2["logits"]), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(pre1["h2o_scores"]), np.asarray(pre2["h2o_scores"]), rtol=1e-5, atol=1e-7
    )


def test_lora_selectivity(params, tokens):
    """Nonzero LoRA must leave prompt-token outputs bit-identical (the
    paper's selective-activation guarantee)."""
    lkv_cfg = DEFAULT_LKV
    from compile.lookahead import init_lkv

    key = jax.random.PRNGKey(3)
    lkv = init_lkv(CFG, lkv_cfg, key)
    # make B nonzero so the adapters actually fire
    lkv["lora"] = [
        {t: (a, jax.random.normal(key, b.shape) * 0.1) for t, (a, b) in layer.items()}
        for layer in lkv["lora"]
    ]
    out_with = M.prefill_lkv(params, CFG, lkv["emb"], lkv["lora"], lkv_cfg, tokens, jnp.int32(50))
    out_without = M.prefill_lkv(params, CFG, lkv["emb"], None, lkv_cfg, tokens, jnp.int32(50))
    # prompt KV and logits identical; only lkv_scores may differ
    np.testing.assert_allclose(np.asarray(out_with["k"]), np.asarray(out_without["k"]), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out_with["logits"]), np.asarray(out_without["logits"]), atol=1e-5
    )
    assert not np.allclose(
        np.asarray(out_with["lkv_scores"]), np.asarray(out_without["lkv_scores"])
    )


def test_suffix_kernel_equals_dense(params, tokens):
    emb_y = params["emb"][tokens[:6]]
    dense, _ = M.suffix_forward(params, CFG, tokens, jnp.int32(50), emb_y, use_kernel=False)
    kern, _ = M.suffix_forward(params, CFG, tokens, jnp.int32(50), emb_y, use_kernel=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(kern), rtol=3e-4, atol=1e-5)


def test_generate_shapes(params, tokens):
    out = M.generate_batch(
        params, CFG, tokens[None], jnp.asarray([50]), jax.random.PRNGKey(0), max_new=4
    )
    assert out.shape == (1, 4)
    assert ((np.asarray(out) >= 0) & (np.asarray(out) < CFG.vocab)).all()

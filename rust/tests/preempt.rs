//! Preemptive KV spill-to-host: correctness of the multi-tenant
//! scheduler's preemption path.
//!
//! The contract is the same shape as `tests/paged.rs`: preemption
//! changes *where bytes live* (arena vs host-side spill store), never
//! *what is computed*. For every `Method::parse`-able policy, a
//! sequence that is preempted mid-decode and later restored must
//! generate exactly the tokens of the unpreempted run — spill/restore
//! moves buffers verbatim, and greedy decoding is per-sequence
//! deterministic regardless of interleaving. On top of the equivalence:
//! the truncating baseline contrast (preemption off ⇒ `kv_exhausted`),
//! per-tenant quota rejection, and an arena-level spill/restore
//! round-trip property over random pool shapes.

use std::sync::mpsc::channel;
use std::sync::Arc;

use lookaheadkv::engine::{Engine, EngineConfig, FinishReason};
use lookaheadkv::eviction::Method;
use lookaheadkv::kvcache::{BlockAllocator, KvArena, KvDims, KvDtype};
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Reply, Request, RequestQueue};
use lookaheadkv::util::proptest::{check, Config};

const ALL_METHODS: &[&str] = &[
    "full", "random", "streaming", "snapkv", "pyramidkv", "h2o", "tova", "laq", "speckv",
    "lookaheadkv", "lkv+suffix",
];

const MODEL: &str = "lkv-tiny";
const BLOCK: usize = 16;

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new(MODEL)).expect("engine")
}

/// Two same-prompt requests — id 0 High, id 1 Low — through the paged
/// monolithic loop. Same prompt + method + budget means identical kept
/// sets and lockstep growth, so a pool sized to exactly two compacted
/// caches forces a deterministic preemption at the first grow.
fn run_pair(
    method: &str,
    pool_slots: usize,
    preemption: bool,
    budget: usize,
    max_new: usize,
) -> (Vec<Reply>, Arc<Metrics>) {
    run_pair_dtype(method, pool_slots, preemption, budget, max_new, KvDtype::F32)
}

/// [`run_pair`] with an explicit arena storage dtype.
fn run_pair_dtype(
    method: &str,
    pool_slots: usize,
    preemption: bool,
    budget: usize,
    max_new: usize,
    dtype: KvDtype,
) -> (Vec<Reply>, Arc<Metrics>) {
    let engine = engine();
    let queue = Arc::new(RequestQueue::new(4));
    let metrics = Arc::new(Metrics::new());
    let prompt = encode("lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;elit;A7K=", true, false);
    let mut receivers = Vec::new();
    for (id, priority) in [(0u64, Priority::High), (1u64, Priority::Low)] {
        let (tx, rx) = channel();
        receivers.push(rx);
        queue
            .submit(Request {
                id,
                prompt: prompt.clone(),
                method: Method::parse(method).expect("method"),
                budget,
                max_new,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: id as u32,
                priority,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
    }
    queue.close();
    let cfg = LoopConfig {
        max_active: 2,
        kv_pool_slots: pool_slots,
        kv_block_slots: BLOCK,
        paged_kv: true,
        preemption,
        tenants: 2,
        kv_dtype: dtype,
        ..LoopConfig::default()
    };
    EngineLoop::new(engine, cfg, Arc::clone(&queue), Arc::clone(&metrics)).run();
    let mut replies: Vec<Reply> =
        receivers.into_iter().map(|rx| rx.recv().expect("reply")).collect();
    replies.sort_by_key(|r| r.id);
    (replies, metrics)
}

/// For every policy: the Low-priority sequence is preempted (KV spilled
/// to host) when the High one grows into a full pool, restored after it
/// finishes, and both generations are bit-identical to an ample-pool
/// run — with zero `kv_exhausted` truncations.
#[test]
fn preempted_generation_bit_identical_for_every_policy() {
    for name in ALL_METHODS {
        // Reference trajectories under an ample pool (no pressure).
        let (full, fm) = run_pair(name, 16 * 1152, true, 16, 16);
        assert!(full[0].error.is_none(), "{name}: ample high errored: {:?}", full[0].error);
        assert!(full[1].error.is_none(), "{name}: ample low errored: {:?}", full[1].error);
        assert_eq!(fm.counter("preemptions_total"), 0, "{name}: ample pool must not preempt");
        let kept = full[0].kept;
        assert_eq!(kept, full[1].kept, "{name}: same prompt+budget must keep the same rows");
        let blocks = kept.div_ceil(BLOCK).max(1);

        // Exactly two compacted caches fit; the first grow must preempt.
        let (tiny, tm) = run_pair(name, 2 * blocks * BLOCK, true, 16, 16);
        for (a, b) in full.iter().zip(tiny.iter()) {
            assert!(b.error.is_none(), "{name} req {}: {:?}", b.id, b.error);
            assert_eq!(a.text, b.text, "{name} req {}: generation differs under preemption", a.id);
            assert_eq!(a.n_tokens, b.n_tokens, "{name} req {}: token count differs", a.id);
            assert_eq!(
                a.finish_reason, b.finish_reason,
                "{name} req {}: finish reason differs",
                a.id
            );
        }
        assert_eq!(
            tm.counter("decode_truncated_total"),
            0,
            "{name}: preemption must replace truncation"
        );
        // Everything drains: pool, arena, and the spill tier.
        assert_eq!(tm.gauge("kv_used_blocks"), Some(0.0), "{name}: pool leak");
        assert_eq!(tm.gauge("kv_arena_bytes"), Some(0.0), "{name}: arena leak");
        assert_eq!(tm.gauge("kv_spill_seqs"), Some(0.0), "{name}: spill-tier seq leak");
        assert_eq!(tm.gauge("kv_spill_bytes"), Some(0.0), "{name}: spill-tier byte leak");

        // KV writes happen for all but the last generated token; growth
        // (and therefore preemption) triggers only once they exceed the
        // compacted cache's block slack.
        let writes = full[0].n_tokens.saturating_sub(1);
        let slack = blocks * BLOCK - kept;
        if writes > slack {
            assert!(tm.counter("preemptions_total") >= 1, "{name}: expected a preemption");
            assert!(tm.counter("spill_blocks_total") >= 1, "{name}: expected spilled blocks");
            assert!(tm.counter("restores_total") >= 1, "{name}: the victim must be restored");
            assert_eq!(
                tm.counter("restore_blocks_total"),
                tm.counter("spill_blocks_total"),
                "{name}: every spilled block must come back"
            );

            // Baseline contrast: the same pressure without preemption
            // truncates with `kv_exhausted` instead.
            let (trunc, xm) = run_pair(name, 2 * blocks * BLOCK, false, 16, 16);
            assert!(
                xm.counter("decode_truncated_total") >= 1,
                "{name}: preemption off must fall back to truncation"
            );
            assert!(
                trunc.iter().any(|r| r.finish_reason == FinishReason::KvExhausted),
                "{name}: no kv_exhausted finish in the truncating baseline"
            );
            assert_eq!(xm.counter("preemptions_total"), 0, "{name}: preemption was disabled");
        } else {
            eprintln!(
                "{name}: no growth needed (writes {writes} <= slack {slack}); \
                 preemption not exercised"
            );
        }
    }
}

/// A request whose `prompt + max_new` charge exceeds the whole
/// per-tenant quota is rejected with an error (it could never run);
/// requests within quota still complete normally.
#[test]
fn over_quota_request_is_rejected_not_queued() {
    let engine = engine();
    let queue = Arc::new(RequestQueue::new(4));
    let metrics = Arc::new(Metrics::new());
    let big = encode("lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;elit;A7K=", true, false);
    let small = encode("a;b;c", true, false);
    assert!(big.len() + 16 > 32, "the big request must exceed the quota");
    assert!(small.len() + 8 <= 32, "the small request must fit the quota");
    let mut receivers = Vec::new();
    for (id, prompt, max_new) in [(0u64, big, 16usize), (1u64, small, 8usize)] {
        let (tx, rx) = channel();
        receivers.push(rx);
        queue
            .submit(Request {
                id,
                prompt,
                method: Method::SnapKV,
                budget: 16,
                max_new,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: 0,
                priority: Priority::Normal,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
    }
    queue.close();
    let cfg = LoopConfig { quota_tokens: 32, ..LoopConfig::default() };
    EngineLoop::new(engine, cfg, Arc::clone(&queue), Arc::clone(&metrics)).run();
    let replies: Vec<Reply> =
        receivers.into_iter().map(|rx| rx.recv().expect("reply")).collect();
    let over = &replies[0];
    assert_eq!(over.finish_reason, FinishReason::Error);
    let msg = over.error.as_deref().expect("over-quota request must carry an error");
    assert!(msg.contains("quota"), "unexpected rejection message: {msg}");
    let ok = &replies[1];
    assert!(ok.error.is_none(), "in-quota request failed: {:?}", ok.error);
    assert!(ok.n_tokens > 0);
}

/// Arena-level spill/restore property: over random pool shapes, block
/// sizes, head dims, storage dtypes and id-permuting interlopers, a
/// spill → realloc → restore round trip reproduces the *stored*
/// representation bit for bit (u8 codes and quant params included) and
/// byte accounting returns to exactly its pre-spill state.
#[test]
fn arena_spill_restore_roundtrip_property() {
    check(
        "arena spill/restore round trip",
        &Config { cases: 48, max_size: 10, ..Config::new() },
        |rng, size| {
            let bs = 1 + rng.below(6);
            let nb = 3 + rng.below(size.max(1) + 4);
            let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 1 + rng.below(12) };
            let dtype = [KvDtype::F32, KvDtype::F16, KvDtype::U8][rng.below(3)];
            let mut arena = KvArena::with_dtype(nb, bs, dtype);
            let mut alloc = BlockAllocator::new(nb * bs, bs);

            // Owner 1: the spill victim, with a random KV pattern.
            let na = 1 + rng.below(nb - 1);
            let ids = alloc.alloc(1, na * bs).expect("victim alloc");
            arena.bind(&ids, &dims);
            let mut bufs = arena.take(&ids).expect("take for fill");
            for b in &mut bufs {
                let k: Vec<f32> = (0..b.k.len()).map(|_| rng.f32()).collect();
                b.k.encode_block(&k);
                let v: Vec<f32> = (0..b.v.len()).map(|_| rng.f32()).collect();
                b.v.encode_block(&v);
            }
            let expected = bufs.clone();
            arena.put(&ids, bufs);

            // Owner 2 (optional): a bystander that stays resident.
            let spare = nb - na;
            let n2 = rng.below(spare + 1);
            let other = if n2 > 0 {
                let ids2 = alloc.alloc(2, n2 * bs).expect("bystander alloc");
                arena.bind(&ids2, &dims);
                ids2
            } else {
                Vec::new()
            };
            let bytes_before = arena.bytes_in_use();
            let victim_bytes = na * dtype.block_bytes(&dims, bs);

            let spilled = arena.spill(&ids).expect("spill");
            alloc.free(&ids);
            assert_eq!(spilled.len(), na);
            assert_eq!(arena.bytes_in_use(), bytes_before - victim_bytes);

            // An interloper grabs some of the freed ids so the restore
            // lands on a (generally) different block table.
            let n3 = rng.below(nb - n2 - na + 1);
            let interloper =
                if n3 > 0 { alloc.alloc(3, n3 * bs).expect("interloper") } else { Vec::new() };
            // Spilling allocator-only (unbound) blocks must fail cleanly.
            if !interloper.is_empty() {
                assert!(arena.spill(&interloper).is_err());
            }

            let ids_new = alloc.alloc(1, na * bs).expect("realloc after spill");
            arena.restore(&ids_new, spilled);
            assert_eq!(arena.bytes_in_use(), bytes_before);
            for (id, exp) in ids_new.iter().zip(&expected) {
                let blk = arena.block_raw(*id).expect("restored block bound");
                assert_eq!(blk.k, exp.k, "stored K must survive spill/restore bit-identically");
                assert_eq!(blk.v, exp.v, "stored V must survive spill/restore bit-identically");
            }

            // Full teardown leaves nothing resident.
            arena.release(&ids_new);
            arena.release(&other);
            alloc.free(&ids_new);
            alloc.free(&other);
            alloc.free(&interloper);
            assert_eq!(arena.bytes_in_use(), 0);
            assert_eq!(arena.logical_bytes_in_use(), 0);
            assert_eq!(alloc.used_blocks(), 0);
        },
    );
}

/// A u8 sequence preempted to the host spill store and restored
/// generates exactly the text of a never-spilled u8 run: spill moves
/// the quantized representation verbatim, so preemption and
/// quantization compose without requantization drift.
#[test]
fn u8_spill_restore_reproduces_unspilled_generation() {
    for name in ["snapkv", "lookaheadkv"] {
        // Never-spilled u8 reference under an ample pool.
        let (full, fm) = run_pair_dtype(name, 16 * 1152, true, 16, 16, KvDtype::U8);
        assert!(full[0].error.is_none(), "{name}: ample high errored: {:?}", full[0].error);
        assert!(full[1].error.is_none(), "{name}: ample low errored: {:?}", full[1].error);
        assert_eq!(fm.counter("preemptions_total"), 0, "{name}: ample pool must not preempt");
        let kept = full[0].kept;
        let blocks = kept.div_ceil(BLOCK).max(1);

        // Exactly two compacted caches fit; the first grow must preempt.
        let (tiny, tm) = run_pair_dtype(name, 2 * blocks * BLOCK, true, 16, 16, KvDtype::U8);
        for (a, b) in full.iter().zip(tiny.iter()) {
            assert!(b.error.is_none(), "{name} req {}: {:?}", b.id, b.error);
            assert_eq!(
                a.text, b.text,
                "{name} req {}: u8 generation differs under preemption",
                a.id
            );
            assert_eq!(a.n_tokens, b.n_tokens, "{name} req {}: token count differs", a.id);
            assert_eq!(b.stats.kv_dtype, "u8", "{name} req {}: stats dtype", b.id);
        }
        assert_eq!(tm.counter("decode_truncated_total"), 0, "{name}: truncated under preemption");
        let writes = full[0].n_tokens.saturating_sub(1);
        let slack = blocks * BLOCK - kept;
        if writes > slack {
            assert!(tm.counter("preemptions_total") >= 1, "{name}: expected a preemption");
            assert!(tm.counter("spill_blocks_total") >= 1, "{name}: expected spilled blocks");
        } else {
            eprintln!("{name}: no growth (writes {writes} <= slack {slack}); spill not exercised");
        }
        // The quantized spill tier drains completely.
        assert_eq!(tm.gauge("kv_spill_seqs"), Some(0.0), "{name}: spill-tier seq leak");
        assert_eq!(tm.gauge("kv_spill_bytes"), Some(0.0), "{name}: spill-tier byte leak");
        assert_eq!(tm.gauge("kv_arena_bytes"), Some(0.0), "{name}: arena leak");
    }
}

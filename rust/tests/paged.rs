//! Paged-vs-dense equivalence and arena lifecycle.
//!
//! The paged KV arena's contract is that it changes *where bytes live*,
//! never *what is computed*: for every `Method::parse`-able policy,
//! gather-compaction into arena blocks must equal
//! `SeqCache::from_selection` bit for bit, paged chunked prefill must
//! reproduce the dense pass exactly (logits, score bundles, prompt KV),
//! and paged decode must emit the same logits as the dense kernel at
//! every step while growing block-by-block instead of stopping at a cap.
//! On top of the equivalence: leak checks (every block returns to the
//! pool on finish) and the `finish_reason` / `decode_truncated_total`
//! observability of pool-driven truncation.

use std::sync::mpsc::channel;
use std::sync::Arc;

use lookaheadkv::engine::{Engine, EngineConfig, FinishReason};
use lookaheadkv::eviction::{EvictionConfig, Method, ScoreBundle};
use lookaheadkv::kvcache::{
    BlockAllocator, CacheManager, KvArena, KvDims, KvDtype, PagedSeqCache, SeqCache,
};
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Reply, Request, RequestQueue};
use lookaheadkv::util::rng::argmax;

const ALL_METHODS: &[&str] = &[
    "full", "random", "streaming", "snapkv", "pyramidkv", "h2o", "tova", "laq", "speckv",
    "lookaheadkv", "lkv+suffix",
];

const MODEL: &str = "lkv-tiny";
const BLOCK: usize = 16;

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new(MODEL)).expect("engine")
}

fn test_prompt() -> Vec<i32> {
    encode(
        "lorem;ipsum;K7F=Q2Z;amet;tempor;labore;magna;aliqua;erat;sed;K7F=",
        true,
        false,
    )
}

fn assert_bundles_identical(a: &ScoreBundle, b: &ScoreBundle, tag: &str) {
    assert_eq!(a.len, b.len, "{tag}: bundle len");
    assert_eq!(a.win_start, b.win_start, "{tag}: win_start");
    assert_eq!(a.win_rows, b.win_rows, "{tag}: win_rows");
    assert_eq!(a.w_use_override, b.w_use_override, "{tag}: w_use_override");
    let pairs = [
        ("window_scores", &a.window_scores, &b.window_scores),
        ("h2o_scores", &a.h2o_scores, &b.h2o_scores),
        ("lkv_scores", &a.lkv_scores, &b.lkv_scores),
    ];
    for (name, ta, tb) in pairs {
        match (ta, tb) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.shape, y.shape, "{tag}: {name} shape");
                assert_eq!(x.data, y.data, "{tag}: {name} not bit-identical");
            }
            _ => panic!("{tag}: {name} presence differs (dense vs paged)"),
        }
    }
}

/// For every policy: gather-compaction into arena blocks equals
/// `SeqCache::from_selection` bit for bit, and a paged decode emits the
/// dense kernel's exact logits at every step — growing by a block
/// whenever its table fills, instead of finishing at a cap.
#[test]
fn paged_compaction_and_decode_match_dense_for_every_policy() {
    const STEPS: usize = 6;
    let engine = engine();
    let prompt = test_prompt();
    let n_layers = engine.n_layers(MODEL);
    let dims = engine.kv_dims(MODEL).expect("dims");
    let mut arena = KvArena::new(256, BLOCK);
    let mut alloc = BlockAllocator::new(256 * BLOCK, BLOCK);
    for (mi, name) in ALL_METHODS.iter().enumerate() {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let pre = engine.prefill_for_method(&prompt, &method).expect("prefill");
        let evcfg = EvictionConfig::new(24);
        let sel = method.select(&evcfg, n_layers, &pre.bundle);
        let cap = engine
            .rt
            .manifest()
            .decode_cap(MODEL, sel.max_kept() + STEPS + 1)
            .expect("decode cap");
        let owner = mi as u64 + 1;
        let mut dense =
            SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, prompt.len(), cap);
        let mut paged = PagedSeqCache::from_dense_selection(
            &mut arena,
            &mut alloc,
            owner,
            dims,
            &pre.k,
            &pre.v,
            &sel.per_layer,
            prompt.len(),
            cap,
        )
        .expect("paged compaction");
        // at-rest equivalence: same bytes, lens, slot maps
        let g0 = paged.gather_dense(&arena, cap).expect("gather");
        assert_eq!(g0.k.data, dense.k.data, "{name}: compacted K differs");
        assert_eq!(g0.v.data, dense.v.data, "{name}: compacted V differs");
        assert_eq!(g0.lens, dense.lens, "{name}: lens differ");
        assert_eq!(g0.slot_pos, dense.slot_pos, "{name}: slot maps differ");
        // strictly fewer resident slots than the dense cap for this model
        assert!(
            paged.allocated_slots() <= cap,
            "{name}: paged allocated {} > dense cap {cap}",
            paged.allocated_slots()
        );
        // lockstep decode: identical logits at every step; the paged
        // cache grows on demand instead of relying on cap headroom
        let mut token = 65i32;
        for step in 0..STEPS {
            let d = engine.decode_step(MODEL, &mut dense, token).expect("dense step");
            if paged.headroom() == 0 {
                assert!(paged.grow(&mut arena, &mut alloc, owner), "{name}: grow failed");
            }
            let p = {
                let mut refs = vec![&mut paged];
                engine
                    .decode_step_batch_paged(MODEL, &mut arena, &mut refs, &[token])
                    .expect("paged step")
            };
            assert_eq!(p[0].logits, d.logits, "{name} step {step}: logits diverge");
            token = argmax(&d.logits) as i32;
        }
        let g1 = paged.gather_dense(&arena, cap).expect("gather post-decode");
        assert_eq!(g1.k.data, dense.k.data, "{name}: post-decode K differs");
        assert_eq!(g1.v.data, dense.v.data, "{name}: post-decode V differs");
        assert_eq!(g1.lens, dense.lens, "{name}: post-decode lens differ");
        assert_eq!(g1.next_pos, dense.next_pos, "{name}: next_pos differs");
        // free-on-finish: every block back, no resident bytes
        let ids = alloc.take_owner(owner);
        arena.release(&ids);
        assert_eq!(alloc.used_blocks(), 0, "{name}: leaked allocator blocks");
        assert_eq!(arena.bytes_in_use(), 0, "{name}: leaked arena bytes");
    }
}

/// For every policy: a fully paged chunked prefill (prompt KV in arena
/// blocks end to end) reproduces the monolithic dense prefill exactly —
/// logits, score bundle, selection, and the gather-compacted decode
/// cache built straight from the prompt blocks.
#[test]
fn paged_chunked_prefill_matches_dense_for_every_policy() {
    let engine = engine();
    assert!(engine.rt.supports_paged_kv(), "reference backend must support paged KV");
    let prompt = test_prompt();
    let n_layers = engine.n_layers(MODEL);
    let dims = engine.kv_dims(MODEL).expect("dims");
    for name in ALL_METHODS {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let mono = engine.prefill_for_method(&prompt, &method).expect("monolithic prefill");
        let mut mgr = CacheManager::new(256 * BLOCK, BLOCK);
        let paged_out = {
            let mut ctx = mgr.paged_ctx(1);
            let mut job = engine
                .chunked_prefill_begin_paged(&prompt, &method, 13, None, &mut ctx)
                .expect("begin paged");
            assert!(job.is_paged());
            let mut steps = 0;
            while !job.step_paged(&engine, &mut ctx).expect("paged step") {
                steps += 1;
                assert!(steps < 10_000, "paged chunked prefill does not terminate");
            }
            job.into_output().expect("output")
        };
        assert_eq!(paged_out.bucket, mono.bucket, "{name}: bucket");
        assert_eq!(paged_out.logits, mono.logits, "{name}: logits not bit-identical");
        assert_bundles_identical(&mono.bundle, &paged_out.bundle, name);
        let evcfg = EvictionConfig::new(24);
        let sel_m = method.select(&evcfg, n_layers, &mono.bundle);
        let sel_p = method.select(&evcfg, n_layers, &paged_out.bundle);
        assert_eq!(sel_m, sel_p, "{name}: kept-slot selection differs");
        let cap =
            engine.rt.manifest().decode_cap(MODEL, sel_m.max_kept() + 4).expect("decode cap");
        let dense_cache =
            SeqCache::from_selection(&mono.k, &mono.v, &sel_m.per_layer, prompt.len(), cap);
        let blocks = paged_out.blocks.expect("paged output must carry the prompt block table");
        let paged_cache = {
            let (arena, alloc) = mgr.paged_parts();
            PagedSeqCache::from_arena_selection(
                arena,
                alloc,
                2,
                dims,
                &blocks,
                &sel_p.per_layer,
                prompt.len(),
                cap,
            )
            .expect("gather-compaction from prompt blocks")
        };
        // compaction becomes a gather into fresh blocks; the prompt's
        // blocks are freed immediately afterwards
        mgr.paged_ctx(1).free_blocks(&blocks);
        let g = paged_cache.gather_dense(mgr.arena(), cap).expect("gather");
        assert_eq!(g.k.data, dense_cache.k.data, "{name}: compacted K differs");
        assert_eq!(g.v.data, dense_cache.v.data, "{name}: compacted V differs");
        assert_eq!(g.lens, dense_cache.lens, "{name}: lens differ");
        // full lifecycle leaves nothing behind
        mgr.release(2);
        let s = mgr.stats();
        assert_eq!(s.used_blocks, 0, "{name}: leaked blocks");
        assert_eq!(s.arena_bytes, 0, "{name}: leaked arena bytes");
    }
}

/// Drive the full engine loop over explicit (prompt, method) requests
/// with an arena storage dtype, returning ordered replies + metrics.
fn run_loop_with(
    reqs: &[(Vec<i32>, Method)],
    paged: bool,
    chunk: usize,
    pool_slots: usize,
    budget: usize,
    max_new: usize,
    dtype: KvDtype,
) -> (Vec<Reply>, Arc<Metrics>) {
    let engine = engine();
    let queue = Arc::new(RequestQueue::new(reqs.len() + 1));
    let metrics = Arc::new(Metrics::new());
    let mut receivers = Vec::new();
    for (i, (prompt, method)) in reqs.iter().enumerate() {
        let (tx, rx) = channel();
        receivers.push(rx);
        queue
            .submit(Request {
                id: i as u64,
                prompt: prompt.clone(),
                method: method.clone(),
                budget,
                max_new,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: 0,
                priority: Priority::Normal,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
    }
    queue.close();
    let cfg = LoopConfig {
        max_active: 2,
        prefill_chunk_tokens: chunk,
        kv_pool_slots: pool_slots,
        kv_block_slots: BLOCK,
        paged_kv: paged,
        kv_dtype: dtype,
        ..LoopConfig::default()
    };
    EngineLoop::new(engine, cfg, Arc::clone(&queue), Arc::clone(&metrics)).run();
    let mut replies: Vec<Reply> =
        receivers.into_iter().map(|rx| rx.recv().expect("reply")).collect();
    replies.sort_by_key(|r| r.id);
    (replies, metrics)
}

/// Drive the full engine loop over `prompts` (alternating SnapKV /
/// LookaheadKV, f32 arena) and return ordered replies + metrics.
fn run_loop(
    prompts: &[String],
    paged: bool,
    chunk: usize,
    pool_slots: usize,
    budget: usize,
    max_new: usize,
) -> (Vec<Reply>, Arc<Metrics>) {
    let reqs: Vec<(Vec<i32>, Method)> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let method =
                if i % 2 == 0 { Method::SnapKV } else { Method::parse("lookaheadkv").unwrap() };
            (encode(p, true, false), method)
        })
        .collect();
    run_loop_with(&reqs, paged, chunk, pool_slots, budget, max_new, KvDtype::F32)
}

/// End to end through the engine loop, chunked and monolithic: the
/// paged arena serves bit-identical generations to the dense caches,
/// growth happens silently (small blocks force it), and every block is
/// back in the pool when the loop drains.
#[test]
fn engine_loop_paged_matches_dense_generations() {
    let prompts: Vec<String> = vec![
        "lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;elit;A7K=".into(),
        "sed;do;eiusmod;tempor;B3X=W9Y;incididunt;labore;B3X=".into(),
        "magna;aliqua;ut;enim;C5M=R4T;veniam;quis;nostrud;C5M=".into(),
        "duis;aute;irure;dolor;D8P=J6N;reprehenderit;velit;D8P=".into(),
    ];
    for chunk in [16usize, 0] {
        // budget 16 -> one 16-slot block; max_new 24 forces >= 1 grow
        let (dense, _dm) = run_loop(&prompts, false, chunk, 16 * 1152, 16, 24);
        let (paged, pm) = run_loop(&prompts, true, chunk, 16 * 1152, 16, 24);
        assert_eq!(dense.len(), paged.len());
        for (a, b) in dense.iter().zip(paged.iter()) {
            assert!(a.error.is_none(), "chunk {chunk} dense error: {:?}", a.error);
            assert!(b.error.is_none(), "chunk {chunk} paged error: {:?}", b.error);
            assert_eq!(a.text, b.text, "chunk {chunk} req {}: generation differs", a.id);
            assert_eq!(a.n_tokens, b.n_tokens, "chunk {chunk} req {}: token count", a.id);
            assert_eq!(a.kept, b.kept, "chunk {chunk} req {}: kept differs", a.id);
            assert_eq!(
                a.finish_reason, b.finish_reason,
                "chunk {chunk} req {}: finish reason differs",
                a.id
            );
            assert!(
                matches!(b.finish_reason, FinishReason::Eos | FinishReason::Length),
                "chunk {chunk} req {}: unexpected finish {:?}",
                b.id,
                b.finish_reason
            );
        }
        // ample pool: nothing may be truncated, nothing may leak
        assert_eq!(pm.counter("decode_truncated_total"), 0, "chunk {chunk}");
        assert_eq!(pm.gauge("kv_arena_blocks_used"), Some(0.0), "chunk {chunk}: blocks leak");
        assert_eq!(pm.gauge("kv_arena_bytes"), Some(0.0), "chunk {chunk}: bytes leak");
        assert_eq!(pm.gauge("kv_used_blocks"), Some(0.0), "chunk {chunk}: pool leak");
        // arena gauges exist and the per-owner breakdown is exported
        assert!(pm.gauge("kv_arena_blocks_decode").is_some());
        assert!(pm.gauge("kv_arena_blocks_prefix").is_some());
        assert!(pm.gauge("kv_arena_blocks_prefill").is_some());
    }
}

/// Pool-driven truncation is observable: with a pool too small to keep
/// growing, the sequence decodes until genuine exhaustion, finishes with
/// `kv_exhausted` (its text a prefix of the untruncated run), and bumps
/// `decode_truncated_total` — instead of erroring or silently stopping.
#[test]
fn pool_exhaustion_truncates_observably() {
    let prompts: Vec<String> =
        vec!["lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;elit;A7K=".into()];
    // Reference run with an ample pool (budget 16 -> 16 kept rows).
    let (full, _) = run_loop(&prompts, true, 0, 16 * 1152, 16, 40);
    assert!(full[0].error.is_none());
    if full[0].finish_reason != FinishReason::Length {
        // The model emitted EOS within 40 tokens for this prompt; the
        // truncation scenario cannot be staged deterministically here.
        eprintln!("skipping exhaustion assertions: EOS before the pool limit");
        return;
    }
    // Pool of 2 blocks (32 slots): 16 kept + one grow, then exhaustion.
    let (tiny, tm) = run_loop(&prompts, true, 0, 2 * BLOCK, 16, 40);
    let r = &tiny[0];
    assert!(r.error.is_none(), "exhaustion must truncate, not error: {:?}", r.error);
    assert_eq!(r.finish_reason, FinishReason::KvExhausted, "got {:?}", r.finish_reason);
    assert!(
        r.n_tokens < full[0].n_tokens,
        "truncated run produced {} of {} tokens",
        r.n_tokens,
        full[0].n_tokens
    );
    assert!(r.n_tokens > 1, "the sequence must decode until genuine exhaustion");
    assert!(
        full[0].text.starts_with(&r.text),
        "truncated text must be a prefix of the untruncated generation"
    );
    assert_eq!(tm.counter("decode_truncated_total"), 1);
    // even the truncated run returns every block
    assert_eq!(tm.gauge("kv_arena_bytes"), Some(0.0));
}

/// `--kv-dtype u8` end-to-end through the engine loop: for every
/// parseable policy family (plus the learned predictor), the quantized
/// arena reproduces the f32 oracle's generation token for token —
/// chunked and monolithic — and drains without leaking a block. The
/// replies carry the storage dtype and a dtype-true resident-KV byte
/// figure that undercuts the f32 run's.
#[test]
fn engine_loop_u8_matches_f32_for_every_policy() {
    let prompt = test_prompt();
    let names: Vec<&str> = ALL_METHODS.iter().copied().chain(["predictor"]).collect();
    let reqs: Vec<(Vec<i32>, Method)> = names
        .iter()
        .map(|name| {
            let m = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
            (prompt.clone(), m)
        })
        .collect();
    for chunk in [16usize, 0] {
        let (oracle, _) = run_loop_with(&reqs, true, chunk, 16 * 1152, 16, 12, KvDtype::F32);
        let (quant, qm) = run_loop_with(&reqs, true, chunk, 16 * 1152, 16, 12, KvDtype::U8);
        for ((name, a), b) in names.iter().zip(&oracle).zip(&quant) {
            assert!(a.error.is_none(), "{name} chunk {chunk} f32 error: {:?}", a.error);
            assert!(b.error.is_none(), "{name} chunk {chunk} u8 error: {:?}", b.error);
            assert_eq!(a.text, b.text, "{name} chunk {chunk}: u8 generation diverges from f32");
            assert_eq!(a.n_tokens, b.n_tokens, "{name} chunk {chunk}: token count differs");
            assert_eq!(a.kept, b.kept, "{name} chunk {chunk}: kept rows differ");
            assert_eq!(
                a.finish_reason, b.finish_reason,
                "{name} chunk {chunk}: finish reason differs"
            );
            assert_eq!(a.stats.kv_dtype, "f32", "{name} chunk {chunk}: oracle dtype");
            assert_eq!(b.stats.kv_dtype, "u8", "{name} chunk {chunk}: stats dtype");
            assert!(b.stats.resident_kv_bytes > 0, "{name} chunk {chunk}: resident bytes");
            assert!(
                b.stats.resident_kv_bytes < a.stats.resident_kv_bytes,
                "{name} chunk {chunk}: u8 resident {} must undercut f32 {}",
                b.stats.resident_kv_bytes,
                a.stats.resident_kv_bytes
            );
        }
        // quantized pool drains clean: resident and logical both zero
        assert_eq!(qm.gauge("kv_arena_bytes"), Some(0.0), "chunk {chunk}: u8 bytes leak");
        assert_eq!(qm.gauge("kv_arena_bytes_resident"), Some(0.0), "chunk {chunk}");
        assert_eq!(qm.gauge("kv_arena_bytes_logical"), Some(0.0), "chunk {chunk}");
    }
}

/// Quantize→dequantize round-trips the per-(layer, KV head, block) u8
/// scales for adversarial value ranges — all-zero rows, denormal
/// magnitudes, ordinary data, constant rows with a single huge outlier.
/// Every decoded element stays within half a quantization step of its
/// segment's own range (exactly zero error when the segment is flat).
#[test]
fn prop_u8_arena_roundtrip_adversarial_ranges() {
    use lookaheadkv::kvcache::BlockId;
    use lookaheadkv::util::proptest::{check, Config};
    check(
        "u8 arena quantize roundtrip",
        &Config { cases: 64, max_size: 12, ..Config::new() },
        |rng, size| {
            let bs = 1 + rng.below(6);
            let dims = KvDims {
                n_layers: 1 + rng.below(3),
                n_kv_heads: 1 + rng.below(2),
                head_dim: 1 + rng.below(size.max(1) + 4),
            };
            let mut arena = KvArena::with_dtype(2, bs, KvDtype::U8);
            arena.bind(&[BlockId(0)], &dims);
            let elems = dims.slot_floats() * bs;
            let kind = rng.below(4);
            let mut gen = |i: usize| -> f32 {
                match kind {
                    0 => 0.0,
                    1 => (rng.f32() - 0.5) * 2e-39,
                    2 => rng.f32() * 8.0 - 4.0,
                    _ => {
                        if i == 0 {
                            1000.0
                        } else {
                            0.125
                        }
                    }
                }
            };
            let k: Vec<f32> = (0..elems).map(&mut gen).collect();
            let v: Vec<f32> = (0..elems).map(&mut gen).collect();
            arena.write_block(BlockId(0), &k, &v);
            let (dk, dv) = arena.block_kv(BlockId(0)).expect("bound block");
            let seg_elems = bs * dims.head_dim;
            for (plane, orig) in [(&dk, &k), (&dv, &v)] {
                for seg in 0..dims.n_layers * dims.n_kv_heads {
                    let s = &orig[seg * seg_elems..(seg + 1) * seg_elems];
                    let d = &plane[seg * seg_elems..(seg + 1) * seg_elems];
                    let lo = s.iter().copied().fold(f32::INFINITY, f32::min);
                    let hi = s.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let step = if hi > lo { (hi - lo) / 255.0 } else { 0.0 };
                    for (x, y) in s.iter().zip(d) {
                        assert!(
                            (x - y).abs() <= step * 0.5001 + 1e-30,
                            "kind {kind} seg {seg}: {x} decoded as {y} (step {step})"
                        );
                    }
                }
            }
            arena.release(&[BlockId(0)]);
            assert_eq!(arena.bytes_in_use(), 0);
        },
    );
}

/// Gather-compaction never reads freed source blocks: once the prompt's
/// block table is released back to the pool, `from_arena_selection`
/// fails cleanly (no stale-data reuse) and unwinds its own destination
/// allocation — nothing leaks from the failed attempt.
#[test]
fn arena_selection_never_reads_freed_blocks() {
    let engine = engine();
    let prompt = test_prompt();
    let n_layers = engine.n_layers(MODEL);
    let dims = engine.kv_dims(MODEL).expect("dims");
    let method = Method::SnapKV;
    let mut mgr = CacheManager::with_dtype(256 * BLOCK, BLOCK, KvDtype::U8);
    let out = {
        let mut ctx = mgr.paged_ctx(1);
        let mut job = engine
            .chunked_prefill_begin_paged(&prompt, &method, 13, None, &mut ctx)
            .expect("begin paged");
        let mut steps = 0;
        while !job.step_paged(&engine, &mut ctx).expect("paged step") {
            steps += 1;
            assert!(steps < 10_000, "paged chunked prefill does not terminate");
        }
        job.into_output().expect("output")
    };
    let evcfg = EvictionConfig::new(16);
    let sel = method.select(&evcfg, n_layers, &out.bundle);
    let cap = engine.rt.manifest().decode_cap(MODEL, sel.max_kept() + 4).expect("decode cap");
    let blocks = out.blocks.expect("paged output must carry the prompt block table");
    // Free the prompt blocks FIRST: the gather must now refuse to run.
    mgr.paged_ctx(1).free_blocks(&blocks);
    let res = {
        let (arena, alloc) = mgr.paged_parts();
        PagedSeqCache::from_arena_selection(
            arena,
            alloc,
            2,
            dims,
            &blocks,
            &sel.per_layer,
            prompt.len(),
            cap,
        )
    };
    match res {
        Ok(_) => panic!("gather-compaction must not read freed source blocks"),
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(msg.contains("unbound"), "unexpected error: {msg}");
        }
    }
    // The failed attempt unwound its destination allocation entirely.
    let s = mgr.stats();
    assert_eq!(s.used_blocks, 0, "failed compaction leaked allocator blocks");
    assert_eq!(s.arena_bytes, 0, "failed compaction leaked arena bytes");
    assert_eq!(s.arena_logical_bytes, 0, "failed compaction leaked logical bytes");
}

/// A dense-loop sequence hitting its cap reports `kv_exhausted` too
/// (the reason is layout-independent; only the paged path can grow).
#[test]
fn dense_cap_exhaustion_is_reported() {
    let prompts: Vec<String> =
        vec!["lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;elit;A7K=".into()];
    let (full, _) = run_loop(&prompts, false, 0, 16 * 1152, 16, 40);
    assert!(full[0].error.is_none());
    assert!(
        matches!(full[0].finish_reason, FinishReason::Eos | FinishReason::Length),
        "ample dense caps never exhaust: {:?}",
        full[0].finish_reason
    );
}

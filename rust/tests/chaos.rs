//! Chaos soak: drive bursty workloads through the full engine loop with
//! deterministic fault injection enabled and assert the two robustness
//! invariants the PR promises:
//!
//! 1. **Containment** — requests the fault plan never touches finish
//!    token-identical to a fault-free run (faults are per-request, not
//!    per-process).
//! 2. **No leaks** — after every run, arena blocks, spill blocks, and
//!    tenant quota all drain to zero, whatever mix of errors, injected
//!    disconnects, retries, and cold recomputes the plan provoked.
//!
//! Each test writes a machine-readable soak summary under `results/`
//! (uploaded as a CI artifact by the chaos-soak step).

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lookaheadkv::engine::{Engine, EngineConfig, FinishReason};
use lookaheadkv::eviction::Method;
use lookaheadkv::faults::FaultPlan;
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Reply, Request, RequestQueue};
use lookaheadkv::util::json::Json;

/// Covers every seam: prefill chunks use attempt `0..chunks`, decode
/// iterations `100 + iter`, restore retries small integers — 400 bounds
/// them all for these workloads.
const MAX_ATTEMPTS: u64 = 400;

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny")).expect("engine")
}

fn prompts(n: usize) -> Vec<Vec<i32>> {
    let texts = [
        "lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;elit;A7K=",
        "sed;do;eiusmod;B3X=W9Y;tempor;incididunt;ut;labore;B3X=",
        "magna;aliqua;ut;enim;C5M=R4T;ad;minim;veniam;quis;C5M=",
        "duis;aute;irure;dolor;D8N=K1J;in;reprehenderit;D8N=",
    ];
    (0..n).map(|i| encode(texts[i % texts.len()], true, false)).collect()
}

/// Submit the whole burst up front, close the queue, run the loop on a
/// worker thread, and collect one reply per request (order-free).
fn run_burst(
    prompts: &[Vec<i32>],
    cfg: LoopConfig,
    priorities: &[Priority],
    tenants: usize,
) -> (Vec<Reply>, Arc<Metrics>) {
    let queue = Arc::new(RequestQueue::new(prompts.len() + 1));
    let metrics = Arc::new(Metrics::new());
    let (tx, rx) = channel::<Reply>();
    for (i, p) in prompts.iter().enumerate() {
        queue
            .submit(Request {
                id: i as u64,
                prompt: p.clone(),
                method: Method::SnapKV,
                budget: 16,
                max_new: 8,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: (i % tenants) as u32,
                priority: priorities[i % priorities.len()],
                submitted_at: Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(AtomicBool::new(false)),
                reply: tx.clone(),
            })
            .expect("submit");
    }
    queue.close();
    let loop_queue = Arc::clone(&queue);
    let loop_metrics = Arc::clone(&metrics);
    let handle = std::thread::spawn(move || {
        EngineLoop::new(engine(), cfg, loop_queue, loop_metrics).run();
    });
    let mut replies: Vec<Reply> = (0..prompts.len())
        .map(|_| rx.recv_timeout(Duration::from_secs(120)).expect("reply within 120s"))
        .collect();
    handle.join().expect("engine loop must exit cleanly");
    replies.sort_by_key(|r| r.id);
    (replies, metrics)
}

/// The leak canaries: all KV and quota occupancy gauges must read zero
/// once the loop has drained.
fn assert_no_leaks(metrics: &Metrics, label: &str) {
    for gauge in
        ["kv_used_blocks", "kv_arena_blocks_used", "kv_spill_blocks", "quota_tokens_in_flight"]
    {
        let v = metrics.gauge(gauge).unwrap_or_else(|| panic!("{label}: gauge {gauge} missing"));
        assert_eq!(v, 0.0, "{label}: {gauge} = {v} after drain (leak)");
    }
}

fn write_summary(name: &str, summary: Json) {
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{name}.json");
    if std::fs::write(&path, summary.to_string()).is_ok() {
        println!("wrote {path}");
    }
}

/// Permanent, id-targeted faults: the touched set is exact, so every
/// untouched request must be token-identical to the fault-free run.
#[test]
fn fault_untouched_requests_are_token_identical() {
    let n = 20;
    let ps = prompts(n);
    // Generous pool + uniform priority: no organic preemption or
    // exhaustion, so the only cross-run difference is the plan itself.
    let cfg = || LoopConfig {
        max_active: 3,
        prefill_chunk_tokens: 8,
        kv_pool_slots: 4096,
        kv_block_slots: 16,
        paged_kv: true,
        tenants: 2,
        quota_tokens: 1 << 16,
        ..LoopConfig::default()
    };
    let plan = Arc::new(
        FaultPlan::parse("seed=5;backend:ids=2+9;alloc:ids=4;disconnect:ids=7;delay:every=6,ms=2")
            .expect("plan"),
    );
    let (clean, _) = run_burst(&ps, cfg(), &[Priority::Normal], 2);
    let mut faulted_cfg = cfg();
    faulted_cfg.faults = Some(Arc::clone(&plan));
    let (faulted, metrics) = run_burst(&ps, faulted_cfg, &[Priority::Normal], 2);

    let mut touched = 0usize;
    for (c, f) in clean.iter().zip(&faulted) {
        assert_eq!(c.id, f.id);
        if plan.touches(c.id, MAX_ATTEMPTS) {
            touched += 1;
            continue;
        }
        assert_eq!(
            c.text, f.text,
            "request {} is untouched by the plan but its tokens changed",
            c.id
        );
        assert_eq!(c.finish_reason, f.finish_reason, "request {}", c.id);
        assert!(f.error.is_none(), "untouched request {} errored: {:?}", c.id, f.error);
    }
    assert!(touched >= 3, "the plan should touch several requests, got {touched}");
    // Targeted requests fail the way their site dictates.
    for id in [2u64, 9, 4] {
        let r = &faulted[id as usize];
        assert_eq!(r.finish_reason, FinishReason::Error, "request {id}");
        let msg = r.error.as_deref().expect("injected faults carry an error");
        assert!(msg.contains("injected"), "request {id}: {msg}");
    }
    assert_eq!(faulted[7].finish_reason, FinishReason::Cancelled, "injected disconnect");
    assert!(faulted[7].error.is_none(), "cancellation is terminal, not an error");
    assert_no_leaks(&metrics, "determinism soak");

    write_summary(
        "chaos_soak_determinism",
        Json::from_pairs(vec![
            ("plan", Json::Str(plan.source().to_string())),
            ("requests", Json::Num(n as f64)),
            ("touched", Json::Num(touched as f64)),
            ("engine_errors_total", Json::Num(metrics.counter("engine_errors_total") as f64)),
            ("cancellations_total", Json::Num(metrics.counter("cancellations_total") as f64)),
            ("leaked_blocks", Json::Num(0.0)),
        ]),
    );
}

/// Tight pool + mixed priorities + transient rate faults: preemption,
/// spill/restore I/O errors, retry backoff, and cold recompute all fire
/// under pressure, and nothing leaks or deadlocks.
#[test]
fn pressure_soak_with_transient_faults_leaks_nothing() {
    let n = 24;
    let ps = prompts(n);
    let plan = Arc::new(
        FaultPlan::parse(
            "seed=13;restore:rate=0.7;spill:rate=0.15;backend:rate=0.02;delay:rate=0.1,ms=1",
        )
        .expect("plan"),
    );
    let cfg = LoopConfig {
        max_active: 3,
        kv_pool_slots: 8 * 16,
        kv_block_slots: 16,
        paged_kv: true,
        preemption: true,
        tenants: 3,
        quota_tokens: 512,
        faults: Some(Arc::clone(&plan)),
        restore_retries: 2,
        restore_retry_base_ms: 1,
        ..LoopConfig::default()
    };
    let priorities = [Priority::High, Priority::Normal, Priority::Low];
    let (replies, metrics) = run_burst(&ps, cfg, &priorities, 3);

    assert_eq!(replies.len(), n, "every request must get exactly one reply");
    for r in &replies {
        // Errors are allowed (they are injected); silent losses and
        // panics are not — an error reply must say why.
        if r.finish_reason == FinishReason::Error {
            assert!(r.error.is_some(), "request {}: error reply without message", r.id);
        }
    }
    assert_no_leaks(&metrics, "pressure soak");

    write_summary(
        "chaos_soak_pressure",
        Json::from_pairs(vec![
            ("plan", Json::Str(plan.source().to_string())),
            ("requests", Json::Num(n as f64)),
            (
                "errors",
                Json::Num(
                    replies.iter().filter(|r| r.finish_reason == FinishReason::Error).count()
                        as f64,
                ),
            ),
            ("preemptions_total", Json::Num(metrics.counter("preemptions_total") as f64)),
            ("restore_retries_total", Json::Num(metrics.counter("restore_retries_total") as f64)),
            (
                "restore_cold_recomputes_total",
                Json::Num(metrics.counter("restore_cold_recomputes_total") as f64),
            ),
            ("engine_errors_total", Json::Num(metrics.counter("engine_errors_total") as f64)),
            ("leaked_blocks", Json::Num(0.0)),
        ]),
    );
}

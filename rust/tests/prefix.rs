//! Cross-request prefix cache: correctness and serving integration.
//!
//! The core contract is **bit-identical equivalence**: for every
//! `Method::parse`-able policy, a warm prefix-hit prefill (a `ChunkState`
//! resumed from radix-tree blocks) must produce exactly the score
//! bundles, selection, logits and compacted caches of a cold monolithic
//! prefill of the same prompt. Only pre-eviction prefill state is ever
//! cached, so this holds for any per-request eviction budget.
//!
//! Also covered here: the engine loop serving identical generations with
//! the prefix cache on/off (with hit/miss accounting), the once-per-run
//! monolithic fallback for backends without chunked-prefill support, and
//! the `/metrics` HTTP round-trip for `CacheStats` + prefix counters.

use std::sync::mpsc::channel;
use std::sync::Arc;

use lookaheadkv::engine::{Engine, EngineConfig, PrefillOutput, PrefixPlan};
use lookaheadkv::eviction::{EvictionConfig, Method, ScoreBundle};
use lookaheadkv::kvcache::{CacheManager, SeqCache};
use lookaheadkv::metrics::{lint_exposition, Metrics};
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::runtime::{
    Backend, DecodeOut, DecodeSeq, GraphStats, Manifest, ReferenceBackend, Runtime, Value,
};
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Reply, Request, RequestQueue};
use lookaheadkv::server::{serve_listener, ServerConfig};
use lookaheadkv::util::json;

const ALL_METHODS: &[&str] = &[
    "full", "random", "streaming", "snapkv", "pyramidkv", "h2o", "tova", "laq", "speckv",
    "lookaheadkv", "lkv+suffix",
];

const BLOCK: usize = 16;

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny")).expect("engine")
}

fn assert_bundles_identical(a: &ScoreBundle, b: &ScoreBundle, tag: &str) {
    assert_eq!(a.len, b.len, "{tag}: bundle len");
    assert_eq!(a.win_start, b.win_start, "{tag}: win_start");
    assert_eq!(a.win_rows, b.win_rows, "{tag}: win_rows");
    assert_eq!(a.w_use_override, b.w_use_override, "{tag}: w_use_override");
    let pairs = [
        ("window_scores", &a.window_scores, &b.window_scores),
        ("h2o_scores", &a.h2o_scores, &b.h2o_scores),
        ("lkv_scores", &a.lkv_scores, &b.lkv_scores),
    ];
    for (name, ta, tb) in pairs {
        match (ta, tb) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.shape, y.shape, "{tag}: {name} shape");
                assert_eq!(x.data, y.data, "{tag}: {name} not bit-identical");
            }
            _ => panic!("{tag}: {name} presence differs (cold vs warm)"),
        }
    }
}

/// Run one chunked prefill through the prefix cache: lookup, (maybe)
/// resume, record, insert, release. Returns the output plus how many
/// prompt tokens were served from the tree.
fn prefill_with_cache(
    engine: &Engine,
    mgr: &mut CacheManager,
    prompt: &[i32],
    method: &Method,
    chunk: usize,
) -> (PrefillOutput, usize) {
    let info = engine.prefix_pass_info(prompt.len(), method).expect("pass info");
    let mat = mgr
        .prefix_lookup(&info.model, prompt, info.need_scores, info.resume_cap)
        .expect("prefix cache enabled");
    let resume_len = mat.resume_len;
    let pin = mat.pin;
    let plan = Some(PrefixPlan { block_size: BLOCK, seed: mat.seed });
    let mut job = engine
        .chunked_prefill_begin_with_prefix(prompt, method, chunk, plan)
        .expect("begin prefill");
    if resume_len > 0 {
        // the resumed job's first pass really does skip the cached rows
        assert_eq!(job.remaining(), prompt.len() - resume_len, "resume point");
    }
    let mut steps = 0;
    while !job.step(engine).expect("prefill step") {
        steps += 1;
        assert!(steps < 10_000, "chunked prefill does not terminate");
    }
    let records = job.take_prefix_records();
    let out = job.into_output().expect("prefill output");
    if let Some(recs) = records {
        mgr.prefix_insert(&recs.model, prompt, recs.records);
    }
    mgr.prefix_release(pin);
    (out, resume_len)
}

fn assert_equivalent(engine: &Engine, prompt: &[i32], method: &Method, mono: &PrefillOutput, warm: &PrefillOutput, tag: &str) {
    assert_eq!(warm.bucket, mono.bucket, "{tag}: bucket");
    assert_eq!(warm.logits, mono.logits, "{tag}: first-token logits not bit-identical");
    assert_bundles_identical(&mono.bundle, &warm.bundle, tag);
    let evcfg = EvictionConfig::new(24);
    let n_layers = engine.n_layers("lkv-tiny");
    let sel_m = method.select(&evcfg, n_layers, &mono.bundle);
    let sel_w = method.select(&evcfg, n_layers, &warm.bundle);
    assert_eq!(sel_m, sel_w, "{tag}: kept-slot selection");
    let cap = engine
        .rt
        .manifest()
        .decode_cap("lkv-tiny", sel_m.max_kept() + 4)
        .expect("decode cap");
    let cm = SeqCache::from_selection(&mono.k, &mono.v, &sel_m.per_layer, prompt.len(), cap);
    let cw = SeqCache::from_selection(&warm.k, &warm.v, &sel_w.per_layer, prompt.len(), cap);
    assert_eq!(cm.k.data, cw.k.data, "{tag}: compacted K cache");
    assert_eq!(cm.v.data, cw.v.data, "{tag}: compacted V cache");
    assert_eq!(cm.lens, cw.lens, "{tag}: cache lens");
}

/// Acceptance: for every parseable policy, a warm prefix-hit prefill is
/// bit-identical to a cold monolithic prefill. One tree is shared across
/// all methods, so base passes reuse (and upgrade) blocks recorded by
/// lookahead passes and vice versa.
#[test]
fn warm_prefix_hit_matches_cold_for_every_policy() {
    let engine = engine();
    assert!(engine.rt.supports_chunked_prefill());
    let prompt = encode(
        "lorem;ipsum;K7F=Q2Z;amet;tempor;labore;magna;aliqua;erat;sed;K7F=",
        true,
        false,
    );
    assert!(prompt.len() > 2 * BLOCK + 32, "prompt long enough to resume");
    let mut mgr = CacheManager::new(1 << 16, BLOCK);
    mgr.enable_prefix_cache(0);
    for name in ALL_METHODS {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let mono = engine.prefill_for_method(&prompt, &method).expect("monolithic prefill");
        // First pass may or may not hit (depending on what earlier
        // methods recorded) — must be identical either way.
        let (out1, _) = prefill_with_cache(&engine, &mut mgr, &prompt, &method, 7);
        assert_equivalent(&engine, &prompt, &method, &mono, &out1, &format!("{name} pass1"));
        // Second pass must actually resume from the tree.
        let (out2, resumed) = prefill_with_cache(&engine, &mut mgr, &prompt, &method, 16);
        assert!(resumed > 0, "{name}: warm pass must resume from the prefix cache");
        assert_eq!(resumed % BLOCK, 0, "{name}: resume point is block-aligned");
        assert_equivalent(&engine, &prompt, &method, &mono, &out2, &format!("{name} warm"));
    }
    let stats = mgr.prefix_stats().expect("stats");
    assert!(stats.blocks > 0);
    assert_eq!(stats.pinned_nodes, 0, "all pins released");
}

/// Divergent prompts: a warm resume of a prompt sharing only a prefix
/// with the cached one stays bit-identical to its own cold prefill.
#[test]
fn warm_resume_of_diverged_prompt_matches_cold() {
    let engine = engine();
    let shared = "system;tools;ruler;eval;policy;lorem;ipsum;dolor;sit;amet;consectetur;";
    let p1 = encode(&format!("{shared}A7K=Q2Z;find;A7K="), true, false);
    let p2 = encode(&format!("{shared}B3X=W9Y;scan;B3X="), true, false);
    let method = Method::SnapKV;
    let mut mgr = CacheManager::new(1 << 16, BLOCK);
    mgr.enable_prefix_cache(0);
    let (_, r0) = prefill_with_cache(&engine, &mut mgr, &p1, &method, 11);
    assert_eq!(r0, 0, "cold tree");
    let mono2 = engine.prefill_for_method(&p2, &method).expect("monolithic");
    let (warm2, resumed) = prefill_with_cache(&engine, &mut mgr, &p2, &method, 11);
    assert!(resumed > 0, "shared prefix must resume");
    assert!(resumed <= shared.len() + 1, "resume cannot extend past the shared prefix");
    assert_equivalent(&engine, &p2, &method, &mono2, &warm2, "diverged warm");
    // and p1 itself still round-trips exactly
    let mono1 = engine.prefill_for_method(&p1, &method).expect("monolithic");
    let (warm1, r1) = prefill_with_cache(&engine, &mut mgr, &p1, &method, 32);
    assert!(r1 >= resumed);
    assert_equivalent(&engine, &p1, &method, &mono1, &warm1, "original warm");
}

fn run_loop(prompts: &[String], prefix_cache: bool) -> (Vec<Reply>, Arc<Metrics>) {
    let engine = engine();
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let mut receivers = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = channel();
        receivers.push(rx);
        let method = if i % 2 == 0 { Method::SnapKV } else { Method::parse("lkv").unwrap() };
        queue
            .submit(Request {
                id: i as u64,
                prompt: encode(p, true, false),
                method,
                budget: 16,
                max_new: 5,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: 0,
                priority: Priority::Normal,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
    }
    queue.close();
    let cfg = LoopConfig {
        max_active: 2,
        prefill_chunk_tokens: 16,
        kv_block_slots: BLOCK,
        prefix_cache,
        ..LoopConfig::default()
    };
    EngineLoop::new(engine, cfg, Arc::clone(&queue), Arc::clone(&metrics)).run();
    let mut replies: Vec<_> = receivers.into_iter().map(|rx| rx.recv().expect("reply")).collect();
    replies.sort_by_key(|r| r.id);
    (replies, metrics)
}

/// End to end through the engine loop: identical generations with the
/// prefix cache on and off, and the warm run actually hits.
#[test]
fn engine_loop_with_prefix_cache_serves_identical_generations() {
    let shared = "system;tools;ruler;eval;policy;lorem;ipsum;dolor;sit;amet;consectetur;elit;";
    let prompts: Vec<String> = [
        format!("{shared}A7K=Q2Z;find;A7K="),
        format!("{shared}A7K=Q2Z;find;A7K="), // exact repeat -> full hit
        format!("{shared}B3X=W9Y;scan;B3X="), // shared prefix -> partial hit
        format!("{shared}C5M=R4T;list;C5M="),
    ]
    .to_vec();
    let (off, off_metrics) = run_loop(&prompts, false);
    let (on, on_metrics) = run_loop(&prompts, true);
    assert_eq!(off.len(), on.len());
    for (a, b) in off.iter().zip(on.iter()) {
        assert!(a.error.is_none(), "prefix-off loop error: {:?}", a.error);
        assert!(b.error.is_none(), "prefix-on loop error: {:?}", b.error);
        assert_eq!(a.text, b.text, "req {}: generation differs", a.id);
        assert_eq!(a.n_tokens, b.n_tokens, "req {}: token count differs", a.id);
        assert_eq!(a.kept, b.kept, "req {}: kept slots differ", a.id);
    }
    assert_eq!(off_metrics.counter("prefix_hits"), 0);
    assert_eq!(off_metrics.counter("prefix_misses"), 0);
    let hits = on_metrics.counter("prefix_hits") + on_metrics.counter("prefix_partial_hits");
    assert!(hits >= 2, "warm requests must hit the tree (got {hits})");
    assert!(on_metrics.counter("prefix_misses") >= 1, "first request is a miss");
    assert!(on_metrics.counter("prefix_inserted_blocks") >= 1);
    assert!(on_metrics.gauge("prefix_blocks").unwrap_or(0.0) > 0.0);
    assert_eq!(on_metrics.gauge("prefix_pinned_nodes"), Some(0.0), "pins drain");
}

/// A reference backend with chunked prefill disabled: stands in for the
/// pjrt stub path, which advertises `supports_chunked_prefill = false`.
struct NoChunkBackend(ReferenceBackend);

impl Backend for NoChunkBackend {
    fn name(&self) -> &'static str {
        "reference-nochunk"
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn execute(
        &self,
        key: &str,
        variant: Option<(&str, &str)>,
        inputs: &[Value],
    ) -> anyhow::Result<Vec<Value>> {
        self.0.execute(key, variant, inputs)
    }
    fn decode_batch(
        &self,
        model: &str,
        seqs: &mut [DecodeSeq<'_>],
    ) -> anyhow::Result<Vec<DecodeOut>> {
        self.0.decode_batch(model, seqs)
    }
    fn stats(&self) -> Vec<(String, GraphStats)> {
        self.0.stats()
    }
    fn reset_stats(&self) {
        self.0.reset_stats()
    }
}

/// Satellite: a backend without chunked-prefill support (the pjrt stub)
/// must fall back to monolithic prefill — logged once per run, not
/// silent — and still produce identical output for the same requests.
#[test]
fn monolithic_fallback_without_chunked_support_is_identical() {
    let prompts: Vec<String> = vec![
        "A7K=Q2Z;lorem;ipsum;dolor;sit;amet;consectetur;A7K=".into(),
        "B3X=W9Y;tempor;incididunt;ut;labore;et;dolore;B3X=".into(),
    ];
    let run = |nochunk: bool| {
        let engine = if nochunk {
            let be = ReferenceBackend::new(&default_artifacts_dir()).expect("backend");
            Engine {
                rt: Runtime::with_backend(Box::new(NoChunkBackend(be))),
                cfg: EngineConfig::new("lkv-tiny"),
            }
        } else {
            engine()
        };
        assert_eq!(engine.rt.supports_chunked_prefill(), !nochunk);
        let queue = Arc::new(RequestQueue::new(8));
        let metrics = Arc::new(Metrics::new());
        let mut receivers = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let (tx, rx) = channel();
            receivers.push(rx);
            queue
                .submit(Request {
                    id: i as u64,
                    prompt: encode(p, true, false),
                    method: Method::SnapKV,
                    budget: 16,
                    max_new: 4,
                    temperature: 0.0,
                    knobs: Default::default(),
                    tenant: 0,
                    priority: Priority::Normal,
                    submitted_at: std::time::Instant::now(),
                    deadline_ms: 0,
                    cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                    reply: tx,
                })
                .expect("submit");
        }
        queue.close();
        // chunking (and the prefix cache) requested in both runs; the
        // nochunk backend must degrade to monolithic, not fail
        let cfg = LoopConfig {
            max_active: 2,
            prefill_chunk_tokens: 8,
            prefix_cache: true,
            kv_block_slots: BLOCK,
            ..LoopConfig::default()
        };
        EngineLoop::new(engine, cfg, Arc::clone(&queue), Arc::clone(&metrics)).run();
        let mut replies: Vec<_> =
            receivers.into_iter().map(|rx| rx.recv().expect("reply")).collect();
        replies.sort_by_key(|r| r.id);
        (replies, metrics)
    };
    let (chunked, chunked_metrics) = run(false);
    let (fallback, fallback_metrics) = run(true);
    for (a, b) in chunked.iter().zip(fallback.iter()) {
        assert!(a.error.is_none() && b.error.is_none(), "{:?} / {:?}", a.error, b.error);
        assert_eq!(a.text, b.text, "req {}: fallback output differs", a.id);
        assert_eq!(a.kept, b.kept, "req {}: fallback kept differs", a.id);
    }
    assert_eq!(chunked_metrics.counter("chunked_prefills"), prompts.len() as u64);
    assert_eq!(fallback_metrics.counter("chunked_prefills"), 0, "fallback is monolithic");
    assert_eq!(fallback_metrics.counter("prefills"), prompts.len() as u64);
    // prefix cache never engages without chunked prefill
    assert_eq!(fallback_metrics.counter("prefix_hits"), 0);
    assert_eq!(fallback_metrics.counter("prefix_misses"), 0);
}

/// Satellite: `GET /metrics` exposes the KV `CacheStats` gauges and the
/// prefix-cache hit/miss/reclaim counters over real HTTP.
#[test]
fn metrics_http_roundtrip_exposes_cache_stats() {
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let q2 = Arc::clone(&queue);
    let m2 = Arc::clone(&metrics);
    let engine_thread = std::thread::Builder::new()
        .name("engine-test".into())
        .spawn(move || {
            let cfg = LoopConfig {
                max_active: 2,
                prefill_chunk_tokens: 32,
                kv_block_slots: BLOCK,
                prefix_cache: true,
                ..LoopConfig::default()
            };
            EngineLoop::new(engine(), cfg, q2, m2).run()
        })
        .expect("spawn engine");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let q3 = Arc::clone(&queue);
    let m3 = Arc::clone(&metrics);
    std::thread::Builder::new()
        .name("http-test".into())
        .spawn(move || {
            let cfg = ServerConfig { workers: 2, ..ServerConfig::default() };
            let _ = serve_listener(listener, cfg, q3, m3, None);
        })
        .expect("spawn server");

    let shared = "system;tools;ruler;eval;policy;lorem;ipsum;dolor;sit;amet;consectetur;\
                  adipiscing;elit;sed;do;eiusmod;tempor;";
    let body = format!(
        "{{\"prompt\": \"{shared}K7F=Q2Z;find;K7F=\", \"method\": \"snapkv\", \
         \"budget\": 16, \"max_new\": 3}}"
    );
    for i in 0..2 {
        let (status, resp) =
            lookaheadkv::server::http::http_post(&addr, "/generate", &body).expect("post");
        assert_eq!(status, 200, "request {i}: {resp}");
        // finish_reason is part of the public response contract
        let r = json::parse(&resp).expect("generate json");
        let reason = r.req("finish_reason").as_str().expect("finish_reason").to_string();
        assert!(
            ["eos", "length", "kv_exhausted"].contains(&reason.as_str()),
            "request {i}: unexpected finish_reason {reason:?}"
        );
    }
    let (status, resp) = lookaheadkv::server::http::http_get(&addr, "/metrics").expect("get");
    assert_eq!(status, 200);
    let j = json::parse(&resp).expect("metrics json");
    let counters = j.req("counters");
    assert_eq!(counters.req("prefills").as_usize(), Some(2));
    assert_eq!(counters.req("prefix_misses").as_usize(), Some(1));
    assert_eq!(counters.req("prefix_hits").as_usize(), Some(1), "repeat must be a full hit");
    assert!(counters.req("prefix_inserted_blocks").as_usize().unwrap_or(0) >= 1);
    let gauges = j.req("gauges");
    assert!(gauges.req("kv_free_blocks").as_f64().is_some());
    assert!(gauges.req("kv_active_seqs").as_f64().is_some());
    assert!(gauges.req("prefix_blocks").as_f64().unwrap_or(0.0) > 0.0);
    // arena occupancy: bytes + per-owner block breakdown; with requests
    // drained, only the prefix tree still holds resident KV
    assert!(gauges.req("kv_arena_bytes").as_f64().unwrap_or(0.0) > 0.0);
    // dtype-aware occupancy: resident (stored representation) vs logical
    // (f32-equivalent) gauges; at the default f32 dtype the two agree
    let resident = gauges.req("kv_arena_bytes_resident").as_f64().expect("resident gauge");
    let logical = gauges.req("kv_arena_bytes_logical").as_f64().expect("logical gauge");
    assert!(resident > 0.0, "prefix tree must hold resident KV bytes");
    assert_eq!(resident, logical, "f32 arena: resident bytes must equal logical bytes");
    // the arena storage dtype is exported as an info-style gauge
    let info = j.req("info").req("kv_cache_info");
    assert_eq!(info.req("kv_dtype").as_str(), Some("f32"));
    assert!(
        gauges.req("kv_arena_blocks_prefix").as_f64().unwrap_or(0.0) > 0.0,
        "tree blocks must show up in the per-owner breakdown"
    );
    assert!(gauges.req("kv_arena_blocks_decode").as_f64().is_some());
    assert!(gauges.req("kv_arena_blocks_prefill").as_f64().is_some());
    // backend kernel gauges: streaming-suite worker budget and the peak
    // per-call scratch estimate (requests ran, so both must be live)
    assert!(
        gauges.req("prefill_threads_used").as_f64().unwrap_or(0.0) >= 1.0,
        "prefill_threads_used gauge missing or zero"
    );
    assert!(
        gauges.req("prefill_scratch_peak_bytes").as_f64().unwrap_or(0.0) > 0.0,
        "prefill_scratch_peak_bytes gauge missing or zero"
    );
    assert!(j.req("latency").get("ttft_ms").is_some());

    queue.close();
    engine_thread.join().expect("engine thread");
}

/// Satellite: `GET /metrics?format=prometheus` serves a lint-clean text
/// exposition over real HTTP that agrees with the JSON endpoint scraped
/// in the same idle window — counter values and histogram counts match,
/// and `# TYPE` lines are present for both kinds.
#[test]
fn prometheus_exposition_http_roundtrip_agrees_with_json() {
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let q2 = Arc::clone(&queue);
    let m2 = Arc::clone(&metrics);
    let engine_thread = std::thread::Builder::new()
        .name("engine-test".into())
        .spawn(move || {
            let cfg = LoopConfig { max_active: 2, ..LoopConfig::default() };
            EngineLoop::new(engine(), cfg, q2, m2).run()
        })
        .expect("spawn engine");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let q3 = Arc::clone(&queue);
    let m3 = Arc::clone(&metrics);
    std::thread::Builder::new()
        .name("http-test".into())
        .spawn(move || {
            let cfg = ServerConfig { workers: 2, ..ServerConfig::default() };
            let _ = serve_listener(listener, cfg, q3, m3, None);
        })
        .expect("spawn server");

    let body = "{\"prompt\": \"A7K=Q2Z;lorem;ipsum;dolor;A7K=\", \
                \"method\": \"snapkv\", \"budget\": 16, \"max_new\": 3}";
    for _ in 0..2 {
        let (status, resp) =
            lookaheadkv::server::http::http_post(&addr, "/generate", body).expect("post");
        assert_eq!(status, 200, "{resp}");
    }

    // Both replies are in hand and nothing else is queued, so the
    // back-to-back scrapes below see the same registry state.
    let (status, json_body) =
        lookaheadkv::server::http::http_get(&addr, "/metrics").expect("get json");
    assert_eq!(status, 200);
    let (status, prom) = lookaheadkv::server::http::http_get(&addr, "/metrics?format=prometheus")
        .expect("get prometheus");
    assert_eq!(status, 200);
    lint_exposition(&prom).unwrap_or_else(|e| panic!("exposition lint: {e}\n{prom}"));

    // `name value` sample lookup (skips `name_bucket{...}` etc. by
    // requiring a space right after the metric name).
    let sample = |name: &str| -> Option<f64> {
        prom.lines()
            .find(|l| {
                !l.starts_with('#')
                    && l.starts_with(name)
                    && l[name.len()..].starts_with(' ')
            })
            .and_then(|l| l[name.len()..].trim().parse().ok())
    };
    let j = json::parse(&json_body).expect("metrics json");
    let prefills = j.req("counters").req("prefills").as_usize().expect("prefills counter");
    assert!(prefills >= 2);
    assert_eq!(
        sample("prefills"),
        Some(prefills as f64),
        "counter out of sync between JSON and Prometheus:\n{prom}"
    );
    let ttft_n = j.req("latency").req("ttft_ms").req("count").as_usize().expect("ttft count");
    assert_eq!(
        sample("ttft_ms_count"),
        Some(ttft_n as f64),
        "histogram count out of sync between JSON and Prometheus"
    );
    assert!(prom.contains("# TYPE prefills counter"), "missing counter TYPE line:\n{prom}");
    assert!(prom.contains("# TYPE ttft_ms histogram"), "missing histogram TYPE line");
    // the KV storage dtype rides along as a labeled constant-1 info
    // sample and survives the exposition lint above
    assert!(
        prom.contains("kv_cache_info{kv_dtype=\"f32\"} 1"),
        "kv_dtype info sample missing:\n{prom}"
    );

    queue.close();
    engine_thread.join().expect("engine thread");
}

/// Satellite: the structured policy API over real HTTP — `GET /policies`
/// introspection, inline `policy` objects on `/generate` (valid and the
/// 4xx rejection paths), and the legacy `method` string still serving
/// through the same `PolicySpec` construction path.
#[test]
fn policy_api_http_roundtrip() {
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let q2 = Arc::clone(&queue);
    let m2 = Arc::clone(&metrics);
    let engine_thread = std::thread::Builder::new()
        .name("engine-test".into())
        .spawn(move || {
            let cfg = LoopConfig { max_active: 2, ..LoopConfig::default() };
            EngineLoop::new(engine(), cfg, q2, m2).run()
        })
        .expect("spawn engine");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let q3 = Arc::clone(&queue);
    let m3 = Arc::clone(&metrics);
    std::thread::Builder::new()
        .name("http-test".into())
        .spawn(move || {
            let cfg = ServerConfig { workers: 2, ..ServerConfig::default() };
            let _ = serve_listener(listener, cfg, q3, m3, None);
        })
        .expect("spawn server");

    // The predictor-loaded flag is published by the engine loop at
    // startup; wait for it so the assertions below don't race the spawn.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        let (status, resp) = lookaheadkv::server::http::http_get(&addr, "/metrics").expect("get");
        assert_eq!(status, 200);
        let j = json::parse(&resp).expect("metrics json");
        if j.req("gauges").get("policy_predictor_loaded").is_some() {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "predictor gauge never published");
        std::thread::sleep(std::time::Duration::from_millis(20));
    }

    // GET /policies: every family listed, predictor marked available
    // (lkv-tiny ships synthesized predictor weights).
    let (status, resp) = lookaheadkv::server::http::http_get(&addr, "/policies").expect("get");
    assert_eq!(status, 200, "{resp}");
    let j = json::parse(&resp).expect("policies json");
    assert_eq!(j.req("predictor_loaded").as_bool(), Some(true));
    let fams = j.req("families").as_arr().expect("families");
    for expect in ["full", "snapkv", "h2o", "lookaheadkv", "predictor"] {
        assert!(
            fams.iter().any(|f| f.req("family").as_str() == Some(expect)),
            "family {expect} missing from /policies"
        );
    }
    let pred = fams
        .iter()
        .find(|f| f.req("family").as_str() == Some("predictor"))
        .expect("predictor family");
    assert_eq!(pred.req("available").as_bool(), Some(true));
    assert!(j.req("defaults").req("window").as_usize().is_some());
    assert!(j.req("defaults").req("kernel").as_usize().is_some());

    let post = |body: &str| {
        lookaheadkv::server::http::http_post(&addr, "/generate", body).expect("post")
    };
    let prompt = "A7K=Q2Z;lorem;ipsum;dolor;A7K=";

    // Inline structured policy: overrides budget + knobs, serves 200.
    let (status, resp) = post(&format!(
        "{{\"prompt\": \"{prompt}\", \"max_new\": 3, \
         \"policy\": {{\"family\": \"snapkv\", \"budget\": 16, \"window\": 4}}}}"
    ));
    assert_eq!(status, 200, "inline policy: {resp}");
    assert!(json::parse(&resp).expect("json").get("text").is_some());

    // Predictor family end-to-end over HTTP (weights are loaded).
    let (status, resp) = post(&format!(
        "{{\"prompt\": \"{prompt}\", \"max_new\": 3, \
         \"policy\": {{\"family\": \"predictor\", \"budget\": 16}}}}"
    ));
    assert_eq!(status, 200, "predictor policy: {resp}");

    // Legacy method string routes through the same PolicySpec path.
    let (status, resp) =
        post(&format!("{{\"prompt\": \"{prompt}\", \"method\": \"h2o\", \"max_new\": 3}}"));
    assert_eq!(status, 200, "legacy method: {resp}");

    // Rejection paths: each is a 400 with a structured "error" body.
    for bad in [
        // unknown family
        format!("{{\"prompt\": \"{prompt}\", \"policy\": {{\"family\": \"zoomkv\"}}}}"),
        // unknown field (typo'd knob)
        format!(
            "{{\"prompt\": \"{prompt}\", \
             \"policy\": {{\"family\": \"snapkv\", \"kernal\": 5}}}}"
        ),
        // invalid knob value (pooling kernel must be odd)
        format!(
            "{{\"prompt\": \"{prompt}\", \
             \"policy\": {{\"family\": \"snapkv\", \"kernel\": 4}}}}"
        ),
        // variant on a family that takes none
        format!(
            "{{\"prompt\": \"{prompt}\", \
             \"policy\": {{\"family\": \"h2o\", \"variant\": \"main\"}}}}"
        ),
        // unknown legacy method string
        format!("{{\"prompt\": \"{prompt}\", \"method\": \"zoomkv\"}}"),
    ] {
        let (status, resp) = post(&bad);
        assert_eq!(status, 400, "{bad} should be rejected: {resp}");
        let err = json::parse(&resp).expect("error json");
        assert!(
            err.req("error").as_str().map(|s| !s.is_empty()).unwrap_or(false),
            "rejection must carry an error body: {resp}"
        );
    }

    queue.close();
    engine_thread.join().expect("engine thread");
}

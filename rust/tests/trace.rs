//! Request-lifecycle tracing through the serving stack.
//!
//! The tracer's contract is **lifecycle tiling**: every span of a
//! request starts exactly where its previous span ended, so the spans
//! partition the request's wall time. The acceptance test here holds
//! the engine loop to it — for every reply, the recorded non-queue
//! spans must sum to the reply's own `total_ms` within 5% (ISSUE-8
//! acceptance) — and the HTTP test covers `GET /trace/<id>` plus the
//! per-request `stats` / `eviction` fields on `POST /generate`.

use std::sync::mpsc::channel;
use std::sync::Arc;

use lookaheadkv::engine::{Engine, EngineConfig};
use lookaheadkv::eviction::Method;
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Reply, Request, RequestQueue};
use lookaheadkv::server::{serve_listener, ServerConfig};
use lookaheadkv::trace::{Phase, Tracer};
use lookaheadkv::util::json;

const PROMPT: &str =
    "system;tools;ruler;eval;policy;lorem;ipsum;dolor;sit;amet;consectetur;X9Y=Z3W;find;X9Y=";

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny")).expect("engine")
}

/// Drive `n` requests through a traced engine loop; replies sorted by id.
fn run_traced(n: usize, chunk: usize, max_new: usize) -> (Vec<Reply>, Arc<Tracer>) {
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let tracer = Arc::new(Tracer::new());
    let mut receivers = Vec::new();
    for i in 0..n {
        let (tx, rx) = channel();
        receivers.push(rx);
        let method =
            if i % 2 == 0 { Method::SnapKV } else { Method::parse("lookaheadkv").unwrap() };
        queue
            .submit(Request {
                id: i as u64,
                prompt: encode(PROMPT, true, false),
                method,
                budget: 16,
                max_new,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: 0,
                priority: Priority::Normal,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
    }
    queue.close();
    let cfg = LoopConfig { max_active: 2, prefill_chunk_tokens: chunk, ..LoopConfig::default() };
    EngineLoop::new(engine(), cfg, Arc::clone(&queue), metrics)
        .with_tracer(Arc::clone(&tracer))
        .run();
    let mut replies: Vec<Reply> =
        receivers.into_iter().map(|rx| rx.recv().expect("reply")).collect();
    replies.sort_by_key(|r| r.id);
    (replies, tracer)
}

/// Tiling + the 5% acceptance bound for one reply. The sum is compared
/// against `total_ms` with a 0.5 ms absolute floor absorbing per-span
/// microsecond truncation.
fn assert_spans_tile(tracer: &Tracer, reply: &Reply) {
    let spans = tracer.spans_for(reply.id);
    assert!(!spans.is_empty(), "request {}: no spans recorded", reply.id);
    for w in spans.windows(2) {
        assert_eq!(
            w[0].start_us + w[0].dur_us,
            w[1].start_us,
            "request {}: {} -> {} spans do not tile",
            reply.id,
            w[0].phase.as_str(),
            w[1].phase.as_str()
        );
    }
    let sum_ms: f64 = spans
        .iter()
        .filter(|s| s.phase != Phase::Queue)
        .map(|s| s.dur_us as f64 / 1e3)
        .sum();
    assert!(
        (sum_ms - reply.total_ms).abs() <= reply.total_ms * 0.05 + 0.5,
        "request {}: lifecycle spans sum to {sum_ms:.3} ms but the reply \
         reported total_ms {:.3}",
        reply.id,
        reply.total_ms
    );
}

/// Acceptance: monolithic-prefill serving — every request's spans tile
/// its wall time within 5%, cover the expected phases, and agree with
/// the per-request stats threaded onto the reply.
#[test]
fn lifecycle_spans_tile_wall_time_monolithic() {
    let (replies, tracer) = run_traced(4, 0, 6);
    assert_eq!(tracer.dropped(), 0);
    for r in &replies {
        assert!(r.error.is_none(), "req {}: {:?}", r.id, r.error);
        assert_spans_tile(&tracer, r);
        let spans = tracer.spans_for(r.id);
        let count = |p: Phase| spans.iter().filter(|s| s.phase == p).count();
        assert_eq!(count(Phase::Queue), 1, "req {}", r.id);
        assert_eq!(count(Phase::Admission), 1, "req {}", r.id);
        assert_eq!(count(Phase::Eviction), 1, "req {}", r.id);
        assert_eq!(count(Phase::Finish), 1, "req {}", r.id);
        assert_eq!(
            count(Phase::Decode),
            r.stats.decode_iters,
            "req {}: one span per decode iteration",
            r.id
        );
        // Stats ride the same clock as the spans.
        assert!(r.stats.queue_ms >= 0.0);
        assert!(r.stats.ttft_ms <= r.total_ms + 1e-6, "req {}", r.id);
        assert_eq!(r.stats.prefill_chunks, 1, "monolithic prefill is one chunk");
        assert!(!r.stats.evicted_per_layer.is_empty(), "req {}", r.id);
        // An ample dense-cache run never spills.
        assert_eq!(r.stats.spills, 0);
        assert_eq!(r.stats.restores, 0);
        let d = r.eviction.as_ref().expect("eviction decision summary");
        assert_eq!(d.prompt_len, encode(PROMPT, true, false).len());
        assert!(d.kept_total > 0 && d.kept_total <= d.prompt_len * d.kept_per_layer.len());
        assert_eq!(
            r.stats.evicted_per_layer.iter().sum::<usize>(),
            d.evicted_total,
            "req {}: stats and decision summary disagree on evictions",
            r.id
        );
    }
}

/// Acceptance: chunked-prefill serving — one span per prefill chunk
/// (matching `stats.prefill_chunks`), still tiling within 5% even with
/// chunks and decodes interleaving across the two active requests.
#[test]
fn lifecycle_spans_tile_wall_time_chunked() {
    let (replies, tracer) = run_traced(4, 16, 5);
    assert_eq!(tracer.dropped(), 0);
    for r in &replies {
        assert!(r.error.is_none(), "req {}: {:?}", r.id, r.error);
        assert_spans_tile(&tracer, r);
        let spans = tracer.spans_for(r.id);
        let chunks = spans.iter().filter(|s| s.phase == Phase::PrefillChunk).count();
        assert!(chunks >= 2, "req {}: prompt must need several chunks (got {chunks})", r.id);
        assert_eq!(chunks, r.stats.prefill_chunks, "req {}", r.id);
        assert!(r.stats.ttft_ms > 0.0);
    }
}

/// `GET /trace/<id>` over real HTTP, plus the `stats`/`eviction`
/// objects on the `/generate` response itself.
#[test]
fn trace_endpoint_http_roundtrip() {
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let tracer = Arc::new(Tracer::new());
    let q2 = Arc::clone(&queue);
    let m2 = Arc::clone(&metrics);
    let t2 = Arc::clone(&tracer);
    let engine_thread = std::thread::Builder::new()
        .name("engine-test".into())
        .spawn(move || {
            let cfg = LoopConfig { max_active: 2, ..LoopConfig::default() };
            EngineLoop::new(engine(), cfg, q2, m2).with_tracer(t2).run()
        })
        .expect("spawn engine");
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let q3 = Arc::clone(&queue);
    let m3 = Arc::clone(&metrics);
    let t3 = Arc::clone(&tracer);
    std::thread::Builder::new()
        .name("http-test".into())
        .spawn(move || {
            let cfg = ServerConfig { workers: 2, ..ServerConfig::default() };
            let _ = serve_listener(listener, cfg, q3, m3, Some(t3));
        })
        .expect("spawn server");

    let body = format!(
        "{{\"prompt\": \"{PROMPT}\", \"method\": \"snapkv\", \"budget\": 16, \"max_new\": 4}}"
    );
    let (status, resp) =
        lookaheadkv::server::http::http_post(&addr, "/generate", &body).expect("post");
    assert_eq!(status, 200, "{resp}");
    let r = json::parse(&resp).expect("generate json");
    let id = r.req("id").as_usize().expect("id");
    let total_ms = r.req("total_ms").as_f64().expect("total_ms");

    // Per-request stats are part of the response contract.
    let stats = r.req("stats");
    assert!(stats.req("queue_ms").as_f64().is_some());
    assert!(stats.req("ttft_ms").as_f64().unwrap_or(-1.0) >= 0.0);
    assert_eq!(stats.req("prefill_chunks").as_usize(), Some(1));
    assert!(stats.req("decode_iters").as_usize().is_some());
    assert!(!stats.req("evicted_per_layer").usize_arr().is_empty());
    assert!(stats.req("evicted_total").as_usize().is_some());
    assert!(stats.req("peak_arena_blocks").as_usize().is_some());
    assert_eq!(stats.req("spills").as_usize(), Some(0));
    assert_eq!(stats.req("restores").as_usize(), Some(0));
    let ev = r.req("eviction");
    assert_eq!(ev.req("policy").as_str(), Some("SnapKV"));
    assert_eq!(ev.req("budget").as_usize(), Some(16));
    assert!(ev.req("kept_total").as_usize().unwrap_or(0) > 0);
    assert_eq!(ev.req("score_quantiles").as_arr().map(<[json::Json]>::len), Some(5));

    // The reply was sent after the Finish span, so the trace is
    // complete by the time the client can ask for it.
    let (status, resp) =
        lookaheadkv::server::http::http_get(&addr, &format!("/trace/{id}")).expect("get trace");
    assert_eq!(status, 200, "{resp}");
    let t = json::parse(&resp).expect("trace json");
    assert_eq!(t.req("request_id").as_usize(), Some(id));
    let spans = t.req("spans").as_arr().expect("spans");
    assert!(spans.len() >= 4, "expected queue/admission/eviction/decode/finish spans");
    let phases: Vec<&str> = spans.iter().filter_map(|s| s.req("phase").as_str()).collect();
    for expect in ["queue", "admission", "eviction", "decode", "finish"] {
        assert!(phases.contains(&expect), "phase {expect} missing: {phases:?}");
    }
    let sum_us: f64 =
        spans.iter().map(|s| s.req("dur_us").as_f64().expect("dur_us")).sum();
    assert_eq!(t.req("total_us").as_f64(), Some(sum_us));
    // The non-queue spans tile the service time (5% acceptance bound).
    let non_queue_ms: f64 = spans
        .iter()
        .filter(|s| s.req("phase").as_str() != Some("queue"))
        .map(|s| s.req("dur_us").as_f64().unwrap_or(0.0) / 1e3)
        .sum();
    assert!(
        (non_queue_ms - total_ms).abs() <= total_ms * 0.05 + 0.5,
        "trace spans {non_queue_ms:.3} ms vs reported total {total_ms:.3} ms"
    );

    // Unknown id: 404 with an explanatory error; junk id: 400.
    let (status, _) =
        lookaheadkv::server::http::http_get(&addr, "/trace/999999").expect("get unknown");
    assert_eq!(status, 404);
    let (status, _) =
        lookaheadkv::server::http::http_get(&addr, "/trace/abc").expect("get junk");
    assert_eq!(status, 400);

    queue.close();
    engine_thread.join().expect("engine thread");
}

//! Chunked-vs-monolithic prefill equivalence.
//!
//! The chunked prefill contract (`runtime::backend::ChunkState`,
//! `engine::chunked::ChunkedPrefill`) promises **bit-identical** results
//! to the monolithic graphs: same `ScoreBundle` tensors, same kept-slot
//! selection, same first-token logits, and identical compacted decode
//! caches — for every `Method::parse`-able policy and for chunk sizes
//! that do and do not divide the prompt length. These tests enforce that
//! promise on the reference backend, plus an end-to-end check that the
//! mixed-batching engine loop serves identical generations with chunking
//! on and off.

use std::sync::mpsc::channel;
use std::sync::Arc;

use lookaheadkv::engine::{Engine, EngineConfig, PrefillOutput};
use lookaheadkv::eviction::{EvictionConfig, Method, ScoreBundle};
use lookaheadkv::kvcache::SeqCache;
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Request, RequestQueue};
use lookaheadkv::util::proptest;
use lookaheadkv::util::rng::argmax;

const ALL_METHODS: &[&str] = &[
    "full", "random", "streaming", "snapkv", "pyramidkv", "h2o", "tova", "laq", "speckv",
    "lookaheadkv", "lkv+suffix", "predictor",
];

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny")).expect("engine")
}

fn assert_bundles_identical(a: &ScoreBundle, b: &ScoreBundle, tag: &str) {
    assert_eq!(a.len, b.len, "{tag}: bundle len");
    assert_eq!(a.win_start, b.win_start, "{tag}: win_start");
    assert_eq!(a.win_rows, b.win_rows, "{tag}: win_rows");
    assert_eq!(a.w_use_override, b.w_use_override, "{tag}: w_use_override");
    let pairs = [
        ("window_scores", &a.window_scores, &b.window_scores),
        ("h2o_scores", &a.h2o_scores, &b.h2o_scores),
        ("lkv_scores", &a.lkv_scores, &b.lkv_scores),
        ("pred_scores", &a.pred_scores, &b.pred_scores),
    ];
    for (name, ta, tb) in pairs {
        match (ta, tb) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.shape, y.shape, "{tag}: {name} shape");
                // finite probabilities: f32 equality == bit identity here
                assert_eq!(x.data, y.data, "{tag}: {name} not bit-identical");
            }
            _ => panic!("{tag}: {name} presence differs (mono vs chunked)"),
        }
    }
}

fn run_chunked(engine: &Engine, prompt: &[i32], method: &Method, chunk: usize) -> PrefillOutput {
    let mut job = engine.chunked_prefill_begin(prompt, method, chunk).expect("begin");
    let mut steps = 0;
    while !job.step(engine).expect("chunk step") {
        steps += 1;
        assert!(steps < 10_000, "chunked prefill does not terminate");
    }
    job.into_output().expect("output")
}

fn assert_equivalent(
    engine: &Engine,
    prompt: &[i32],
    method: &Method,
    mono: &PrefillOutput,
    chunk: usize,
) {
    let tag = format!("{} len={} chunk={chunk}", method.name(), prompt.len());
    let chunked = run_chunked(engine, prompt, method, chunk);
    assert_eq!(chunked.bucket, mono.bucket, "{tag}: bucket");
    assert_eq!(chunked.logits, mono.logits, "{tag}: first-token logits not bit-identical");
    assert_eq!(argmax(&chunked.logits), argmax(&mono.logits), "{tag}: first decoded token");
    assert_bundles_identical(&mono.bundle, &chunked.bundle, &tag);
    // identical selection, and identical compacted decode caches (dead
    // padding rows may differ between the paths; kept rows must not)
    let evcfg = EvictionConfig::new(24);
    let n_layers = engine.n_layers("lkv-tiny");
    let sel_m = method.select(&evcfg, n_layers, &mono.bundle);
    let sel_c = method.select(&evcfg, n_layers, &chunked.bundle);
    assert_eq!(sel_m, sel_c, "{tag}: kept-slot selection");
    let cap = engine
        .rt
        .manifest()
        .decode_cap("lkv-tiny", sel_m.max_kept() + 4)
        .expect("decode cap");
    let cm = SeqCache::from_selection(&mono.k, &mono.v, &sel_m.per_layer, prompt.len(), cap);
    let cc = SeqCache::from_selection(&chunked.k, &chunked.v, &sel_c.per_layer, prompt.len(), cap);
    assert_eq!(cm.k.data, cc.k.data, "{tag}: compacted K cache");
    assert_eq!(cm.v.data, cc.v.data, "{tag}: compacted V cache");
    assert_eq!(cm.lens, cc.lens, "{tag}: cache lens");
}

/// Every parseable policy, at chunk sizes that do not divide the prompt
/// (7, 16), divide it unevenly, and exceed it (single chunk).
#[test]
fn chunked_prefill_matches_monolithic_for_every_policy() {
    let engine = engine();
    assert!(engine.rt.supports_chunked_prefill(), "reference backend must support chunking");
    let prompt = encode(
        "lorem;ipsum;K7F=Q2Z;amet;tempor;labore;magna;aliqua;erat;sed;K7F=",
        true,
        false,
    );
    for name in ALL_METHODS {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let mono = engine.prefill_for_method(&prompt, &method).expect("monolithic prefill");
        for chunk in [7usize, 16, 1024] {
            assert_equivalent(&engine, &prompt, &method, &mono, chunk);
        }
    }
}

/// Property: random prompt lengths and chunk sizes stay bit-identical
/// for a representative policy mix (score-bundle heavy, lookahead, and
/// draft-based).
#[test]
fn chunked_prefill_equivalence_property() {
    let engine = engine();
    // RefCell caches inside the reference backend are not RefUnwindSafe;
    // the harness only unwinds on assertion failure, never mid-borrow.
    let engine_ref = std::panic::AssertUnwindSafe(&engine);
    let cfg = proptest::Config { cases: 8, max_size: 80, ..proptest::Config::new() };
    proptest::check("chunked prefill == monolithic", &cfg, move |rng, size| {
        let engine: &Engine = engine_ref.0;
        let len = 12 + size.min(80);
        let prompt: Vec<i32> = (0..len).map(|_| (rng.next_u64() % 256) as i32).collect();
        let chunk = 1 + (rng.next_u64() as usize) % (len + 4);
        let methods = ["snapkv", "lookaheadkv", "h2o", "laq"];
        let method = Method::parse(methods[(rng.next_u64() as usize) % methods.len()]).unwrap();
        let mono = engine.prefill_for_method(&prompt, &method).expect("monolithic prefill");
        assert_equivalent(engine, &prompt, &method, &mono, chunk);
    });
}

/// End to end through the engine loop: the same requests produce the
/// same generations with mixed (chunked) batching on and off, and the
/// chunked run records its scheduling metrics.
#[test]
fn engine_loop_chunked_matches_monolithic() {
    let prompts = [
        "A7K=Q2Z;lorem;ipsum;dolor;sit;amet;consectetur;A7K=",
        "B3X=W9Y;tempor;incididunt;ut;labore;et;dolore;B3X=",
        "C5M=R4T;magna;aliqua;ut;enim;ad;minim;veniam;C5M=",
    ];
    let run = |chunk: usize| {
        let engine = engine();
        let queue = Arc::new(RequestQueue::new(16));
        let metrics = Arc::new(Metrics::new());
        let mut receivers = Vec::new();
        for (i, p) in prompts.iter().enumerate() {
            let (tx, rx) = channel();
            receivers.push(rx);
            let method = if i % 2 == 0 { Method::SnapKV } else { Method::parse("lkv").unwrap() };
            queue
                .submit(Request {
                    id: i as u64,
                    prompt: encode(p, true, false),
                    method,
                    budget: 16,
                    max_new: 5,
                    temperature: 0.0,
                    knobs: Default::default(),
                    tenant: 0,
                    priority: Priority::Normal,
                    submitted_at: std::time::Instant::now(),
                    deadline_ms: 0,
                    cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                    reply: tx,
                })
                .expect("submit");
        }
        queue.close();
        let cfg = LoopConfig {
            max_active: 2,
            prefill_chunk_tokens: chunk,
            ..LoopConfig::default()
        };
        EngineLoop::new(engine, cfg, Arc::clone(&queue), Arc::clone(&metrics)).run();
        let mut replies: Vec<_> = receivers
            .into_iter()
            .map(|rx| rx.recv().expect("reply"))
            .collect();
        replies.sort_by_key(|r| r.id);
        (replies, metrics)
    };
    let (mono, mono_metrics) = run(0);
    let (chunked, chunk_metrics) = run(8);
    assert_eq!(mono.len(), chunked.len());
    for (a, b) in mono.iter().zip(chunked.iter()) {
        assert!(a.error.is_none(), "monolithic loop error: {:?}", a.error);
        assert!(b.error.is_none(), "chunked loop error: {:?}", b.error);
        assert_eq!(a.text, b.text, "req {}: generation differs", a.id);
        assert_eq!(a.n_tokens, b.n_tokens, "req {}: token count differs", a.id);
        assert_eq!(a.kept, b.kept, "req {}: kept slots differ", a.id);
    }
    assert_eq!(mono_metrics.counter("chunked_prefills"), 0);
    assert_eq!(chunk_metrics.counter("chunked_prefills"), prompts.len() as u64);
    assert!(
        chunk_metrics.latency_summary("prefill_chunk_ms").map(|s| s.n).unwrap_or(0)
            >= prompts.len(),
        "chunked run must record per-chunk latencies"
    );
}

//! Streaming-vs-naive kernel equivalence (the PR's A/B oracle contract):
//! the default streaming tiled suite must reproduce the frozen naive
//! kernels — logits and score tensors to tight tolerance, and *identical*
//! eviction selections and generated token ids for every
//! `Method::parse`-able policy — across GQA group sizes (lkv-tiny H4/Hkv2,
//! lkv-base H5/Hkv1, lkv-draft H2/Hkv1), shapes that do not divide the
//! register/row tiles, chunked offsets, and LoRA on/off (base vs
//! lookahead prefill). Separately, the streaming suite itself must be
//! **bit-identical** under any thread count or attention tile size, and
//! the naive suite keeps its historical chunked == monolithic guarantee.

use std::path::Path;

use lookaheadkv::engine::{Engine, EngineConfig, GenOptions};
use lookaheadkv::eviction::{EvictionConfig, Method};
use lookaheadkv::kvcache::{CacheManager, KvDtype, PagedSeqCache};
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::{Backend, KernelConfig, ReferenceBackend, Runtime, Value};
use lookaheadkv::util::rng::argmax;

const ALL_METHODS: &[&str] = &[
    "full", "random", "streaming", "snapkv", "pyramidkv", "h2o", "tova", "laq", "speckv",
    "lookaheadkv", "lkv+suffix",
];

fn backend(kcfg: KernelConfig) -> ReferenceBackend {
    // No artifacts on disk -> built-in synthetic manifest.
    ReferenceBackend::with_config(Path::new("/nonexistent-artifacts"), kcfg).expect("backend")
}

fn engine(kcfg: KernelConfig, model: &str) -> Engine {
    Engine { rt: Runtime::with_backend(Box::new(backend(kcfg))), cfg: EngineConfig::new(model) }
}

/// |a - b| within combined absolute + relative tolerance.
fn close(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol + tol * a.abs().max(b.abs())
}

fn assert_close_slice(a: &[f32], b: &[f32], tol: f32, tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    let mut worst = 0.0f32;
    let mut at = 0usize;
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let err = (x - y).abs() / (1.0f32).max(x.abs().max(y.abs()));
        if err > worst {
            worst = err;
            at = i;
        }
    }
    assert!(
        a.iter().zip(b.iter()).all(|(x, y)| close(*x, *y, tol)),
        "{tag}: max rel err {worst:.2e} at {at} ({} vs {})",
        a[at],
        b[at]
    );
}

fn prefill_inputs(tokens: &[i32], bucket: usize, logit_pos: usize) -> Vec<Value> {
    let mut padded = tokens.to_vec();
    padded.resize(bucket, 256); // PAD
    vec![
        Value::vec_i32(padded),
        Value::scalar_i32(tokens.len() as i32),
        Value::scalar_i32(logit_pos as i32),
    ]
}

/// prefill_base equivalence over every synthetic model geometry (GQA
/// group sizes 2, 5 and 2 with Hkv=1) and odd prompt lengths that do not
/// divide the GEMM row/column tiles or the attention column tile.
#[test]
fn streaming_matches_naive_prefill_base_across_geometries() {
    let naive = backend(KernelConfig::naive_oracle());
    let stream = backend(KernelConfig::streaming(3));
    for model in ["lkv-tiny", "lkv-base", "lkv-draft"] {
        for len in [3usize, 37, 101] {
            let tokens: Vec<i32> = (0..len as i32).map(|i| 65 + (i % 26)).collect();
            let key = format!("{model}/prefill_base_s128");
            let inputs = prefill_inputs(&tokens, 128, len - 1);
            let a = naive.execute(&key, None, &inputs).expect("naive prefill");
            let b = stream.execute(&key, None, &inputs).expect("streaming prefill");
            let tag = format!("{model}/len{len}");
            // logits
            assert_close_slice(
                &a[2].as_f32().unwrap().data,
                &b[2].as_f32().unwrap().data,
                1e-3,
                &format!("{tag}: logits"),
            );
            // window + h2o score tensors (identical shapes, tight tolerance)
            for (i, name) in [(3usize, "window"), (4, "h2o")] {
                let (x, y) = (a[i].as_f32().unwrap(), b[i].as_f32().unwrap());
                assert_eq!(x.shape, y.shape, "{tag}: {name} shape");
                assert_close_slice(&x.data, &y.data, 1e-3, &format!("{tag}: {name}"));
            }
            // KV rows < len must agree (rows >= len are dead padding:
            // garbage under naive, zero under streaming)
            let (ka, kb) = (a[0].as_f32().unwrap(), b[0].as_f32().unwrap());
            let (l, hkv, s, dh) = (ka.shape[0], ka.shape[1], ka.shape[2], ka.shape[3]);
            assert_eq!(kb.shape, ka.shape);
            for li in 0..l {
                for g in 0..hkv {
                    let base = ((li * hkv + g) * s) * dh;
                    assert_close_slice(
                        &ka.data[base..base + len * dh],
                        &kb.data[base..base + len * dh],
                        1e-3,
                        &format!("{tag}: K rows<len l{li} g{g}"),
                    );
                }
            }
        }
    }
}

/// prefill_lkv (LoRA live on suffix rows) equivalence.
#[test]
fn streaming_matches_naive_prefill_lkv() {
    let naive = backend(KernelConfig::naive_oracle());
    let stream = backend(KernelConfig::streaming(2));
    for len in [5usize, 61] {
        let tokens: Vec<i32> = (0..len as i32).map(|i| 97 + (i % 13)).collect();
        let mut padded = tokens.clone();
        padded.resize(128, 256);
        let inputs = vec![Value::vec_i32(padded), Value::scalar_i32(len as i32)];
        let key = "lkv-tiny/prefill_lkv_s128_n8_all";
        let a = naive.execute(key, Some(("lkv-tiny", "main")), &inputs).expect("naive lkv");
        let b = stream.execute(key, Some(("lkv-tiny", "main")), &inputs).expect("stream lkv");
        assert_close_slice(
            &a[2].as_f32().unwrap().data,
            &b[2].as_f32().unwrap().data,
            1e-3,
            &format!("lkv len{len}: logits"),
        );
        let (x, y) = (a[3].as_f32().unwrap(), b[3].as_f32().unwrap());
        assert_eq!(x.shape, y.shape);
        assert_close_slice(&x.data, &y.data, 1e-3, &format!("lkv len{len}: scores"));
    }
}

/// End-to-end: identical eviction selections (kept slots per layer) and
/// identical greedily generated token ids for every parseable policy.
#[test]
fn selections_and_token_ids_identical_for_every_policy() {
    let naive = engine(KernelConfig::naive_oracle(), "lkv-tiny");
    let stream = engine(KernelConfig::streaming(3), "lkv-tiny");
    let prompt = encode("A7K=Q2Z;lorem;ipsum;dolor;sit;amet;consectetur;A7K=", true, false);
    for name in ALL_METHODS {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let opts = GenOptions::new(24, 4);
        let a = naive.generate(&prompt, &method, &opts).expect("naive generate");
        let b = stream.generate(&prompt, &method, &opts).expect("streaming generate");
        assert_eq!(a.kept_per_layer, b.kept_per_layer, "{name}: kept slots diverged");
        assert_eq!(a.tokens, b.tokens, "{name}: generated token ids diverged");
        assert_eq!(a.text, b.text, "{name}: text diverged");
    }
}

/// The streaming suite must be **bit-identical** across thread counts
/// and attention tile sizes (including tiles that do not divide the
/// visible column count) — partitioning must never change a float op.
#[test]
fn streaming_is_bit_identical_across_threads_and_tiles() {
    let reference = backend(KernelConfig { naive: false, threads: 1, tile_k: 512 });
    let tokens: Vec<i32> = (0..90).map(|i| 65 + (i % 26)).collect();
    let inputs = prefill_inputs(&tokens, 128, 89);
    let base = reference.execute("lkv-tiny/prefill_base_s128", None, &inputs).unwrap();
    for (threads, tile_k) in [(3usize, 512usize), (2, 7), (5, 33), (1, 1)] {
        let alt = backend(KernelConfig { naive: false, threads, tile_k });
        let out = alt.execute("lkv-tiny/prefill_base_s128", None, &inputs).unwrap();
        for i in 0..base.len() {
            assert_eq!(
                base[i].as_f32().unwrap().data,
                out[i].as_f32().unwrap().data,
                "output {i} not bit-identical at threads={threads} tile_k={tile_k}"
            );
        }
    }
}

/// Chunked prefill under the naive oracle keeps its historical
/// bit-identity with naive monolithic prefill (the streaming-mode
/// counterpart is enforced for every policy by tests/chunked.rs), and
/// chunked offsets agree across suites to tolerance.
#[test]
fn chunked_offsets_agree_within_and_across_suites() {
    let naive = engine(KernelConfig::naive_oracle(), "lkv-tiny");
    let stream = engine(KernelConfig::streaming(2), "lkv-tiny");
    let prompt = encode("pack;my;box;with;five;dozen;liquor;jugs;and;then;some;more", true, false);
    let method = Method::SnapKV;
    let mono_naive = naive.prefill_for_method(&prompt, &method).expect("naive mono");
    for chunk in [7usize, 64] {
        let run = |engine: &Engine| {
            let mut job = engine.chunked_prefill_begin(&prompt, &method, chunk).expect("begin");
            let mut steps = 0;
            while !job.step(engine).expect("step") {
                steps += 1;
                assert!(steps < 10_000, "chunked prefill does not terminate");
            }
            job.into_output().expect("output")
        };
        let cn = run(&naive);
        assert_eq!(
            cn.logits, mono_naive.logits,
            "chunk {chunk}: naive chunked logits != naive monolithic"
        );
        let h2o_n = cn.bundle.h2o_scores.as_ref().unwrap();
        let h2o_m = mono_naive.bundle.h2o_scores.as_ref().unwrap();
        assert_eq!(h2o_n.data, h2o_m.data, "chunk {chunk}: naive chunked h2o");
        let cs = run(&stream);
        assert_close_slice(&cs.logits, &cn.logits, 1e-3, &format!("chunk {chunk}: cross-suite"));
        let h2o_s = cs.bundle.h2o_scores.as_ref().unwrap();
        assert_close_slice(
            &h2o_s.data,
            &h2o_n.data,
            1e-3,
            &format!("chunk {chunk}: cross-suite h2o"),
        );
    }
    // selections from the two suites' bundles agree exactly
    let cfg = EvictionConfig::new(16);
    let mono_stream = stream.prefill_for_method(&prompt, &method).expect("stream mono");
    let sel_n = method.select(&cfg, 4, &mono_naive.bundle);
    let sel_s = method.select(&cfg, 4, &mono_stream.bundle);
    assert_eq!(sel_n, sel_s, "eviction selections diverged across kernel suites");
}

/// Paged prefill → select → gather-compact → greedy paged decode, with
/// the arena storing KV in `dtype` (the low-precision A/B harness: the
/// whole pipeline reads KV through the fused-dequant `KvAccess` seam).
/// Returns (prefill logits, kept slots per layer, greedy token ids).
fn paged_run(
    engine: &Engine,
    dtype: KvDtype,
    prompt: &[i32],
    method: &Method,
    budget: usize,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<usize>>, Vec<i32>) {
    const BLOCK: usize = 16;
    let model = "lkv-tiny";
    let n_layers = engine.n_layers(model);
    let dims = engine.kv_dims(model).expect("dims");
    let mut mgr = CacheManager::with_dtype(64 * BLOCK, BLOCK, dtype);
    let out = {
        let mut ctx = mgr.paged_ctx(1);
        let mut job = engine
            .chunked_prefill_begin_paged(prompt, method, 13, None, &mut ctx)
            .expect("begin paged");
        let mut n = 0;
        while !job.step_paged(engine, &mut ctx).expect("paged chunk") {
            n += 1;
            assert!(n < 10_000, "paged chunked prefill does not terminate");
        }
        job.into_output().expect("output")
    };
    let evcfg = EvictionConfig::new(budget);
    let sel = method.select(&evcfg, n_layers, &out.bundle);
    let cap = engine
        .rt
        .manifest()
        .decode_cap(model, sel.max_kept() + steps + 1)
        .expect("decode cap");
    let blocks = out.blocks.clone().expect("paged prefill must carry its block table");
    let mut cache = {
        let (arena, alloc) = mgr.paged_parts();
        PagedSeqCache::from_arena_selection(
            arena,
            alloc,
            2,
            dims,
            &blocks,
            &sel.per_layer,
            prompt.len(),
            cap,
        )
        .expect("gather-compaction")
    };
    mgr.paged_ctx(1).free_blocks(&blocks);
    let mut token = argmax(&out.logits) as i32;
    let mut tokens = vec![token];
    for _ in 0..steps {
        let (arena, alloc) = mgr.paged_parts();
        if cache.headroom() == 0 {
            assert!(cache.grow(arena, alloc, 2), "grow failed");
        }
        let step = {
            let mut refs = vec![&mut cache];
            engine.decode_step_batch_paged(model, arena, &mut refs, &[token]).expect("paged decode")
        };
        token = argmax(&step[0].logits) as i32;
        tokens.push(token);
    }
    (out.logits, sel.per_layer.clone(), tokens)
}

/// The f32 arena is the frozen oracle: a `--kv-dtype f32` paged prefill
/// stays bit-identical to the dense monolithic pass (no tolerance).
#[test]
fn dtype_f32_arena_stays_bit_identical_to_dense() {
    let eng = engine(KernelConfig::streaming(3), "lkv-tiny");
    let prompt = encode("A7K=Q2Z;lorem;ipsum;dolor;sit;amet;consectetur;A7K=", true, false);
    let method = Method::SnapKV;
    let mono = eng.prefill_for_method(&prompt, &method).expect("dense prefill");
    let (l32, _, _) = paged_run(&eng, KvDtype::F32, &prompt, &method, 16, 4);
    assert_eq!(l32, mono.logits, "f32 arena prefill logits drifted from the dense oracle");
}

/// Per-dtype A/B against the f32 oracle: logit drift stays within the
/// per-dtype bound, and the eviction selections (kept slots per layer)
/// are **identical** to f32's for every score-driven policy family —
/// quantization noise must never flip what gets evicted at these
/// budgets.
#[test]
fn dtype_ab_logit_drift_bounded_and_selections_identical() {
    let eng = engine(KernelConfig::streaming(3), "lkv-tiny");
    let prompt = encode("A7K=Q2Z;lorem;ipsum;dolor;sit;amet;consectetur;A7K=", true, false);
    for name in ["h2o", "snapkv", "tova", "lookaheadkv", "predictor"] {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let (l32, sel32, _t32) = paged_run(&eng, KvDtype::F32, &prompt, &method, 16, 4);
        // f16 carries ~11 bits of mantissa: drift is rounding noise.
        // u8 is per-(layer, head, block) affine: drift is bounded by the
        // quantization step through one attention readback, far below
        // anything selection-relevant but not rounding-tight.
        for (dtype, tol) in [(KvDtype::F16, 5e-3f32), (KvDtype::U8, 0.25)] {
            let (l, sel, _t) = paged_run(&eng, dtype, &prompt, &method, 16, 4);
            assert_close_slice(&l, &l32, tol, &format!("{name}/{dtype}: prefill logits"));
            assert_eq!(sel, sel32, "{name}/{dtype}: eviction selection diverged from f32");
        }
    }
}

//! Integration tests over the pluggable execution backend.
//!
//! The default build runs everything against the pure-Rust reference
//! backend — no artifacts required, so these tests execute (not skip) in
//! every offline CI run: the full prefill→select→compact→decode path for
//! every `Method::parse`-able policy, engine/runtime invariants, batched
//! vs per-sequence decode dispatch, and a scheduler round-trip.
//!
//! Golden-vector parity with the Python AOT build additionally runs under
//! `--features pjrt` when artifacts exist.

use lookaheadkv::engine::{Engine, EngineConfig, GenOptions};
use lookaheadkv::eviction::Method;
use lookaheadkv::kvcache::SeqCache;
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::{encode, EOS_ID};
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::runtime::Value;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, Priority, Request, RequestQueue};

fn engine() -> Engine {
    Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny")).expect("engine")
}

#[test]
fn manifest_validates() {
    let engine = engine();
    engine.rt.manifest().validate().expect("manifest entries resolvable");
    assert!(engine.rt.manifest().graphs.len() >= 10);
    assert!(engine.rt.manifest().variants.contains_key("lkv-tiny/main"));
    assert!(!engine.rt.backend_name().is_empty());
}

/// Every parseable policy name must run the full
/// prefill→select→compact→decode path and produce a well-formed
/// generation within budget — including the draft-based LAQ/SpecKV
/// pipelines and the Table-7 `lkv+suffix` combination.
#[test]
fn end_to_end_every_parseable_method() {
    let engine = engine();
    let prompt = encode(
        "lorem;ipsum;K7F=Q2Z;amet;tempor;labore;magna;aliqua;erat;sed;K7F=",
        true,
        false,
    );
    let names = [
        "full", "random", "streaming", "snapkv", "pyramidkv", "h2o", "tova", "laq", "speckv",
        "lookaheadkv", "lkv", "lkv+suffix",
    ];
    for name in names {
        let method = Method::parse(name).unwrap_or_else(|| panic!("{name:?} must parse"));
        let budget = if matches!(method, Method::FullKV) { 1024 } else { 16 };
        let res = engine
            .generate(&prompt, &method, &GenOptions::new(budget, 6))
            .unwrap_or_else(|e| panic!("{}: {e:#}", method.name()));
        assert!(!res.tokens.is_empty() && res.tokens.len() <= 6, "{name}");
        assert!(res.tokens.iter().all(|&t| (0..320).contains(&t)), "{name}: {:?}", res.tokens);
        assert_eq!(res.prompt_len, prompt.len());
        assert!(res.ttft_ms >= res.forward_ms, "{name}: breakdown inconsistent");
        if matches!(method, Method::FullKV) {
            assert_eq!(res.kept_per_layer, vec![prompt.len(); 4]);
        } else {
            assert!(
                res.kept_per_layer
                    .iter()
                    .all(|&k| k <= budget * 2 && k >= budget.min(prompt.len()) / 2),
                "{name}: kept {:?}",
                res.kept_per_layer
            );
        }
        println!(
            "{:<16} kept={:?} text={:?} ttft={:.1}ms (+{:.2}ms evict)",
            method.name(),
            res.kept_per_layer,
            res.text,
            res.ttft_ms,
            res.eviction_overhead_ms
        );
    }
}

/// Prefill contract invariants, through the public runtime API: window
/// rows are probability rows over the valid prefix; H2O columns are
/// means of probability rows.
#[test]
fn prefill_score_tensors_are_distributions() {
    let engine = engine();
    let m = engine.rt.manifest();
    let prompt = encode("abcabcabcabc", true, false);
    let bucket = m.prefill_bucket(prompt.len()).unwrap();
    let key = m.graph_key_prefill_base("lkv-tiny", bucket);
    let inputs = vec![
        Value::vec_i32(lookaheadkv::model::tokenizer::pad_to(&prompt, bucket)),
        Value::scalar_i32(prompt.len() as i32),
        Value::scalar_i32(prompt.len() as i32 - 1),
    ];
    let out = engine.rt.execute(&key, None, &inputs).expect("prefill");
    let logits = out[2].as_f32().unwrap();
    assert_eq!(logits.data.len(), 320);
    assert!(logits.data.iter().all(|x| x.is_finite()));
    // win_start = clamp(len-W, 0, S-W) = 0 for this short prompt, so the
    // last *valid* row is absolute position len-1.
    let win = out[3].as_f32().unwrap();
    let row = win.index(&[0, 0, prompt.len() - 1]);
    let sum: f32 = row[..prompt.len()].iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "window row should sum to 1 over prompt, got {sum}");
    let h2o = out[4].as_f32().unwrap();
    let hrow = h2o.index(&[0, 0]);
    let hsum: f32 = hrow[..prompt.len()].iter().sum();
    assert!((hsum - 1.0).abs() < 1e-2, "h2o col-mean mass {hsum}");
}

/// Batched decode must be bit-identical to the per-sequence round-trip
/// on real post-eviction caches, while mutating caches in place.
#[test]
fn batched_decode_matches_per_sequence() {
    let engine = engine();
    let prompt = encode("the;quick;brown;fox;jumps;over;the;lazy;dog;again;", true, false);
    let pre = engine.prefill_for_method(&prompt, &Method::SnapKV).expect("prefill");
    let mut evcfg = engine.cfg.eviction;
    evcfg.budget = 16;
    let sel = Method::SnapKV.select(&evcfg, 4, &pre.bundle);
    let cap = engine
        .rt
        .manifest()
        .decode_cap("lkv-tiny", sel.max_kept() + 8)
        .expect("cap");
    let base = SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, prompt.len(), cap);

    let mut a = base.clone();
    let mut b1 = base.clone();
    let mut b2 = base.clone();
    for step in 0..4 {
        let tok = 97 + step;
        let sa = engine.decode_step("lkv-tiny", &mut a, tok).expect("per-seq");
        let mut refs: Vec<&mut SeqCache> = vec![&mut b1, &mut b2];
        let sb = engine
            .decode_step_batch("lkv-tiny", &mut refs, &[tok, tok])
            .expect("batched");
        assert_eq!(sa.logits, sb[0].logits, "step {step} logits diverge");
        assert_eq!(sa.logits, sb[1].logits, "step {step} batch member diverges");
        assert_eq!(sa.probs.data, sb[0].probs.data, "step {step} probs diverge");
    }
    assert_eq!(a.k.data, b1.k.data, "caches diverge after batched steps");
    assert_eq!(a.lens, b1.lens);
    assert_eq!(a.next_pos, b1.next_pos);
}

/// The continuous-batching engine loop serves queued requests to
/// completion with batched decode dispatch.
#[test]
fn engine_loop_serves_requests_batched() {
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    let engine = engine();
    let queue = Arc::new(RequestQueue::new(16));
    let metrics = Arc::new(Metrics::new());
    let mut receivers = Vec::new();
    for i in 0..5u64 {
        let (tx, rx) = channel();
        receivers.push(rx);
        queue
            .submit(Request {
                id: i,
                prompt: encode("alpha;beta;X9Y=Z3W;gamma;delta;X9Y=", true, false),
                method: if i % 2 == 0 { Method::SnapKV } else { Method::StreamingLLM },
                budget: 16,
                max_new: 5,
                temperature: 0.0,
                knobs: Default::default(),
                tenant: 0,
                priority: Priority::Normal,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
    }
    queue.close();
    let cfg = LoopConfig { max_active: 3, batched_decode: true, ..LoopConfig::default() };
    EngineLoop::new(engine, cfg, Arc::clone(&queue), metrics).run();
    for rx in receivers {
        let reply = rx.recv().expect("reply delivered");
        assert!(reply.error.is_none(), "{:?}", reply.error);
        assert!(reply.n_tokens >= 1 && reply.n_tokens <= 5);
        assert!(reply.ttft_ms >= 0.0 && reply.total_ms >= reply.ttft_ms);
    }
}

/// GT-importance accumulation must be a probability-ish distribution over
/// prompt positions.
#[test]
fn gt_importance_sane() {
    let engine = engine();
    let prompt = encode("xx;yy;K7F=Q2Z;zz;ww;vv;uu;tt;K7F=", true, false);
    let gt = engine.gt_importance(&prompt, 0.0, 0, 8).expect("gt");
    assert_eq!(gt.shape, vec![4, 4, prompt.len()]);
    let row = gt.index(&[0, 0]);
    assert!(row.iter().all(|x| x.is_finite() && *x >= 0.0));
    let mass: f32 = row.iter().sum();
    // All-zero only if generation hit EOS before any decode step.
    assert!(mass <= 1.5, "mass {mass}");
    assert!(mass > 0.1 || mass == 0.0, "mass {mass}");
}

/// Temperature sampling must terminate and produce valid tokens.
#[test]
fn stochastic_generation() {
    let engine = engine();
    let prompt = encode("A1B=C2D;noise;noise;A1B=", true, false);
    let opts = GenOptions { temperature: 0.8, seed: 7, ..GenOptions::new(16, 8) };
    let res = engine.generate(&prompt, &Method::SnapKV, &opts).expect("gen");
    assert!(!res.tokens.is_empty());
    assert!(res.tokens.iter().all(|&t| (0..320).contains(&t) || t == EOS_ID));
}

/// Replay the aot.py golden vectors through the PJRT backend and compare
/// (f32 tolerance) — proves the HLO-text interchange and positional
/// argument contract. Requires `--features pjrt`, a real `xla` binding
/// and built artifacts; skips otherwise.
#[cfg(feature = "pjrt")]
#[test]
fn golden_vectors_match() {
    use lookaheadkv::runtime::Runtime;
    use xla::{FromRawBytes, Literal};

    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("golden: artifacts missing; skipping (run `make artifacts`)");
        return;
    }
    let rt = match Runtime::pjrt(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("golden: pjrt unavailable ({e:#}); skipping");
            return;
        }
    };
    let m = rt.manifest();
    let goldens: Vec<(String, String)> =
        m.goldens.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    assert!(!goldens.is_empty(), "aot.py wrote no goldens");
    for (key, file) in goldens {
        let meta = m.graph(&key).unwrap().clone();
        let pairs = Literal::read_npz(&m.path(&file), &()).expect("golden npz");
        let mut inputs: Vec<Option<Value>> = (0..meta.inputs.len()).map(|_| None).collect();
        let mut outputs: Vec<(usize, Vec<f32>)> = Vec::new();
        for (name, lit) in pairs {
            let as_f32 = |l: &Literal| {
                l.to_vec::<f32>().or_else(|_| {
                    l.to_vec::<i32>().map(|v| v.iter().map(|&x| x as f32).collect())
                })
            };
            if let Some(stripped) = name.strip_prefix("in_") {
                let idx = meta.inputs.iter().position(|i| i.name == stripped).unwrap();
                let spec = &meta.inputs[idx];
                let val = if spec.dtype == "int32" {
                    let data = lit.to_vec::<i32>().expect("golden i32 input");
                    Value::I32(lookaheadkv::util::tensor::TensorI::new(spec.shape.clone(), data))
                } else {
                    let data = lit.to_vec::<f32>().expect("golden f32 input");
                    Value::F32(lookaheadkv::util::tensor::TensorF::new(spec.shape.clone(), data))
                };
                inputs[idx] = Some(val);
            } else if let Some(i) = name.strip_prefix("out_") {
                outputs.push((i.parse().unwrap(), as_f32(&lit).expect("golden output")));
            }
        }
        let inputs: Vec<Value> = inputs.into_iter().map(Option::unwrap).collect();
        let variant = (meta.n_lkv_weight_args > 0).then_some(("lkv-tiny", "main"));
        let got = rt.execute(&key, variant, &inputs).expect("execute");
        outputs.sort_by_key(|(i, _)| *i);
        for (i, want) in outputs {
            let g: Vec<f32> = match &got[i] {
                Value::F32(t) => t.data.clone(),
                Value::I32(t) => t.data.iter().map(|&x| x as f32).collect(),
            };
            assert_eq!(want.len(), g.len(), "{key} output {i} length");
            let max_err = want
                .iter()
                .zip(&g)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0f64, f64::max);
            assert!(max_err < 1e-3, "{key} output {i}: max err {max_err}");
        }
        println!("golden ok: {key}");
    }
}

//! Integration tests over the real AOT artifacts: golden-vector parity
//! with the Python build, end-to-end generation under every eviction
//! method, and engine/runtime invariants.
//!
//! All tests skip (pass trivially) when artifacts have not been built;
//! `make test` builds them first.

use lookaheadkv::engine::{Engine, EngineConfig, GenOptions};
use lookaheadkv::eviction::Method;
use lookaheadkv::model::tokenizer::{encode, EOS_ID};
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::runtime::literal::{literal_i32, literal_scalar_i32, tensor_f32};
use lookaheadkv::util::tensor::TensorI;
use xla::{FromRawBytes, Literal};

fn engine() -> Option<Engine> {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("integration: artifacts missing; skipping (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir, EngineConfig::new("lkv-tiny")).expect("engine"))
}

#[test]
fn manifest_validates() {
    let Some(engine) = engine() else { return };
    engine.rt.manifest().validate().expect("all artifact files present");
    assert!(engine.rt.manifest().graphs.len() >= 10);
    assert!(engine.rt.manifest().variants.contains_key("lkv-tiny/main"));
}

/// Replay the aot.py golden vectors through the Rust runtime and compare
/// bit-for-bit-ish (f32 tolerance) — proves the HLO-text interchange and
/// positional argument contract.
#[test]
fn golden_vectors_match() {
    let Some(engine) = engine() else { return };
    let m = engine.rt.manifest();
    let goldens: Vec<(String, String)> =
        m.goldens.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    assert!(!goldens.is_empty(), "aot.py wrote no goldens");
    for (key, file) in goldens {
        let meta = m.graph(&key).unwrap().clone();
        let pairs = Literal::read_npz(&m.path(&file), &()).expect("golden npz");
        let mut inputs: Vec<Option<Literal>> = (0..meta.inputs.len()).map(|_| None).collect();
        let mut outputs: Vec<(usize, Literal)> = Vec::new();
        for (name, lit) in pairs {
            if let Some(stripped) = name.strip_prefix("in_") {
                let idx = meta.inputs.iter().position(|i| i.name == stripped).unwrap();
                inputs[idx] = Some(lit);
            } else if let Some(i) = name.strip_prefix("out_") {
                outputs.push((i.parse().unwrap(), lit));
            }
        }
        let inputs: Vec<Literal> = inputs.into_iter().map(Option::unwrap).collect();
        let variant = (meta.n_lkv_weight_args > 0).then_some(("lkv-tiny", "main"));
        let got = engine.rt.execute(&key, variant, &inputs).expect("execute");
        outputs.sort_by_key(|(i, _)| *i);
        for (i, want) in outputs {
            let w = want.to_vec::<f32>().or_else(|_| {
                want.to_vec::<i32>().map(|v| v.into_iter().map(|x| x as f32).collect())
            });
            let g = got[i].to_vec::<f32>().or_else(|_| {
                got[i].to_vec::<i32>().map(|v| v.into_iter().map(|x| x as f32).collect())
            });
            let (w, g) = (w.unwrap(), g.unwrap());
            assert_eq!(w.len(), g.len(), "{key} output {i} length");
            let max_err = w
                .iter()
                .zip(&g)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0f64, f64::max);
            assert!(max_err < 1e-3, "{key} output {i}: max err {max_err}");
        }
        println!("golden ok: {key}");
    }
}

/// FullKV must reproduce the model's unevicted generation, and every
/// method must produce a well-formed generation within budget.
#[test]
fn end_to_end_all_methods() {
    let Some(engine) = engine() else { return };
    let prompt = encode(
        "lorem;ipsum;K7F=Q2Z;amet;tempor;labore;magna;aliqua;erat;sed;K7F=",
        true,
        false,
    );
    let full = engine
        .generate(&prompt, &Method::FullKV, &GenOptions::new(1024, 6))
        .expect("fullkv");
    assert_eq!(full.kept_per_layer, vec![prompt.len(); 4]);
    for method in [
        Method::Random { seed: 3 },
        Method::StreamingLLM,
        Method::SnapKV,
        Method::PyramidKV,
        Method::H2O,
        Method::Tova,
        Method::Laq,
        Method::SpecKV,
        Method::LookaheadKV { variant: "main".into() },
        Method::LkvSuffix { variant: "main".into() },
    ] {
        let budget = 16;
        let res = engine
            .generate(&prompt, &method, &GenOptions::new(budget, 6))
            .unwrap_or_else(|e| panic!("{}: {e:#}", method.name()));
        assert!(res.tokens.len() <= 6);
        assert!(
            res.kept_per_layer.iter().all(|&k| k <= budget * 2 && k >= budget.min(prompt.len()) / 2),
            "{}: kept {:?}",
            method.name(),
            res.kept_per_layer
        );
        assert!(res.tokens.iter().all(|&t| (0..320).contains(&t)), "{}", method.name());
        println!(
            "{:<16} kept={:?} text={:?} ttft={:.1}ms",
            method.name(),
            res.kept_per_layer,
            res.text,
            res.ttft_ms
        );
    }
}

/// Decode-graph consistency: running the decode graph one token at a time
/// from a FullKV prefill must match the prefill logits path (the first
/// sampled token from prefill logits equals greedy continuation).
#[test]
fn decode_graph_consistency() {
    let Some(engine) = engine() else { return };
    let m = engine.rt.manifest();
    let prompt = encode("abcabcabcabc", true, false);
    let bucket = m.prefill_bucket(prompt.len()).unwrap();
    let key = m.graph_key_prefill_base("lkv-tiny", bucket);
    let inputs = vec![
        literal_i32(&TensorI::from_vec(lookaheadkv::model::tokenizer::pad_to(&prompt, bucket)))
            .unwrap(),
        literal_scalar_i32(prompt.len() as i32),
        literal_scalar_i32(prompt.len() as i32 - 1),
    ];
    let out = engine.rt.execute(&key, None, &inputs).expect("prefill");
    let logits = out[2].to_vec::<f32>().unwrap();
    assert_eq!(logits.len(), 320);
    assert!(logits.iter().all(|x| x.is_finite()));
    // window scores rows are probability rows over the valid prefix
    let win = tensor_f32(&out[3]).unwrap();
    // win_start = clamp(len-W, 0, S-W) = 0 for this short prompt, so the
    // last *valid* row is absolute position len-1.
    let row = win.index(&[0, 0, prompt.len() - 1]);
    let sum: f32 = row[..prompt.len()].iter().sum();
    assert!((sum - 1.0).abs() < 1e-3, "window row should sum to 1 over prompt, got {sum}");
    // h2o rows are means of probability rows: sum over cols <= 1
    let h2o = tensor_f32(&out[4]).unwrap();
    let hrow = h2o.index(&[0, 0]);
    let hsum: f32 = hrow[..prompt.len()].iter().sum();
    assert!((hsum - 1.0).abs() < 1e-2, "h2o col-mean mass {hsum}");
}

/// GT-importance accumulation must be a probability-ish distribution over
/// prompt positions and favor the needle for a retrieval prompt.
#[test]
fn gt_importance_sane() {
    let Some(engine) = engine() else { return };
    let prompt = encode("xx;yy;K7F=Q2Z;zz;ww;vv;uu;tt;K7F=", true, false);
    let gt = engine.gt_importance(&prompt, 0.0, 0, 8).expect("gt");
    assert_eq!(gt.shape, vec![4, 4, prompt.len()]);
    let row = gt.index(&[0, 0]);
    assert!(row.iter().all(|x| x.is_finite() && *x >= 0.0));
    let mass: f32 = row.iter().sum();
    assert!(mass > 0.1 && mass <= 1.5, "mass {mass}");
}

/// Temperature sampling must terminate and produce valid tokens.
#[test]
fn stochastic_generation() {
    let Some(engine) = engine() else { return };
    let prompt = encode("A1B=C2D;noise;noise;A1B=", true, false);
    let opts = GenOptions { temperature: 0.8, seed: 7, ..GenOptions::new(16, 8) };
    let res = engine.generate(&prompt, &Method::SnapKV, &opts).expect("gen");
    assert!(!res.tokens.is_empty());
    assert!(res.tokens.iter().all(|&t| (0..320).contains(&t) || t == EOS_ID));
}

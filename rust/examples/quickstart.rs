//! Quickstart: serve one prompt with LookaheadKV eviction and print the
//! generation plus the latency breakdown. Runs offline on the pure-Rust
//! reference backend (no artifacts needed); with `--features pjrt` and
//! `make artifacts`, the same binary serves the AOT graphs instead.
//!
//!     cargo run --release --example quickstart

use lookaheadkv::engine::{Engine, EngineConfig, GenOptions};
use lookaheadkv::eviction::Method;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny"))?;

    // A needle-in-a-haystack prompt: the answer Q2Z is buried in noise.
    let prompt = "lorem;ipsum;dolor;K7F=Q2Z;amet;tempor;labore;magna;aliqua;\
                  erat;sed;diam;nonumy;eirmod;invidunt;K7F=";
    let tokens = encode(prompt, true, false);

    for method in [Method::FullKV, Method::SnapKV, Method::LookaheadKV { variant: "main".into() }]
    {
        let res = engine.generate(&tokens, &method, &GenOptions::new(16, 8))?;
        println!(
            "{:<14} -> {:<8}  (kept {:?} of {} | ttft {:.1} ms, evict +{:.2} ms)",
            method.name(),
            res.text,
            res.kept_per_layer,
            res.prompt_len,
            res.ttft_ms,
            res.eviction_overhead_ms
        );
    }
    Ok(())
}

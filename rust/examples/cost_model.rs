//! Print the analytical TTFT table at the paper's configuration
//! (LLaMA3.1-8B / H100 / C=128) — the Table 3 + Table 15 + Fig. 3a
//! reproduction, runnable without artifacts.
//!
//!     cargo run --release --example cost_model

use lookaheadkv::costmodel::{method_cost, methods::CostConfig, profiles, MethodKind};

fn main() {
    let cfg = CostConfig::default();
    println!("Theoretical TTFT — LLaMA3.1-8B on H100-80GB (paper §B / Table 15)");
    println!(
        "{:<8} {:<18} {:>10} {:>12} {:>10} {:>13} {:>10}",
        "context", "method", "TFLOPs", "traffic(GB)", "TTFT(ms)", "overhead(ms)", "ovh %"
    );
    for ctx in [4096, 8192, 16384, 32768] {
        let base = method_cost(
            MethodKind::ForwardOnly,
            &profiles::LLAMA31_8B,
            &profiles::LLAMA32_1B,
            &profiles::H100,
            ctx,
            &cfg,
        );
        for m in MethodKind::all() {
            let r = method_cost(
                m,
                &profiles::LLAMA31_8B,
                &profiles::LLAMA32_1B,
                &profiles::H100,
                ctx,
                &cfg,
            );
            println!(
                "{:<8} {:<18} {:>10.0} {:>12.1} {:>10.0} {:>13.2} {:>9.2}%",
                ctx,
                r.method.label(),
                r.tflops,
                r.traffic_gb,
                r.ttft_ms,
                r.overhead_ms,
                100.0 * r.overhead_ms / base.ttft_ms
            );
        }
        println!();
    }
    let lkv = method_cost(
        MethodKind::LookaheadKV,
        &profiles::LLAMA31_8B,
        &profiles::LLAMA32_1B,
        &profiles::H100,
        32768,
        &cfg,
    );
    let laq = method_cost(
        MethodKind::Laq,
        &profiles::LLAMA31_8B,
        &profiles::LLAMA32_1B,
        &profiles::H100,
        32768,
        &cfg,
    );
    println!(
        "headline: LookaheadKV eviction cost is {:.1}x lower than LAQ at 32K (paper: 14.5x)",
        laq.overhead_ms / lkv.overhead_ms.max(1e-9)
    );
}

//! End-to-end serving validation: start the full stack
//! (engine loop + scheduler + HTTP server), drive it with a concurrent
//! load generator over a real workload, and report TTFT / end-to-end
//! latency / throughput per eviction method.
//!
//!     cargo run --release --example serve_bench -- --requests 24 --concurrency 4

use std::sync::Arc;
use std::time::Instant;

use lookaheadkv::engine::{Engine, EngineConfig};
use lookaheadkv::metrics::Metrics;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, RequestQueue};
use lookaheadkv::server::http::{http_get, http_post};
use lookaheadkv::server::{serve, ServerConfig};
use lookaheadkv::util::cli::Args;
use lookaheadkv::util::json;
use lookaheadkv::util::stats::summarize;
use lookaheadkv::util::threadpool::{ThreadPool, WaitGroup};
use lookaheadkv::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let n_requests = args.usize("requests", 24);
    let concurrency = args.usize("concurrency", 4);
    let ctx = args.usize("ctx", 256);
    let addr = args.get_or("addr", "127.0.0.1:18931").to_string();

    // Engine thread (owns the PJRT client).
    let queue = Arc::new(RequestQueue::new(128));
    let metrics = Arc::new(Metrics::new());
    let (q2, m2) = (Arc::clone(&queue), Arc::clone(&metrics));
    let art = default_artifacts_dir();
    std::thread::spawn(move || {
        let engine = Engine::new(&art, EngineConfig::new("lkv-tiny")).expect("engine");
        EngineLoop::new(engine, LoopConfig { max_active: 4, ..Default::default() }, q2, m2).run();
    });
    // HTTP server thread.
    let (q3, m3) = (Arc::clone(&queue), Arc::clone(&metrics));
    let addr2 = addr.clone();
    std::thread::spawn(move || {
        let cfg = ServerConfig {
            addr: addr2,
            workers: concurrency + 2,
            queue_cap: 128,
            ..Default::default()
        };
        serve(cfg, q3, m3, None).expect("server");
    });
    for _ in 0..100 {
        std::thread::sleep(std::time::Duration::from_millis(100));
        if http_get(&addr, "/healthz").is_ok() {
            break;
        }
    }

    let suite = workload::ruler_suite(3, (n_requests / 4).max(1), ctx);
    for method in ["snapkv", "lookaheadkv", "streaming"] {
        let pool = ThreadPool::new(concurrency, "loadgen");
        let results = Arc::new(std::sync::Mutex::new(Vec::<(f64, f64)>::new()));
        let total_launch = n_requests.min(suite.samples.len() * 4);
        let wg = WaitGroup::new(total_launch);
        let t0 = Instant::now();
        let mut launched = 0;
        'outer: for _ in 0..4 {
            for s in &suite.samples {
                if launched >= total_launch {
                    break 'outer;
                }
                launched += 1;
                let prompt = s.prompt();
                let addr = addr.clone();
                let results = Arc::clone(&results);
                let guard = wg.guard();
                let method = method.to_string();
                let submitted = pool.execute(move || {
                    let _g = guard;
                    let mut o = json::Json::obj();
                    o.set("prompt", prompt.as_str().into());
                    o.set("method", method.as_str().into());
                    o.set("budget", 32usize.into());
                    o.set("max_new", 8usize.into());
                    if let Ok((200, resp)) = http_post(&addr, "/generate", &o.to_string()) {
                        if let Ok(v) = json::parse(&resp) {
                            let ttft = v.req("ttft_ms").as_f64().unwrap_or(0.0);
                            let total = v.req("total_ms").as_f64().unwrap_or(0.0);
                            results.lock().unwrap().push((ttft, total));
                        }
                    }
                });
                submitted.expect("loadgen pool alive");
            }
        }
        wg.wait();
        let wall = t0.elapsed().as_secs_f64();
        let rs = results.lock().unwrap();
        let ttfts: Vec<f64> = rs.iter().map(|(t, _)| *t).collect();
        let totals: Vec<f64> = rs.iter().map(|(_, t)| *t).collect();
        let st = summarize(&ttfts);
        let se = summarize(&totals);
        println!(
            "{:<14} n={:<3} ttft p50={:.1}ms p99={:.1}ms | e2e p50={:.1}ms | {:.2} req/s",
            method,
            rs.len(),
            st.p50,
            st.p99,
            se.p50,
            rs.len() as f64 / wall
        );
    }
    let (_, m) = http_get(&addr, "/metrics")?;
    println!("\n/metrics: {m}");
    Ok(())
}

//! Compare every eviction policy on the same workload: task score,
//! GT-overlap quality (recall@C vs the true response's attention — the
//! paper's Table-8 metric) and eviction latency.
//!
//!     cargo run --release --example eviction_compare -- --ctx 256 --budget 16 --n 6

use lookaheadkv::engine::{Engine, EngineConfig};
use lookaheadkv::eval::runner;
use lookaheadkv::eviction::{EvictionConfig, Method};
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::util::cli::Args;
use lookaheadkv::util::stats;
use lookaheadkv::workload;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&[]);
    let ctx = args.usize("ctx", 256);
    let budget = args.usize("budget", 16);
    let n = args.usize("n", 6);
    let engine = Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny"))?;
    let suite = workload::ruler_suite(7, n, ctx);

    let methods = [
        Method::FullKV,
        Method::Random { seed: 1 },
        Method::StreamingLLM,
        Method::SnapKV,
        Method::PyramidKV,
        Method::H2O,
        Method::Tova,
        Method::Laq,
        Method::SpecKV,
        Method::LookaheadKV { variant: "main".into() },
        // Learned importance predictor (synthesized weights offline).
        Method::Predictor,
    ];

    // GT importance per sample (FullKV greedy decode attention, Eq. 1).
    let mut gts = Vec::new();
    for s in &suite.samples {
        let prompt = encode(&s.prompt(), true, false);
        let gt = engine.gt_importance(&prompt, 0.0, 0, 12)?;
        gts.push((prompt, gt));
    }

    println!("{:<16} {:>8} {:>10} {:>12}", "method", "score", "recall@C", "evict(ms)");
    let n_layers = engine.n_layers("lkv-tiny");
    for method in &methods {
        let cfg = runner::EvalConfig { budget, max_new: 8, temperature: 0.0, seed: 0 };
        let res = runner::run_suite(&engine, &suite, method, &cfg)?;
        // GT-overlap: recall of the kept set against the GT top-C set.
        let mut recalls = Vec::new();
        if !matches!(method, Method::FullKV) {
            for (prompt, gt) in &gts {
                let pre = engine.prefill_for_method(prompt, method)?;
                let evcfg = EvictionConfig::new(budget);
                let sel = method.select(&evcfg, n_layers, &pre.bundle);
                let (l, h) = (gt.shape[0], gt.shape[1]);
                for li in 0..l {
                    let mut gt_mean = vec![0.0f32; prompt.len()];
                    for hi in 0..h {
                        let row = gt.index(&[li, hi]);
                        for (j, g) in gt_mean.iter_mut().enumerate() {
                            *g += row[j];
                        }
                    }
                    let gt_top = stats::topk_indices(&gt_mean, sel.per_layer[li].len());
                    let kept: std::collections::HashSet<usize> =
                        sel.per_layer[li].iter().copied().collect();
                    let inter = gt_top.iter().filter(|i| kept.contains(i)).count();
                    recalls.push(inter as f64 / gt_top.len().max(1) as f64);
                }
            }
        }
        let recall = if recalls.is_empty() {
            1.0
        } else {
            recalls.iter().sum::<f64>() / recalls.len() as f64
        };
        println!(
            "{:<16} {:>8.3} {:>10.3} {:>12.2}",
            res.method, res.score, recall, res.overhead_ms_mean
        );
    }
    Ok(())
}

//! Offline stand-in for the `log` crate: a minimal stderr facade.
//!
//! `error!`/`warn!`/`info!` always print to stderr; `debug!`/`trace!`
//! only when the `LKV_LOG` environment variable is set to `debug` or
//! `trace`. No global logger registration is needed (or possible).

use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

fn max_level() -> Level {
    static MAX: OnceLock<Level> = OnceLock::new();
    *MAX.get_or_init(|| match std::env::var("LKV_LOG").as_deref() {
        Ok("trace") => Level::Trace,
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    })
}

/// Macro plumbing — not part of the public facade.
pub fn __emit(level: Level, msg: std::fmt::Arguments<'_>) {
    if level <= max_level() {
        eprintln!("[{}] {}", level.tag(), msg);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Error, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Debug, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::__emit($crate::Level::Trace, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Info);
        assert!(Level::Info < Level::Trace);
    }

    #[test]
    fn macros_expand() {
        info!("hello {}", 1);
        debug!("quiet {}", 2);
        error!("boom");
    }
}

//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Error values carry a
//! context chain; `{}` prints the outermost context, `{:#}` the full
//! `outer: ...: root` chain (matching real anyhow's display modes).

use std::fmt;

/// A context-carrying error. Like the real `anyhow::Error`, this type
/// deliberately does **not** implement `std::error::Error`, which is what
/// makes the blanket `From<E: std::error::Error>` conversion coherent.
pub struct Error {
    /// Context chain, outermost first; the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_display() {
        let e: Error = Err::<(), _>(io_err()).context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x > 1, "too small: {x}");
            if x > 10 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(0).unwrap_err()), "too small: 0");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big");
        let e = anyhow!("plain {}", 1);
        assert_eq!(format!("{e}"), "plain 1");
    }
}

//! API **stub** for an XLA/PJRT Rust binding.
//!
//! The offline build environment cannot carry a real XLA binding, but the
//! `pjrt` cargo feature still has to compile so the PJRT backend stays
//! honest (type-checked against the exact API surface it needs). Every
//! entry point that would touch PJRT returns [`XlaError::Unavailable`] at
//! runtime; to execute real AOT artifacts, point the `xla` path
//! dependency in `rust/Cargo.toml` at an actual binding with this API.

use std::path::Path;

#[derive(Debug)]
pub enum XlaError {
    /// Stub build: no real XLA binding is linked in.
    Unavailable(&'static str),
}

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            XlaError::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires a real XLA/PJRT binding \
                 (swap the `xla` path dependency in rust/Cargo.toml)"
            ),
        }
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(XlaError::Unavailable(what))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Maps native scalar types to their XLA element type.
pub trait ArrayElement {
    const TY: ElementType;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal (stub: carries no data).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar<T: ArrayElement>(_v: T) -> Literal {
        Literal { _private: () }
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn ty(&self) -> Result<ElementType> {
        unavailable("Literal::ty")
    }

    pub fn element_count(&self) -> usize {
        0
    }

    pub fn copy_raw_to<T: ArrayElement>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }
}

/// Deserialization entry points (`.npz` weight/golden archives).
pub trait FromRawBytes: Sized {
    type Context;

    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz<P: AsRef<Path>>(_path: P, _ctx: &()) -> Result<Vec<(String, Literal)>> {
        unavailable("Literal::read_npz")
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

//! Task-format contract shared with `python/compile/data.py`.
//!
//! * records are `KEY=VAL;` with keys/values over `[A-Z0-9]`;
//! * noise is lowercase words terminated by `;`;
//! * queries are the exact record prefix `KEY=` (exact-continuation);
//! * few-shot pairs `x->Y;` with a final incomplete pair as query;
//! * longproc records `<NAME:VAL>`, instruction `!tsv;`, answer
//!   `NAME\tVAL;` per record in order.

use crate::util::rng::Rng;

pub const CODE_CHARS: &[u8] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789";
pub const NOISE_WORDS: &[&str] = &[
    "lorem", "ipsum", "dolor", "amet", "tempor", "incidunt", "labore", "magna", "aliqua", "erat",
    "sed", "diam", "nonumy", "eirmod", "invidunt", "ut", "vero", "accusam", "justo", "duo", "kasd",
    "gubergren", "clita", "takimata", "sanctus", "est", "sit", "elitr",
];

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskFamily {
    Kv,
    MultiKv,
    Vt,
    Fewshot,
    Code,
    Qa,
    Cwe,
    LongProc,
    MtBench,
}

impl TaskFamily {
    pub fn name(&self) -> &'static str {
        match self {
            TaskFamily::Kv => "kv",
            TaskFamily::MultiKv => "multikv",
            TaskFamily::Vt => "vt",
            TaskFamily::Fewshot => "fewshot",
            TaskFamily::Code => "code",
            TaskFamily::Qa => "qa",
            TaskFamily::Cwe => "cwe",
            TaskFamily::LongProc => "longproc",
            TaskFamily::MtBench => "mtbench",
        }
    }
}

/// One evaluation sample; `turns` holds extra (query, answer) pairs for
/// multi-turn suites.
#[derive(Debug, Clone)]
pub struct Sample {
    pub family: TaskFamily,
    pub context: String,
    pub query: String,
    pub answer: String,
    pub turns: Vec<(String, String)>,
}

impl Sample {
    pub fn prompt(&self) -> String {
        format!("{}{}", self.context, self.query)
    }
}

pub fn code(rng: &mut Rng, n: usize) -> String {
    (0..n).map(|_| CODE_CHARS[rng.below(CODE_CHARS.len())] as char).collect()
}

pub fn noise_word(rng: &mut Rng) -> String {
    format!("{};", NOISE_WORDS[rng.below(NOISE_WORDS.len())])
}

pub fn shuffle_merge(rng: &mut Rng, records: Vec<String>, noise_words: usize) -> String {
    let mut parts = records;
    for _ in 0..noise_words {
        parts.push(noise_word(rng));
    }
    rng.shuffle(&mut parts);
    parts.concat()
}

pub fn gen_kv(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let key = code(rng, 3);
    let val = code(rng, 3);
    let rec = format!("{key}={val};");
    let noise = ctx_chars.saturating_sub(rec.len()) / 6;
    Sample {
        family: TaskFamily::Kv,
        context: shuffle_merge(rng, vec![rec], noise),
        query: format!("{key}="),
        answer: val,
        turns: vec![],
    }
}

pub fn gen_multikv(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let n_keys = 4;
    let mut keys = Vec::new();
    let mut vals = Vec::new();
    while keys.len() < n_keys {
        let k = code(rng, 3);
        if !keys.contains(&k) {
            keys.push(k);
            vals.push(code(rng, 3));
        }
    }
    let recs: Vec<String> =
        keys.iter().zip(&vals).map(|(k, v)| format!("{k}={v};")).collect();
    let used: usize = recs.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let i = rng.below(n_keys);
    Sample {
        family: TaskFamily::MultiKv,
        context: shuffle_merge(rng, recs, noise),
        query: format!("{}=", keys[i]),
        answer: vals[i].clone(),
        turns: vec![],
    }
}

pub fn gen_vt(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let depth = 3;
    let letters: Vec<char> = "abcdefghijklmnopqrstuvwxyz".chars().collect();
    let names = rng.sample_indices(letters.len(), depth + 4);
    let name = |i: usize| letters[names[i]];
    let val = code(rng, 3);
    let mut recs = vec![format!("{}={val};", name(0))];
    for i in 1..depth {
        recs.push(format!("{}={};", name(i), name(i - 1)));
    }
    let dval = code(rng, 3);
    recs.push(format!("{}={dval};", name(depth)));
    recs.push(format!("{}={};", name(depth + 1), name(depth)));
    let used: usize = recs.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let per = noise / recs.len().max(1);
    let mut ctx = String::new();
    for r in &recs {
        for _ in 0..per {
            ctx.push_str(&noise_word(rng));
        }
        ctx.push_str(r);
    }
    Sample {
        family: TaskFamily::Vt,
        context: ctx,
        query: format!("{}=", name(depth - 1)),
        answer: val,
        turns: vec![],
    }
}

pub fn gen_fewshot(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let short: Vec<&str> = NOISE_WORDS.iter().copied().filter(|w| w.len() <= 5).collect();
    let n_shots = (ctx_chars / 24).clamp(2, 8);
    let picks = rng.sample_indices(short.len(), n_shots + 1);
    let recs: Vec<String> =
        picks[..n_shots].iter().map(|&i| format!("{}->{};", short[i], short[i].to_uppercase())).collect();
    let used: usize = recs.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let mut ctx = String::new();
    for _ in 0..noise / 2 {
        ctx.push_str(&noise_word(rng));
    }
    ctx.push_str(&recs.concat());
    for _ in 0..noise - noise / 2 {
        ctx.push_str(&noise_word(rng));
    }
    let q = short[picks[n_shots]];
    Sample {
        family: TaskFamily::Fewshot,
        context: ctx,
        query: format!("{q}->"),
        answer: q.to_uppercase(),
        turns: vec![],
    }
}

pub fn gen_code(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let n_fns = (ctx_chars / 40).max(2);
    let mut names = Vec::new();
    let mut args = Vec::new();
    while names.len() < n_fns {
        let n = code(rng, 4).to_lowercase();
        if !names.contains(&n) {
            names.push(n);
            args.push(code(rng, 3).to_lowercase());
        }
    }
    let recs: Vec<String> =
        names.iter().zip(&args).map(|(n, a)| format!("fn {n}({a});")).collect();
    let used: usize = recs.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let i = rng.below(n_fns);
    Sample {
        family: TaskFamily::Code,
        context: shuffle_merge(rng, recs, noise),
        query: format!("fn {}(", names[i]),
        answer: args[i].clone(),
        turns: vec![],
    }
}

pub fn gen_qa(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let short: Vec<&str> = NOISE_WORDS.iter().copied().filter(|w| w.len() <= 6).collect();
    let oi = rng.sample_indices(short.len(), 3);
    let vi = rng.sample_indices(short.len(), 3);
    let recs: Vec<String> =
        (0..3).map(|i| format!("{}={};", short[oi[i]], short[vi[i]])).collect();
    let used: usize = recs.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let i = rng.below(3);
    Sample {
        family: TaskFamily::Qa,
        context: shuffle_merge(rng, recs, noise),
        query: format!("{}=", short[oi[i]]),
        answer: short[vi[i]].to_string(),
        turns: vec![],
    }
}

pub fn gen_cwe(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let short: Vec<&str> = NOISE_WORDS.iter().copied().filter(|w| w.len() <= 5).collect();
    let target = short[rng.below(short.len())];
    let reps = (ctx_chars / 30).max(4);
    let others = (ctx_chars / 8).saturating_sub(reps);
    let mut parts: Vec<String> = (0..reps).map(|_| format!("{target};")).collect();
    for _ in 0..others {
        let mut w = NOISE_WORDS[rng.below(NOISE_WORDS.len())];
        while w == target {
            w = NOISE_WORDS[rng.below(NOISE_WORDS.len())];
        }
        parts.push(format!("{w};"));
    }
    rng.shuffle(&mut parts);
    Sample {
        family: TaskFamily::Cwe,
        context: parts.concat(),
        query: "?max=".to_string(),
        answer: target.to_string(),
        turns: vec![],
    }
}

pub fn gen_longproc(rng: &mut Rng, ctx_chars: usize, n_records: usize) -> Sample {
    let recs: Vec<(String, String)> =
        (0..n_records).map(|_| (code(rng, 3), code(rng, 3))).collect();
    let tagged: Vec<String> = recs.iter().map(|(n, v)| format!("<{n}:{v}>")).collect();
    let used: usize = tagged.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let per = noise / n_records.max(1);
    let mut ctx = String::new();
    for t in &tagged {
        for _ in 0..per {
            ctx.push_str(&noise_word(rng));
        }
        ctx.push_str(t);
    }
    let answer: String = recs.iter().map(|(n, v)| format!("{n}\t{v};")).collect();
    Sample {
        family: TaskFamily::LongProc,
        context: ctx,
        query: "!tsv;".to_string(),
        answer,
        turns: vec![],
    }
}

pub fn gen_mtbench(rng: &mut Rng, ctx_chars: usize) -> Sample {
    let mut keys = Vec::new();
    let mut vals = Vec::new();
    while keys.len() < 3 {
        let k = code(rng, 3);
        if !keys.contains(&k) {
            keys.push(k);
            vals.push(code(rng, 3));
        }
    }
    let recs: Vec<String> =
        keys.iter().zip(&vals).map(|(k, v)| format!("{k}={v};")).collect();
    let used: usize = recs.iter().map(String::len).sum();
    let noise = ctx_chars.saturating_sub(used) / 6;
    let picks = rng.sample_indices(3, 2);
    Sample {
        family: TaskFamily::MtBench,
        context: shuffle_merge(rng, recs, noise),
        query: format!("{}=", keys[picks[0]]),
        answer: vals[picks[0]].clone(),
        turns: vec![(format!("{}=", keys[picks[1]]), vals[picks[1]].clone())],
    }
}

pub fn generate(rng: &mut Rng, family: TaskFamily, ctx_chars: usize) -> Sample {
    match family {
        TaskFamily::Kv => gen_kv(rng, ctx_chars),
        TaskFamily::MultiKv => gen_multikv(rng, ctx_chars),
        TaskFamily::Vt => gen_vt(rng, ctx_chars),
        TaskFamily::Fewshot => gen_fewshot(rng, ctx_chars),
        TaskFamily::Code => gen_code(rng, ctx_chars),
        TaskFamily::Qa => gen_qa(rng, ctx_chars),
        TaskFamily::Cwe => gen_cwe(rng, ctx_chars),
        TaskFamily::LongProc => gen_longproc(rng, ctx_chars, 4),
        TaskFamily::MtBench => gen_mtbench(rng, ctx_chars),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234)
    }

    #[test]
    fn kv_answer_in_context() {
        let mut r = rng();
        for _ in 0..20 {
            let s = gen_kv(&mut r, 120);
            let needle = format!("{}{};", s.query, s.answer);
            assert!(s.context.contains(&needle), "{s:?}");
        }
    }

    #[test]
    fn multikv_queried_needle_present() {
        let mut r = rng();
        let s = gen_multikv(&mut r, 200);
        assert!(s.context.contains(&format!("{}{};", s.query, s.answer)));
    }

    #[test]
    fn vt_chain_resolves() {
        let mut r = rng();
        for _ in 0..10 {
            let s = gen_vt(&mut r, 200);
            // the queried variable must resolve through the chain to answer
            assert_eq!(s.answer.len(), 3);
            assert!(s.context.contains(&format!("={};", s.answer)) || s.context.contains(&format!("={}", s.answer)));
        }
    }

    #[test]
    fn code_query_prefix_present() {
        let mut r = rng();
        let s = gen_code(&mut r, 200);
        assert!(s.context.contains(&format!("{}{});", s.query, s.answer)));
    }

    #[test]
    fn longproc_answer_order_matches_context() {
        let mut r = rng();
        let s = gen_longproc(&mut r, 300, 4);
        let names: Vec<&str> = s.answer.split(';').filter(|x| !x.is_empty()).collect();
        assert_eq!(names.len(), 4);
        let mut last = 0;
        for rec in names {
            let name = &rec[..3];
            let pos = s.context[last..].find(&format!("<{name}:")).expect("in order") + last;
            last = pos;
        }
    }

    #[test]
    fn mtbench_has_second_turn() {
        let mut r = rng();
        let s = gen_mtbench(&mut r, 150);
        assert_eq!(s.turns.len(), 1);
        assert!(s.context.contains(&format!("{}{};", s.turns[0].0, s.turns[0].1)));
    }

    #[test]
    fn sizes_roughly_respected() {
        let mut r = rng();
        for fam in [TaskFamily::Kv, TaskFamily::Qa, TaskFamily::Code] {
            let s = generate(&mut r, fam, 400);
            let n = s.context.len();
            assert!(n >= 150 && n <= 700, "{fam:?} -> {n}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        let s1 = gen_kv(&mut a, 100);
        let s2 = gen_kv(&mut b, 100);
        assert_eq!(s1.context, s2.context);
        assert_eq!(s1.answer, s2.answer);
    }
}

//! Benchmark suites: named collections of samples sized to a token
//! budget, mirroring the paper's evaluation sets (task families:
//! see [`crate::workload`] module docs).
//!
//! Context sizes are specified in *tokens* (≈ characters + BOS for the
//! byte tokenizer); generators are given a character budget slightly
//! below the target bucket so prompts always fit.

use super::spec::{self, Sample, TaskFamily};
use crate::scheduler::Priority;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Suite {
    pub name: String,
    pub samples: Vec<Sample>,
}

/// Convert a token-bucket target into a safe character budget for the
/// context (leave room for BOS + query + slack).
fn ctx_chars_for(tokens: usize) -> usize {
    tokens.saturating_sub(24) * 9 / 10
}

/// LongBench analog: mixed task families at a mid-size context.
pub fn longbench_suite(seed: u64, n_per_family: usize, ctx_tokens: usize) -> Suite {
    let mut rng = Rng::new(seed ^ 0x10b2);
    let fams = [
        TaskFamily::Kv,
        TaskFamily::MultiKv,
        TaskFamily::Vt,
        TaskFamily::Fewshot,
        TaskFamily::Code,
        TaskFamily::Qa,
    ];
    let mut samples = Vec::new();
    for fam in fams {
        for _ in 0..n_per_family {
            let c = ctx_chars_for(ctx_tokens);
            let chars = rng.range(c / 2, c);
            samples.push(spec::generate(&mut rng, fam, chars));
        }
    }
    Suite { name: format!("longbench@{ctx_tokens}"), samples }
}

/// RULER analog: NIAH-style retrieval at a *fixed* context length.
pub fn ruler_suite(seed: u64, n_per_family: usize, ctx_tokens: usize) -> Suite {
    let mut rng = Rng::new(seed ^ 0x0517);
    let fams = [TaskFamily::Kv, TaskFamily::MultiKv, TaskFamily::Vt, TaskFamily::Cwe];
    let mut samples = Vec::new();
    for fam in fams {
        for _ in 0..n_per_family {
            samples.push(spec::generate(&mut rng, fam, ctx_chars_for(ctx_tokens)));
        }
    }
    Suite { name: format!("ruler@{ctx_tokens}"), samples }
}

/// QASPER analog (Fig. 2): document QA only.
pub fn qasper_suite(seed: u64, n: usize, ctx_tokens: usize) -> Suite {
    let mut rng = Rng::new(seed ^ 0x9a5e);
    let samples = (0..n)
        .map(|_| spec::generate(&mut rng, TaskFamily::Qa, ctx_chars_for(ctx_tokens)))
        .collect();
    Suite { name: format!("qasper@{ctx_tokens}"), samples }
}

/// LongProc analog (Fig. 5): long-form structured extraction.
/// `n_records` scales the output length (the paper's 0.5K vs 2K outputs).
pub fn longproc_suite(seed: u64, n: usize, ctx_tokens: usize, n_records: usize) -> Suite {
    let mut rng = Rng::new(seed ^ 0x70c5);
    let samples = (0..n)
        .map(|_| {
            let mut s = spec::gen_longproc(&mut rng, ctx_chars_for(ctx_tokens), n_records);
            s.family = TaskFamily::LongProc;
            s
        })
        .collect();
    Suite { name: format!("longproc@{ctx_tokens}x{n_records}"), samples }
}

/// MT-Bench analog (Table 2): two-turn conversations.
pub fn mtbench_suite(seed: u64, n: usize, ctx_tokens: usize) -> Suite {
    let mut rng = Rng::new(seed ^ 0x3b7c);
    let samples =
        (0..n).map(|_| spec::gen_mtbench(&mut rng, ctx_chars_for(ctx_tokens))).collect();
    Suite { name: format!("mtbench@{ctx_tokens}"), samples }
}

/// Shared-system-prompt workload (the prefix-cache scenario): every
/// sample's context starts with one fixed "system prompt" occupying
/// `shared_pct`% of the context budget, followed by a sample-specific KV
/// retrieval task. The shared prefix is byte-identical across samples,
/// so with the byte tokenizer the first `1 + shared_chars` prompt tokens
/// (BOS included) are shared — the fraction `bench_prefix` reuses.
pub fn shared_prefix_suite(seed: u64, n: usize, ctx_tokens: usize, shared_pct: usize) -> Suite {
    assert!(shared_pct < 100, "the per-sample tail needs some budget");
    let mut rng = Rng::new(seed ^ 0x5afe);
    let budget = ctx_chars_for(ctx_tokens);
    let shared_chars = budget * shared_pct / 100;
    // One fixed pseudo system prompt: deterministic noise + a few policy
    // records, identical for every sample.
    let mut shared = String::from("system:tools=ruler,eval;policy=");
    shared.push_str(&spec::code(&mut rng, 8));
    shared.push(';');
    while shared.len() < shared_chars {
        shared.push_str(&spec::noise_word(&mut rng));
    }
    shared.truncate(shared_chars);
    let samples = (0..n)
        .map(|_| {
            let mut s = spec::gen_kv(&mut rng, budget - shared_chars);
            s.context = format!("{shared}{}", s.context);
            s
        })
        .collect();
    Suite { name: format!("shared_prefix@{ctx_tokens}x{shared_pct}pct"), samples }
}

/// One request of an open-loop serving trace: what to ask, when it
/// arrives, and who it belongs to.
#[derive(Debug, Clone)]
pub struct Arrival {
    pub sample: Sample,
    /// Arrival offset from trace start (the driver sleeps or fast-forwards
    /// to it; arrivals are non-decreasing).
    pub at_ms: f64,
    pub tenant: u32,
    pub priority: Priority,
}

/// A timed request trace (open-loop: arrivals don't wait for service).
#[derive(Debug, Clone)]
pub struct OpenLoopSuite {
    pub name: String,
    pub arrivals: Vec<Arrival>,
}

/// Bursty multi-tenant open-loop trace (the serving-bench scenario):
/// Poisson arrivals (exponential inter-arrival gaps around
/// `mean_gap_ms`), heavy-tailed prompt lengths (bounded Pareto,
/// α≈1.2 — mostly short prompts with an occasional near-`ctx_tokens`
/// monster), tenants assigned uniformly. Tenant 0 is the
/// latency-sensitive one: always [`Priority::High`]; other tenants are
/// mostly [`Priority::Normal`] with a [`Priority::Low`] batch-job tail.
/// With `tenants == 1` every arrival is tenant 0 / High (degenerate
/// single-tenant trace).
pub fn bursty_open_loop_suite(
    seed: u64,
    n: usize,
    mean_gap_ms: f64,
    ctx_tokens: usize,
    tenants: usize,
) -> OpenLoopSuite {
    assert!(tenants >= 1, "need at least one tenant");
    let mut rng = Rng::new(seed ^ 0xb065);
    let mut t = 0.0f64;
    let xm = (ctx_tokens / 8).max(48) as f64;
    let mut arrivals = Vec::with_capacity(n);
    for _ in 0..n {
        // Exponential inter-arrival gap: -mean * ln(1 - U), U ∈ [0, 1).
        t += -mean_gap_ms * (1.0 - rng.f64()).ln();
        // Bounded Pareto length: xm / (1 - U)^(1/α), clamped to the bucket.
        let toks =
            (xm / (1.0 - rng.f64()).powf(1.0 / 1.2)).min(ctx_tokens as f64) as usize;
        let toks = toks.clamp(48, ctx_tokens);
        let tenant = rng.below(tenants) as u32;
        let priority = if tenant == 0 {
            Priority::High
        } else if rng.chance(0.25) {
            Priority::Low
        } else {
            Priority::Normal
        };
        arrivals.push(Arrival {
            sample: spec::generate(&mut rng, TaskFamily::Kv, ctx_chars_for(toks)),
            at_ms: t,
            tenant,
            priority,
        });
    }
    OpenLoopSuite { name: format!("bursty@{ctx_tokens}x{tenants}t"), arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suites_fit_bucket() {
        for s in longbench_suite(1, 3, 256).samples {
            assert!(s.prompt().len() + 2 <= 256, "{}", s.prompt().len());
        }
        for s in ruler_suite(1, 3, 512).samples {
            assert!(s.prompt().len() + 2 <= 512);
        }
    }

    #[test]
    fn suites_deterministic() {
        let a = ruler_suite(7, 2, 128);
        let b = ruler_suite(7, 2, 128);
        assert_eq!(a.samples[0].context, b.samples[0].context);
    }

    #[test]
    fn longproc_output_scales() {
        let s = longproc_suite(1, 1, 512, 8);
        assert!(s.samples[0].answer.len() >= 8 * 8);
    }

    #[test]
    fn bursty_trace_is_deterministic_and_well_formed() {
        let a = bursty_open_loop_suite(11, 64, 20.0, 512, 3);
        let b = bursty_open_loop_suite(11, 64, 20.0, 512, 3);
        assert_eq!(a.arrivals.len(), 64);
        let mut prev = 0.0;
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.sample.context, y.sample.context, "trace must be deterministic");
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.priority, y.priority);
            assert!(x.at_ms >= prev, "arrivals must be non-decreasing");
            prev = x.at_ms;
            assert!(x.tenant < 3);
            assert!(x.sample.prompt().len() + 2 <= 512, "{}", x.sample.prompt().len());
            if x.tenant == 0 {
                assert_eq!(x.priority, Priority::High, "tenant 0 is the latency tenant");
            } else {
                assert_ne!(x.priority, Priority::High);
            }
        }
        // The trace actually mixes tenants and priorities.
        assert!(a.arrivals.iter().any(|x| x.tenant == 0));
        assert!(a.arrivals.iter().any(|x| x.tenant != 0));
        assert!(a.arrivals.iter().any(|x| x.priority == Priority::Low));
        assert!(a.arrivals.iter().any(|x| x.priority == Priority::Normal));
        // Heavy tail: lengths genuinely vary.
        let lens: Vec<usize> = a.arrivals.iter().map(|x| x.sample.prompt().len()).collect();
        assert!(lens.iter().max().unwrap() > &(2 * lens.iter().min().unwrap()));
    }

    #[test]
    fn bursty_trace_single_tenant_degenerates() {
        let s = bursty_open_loop_suite(5, 16, 10.0, 256, 1);
        assert!(s.arrivals.iter().all(|x| x.tenant == 0 && x.priority == Priority::High));
    }

    #[test]
    fn shared_prefix_suite_shares_exactly_the_prefix() {
        let s = shared_prefix_suite(3, 4, 512, 80);
        let budget = ctx_chars_for(512);
        let shared = budget * 80 / 100;
        let first = &s.samples[0].context[..shared];
        for sample in &s.samples {
            assert!(sample.prompt().len() + 2 <= 512, "{}", sample.prompt().len());
            assert_eq!(&sample.context[..shared], first, "shared prefix must be byte-identical");
        }
        // tails diverge (distinct KV tasks)
        assert_ne!(&s.samples[0].context[shared..], &s.samples[1].context[shared..]);
        // deterministic
        let s2 = shared_prefix_suite(3, 4, 512, 80);
        assert_eq!(s.samples[2].context, s2.samples[2].context);
    }
}

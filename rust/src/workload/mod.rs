//! Synthetic evaluation workloads — the Rust mirror of
//! `python/compile/data.py` (same task families and format contract,
//! disjoint seeds), standing in for LongBench / RULER / QASPER /
//! LongProc / MT-Bench (task families documented alongside the
//! generators in `python/compile/data.py`).
//!
//! Each [`Sample`] carries its prompt, the reference answer(s) and enough
//! metadata (needle positions are implied by the format) for the scorers
//! in [`crate::eval`].

pub mod spec;
pub mod suites;

pub use spec::{Sample, TaskFamily};
pub use suites::{
    bursty_open_loop_suite, longbench_suite, longproc_suite, mtbench_suite, qasper_suite,
    ruler_suite, shared_prefix_suite, Arrival, OpenLoopSuite, Suite,
};

//! Fixed-size block allocator with free-list reuse.
//!
//! Capacity is expressed in *slots* (one slot = one token's KV across all
//! layers/heads of a model); blocks group `block_size` slots. The
//! scheduler uses `can_alloc`/`alloc`/`free` for admission control and
//! backpressure.

use std::collections::HashMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId(pub u32);

#[derive(Debug)]
pub struct BlockAllocator {
    block_size: usize,
    n_blocks: usize,
    free: Vec<BlockId>,
    /// Owner tag per allocated block (sequence id), for leak diagnostics.
    owners: HashMap<BlockId, u64>,
    peak_used: usize,
}

impl BlockAllocator {
    /// `total_slots` not divisible by `block_size` rounds *up* to the next
    /// whole block (a budget of 65 slots at block size 8 yields 9 blocks,
    /// never a silently smaller pool). A zero-slot budget is a
    /// configuration error and is rejected loudly.
    pub fn new(total_slots: usize, block_size: usize) -> Self {
        assert!(block_size > 0, "BlockAllocator block_size must be > 0");
        assert!(
            total_slots > 0,
            "BlockAllocator needs a nonzero slot budget (got total_slots = 0)"
        );
        let n_blocks = total_slots.div_ceil(block_size);
        let free = (0..n_blocks as u32).rev().map(BlockId).collect();
        BlockAllocator { block_size, n_blocks, free, owners: HashMap::new(), peak_used: 0 }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    pub fn blocks_for_slots(&self, slots: usize) -> usize {
        slots.div_ceil(self.block_size)
    }

    pub fn can_alloc(&self, slots: usize) -> bool {
        self.blocks_for_slots(slots) <= self.free.len()
    }

    /// Allocate enough blocks for `slots` slots, tagged with `owner`.
    pub fn alloc(&mut self, owner: u64, slots: usize) -> Option<Vec<BlockId>> {
        let need = self.blocks_for_slots(slots);
        if need > self.free.len() {
            return None;
        }
        let mut out = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            debug_assert!(!self.owners.contains_key(&b), "double allocation of {b:?}");
            self.owners.insert(b, owner);
            out.push(b);
        }
        self.peak_used = self.peak_used.max(self.used_blocks());
        Some(out)
    }

    /// Return blocks to the pool. Panics on double-free or foreign blocks.
    pub fn free(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            assert!(self.owners.remove(&b).is_some(), "freeing unallocated block {b:?}");
            self.free.push(b);
        }
    }

    /// Free every block owned by `owner`; returns how many were freed.
    pub fn free_owner(&mut self, owner: u64) -> usize {
        self.take_owner(owner).len()
    }

    /// Free every block owned by `owner` and return their ids (so the
    /// caller can release the matching [`super::arena::KvArena`] buffers).
    pub fn take_owner(&mut self, owner: u64) -> Vec<BlockId> {
        let mine: Vec<BlockId> =
            self.owners.iter().filter(|(_, &o)| o == owner).map(|(&b, _)| b).collect();
        self.free(&mine);
        mine
    }

    /// Allocated block count per owner (per-owner occupancy metrics).
    pub fn owner_block_counts(&self) -> HashMap<u64, usize> {
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for &o in self.owners.values() {
            *counts.entry(o).or_insert(0) += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = BlockAllocator::new(64, 8); // 8 blocks
        assert_eq!(a.total_blocks(), 8);
        let b1 = a.alloc(1, 20).unwrap(); // 3 blocks
        assert_eq!(b1.len(), 3);
        assert_eq!(a.free_blocks(), 5);
        assert!(a.can_alloc(40));
        assert!(!a.can_alloc(41));
        a.free(&b1);
        assert_eq!(a.free_blocks(), 8);
    }

    /// Regression: a slot budget that does not divide the block size used
    /// to be silently truncated (65 slots @ block 8 -> 8 blocks = 64
    /// slots). It must round up so the full budget is always allocatable.
    #[test]
    fn non_divisible_budget_rounds_up() {
        let mut a = BlockAllocator::new(65, 8);
        assert_eq!(a.total_blocks(), 9);
        assert!(a.can_alloc(65));
        let b = a.alloc(1, 65).unwrap();
        assert_eq!(b.len(), 9);
        assert_eq!(a.free_blocks(), 0);
        // sub-block budgets still yield one usable block
        let a2 = BlockAllocator::new(3, 8);
        assert_eq!(a2.total_blocks(), 1);
        assert!(a2.can_alloc(3));
    }

    #[test]
    #[should_panic(expected = "nonzero slot budget")]
    fn zero_slot_budget_is_rejected() {
        let _ = BlockAllocator::new(0, 8);
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut a = BlockAllocator::new(16, 8);
        assert!(a.alloc(1, 16).is_some());
        assert!(a.alloc(2, 1).is_none());
    }

    #[test]
    #[should_panic(expected = "freeing unallocated")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(16, 8);
        let b = a.alloc(1, 8).unwrap();
        a.free(&b);
        a.free(&b);
    }

    #[test]
    fn free_owner_collects_all() {
        let mut a = BlockAllocator::new(64, 8);
        a.alloc(7, 24).unwrap();
        a.alloc(8, 8).unwrap();
        assert_eq!(a.free_owner(7), 3);
        assert_eq!(a.used_blocks(), 1);
    }

    /// Property: any interleaving of allocs/frees preserves capacity and
    /// never double-assigns a block.
    #[test]
    fn prop_no_leaks_no_double_assign() {
        check("allocator invariants", &Config { cases: 128, ..Config::new() }, |rng, size| {
            let mut a = BlockAllocator::new(size * 8, 4);
            let mut live: Vec<(u64, Vec<BlockId>)> = Vec::new();
            let mut next_owner = 0u64;
            for _ in 0..size {
                if rng.chance(0.6) || live.is_empty() {
                    let slots = rng.range(1, 16);
                    if let Some(bs) = a.alloc(next_owner, slots) {
                        live.push((next_owner, bs));
                        next_owner += 1;
                    }
                } else {
                    let i = rng.below(live.len());
                    let (_, bs) = live.swap_remove(i);
                    a.free(&bs);
                }
                // capacity invariant
                let live_blocks: usize = live.iter().map(|(_, b)| b.len()).sum();
                assert_eq!(live_blocks + a.free_blocks(), a.total_blocks());
                // uniqueness invariant
                let mut all: Vec<BlockId> = live.iter().flat_map(|(_, b)| b.clone()).collect();
                all.sort();
                let n = all.len();
                all.dedup();
                assert_eq!(all.len(), n, "duplicate block assignment");
            }
        });
    }
}

//! Cache manager: per-sequence cache registry + global memory accounting.

use std::collections::HashMap;

use super::block::BlockAllocator;
use super::cache::SeqCache;

/// Bytes per slot for a model (one token's KV across layers/heads).
pub fn bytes_per_slot(n_layers: usize, n_kv_heads: usize, head_dim: usize) -> usize {
    n_layers * n_kv_heads * head_dim * 4 * 2 // K and V, f32
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub active_seqs: usize,
    pub live_slots: usize,
    pub used_blocks: usize,
    pub free_blocks: usize,
    pub peak_used_blocks: usize,
}

pub struct CacheManager {
    allocator: BlockAllocator,
    seqs: HashMap<u64, SeqCache>,
}

impl CacheManager {
    /// `total_slots` is the global KV budget in token slots (the analog of
    /// GPU KV memory); `block_size` the allocation granularity.
    pub fn new(total_slots: usize, block_size: usize) -> CacheManager {
        CacheManager { allocator: BlockAllocator::new(total_slots, block_size), seqs: HashMap::new() }
    }

    /// Admission check for a sequence needing `cap` slots.
    pub fn can_admit(&self, cap: usize) -> bool {
        self.allocator.can_alloc(cap)
    }

    /// Register a prefilled+evicted sequence. Returns false (and drops the
    /// cache) if memory is exhausted — callers should have checked
    /// `can_admit` via the scheduler's admission control.
    pub fn insert(&mut self, seq_id: u64, cache: SeqCache) -> bool {
        if self.allocator.alloc(seq_id, cache.cap).is_none() {
            return false;
        }
        self.seqs.insert(seq_id, cache);
        true
    }

    pub fn get_mut(&mut self, seq_id: u64) -> Option<&mut SeqCache> {
        self.seqs.get_mut(&seq_id)
    }

    pub fn get(&self, seq_id: u64) -> Option<&SeqCache> {
        self.seqs.get(&seq_id)
    }

    /// Accounting-only reservation (cache owned elsewhere, e.g. by the
    /// engine loop's active set). Pairs with [`CacheManager::release`].
    pub fn reserve(&mut self, seq_id: u64, slots: usize) -> bool {
        self.allocator.alloc(seq_id, slots).is_some()
    }

    /// Release an accounting-only reservation.
    pub fn release(&mut self, seq_id: u64) -> usize {
        self.allocator.free_owner(seq_id)
    }

    /// Release a finished sequence's memory.
    pub fn remove(&mut self, seq_id: u64) -> Option<SeqCache> {
        let c = self.seqs.remove(&seq_id);
        if c.is_some() {
            self.allocator.free_owner(seq_id);
        }
        c
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            active_seqs: self.seqs.len(),
            live_slots: self.seqs.values().map(SeqCache::live_slots).sum(),
            used_blocks: self.allocator.used_blocks(),
            free_blocks: self.allocator.free_blocks(),
            peak_used_blocks: self.allocator.peak_used_blocks(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::TensorF;

    fn mk_cache(cap: usize) -> SeqCache {
        let k = TensorF::zeros(vec![1, 1, 4, 2]);
        SeqCache::from_selection(&k, &k, &[vec![0, 1]], 4, cap)
    }

    #[test]
    fn admit_insert_remove() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.can_admit(32));
        assert!(m.insert(1, mk_cache(32)));
        assert!(m.insert(2, mk_cache(32)));
        assert!(!m.can_admit(8));
        assert!(!m.insert(3, mk_cache(8)));
        assert!(m.remove(1).is_some());
        assert!(m.can_admit(32));
        let s = m.stats();
        assert_eq!(s.active_seqs, 1);
        assert_eq!(s.peak_used_blocks, 8);
    }

    #[test]
    fn remove_unknown_is_none() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.remove(99).is_none());
    }
}

//! Cache manager: the single home of the physical KV pool — a
//! [`BlockAllocator`] (who owns which block) plus a [`KvArena`] (the
//! bytes) — with a per-sequence dense-cache registry kept for the
//! reference path and an optional cross-request [`PrefixCache`] whose
//! nodes page into the same arena (tree blocks are reclaimed before an
//! admission is allowed to fail — see
//! [`CacheManager::prefix_reclaim_for`]).

use std::collections::HashMap;

use super::arena::{KvArena, PagedCtx};
use super::block::BlockAllocator;
use super::cache::SeqCache;
use super::paged::PagedSeqCache;
use super::prefix::{
    BlockRecord, PrefixCache, PrefixCacheConfig, PrefixMatch, PrefixPin, PrefixStats,
    PREFIX_OWNER,
};

/// Bytes per slot for a model (one token's KV across layers/heads).
pub fn bytes_per_slot(n_layers: usize, n_kv_heads: usize, head_dim: usize) -> usize {
    n_layers * n_kv_heads * head_dim * 4 * 2 // K and V, f32
}

/// What a (non-prefix) owner's blocks are charged as, for the per-owner
/// occupancy breakdown exported under `/metrics`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OwnerClass {
    /// An active sequence's decode cache (also dense reservations).
    Decode,
    /// An in-flight chunked prefill's prompt blocks.
    Prefill,
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub active_seqs: usize,
    pub live_slots: usize,
    pub used_blocks: usize,
    pub free_blocks: usize,
    pub peak_used_blocks: usize,
    /// Resident arena bytes (bound K+V buffers).
    pub arena_bytes: usize,
    pub arena_peak_bytes: usize,
    /// Arena blocks with bound buffers (≤ `used_blocks`: dense
    /// reservations charge the allocator without binding bytes).
    pub arena_blocks: usize,
    /// Allocator-block breakdown by owner class.
    pub blocks_decode: usize,
    pub blocks_prefix: usize,
    pub blocks_prefill: usize,
}

pub struct CacheManager {
    allocator: BlockAllocator,
    arena: KvArena,
    seqs: HashMap<u64, SeqCache>,
    prefix: Option<PrefixCache>,
    classes: HashMap<u64, OwnerClass>,
}

impl CacheManager {
    /// `total_slots` is the global KV budget in token slots (the analog of
    /// GPU KV memory); `block_size` the allocation granularity.
    pub fn new(total_slots: usize, block_size: usize) -> CacheManager {
        let allocator = BlockAllocator::new(total_slots, block_size);
        let arena = KvArena::new(allocator.total_blocks(), block_size);
        CacheManager {
            allocator,
            arena,
            seqs: HashMap::new(),
            prefix: None,
            classes: HashMap::new(),
        }
    }

    pub fn block_size(&self) -> usize {
        self.allocator.block_size()
    }

    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    /// Split borrow of the physical pool for engine calls that thread
    /// both halves (paged prefill, batched paged decode).
    pub fn paged_parts(&mut self) -> (&mut KvArena, &mut BlockAllocator) {
        (&mut self.arena, &mut self.allocator)
    }

    /// A [`PagedCtx`] charging `owner` for whatever it allocates (with
    /// the prefix tree wired in for before-failing LRU reclamation).
    pub fn paged_ctx(&mut self, owner: u64) -> PagedCtx<'_> {
        PagedCtx {
            arena: &mut self.arena,
            alloc: &mut self.allocator,
            prefix: self.prefix.as_mut(),
            owner,
        }
    }

    /// Tag `owner`'s blocks for the per-class occupancy breakdown.
    pub fn tag(&mut self, owner: u64, class: OwnerClass) {
        self.classes.insert(owner, class);
    }

    /// Grow a paged cache by one block, LRU-reclaiming prefix-tree blocks
    /// first when the pool is empty. False = genuine pool exhaustion
    /// (the caller finishes the sequence with `kv_exhausted`).
    pub fn grow_paged(&mut self, owner: u64, cache: &mut PagedSeqCache) -> bool {
        let bs = self.allocator.block_size();
        if !self.allocator.can_alloc(bs) {
            self.prefix_reclaim_for(bs);
        }
        cache.grow(&mut self.arena, &mut self.allocator, owner)
    }

    /// Turn on the cross-request prefix cache, capped at `max_slots` KV
    /// slots out of the shared pool (0 = bounded only by the pool itself
    /// plus LRU reclamation under admission pressure).
    pub fn enable_prefix_cache(&mut self, max_slots: usize) {
        let block = self.allocator.block_size();
        let max_blocks =
            if max_slots == 0 { usize::MAX } else { max_slots.div_ceil(block).max(1) };
        self.prefix = Some(PrefixCache::new(PrefixCacheConfig { block_size: block, max_blocks }));
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Longest cached-prefix match (pins the path). None when the prefix
    /// cache is disabled.
    pub fn prefix_lookup(
        &mut self,
        model: &str,
        tokens: &[i32],
        need_scores: bool,
        max_len: usize,
    ) -> Option<PrefixMatch> {
        let arena = &self.arena;
        self.prefix.as_mut().map(|p| p.lookup(arena, model, tokens, need_scores, max_len))
    }

    /// Insert freshly recorded prefill blocks; returns blocks added.
    pub fn prefix_insert(
        &mut self,
        model: &str,
        tokens: &[i32],
        records: Vec<BlockRecord>,
    ) -> usize {
        match self.prefix.as_mut() {
            Some(p) => p.insert(&mut self.allocator, &mut self.arena, model, tokens, records),
            None => 0,
        }
    }

    /// Release a pinned match path.
    pub fn prefix_release(&mut self, pin: PrefixPin) {
        if let Some(p) = self.prefix.as_mut() {
            p.release(pin);
        }
    }

    /// Free unpinned prefix-tree blocks (LRU leaves first) until `slots`
    /// more slots are allocatable, or the tree has nothing left to give.
    /// Returns the number of blocks reclaimed. Called by the scheduler
    /// before letting an admission fail on "kv pool exhausted".
    pub fn prefix_reclaim_for(&mut self, slots: usize) -> usize {
        let Some(p) = self.prefix.as_mut() else { return 0 };
        let mut freed = 0;
        while !self.allocator.can_alloc(slots) {
            // ask for the whole shortfall at once (one batched LRU sweep
            // per iteration, not one tree scan per block)
            let need = self
                .allocator
                .blocks_for_slots(slots)
                .saturating_sub(self.allocator.free_blocks())
                .max(1);
            let n = p.reclaim(&mut self.allocator, &mut self.arena, need);
            if n == 0 {
                break;
            }
            freed += n;
        }
        freed
    }

    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(PrefixCache::stats)
    }

    /// Admission check for a sequence needing `cap` slots.
    pub fn can_admit(&self, cap: usize) -> bool {
        self.allocator.can_alloc(cap)
    }

    /// Register a prefilled+evicted sequence. Returns false (and drops the
    /// cache) if memory is exhausted — callers should have checked
    /// `can_admit` via the scheduler's admission control.
    pub fn insert(&mut self, seq_id: u64, cache: SeqCache) -> bool {
        if self.allocator.alloc(seq_id, cache.cap).is_none() {
            return false;
        }
        self.classes.insert(seq_id, OwnerClass::Decode);
        self.seqs.insert(seq_id, cache);
        true
    }

    pub fn get_mut(&mut self, seq_id: u64) -> Option<&mut SeqCache> {
        self.seqs.get_mut(&seq_id)
    }

    pub fn get(&self, seq_id: u64) -> Option<&SeqCache> {
        self.seqs.get(&seq_id)
    }

    /// Accounting-only reservation (cache owned elsewhere, e.g. by the
    /// engine loop's active set). Pairs with [`CacheManager::release`].
    pub fn reserve(&mut self, seq_id: u64, slots: usize) -> bool {
        if self.allocator.alloc(seq_id, slots).is_none() {
            return false;
        }
        self.classes.insert(seq_id, OwnerClass::Decode);
        true
    }

    /// Release everything an owner holds: allocator blocks, any bound
    /// arena buffers, and its class tag. Returns blocks freed.
    pub fn release(&mut self, seq_id: u64) -> usize {
        let ids = self.allocator.take_owner(seq_id);
        self.arena.release(&ids);
        self.classes.remove(&seq_id);
        ids.len()
    }

    /// Release a finished sequence's memory.
    pub fn remove(&mut self, seq_id: u64) -> Option<SeqCache> {
        let c = self.seqs.remove(&seq_id);
        if c.is_some() {
            self.release(seq_id);
        }
        c
    }

    pub fn stats(&self) -> CacheStats {
        let mut by_class = [0usize; 3]; // decode, prefix, prefill
        for (owner, n) in self.allocator.owner_block_counts() {
            if owner == PREFIX_OWNER {
                by_class[1] += n;
            } else {
                match self.classes.get(&owner) {
                    Some(OwnerClass::Prefill) => by_class[2] += n,
                    _ => by_class[0] += n,
                }
            }
        }
        CacheStats {
            active_seqs: self.seqs.len(),
            live_slots: self.seqs.values().map(SeqCache::live_slots).sum(),
            used_blocks: self.allocator.used_blocks(),
            free_blocks: self.allocator.free_blocks(),
            peak_used_blocks: self.allocator.peak_used_blocks(),
            arena_bytes: self.arena.bytes_in_use(),
            arena_peak_bytes: self.arena.peak_bytes(),
            arena_blocks: self.arena.blocks_bound(),
            blocks_decode: by_class[0],
            blocks_prefix: by_class[1],
            blocks_prefill: by_class[2],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::arena::KvDims;
    use crate::util::tensor::TensorF;

    fn mk_cache(cap: usize) -> SeqCache {
        let k = TensorF::zeros(vec![1, 1, 4, 2]);
        SeqCache::from_selection(&k, &k, &[vec![0, 1]], 4, cap)
    }

    #[test]
    fn admit_insert_remove() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.can_admit(32));
        assert!(m.insert(1, mk_cache(32)));
        assert!(m.insert(2, mk_cache(32)));
        assert!(!m.can_admit(8));
        assert!(!m.insert(3, mk_cache(8)));
        assert!(m.remove(1).is_some());
        assert!(m.can_admit(32));
        let s = m.stats();
        assert_eq!(s.active_seqs, 1);
        assert_eq!(s.peak_used_blocks, 8);
        assert_eq!(s.blocks_decode, 4);
        assert_eq!(s.arena_blocks, 0, "dense registrations bind no arena bytes");
    }

    #[test]
    fn remove_unknown_is_none() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.remove(99).is_none());
    }

    #[test]
    fn paged_owner_release_returns_arena_bytes() {
        let mut m = CacheManager::new(64, 8);
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 2 };
        m.tag(7, OwnerClass::Prefill);
        let ids = m.paged_ctx(7).alloc_blocks(20, dims.slot_floats()).unwrap();
        assert_eq!(ids.len(), 3);
        let s = m.stats();
        assert_eq!(s.blocks_prefill, 3);
        assert_eq!(s.arena_blocks, 3);
        assert!(s.arena_bytes > 0);
        assert_eq!(m.release(7), 3);
        let s = m.stats();
        assert_eq!(s.arena_bytes, 0);
        assert_eq!(s.blocks_prefill, 0);
        assert_eq!(s.used_blocks, 0);
    }

    #[test]
    fn grow_paged_reclaims_tree_blocks_under_pressure() {
        let mut m = CacheManager::new(32, 8); // 4 blocks
        m.enable_prefix_cache(0);
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 2 };
        // tree holds one block
        let tokens: Vec<i32> = (0..8).collect();
        let records = vec![BlockRecord {
            start: 0,
            tokens: tokens.clone(),
            k: TensorF::zeros(vec![1, 1, 8, 2]),
            v: TensorF::zeros(vec![1, 1, 8, 2]),
            h2o: None,
        }];
        assert_eq!(m.prefix_insert("m", &tokens, records), 1);
        // a paged cache takes the remaining 3 blocks
        let k = TensorF::zeros(vec![1, 1, 8, 2]);
        let kept = vec![(0..8).collect::<Vec<usize>>()];
        let (arena, alloc) = m.paged_parts();
        let mut cache = PagedSeqCache::from_dense_selection(
            arena, alloc, 1, dims, &k, &k, &kept, 8, 64,
        )
        .unwrap();
        assert_eq!(cache.blocks.len(), 1);
        assert!(m.grow_paged(1, &mut cache));
        assert!(m.grow_paged(1, &mut cache));
        // pool is now full (3 decode + 1 tree): growth must evict the tree
        assert!(!m.can_admit(8));
        assert!(m.grow_paged(1, &mut cache), "grow must reclaim the unpinned tree block");
        assert_eq!(m.prefix_stats().unwrap().blocks, 0);
        assert_eq!(cache.blocks.len(), 4);
        // nothing left anywhere: growth finally fails
        assert!(!m.grow_paged(1, &mut cache));
    }

    /// Prefix-tree blocks come out of the same pool as sequence caches,
    /// and are given back (LRU) before an admission is allowed to fail.
    #[test]
    fn prefix_blocks_are_reclaimed_under_admission_pressure() {
        let mut m = CacheManager::new(64, 8); // 8 blocks
        m.enable_prefix_cache(0);
        assert!(m.prefix_enabled());
        let tokens: Vec<i32> = (0..16).collect(); // 2 blocks
        let records: Vec<BlockRecord> = (0..2)
            .map(|d| BlockRecord {
                start: d * 8,
                tokens: tokens[d * 8..(d + 1) * 8].to_vec(),
                k: TensorF::zeros(vec![1, 1, 8, 2]),
                v: TensorF::zeros(vec![1, 1, 8, 2]),
                h2o: Some(TensorF::zeros(vec![1, 2, (d + 1) * 8])),
            })
            .collect();
        assert_eq!(m.prefix_insert("m", &tokens, records), 2);
        assert_eq!(m.prefix_stats().unwrap().blocks, 2);
        assert_eq!(m.stats().blocks_prefix, 2);
        // sequences fill the remaining 6 blocks; the next admission must
        // succeed only after the tree gives its 2 blocks back
        assert!(m.reserve(1, 48));
        assert!(!m.can_admit(16));
        assert_eq!(m.prefix_reclaim_for(16), 2);
        assert!(m.can_admit(16));
        assert_eq!(m.prefix_stats().unwrap().blocks, 0);
        assert_eq!(m.prefix_stats().unwrap().reclaimed_blocks, 2);
        assert_eq!(m.stats().arena_bytes, 0, "reclaimed tree blocks must release bytes");
    }
}

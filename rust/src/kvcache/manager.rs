//! Cache manager: per-sequence cache registry + global memory accounting,
//! with an optional cross-request [`PrefixCache`] sharing the same block
//! pool (tree blocks are reclaimed before an admission is allowed to
//! fail — see [`CacheManager::prefix_reclaim_for`]).

use std::collections::HashMap;

use super::block::BlockAllocator;
use super::cache::SeqCache;
use super::prefix::{
    BlockRecord, PrefixCache, PrefixCacheConfig, PrefixMatch, PrefixPin, PrefixStats,
};

/// Bytes per slot for a model (one token's KV across layers/heads).
pub fn bytes_per_slot(n_layers: usize, n_kv_heads: usize, head_dim: usize) -> usize {
    n_layers * n_kv_heads * head_dim * 4 * 2 // K and V, f32
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub active_seqs: usize,
    pub live_slots: usize,
    pub used_blocks: usize,
    pub free_blocks: usize,
    pub peak_used_blocks: usize,
}

pub struct CacheManager {
    allocator: BlockAllocator,
    seqs: HashMap<u64, SeqCache>,
    prefix: Option<PrefixCache>,
}

impl CacheManager {
    /// `total_slots` is the global KV budget in token slots (the analog of
    /// GPU KV memory); `block_size` the allocation granularity.
    pub fn new(total_slots: usize, block_size: usize) -> CacheManager {
        CacheManager {
            allocator: BlockAllocator::new(total_slots, block_size),
            seqs: HashMap::new(),
            prefix: None,
        }
    }

    /// Turn on the cross-request prefix cache, capped at `max_slots` KV
    /// slots out of the shared pool (0 = bounded only by the pool itself
    /// plus LRU reclamation under admission pressure).
    pub fn enable_prefix_cache(&mut self, max_slots: usize) {
        let block = self.allocator.block_size();
        let max_blocks =
            if max_slots == 0 { usize::MAX } else { max_slots.div_ceil(block).max(1) };
        self.prefix = Some(PrefixCache::new(PrefixCacheConfig { block_size: block, max_blocks }));
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Longest cached-prefix match (pins the path). None when the prefix
    /// cache is disabled.
    pub fn prefix_lookup(
        &mut self,
        model: &str,
        tokens: &[i32],
        need_scores: bool,
        max_len: usize,
    ) -> Option<PrefixMatch> {
        self.prefix.as_mut().map(|p| p.lookup(model, tokens, need_scores, max_len))
    }

    /// Insert freshly recorded prefill blocks; returns blocks added.
    pub fn prefix_insert(
        &mut self,
        model: &str,
        tokens: &[i32],
        records: Vec<BlockRecord>,
    ) -> usize {
        match self.prefix.as_mut() {
            Some(p) => p.insert(&mut self.allocator, model, tokens, records),
            None => 0,
        }
    }

    /// Release a pinned match path.
    pub fn prefix_release(&mut self, pin: PrefixPin) {
        if let Some(p) = self.prefix.as_mut() {
            p.release(pin);
        }
    }

    /// Free unpinned prefix-tree blocks (LRU leaves first) until `slots`
    /// more slots are allocatable, or the tree has nothing left to give.
    /// Returns the number of blocks reclaimed. Called by the scheduler
    /// before letting an admission fail on "kv pool exhausted".
    pub fn prefix_reclaim_for(&mut self, slots: usize) -> usize {
        let Some(p) = self.prefix.as_mut() else { return 0 };
        let mut freed = 0;
        while !self.allocator.can_alloc(slots) {
            // ask for the whole shortfall at once (one batched LRU sweep
            // per iteration, not one arena scan per block)
            let need = self
                .allocator
                .blocks_for_slots(slots)
                .saturating_sub(self.allocator.free_blocks())
                .max(1);
            let n = p.reclaim(&mut self.allocator, need);
            if n == 0 {
                break;
            }
            freed += n;
        }
        freed
    }

    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(PrefixCache::stats)
    }

    /// Admission check for a sequence needing `cap` slots.
    pub fn can_admit(&self, cap: usize) -> bool {
        self.allocator.can_alloc(cap)
    }

    /// Register a prefilled+evicted sequence. Returns false (and drops the
    /// cache) if memory is exhausted — callers should have checked
    /// `can_admit` via the scheduler's admission control.
    pub fn insert(&mut self, seq_id: u64, cache: SeqCache) -> bool {
        if self.allocator.alloc(seq_id, cache.cap).is_none() {
            return false;
        }
        self.seqs.insert(seq_id, cache);
        true
    }

    pub fn get_mut(&mut self, seq_id: u64) -> Option<&mut SeqCache> {
        self.seqs.get_mut(&seq_id)
    }

    pub fn get(&self, seq_id: u64) -> Option<&SeqCache> {
        self.seqs.get(&seq_id)
    }

    /// Accounting-only reservation (cache owned elsewhere, e.g. by the
    /// engine loop's active set). Pairs with [`CacheManager::release`].
    pub fn reserve(&mut self, seq_id: u64, slots: usize) -> bool {
        self.allocator.alloc(seq_id, slots).is_some()
    }

    /// Release an accounting-only reservation.
    pub fn release(&mut self, seq_id: u64) -> usize {
        self.allocator.free_owner(seq_id)
    }

    /// Release a finished sequence's memory.
    pub fn remove(&mut self, seq_id: u64) -> Option<SeqCache> {
        let c = self.seqs.remove(&seq_id);
        if c.is_some() {
            self.allocator.free_owner(seq_id);
        }
        c
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            active_seqs: self.seqs.len(),
            live_slots: self.seqs.values().map(SeqCache::live_slots).sum(),
            used_blocks: self.allocator.used_blocks(),
            free_blocks: self.allocator.free_blocks(),
            peak_used_blocks: self.allocator.peak_used_blocks(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::TensorF;

    fn mk_cache(cap: usize) -> SeqCache {
        let k = TensorF::zeros(vec![1, 1, 4, 2]);
        SeqCache::from_selection(&k, &k, &[vec![0, 1]], 4, cap)
    }

    #[test]
    fn admit_insert_remove() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.can_admit(32));
        assert!(m.insert(1, mk_cache(32)));
        assert!(m.insert(2, mk_cache(32)));
        assert!(!m.can_admit(8));
        assert!(!m.insert(3, mk_cache(8)));
        assert!(m.remove(1).is_some());
        assert!(m.can_admit(32));
        let s = m.stats();
        assert_eq!(s.active_seqs, 1);
        assert_eq!(s.peak_used_blocks, 8);
    }

    #[test]
    fn remove_unknown_is_none() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.remove(99).is_none());
    }

    /// Prefix-tree blocks come out of the same pool as sequence caches,
    /// and are given back (LRU) before an admission is allowed to fail.
    #[test]
    fn prefix_blocks_are_reclaimed_under_admission_pressure() {
        let mut m = CacheManager::new(64, 8); // 8 blocks
        m.enable_prefix_cache(0);
        assert!(m.prefix_enabled());
        let tokens: Vec<i32> = (0..16).collect(); // 2 blocks
        let records: Vec<BlockRecord> = (0..2)
            .map(|d| BlockRecord {
                start: d * 8,
                tokens: tokens[d * 8..(d + 1) * 8].to_vec(),
                k: TensorF::zeros(vec![1, 1, 8, 2]),
                v: TensorF::zeros(vec![1, 1, 8, 2]),
                h2o: Some(TensorF::zeros(vec![1, 2, (d + 1) * 8])),
            })
            .collect();
        assert_eq!(m.prefix_insert("m", &tokens, records), 2);
        assert_eq!(m.prefix_stats().unwrap().blocks, 2);
        // sequences fill the remaining 6 blocks; the next admission must
        // succeed only after the tree gives its 2 blocks back
        assert!(m.reserve(1, 48));
        assert!(!m.can_admit(16));
        assert_eq!(m.prefix_reclaim_for(16), 2);
        assert!(m.can_admit(16));
        assert_eq!(m.prefix_stats().unwrap().blocks, 0);
        assert_eq!(m.prefix_stats().unwrap().reclaimed_blocks, 2);
    }
}

//! Cache manager: the single home of the physical KV pool — a
//! [`BlockAllocator`] (who owns which block) plus a [`KvArena`] (the
//! bytes) — with a per-sequence dense-cache registry kept for the
//! reference path and an optional cross-request [`PrefixCache`] whose
//! nodes page into the same arena (tree blocks are reclaimed before an
//! admission is allowed to fail — see
//! [`CacheManager::prefix_reclaim_for`]).

use std::collections::HashMap;
use std::sync::Arc;

use crate::faults::{FaultPlan, FaultSite};

use super::arena::{KvArena, KvBlock, KvDtype, PagedCtx};
use super::block::BlockAllocator;
use super::cache::SeqCache;
use super::paged::PagedSeqCache;
use super::prefix::{
    BlockRecord, PrefixCache, PrefixCacheConfig, PrefixMatch, PrefixPin, PrefixStats,
    PREFIX_OWNER,
};

/// Bytes per slot for a model (one token's KV across layers/heads),
/// at the logical f32 representation.
pub fn bytes_per_slot(n_layers: usize, n_kv_heads: usize, head_dim: usize) -> usize {
    bytes_per_slot_dtype(n_layers, n_kv_heads, head_dim, KvDtype::F32)
}

/// Dtype-true bytes per slot — what a bound arena slot actually costs
/// (u8 per-segment quant params are amortized over whole blocks and
/// charged by [`KvDtype::block_bytes`], not here). The scheduler's
/// admission/quota math charges this, so a u8 pool admits ~4× the
/// sequences of an f32 pool of the same byte budget.
pub fn bytes_per_slot_dtype(
    n_layers: usize,
    n_kv_heads: usize,
    head_dim: usize,
    dtype: KvDtype,
) -> usize {
    n_layers * n_kv_heads * head_dim * dtype.bytes_per_elem() * 2 // K and V
}

/// What a (non-prefix) owner's blocks are charged as, for the per-owner
/// occupancy breakdown exported under `/metrics`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OwnerClass {
    /// An active sequence's decode cache (also dense reservations).
    Decode,
    /// An in-flight chunked prefill's prompt blocks.
    Prefill,
}

#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub active_seqs: usize,
    pub live_slots: usize,
    pub used_blocks: usize,
    pub free_blocks: usize,
    pub peak_used_blocks: usize,
    /// Resident arena bytes (bound K+V buffers, dtype-true).
    pub arena_bytes: usize,
    /// What the same bound blocks would cost at f32; the
    /// resident/logical ratio is the arena's compression factor.
    pub arena_logical_bytes: usize,
    pub arena_peak_bytes: usize,
    /// Arena blocks with bound buffers (≤ `used_blocks`: dense
    /// reservations charge the allocator without binding bytes).
    pub arena_blocks: usize,
    /// Allocator-block breakdown by owner class.
    pub blocks_decode: usize,
    pub blocks_prefix: usize,
    pub blocks_prefill: usize,
}

/// Cold spill tier: preempted sequences' KV blocks parked in host-side
/// byte buffers, out of the arena's resident accounting. Buffers move
/// verbatim (no re-encoding), so a spill → restore round trip is
/// bit-identical by construction.
#[derive(Debug, Default)]
pub struct SpillStore {
    seqs: HashMap<u64, Vec<KvBlock>>,
    bytes: usize,
    peak_bytes: usize,
    spilled_blocks_total: usize,
    restored_blocks_total: usize,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct SpillStats {
    /// Sequences currently parked host-side.
    pub seqs: usize,
    /// Blocks currently parked host-side.
    pub blocks: usize,
    pub bytes: usize,
    pub peak_bytes: usize,
    /// Cumulative blocks ever spilled / restored.
    pub spilled_blocks_total: usize,
    pub restored_blocks_total: usize,
}

/// Result of [`CacheManager::try_restore_seq`].
#[derive(Debug, PartialEq, Eq)]
pub enum RestoreOutcome {
    /// Restored; `.0` blocks were re-bound into the arena.
    Restored(usize),
    /// Still spilled: the pool has no room for the sequence's blocks.
    NoSpace,
    /// The owner has nothing in the spill store.
    NotSpilled,
    /// The restore read failed (an injected — or, with a real backing
    /// store, actual — I/O error). The spill entry is intact; the
    /// caller may retry, and each retry re-rolls a transient fault.
    IoError,
}

pub struct CacheManager {
    allocator: BlockAllocator,
    arena: KvArena,
    seqs: HashMap<u64, SeqCache>,
    prefix: Option<PrefixCache>,
    classes: HashMap<u64, OwnerClass>,
    spill: SpillStore,
    /// Deterministic fault schedule for the spill/restore seams; None
    /// (the default) costs one null-check per call.
    faults: Option<Arc<FaultPlan>>,
    /// Per-owner spill/restore call counters — the *attempt* index fed
    /// to the fault plan, so rate faults are transient under retry.
    spill_attempts: HashMap<u64, u64>,
    restore_attempts: HashMap<u64, u64>,
}

impl CacheManager {
    /// `total_slots` is the global KV budget in token slots (the analog of
    /// GPU KV memory); `block_size` the allocation granularity.
    pub fn new(total_slots: usize, block_size: usize) -> CacheManager {
        CacheManager::with_dtype(total_slots, block_size, KvDtype::F32)
    }

    /// Like [`CacheManager::new`], with the arena storing KV in `dtype`
    /// (`--kv-dtype`; f16/u8 quantize at write time).
    pub fn with_dtype(total_slots: usize, block_size: usize, dtype: KvDtype) -> CacheManager {
        let allocator = BlockAllocator::new(total_slots, block_size);
        let arena = KvArena::with_dtype(allocator.total_blocks(), block_size, dtype);
        CacheManager {
            allocator,
            arena,
            seqs: HashMap::new(),
            prefix: None,
            classes: HashMap::new(),
            spill: SpillStore::default(),
            faults: None,
            spill_attempts: HashMap::new(),
            restore_attempts: HashMap::new(),
        }
    }

    /// Arm deterministic fault injection at the spill/restore seams.
    pub fn set_faults(&mut self, plan: Arc<FaultPlan>) {
        self.faults = Some(plan);
    }

    pub fn block_size(&self) -> usize {
        self.allocator.block_size()
    }

    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    pub fn kv_dtype(&self) -> KvDtype {
        self.arena.dtype()
    }

    /// Split borrow of the physical pool for engine calls that thread
    /// both halves (paged prefill, batched paged decode).
    pub fn paged_parts(&mut self) -> (&mut KvArena, &mut BlockAllocator) {
        (&mut self.arena, &mut self.allocator)
    }

    /// A [`PagedCtx`] charging `owner` for whatever it allocates (with
    /// the prefix tree wired in for before-failing LRU reclamation).
    pub fn paged_ctx(&mut self, owner: u64) -> PagedCtx<'_> {
        PagedCtx {
            arena: &mut self.arena,
            alloc: &mut self.allocator,
            prefix: self.prefix.as_mut(),
            owner,
        }
    }

    /// Tag `owner`'s blocks for the per-class occupancy breakdown.
    pub fn tag(&mut self, owner: u64, class: OwnerClass) {
        self.classes.insert(owner, class);
    }

    /// Grow a paged cache by one block, LRU-reclaiming prefix-tree blocks
    /// first when the pool is empty. False = genuine pool exhaustion
    /// (the caller finishes the sequence with `kv_exhausted`).
    pub fn grow_paged(&mut self, owner: u64, cache: &mut PagedSeqCache) -> bool {
        let bs = self.allocator.block_size();
        if !self.allocator.can_alloc(bs) {
            self.prefix_reclaim_for(bs);
        }
        cache.grow(&mut self.arena, &mut self.allocator, owner)
    }

    /// Turn on the cross-request prefix cache, capped at `max_slots` KV
    /// slots out of the shared pool (0 = bounded only by the pool itself
    /// plus LRU reclamation under admission pressure).
    pub fn enable_prefix_cache(&mut self, max_slots: usize) {
        let block = self.allocator.block_size();
        let max_blocks =
            if max_slots == 0 { usize::MAX } else { max_slots.div_ceil(block).max(1) };
        self.prefix = Some(PrefixCache::new(PrefixCacheConfig { block_size: block, max_blocks }));
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Longest cached-prefix match (pins the path). None when the prefix
    /// cache is disabled.
    pub fn prefix_lookup(
        &mut self,
        model: &str,
        tokens: &[i32],
        need_scores: bool,
        max_len: usize,
    ) -> Option<PrefixMatch> {
        let arena = &self.arena;
        self.prefix.as_mut().map(|p| p.lookup(arena, model, tokens, need_scores, max_len))
    }

    /// Insert freshly recorded prefill blocks; returns blocks added.
    pub fn prefix_insert(
        &mut self,
        model: &str,
        tokens: &[i32],
        records: Vec<BlockRecord>,
    ) -> usize {
        match self.prefix.as_mut() {
            Some(p) => p.insert(&mut self.allocator, &mut self.arena, model, tokens, records),
            None => 0,
        }
    }

    /// Release a pinned match path.
    pub fn prefix_release(&mut self, pin: PrefixPin) {
        if let Some(p) = self.prefix.as_mut() {
            p.release(pin);
        }
    }

    /// Free unpinned prefix-tree blocks (LRU leaves first) until `slots`
    /// more slots are allocatable, or the tree has nothing left to give.
    /// Returns the number of blocks reclaimed. Called by the scheduler
    /// before letting an admission fail on "kv pool exhausted".
    pub fn prefix_reclaim_for(&mut self, slots: usize) -> usize {
        let Some(p) = self.prefix.as_mut() else { return 0 };
        let mut freed = 0;
        while !self.allocator.can_alloc(slots) {
            // ask for the whole shortfall at once (one batched LRU sweep
            // per iteration, not one tree scan per block)
            let need = self
                .allocator
                .blocks_for_slots(slots)
                .saturating_sub(self.allocator.free_blocks())
                .max(1);
            let n = p.reclaim(&mut self.allocator, &mut self.arena, need);
            if n == 0 {
                break;
            }
            freed += n;
        }
        freed
    }

    pub fn prefix_stats(&self) -> Option<PrefixStats> {
        self.prefix.as_ref().map(PrefixCache::stats)
    }

    /// Preempt a paged sequence: move its bound arena buffers into the
    /// host-side spill store and free its allocator blocks. The cache's
    /// block table goes stale until [`CacheManager::try_restore_seq`]
    /// rebinds it — callers must not decode against a spilled sequence.
    /// Returns the number of blocks spilled.
    pub fn spill_seq(
        &mut self,
        owner: u64,
        cache: &PagedSeqCache,
    ) -> anyhow::Result<usize> {
        anyhow::ensure!(
            !self.spill.seqs.contains_key(&owner),
            "owner {owner} already has spilled blocks"
        );
        if let Some(plan) = &self.faults {
            let attempt = self.spill_attempts.entry(owner).or_insert(0);
            let fired = plan.fires(FaultSite::Spill, owner, *attempt);
            *attempt += 1;
            anyhow::ensure!(!fired, "injected spill I/O fault (owner {owner})");
        }
        let bufs = self.arena.spill(&cache.blocks)?;
        self.allocator.free(&cache.blocks);
        let bytes: usize = bufs.iter().map(KvBlock::bytes).sum();
        let n = bufs.len();
        self.spill.bytes += bytes;
        self.spill.peak_bytes = self.spill.peak_bytes.max(self.spill.bytes);
        self.spill.spilled_blocks_total += n;
        self.spill.seqs.insert(owner, bufs);
        Ok(n)
    }

    pub fn is_spilled(&self, owner: u64) -> bool {
        self.spill.seqs.contains_key(&owner)
    }

    /// Blocks a restore of `owner` would need (0 when not spilled).
    pub fn spilled_blocks(&self, owner: u64) -> usize {
        self.spill.seqs.get(&owner).map_or(0, Vec::len)
    }

    /// Resume a preempted sequence: allocate fresh blocks (reclaiming
    /// prefix-tree blocks first under pressure), re-bind the parked
    /// buffers verbatim, and rewrite the cache's block table. The KV
    /// contents are bit-identical to the moment of preemption.
    pub fn try_restore_seq(&mut self, owner: u64, cache: &mut PagedSeqCache) -> RestoreOutcome {
        let Some(bufs) = self.spill.seqs.get(&owner) else {
            return RestoreOutcome::NotSpilled;
        };
        if let Some(plan) = &self.faults {
            let attempt = self.restore_attempts.entry(owner).or_insert(0);
            let fired = plan.fires(FaultSite::Restore, owner, *attempt);
            *attempt += 1;
            if fired {
                return RestoreOutcome::IoError;
            }
        }
        let need_slots = bufs.len() * self.allocator.block_size();
        if !self.allocator.can_alloc(need_slots) {
            self.prefix_reclaim_for(need_slots);
        }
        let Some(ids) = self.allocator.alloc(owner, need_slots) else {
            return RestoreOutcome::NoSpace;
        };
        let bufs = self.spill.seqs.remove(&owner).unwrap();
        let bytes: usize = bufs.iter().map(KvBlock::bytes).sum();
        let n = bufs.len();
        self.spill.bytes -= bytes;
        self.spill.restored_blocks_total += n;
        self.arena.restore(&ids, bufs);
        cache.blocks = ids;
        RestoreOutcome::Restored(n)
    }

    /// Drop a spilled sequence without restoring it (abort/shutdown of
    /// a preempted request). Returns blocks dropped.
    pub fn drop_spilled(&mut self, owner: u64) -> usize {
        self.restore_attempts.remove(&owner);
        match self.spill.seqs.remove(&owner) {
            Some(bufs) => {
                let bytes: usize = bufs.iter().map(KvBlock::bytes).sum();
                self.spill.bytes -= bytes;
                bufs.len()
            }
            None => 0,
        }
    }

    pub fn spill_stats(&self) -> SpillStats {
        SpillStats {
            seqs: self.spill.seqs.len(),
            blocks: self.spill.seqs.values().map(Vec::len).sum(),
            bytes: self.spill.bytes,
            peak_bytes: self.spill.peak_bytes,
            spilled_blocks_total: self.spill.spilled_blocks_total,
            restored_blocks_total: self.spill.restored_blocks_total,
        }
    }

    /// Admission check for a sequence needing `cap` slots.
    pub fn can_admit(&self, cap: usize) -> bool {
        self.allocator.can_alloc(cap)
    }

    /// Register a prefilled+evicted sequence. Returns false (and drops the
    /// cache) if memory is exhausted — callers should have checked
    /// `can_admit` via the scheduler's admission control.
    pub fn insert(&mut self, seq_id: u64, cache: SeqCache) -> bool {
        if self.allocator.alloc(seq_id, cache.cap).is_none() {
            return false;
        }
        self.classes.insert(seq_id, OwnerClass::Decode);
        self.seqs.insert(seq_id, cache);
        true
    }

    pub fn get_mut(&mut self, seq_id: u64) -> Option<&mut SeqCache> {
        self.seqs.get_mut(&seq_id)
    }

    pub fn get(&self, seq_id: u64) -> Option<&SeqCache> {
        self.seqs.get(&seq_id)
    }

    /// Accounting-only reservation (cache owned elsewhere, e.g. by the
    /// engine loop's active set). Pairs with [`CacheManager::release`].
    pub fn reserve(&mut self, seq_id: u64, slots: usize) -> bool {
        if self.allocator.alloc(seq_id, slots).is_none() {
            return false;
        }
        self.classes.insert(seq_id, OwnerClass::Decode);
        true
    }

    /// Release everything an owner holds: allocator blocks, any bound
    /// arena buffers, and its class tag. Returns blocks freed.
    pub fn release(&mut self, seq_id: u64) -> usize {
        let ids = self.allocator.take_owner(seq_id);
        self.arena.release(&ids);
        self.classes.remove(&seq_id);
        self.spill_attempts.remove(&seq_id);
        self.restore_attempts.remove(&seq_id);
        ids.len()
    }

    /// Release a finished sequence's memory.
    pub fn remove(&mut self, seq_id: u64) -> Option<SeqCache> {
        let c = self.seqs.remove(&seq_id);
        if c.is_some() {
            self.release(seq_id);
        }
        c
    }

    pub fn stats(&self) -> CacheStats {
        let mut by_class = [0usize; 3]; // decode, prefix, prefill
        for (owner, n) in self.allocator.owner_block_counts() {
            if owner == PREFIX_OWNER {
                by_class[1] += n;
            } else {
                match self.classes.get(&owner) {
                    Some(OwnerClass::Prefill) => by_class[2] += n,
                    _ => by_class[0] += n,
                }
            }
        }
        CacheStats {
            active_seqs: self.seqs.len(),
            live_slots: self.seqs.values().map(SeqCache::live_slots).sum(),
            used_blocks: self.allocator.used_blocks(),
            free_blocks: self.allocator.free_blocks(),
            peak_used_blocks: self.allocator.peak_used_blocks(),
            arena_bytes: self.arena.bytes_in_use(),
            arena_logical_bytes: self.arena.logical_bytes_in_use(),
            arena_peak_bytes: self.arena.peak_bytes(),
            arena_blocks: self.arena.blocks_bound(),
            blocks_decode: by_class[0],
            blocks_prefix: by_class[1],
            blocks_prefill: by_class[2],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::arena::KvDims;
    use crate::util::tensor::TensorF;

    fn mk_cache(cap: usize) -> SeqCache {
        let k = TensorF::zeros(vec![1, 1, 4, 2]);
        SeqCache::from_selection(&k, &k, &[vec![0, 1]], 4, cap)
    }

    #[test]
    fn admit_insert_remove() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.can_admit(32));
        assert!(m.insert(1, mk_cache(32)));
        assert!(m.insert(2, mk_cache(32)));
        assert!(!m.can_admit(8));
        assert!(!m.insert(3, mk_cache(8)));
        assert!(m.remove(1).is_some());
        assert!(m.can_admit(32));
        let s = m.stats();
        assert_eq!(s.active_seqs, 1);
        assert_eq!(s.peak_used_blocks, 8);
        assert_eq!(s.blocks_decode, 4);
        assert_eq!(s.arena_blocks, 0, "dense registrations bind no arena bytes");
    }

    #[test]
    fn remove_unknown_is_none() {
        let mut m = CacheManager::new(64, 8);
        assert!(m.remove(99).is_none());
    }

    #[test]
    fn paged_owner_release_returns_arena_bytes() {
        let mut m = CacheManager::new(64, 8);
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 2 };
        m.tag(7, OwnerClass::Prefill);
        let ids = m.paged_ctx(7).alloc_blocks(20, &dims).unwrap();
        assert_eq!(ids.len(), 3);
        let s = m.stats();
        assert_eq!(s.blocks_prefill, 3);
        assert_eq!(s.arena_blocks, 3);
        assert!(s.arena_bytes > 0);
        assert_eq!(m.release(7), 3);
        let s = m.stats();
        assert_eq!(s.arena_bytes, 0);
        assert_eq!(s.blocks_prefill, 0);
        assert_eq!(s.used_blocks, 0);
    }

    #[test]
    fn grow_paged_reclaims_tree_blocks_under_pressure() {
        let mut m = CacheManager::new(32, 8); // 4 blocks
        m.enable_prefix_cache(0);
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 2 };
        // tree holds one block
        let tokens: Vec<i32> = (0..8).collect();
        let records = vec![BlockRecord {
            start: 0,
            tokens: tokens.clone(),
            k: TensorF::zeros(vec![1, 1, 8, 2]),
            v: TensorF::zeros(vec![1, 1, 8, 2]),
            h2o: None,
        }];
        assert_eq!(m.prefix_insert("m", &tokens, records), 1);
        // a paged cache takes the remaining 3 blocks
        let k = TensorF::zeros(vec![1, 1, 8, 2]);
        let kept = vec![(0..8).collect::<Vec<usize>>()];
        let (arena, alloc) = m.paged_parts();
        let mut cache = PagedSeqCache::from_dense_selection(
            arena, alloc, 1, dims, &k, &k, &kept, 8, 64,
        )
        .unwrap();
        assert_eq!(cache.blocks.len(), 1);
        assert!(m.grow_paged(1, &mut cache));
        assert!(m.grow_paged(1, &mut cache));
        // pool is now full (3 decode + 1 tree): growth must evict the tree
        assert!(!m.can_admit(8));
        assert!(m.grow_paged(1, &mut cache), "grow must reclaim the unpinned tree block");
        assert_eq!(m.prefix_stats().unwrap().blocks, 0);
        assert_eq!(cache.blocks.len(), 4);
        // nothing left anywhere: growth finally fails
        assert!(!m.grow_paged(1, &mut cache));
    }

    #[test]
    fn spill_restore_roundtrip_bit_identical() {
        let mut m = CacheManager::new(64, 8); // 8 blocks
        let dims = KvDims { n_layers: 2, n_kv_heads: 1, head_dim: 2 };
        let mut k = TensorF::zeros(vec![2, 1, 12, 2]);
        let mut v = TensorF::zeros(vec![2, 1, 12, 2]);
        for (i, x) in k.data.iter_mut().enumerate() {
            *x = i as f32 * 0.5 + 1.0;
        }
        for (i, x) in v.data.iter_mut().enumerate() {
            *x = -(i as f32) * 0.25;
        }
        let kept = vec![(0..12).collect::<Vec<usize>>(), (2..12).collect::<Vec<usize>>()];
        let (arena, alloc) = m.paged_parts();
        let mut cache =
            PagedSeqCache::from_dense_selection(arena, alloc, 1, dims, &k, &v, &kept, 12, 32)
                .unwrap();
        m.tag(1, OwnerClass::Decode);
        let before = cache.gather_dense(m.arena(), 32).unwrap();
        let bytes_resident = m.stats().arena_bytes;
        assert!(bytes_resident > 0);

        let spilled = m.spill_seq(1, &cache).unwrap();
        assert_eq!(spilled, cache.blocks.len());
        assert!(m.is_spilled(1));
        assert_eq!(m.stats().arena_bytes, 0, "spilled bytes must leave resident accounting");
        assert_eq!(m.stats().used_blocks, 0, "spilled blocks must return to the allocator");
        let ss = m.spill_stats();
        assert_eq!((ss.seqs, ss.blocks, ss.spilled_blocks_total), (1, spilled, spilled));
        assert!(ss.bytes > 0);

        // double-spill is rejected, restore of an unknown owner is NotSpilled
        assert!(m.spill_seq(1, &cache).is_err());
        let mut other = cache.gather_dense(m.arena(), 32);
        assert!(other.is_err() || m.try_restore_seq(99, &mut cache) == RestoreOutcome::NotSpilled);

        match m.try_restore_seq(1, &mut cache) {
            RestoreOutcome::Restored(n) => assert_eq!(n, spilled),
            o => panic!("restore failed: {o:?}"),
        }
        assert!(!m.is_spilled(1));
        assert_eq!(m.stats().arena_bytes, bytes_resident);
        let after = cache.gather_dense(m.arena(), 32).unwrap();
        assert_eq!(before.k.data, after.k.data, "K must survive spill/restore bit-identically");
        assert_eq!(before.v.data, after.v.data, "V must survive spill/restore bit-identically");
        assert_eq!(m.spill_stats().restored_blocks_total, spilled);
        assert_eq!(m.spill_stats().bytes, 0);
        other = cache.gather_dense(m.arena(), 32);
        assert!(other.is_ok());
    }

    #[test]
    fn restore_reports_no_space_when_pool_full() {
        let mut m = CacheManager::new(32, 8); // 4 blocks
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 2 };
        let k = TensorF::zeros(vec![1, 1, 8, 2]);
        let kept = vec![(0..8).collect::<Vec<usize>>()];
        let (arena, alloc) = m.paged_parts();
        let mut cache =
            PagedSeqCache::from_dense_selection(arena, alloc, 1, dims, &k, &k, &kept, 8, 32)
                .unwrap();
        m.spill_seq(1, &mut cache).unwrap();
        assert!(m.reserve(2, 32), "another owner grabs the whole pool");
        assert_eq!(m.try_restore_seq(1, &mut cache), RestoreOutcome::NoSpace);
        assert!(m.is_spilled(1), "NoSpace must leave the spill entry intact");
        m.release(2);
        assert!(matches!(m.try_restore_seq(1, &mut cache), RestoreOutcome::Restored(1)));
        // dropping a restored owner is a no-op; dropping a spilled one frees it
        assert_eq!(m.drop_spilled(1), 0);
        m.spill_seq(1, &cache).unwrap();
        assert_eq!(m.drop_spilled(1), 1);
        assert_eq!(m.spill_stats().bytes, 0);
    }

    /// Injected spill/restore faults: a permanent (ids-based) restore
    /// fault returns `IoError` on every attempt and leaves the spill
    /// entry intact; transient (rate-based) faults clear under retry.
    /// No fault ever corrupts the round-trip payload.
    #[test]
    fn injected_faults_fail_cleanly_and_retry_clears_transients() {
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 2 };
        let k = TensorF::zeros(vec![1, 1, 8, 2]);
        let kept = vec![(0..8).collect::<Vec<usize>>()];

        // Permanent restore fault for owner 1: IoError forever, entry intact.
        let mut m = CacheManager::new(64, 8);
        m.set_faults(Arc::new(crate::faults::FaultPlan::parse("restore:ids=1").unwrap()));
        let (arena, alloc) = m.paged_parts();
        let mut cache =
            PagedSeqCache::from_dense_selection(arena, alloc, 1, dims, &k, &k, &kept, 8, 32)
                .unwrap();
        m.spill_seq(1, &cache).unwrap();
        for _ in 0..4 {
            assert_eq!(m.try_restore_seq(1, &mut cache), RestoreOutcome::IoError);
            assert!(m.is_spilled(1), "IoError must leave the spill entry intact");
        }
        assert_eq!(m.drop_spilled(1), 1);
        assert_eq!(m.spill_stats().bytes, 0);

        // Transient restore fault: with rate=0.5, some attempt in a
        // reasonable retry budget succeeds, and the data is intact.
        let mut m = CacheManager::new(64, 8);
        m.set_faults(Arc::new(
            crate::faults::FaultPlan::parse("seed=3;restore:rate=0.5").unwrap(),
        ));
        let (arena, alloc) = m.paged_parts();
        let mut cache =
            PagedSeqCache::from_dense_selection(arena, alloc, 2, dims, &k, &k, &kept, 8, 32)
                .unwrap();
        let before = cache.gather_dense(m.arena(), 32).unwrap();
        m.spill_seq(2, &cache).unwrap();
        let mut restored = false;
        for _ in 0..64 {
            match m.try_restore_seq(2, &mut cache) {
                RestoreOutcome::Restored(n) => {
                    assert_eq!(n, 1);
                    restored = true;
                    break;
                }
                RestoreOutcome::IoError => continue,
                o => panic!("unexpected outcome {o:?}"),
            }
        }
        assert!(restored, "a rate=0.5 fault must clear within 64 retries");
        let after = cache.gather_dense(m.arena(), 32).unwrap();
        assert_eq!(before.k.data, after.k.data, "payload must survive faulted retries");

        // A fired spill fault leaves the sequence resident and retryable.
        let mut m = CacheManager::new(64, 8);
        m.set_faults(Arc::new(crate::faults::FaultPlan::parse("spill:every=1").unwrap()));
        let (arena, alloc) = m.paged_parts();
        let cache =
            PagedSeqCache::from_dense_selection(arena, alloc, 3, dims, &k, &k, &kept, 8, 32)
                .unwrap();
        let resident = m.stats().arena_bytes;
        assert!(m.spill_seq(3, &cache).is_err(), "every=1 spill fault must fire");
        assert!(!m.is_spilled(3));
        assert_eq!(m.stats().arena_bytes, resident, "failed spill must leave bytes resident");
        assert_eq!(m.release(3), 1);
    }

    /// Prefix-tree blocks come out of the same pool as sequence caches,
    /// and are given back (LRU) before an admission is allowed to fail.
    #[test]
    fn prefix_blocks_are_reclaimed_under_admission_pressure() {
        let mut m = CacheManager::new(64, 8); // 8 blocks
        m.enable_prefix_cache(0);
        assert!(m.prefix_enabled());
        let tokens: Vec<i32> = (0..16).collect(); // 2 blocks
        let records: Vec<BlockRecord> = (0..2)
            .map(|d| BlockRecord {
                start: d * 8,
                tokens: tokens[d * 8..(d + 1) * 8].to_vec(),
                k: TensorF::zeros(vec![1, 1, 8, 2]),
                v: TensorF::zeros(vec![1, 1, 8, 2]),
                h2o: Some(TensorF::zeros(vec![1, 2, (d + 1) * 8])),
            })
            .collect();
        assert_eq!(m.prefix_insert("m", &tokens, records), 2);
        assert_eq!(m.prefix_stats().unwrap().blocks, 2);
        assert_eq!(m.stats().blocks_prefix, 2);
        // sequences fill the remaining 6 blocks; the next admission must
        // succeed only after the tree gives its 2 blocks back
        assert!(m.reserve(1, 48));
        assert!(!m.can_admit(16));
        assert_eq!(m.prefix_reclaim_for(16), 2);
        assert!(m.can_admit(16));
        assert_eq!(m.prefix_stats().unwrap().blocks, 0);
        assert_eq!(m.prefix_stats().unwrap().reclaimed_blocks, 2);
        assert_eq!(m.stats().arena_bytes, 0, "reclaimed tree blocks must release bytes");
    }
}

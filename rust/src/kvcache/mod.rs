//! Paged KV-cache management.
//!
//! * [`block::BlockAllocator`] — a vLLM-style fixed-size block pool with
//!   global capacity accounting (admission control for the scheduler);
//! * [`cache::SeqCache`] — one sequence's compacted post-eviction cache:
//!   host K/V tensors shaped `[L, Hkv, cap, dh]`, per-layer live lengths,
//!   and the slot→absolute-position map needed to interpret decode-time
//!   attention probabilities (GT importance tracking, Table 8);
//! * [`prefix::PrefixCache`] — the cross-request prefix cache: a radix
//!   tree over token-id block chunks whose nodes own ref-counted blocks
//!   of *pre-eviction* chunked-prefill state (per-layer KV + the running
//!   H2O score accumulator), enabling prefix-aware prefill resume;
//! * [`manager::CacheManager`] — ties all three together over one shared
//!   block pool.

pub mod block;
pub mod cache;
pub mod manager;
pub mod prefix;

pub use block::BlockAllocator;
pub use cache::SeqCache;
pub use manager::CacheManager;
pub use prefix::{
    BlockRecord, MatchKind, PrefixCache, PrefixCacheConfig, PrefixMatch, PrefixPin, PrefixStats,
};

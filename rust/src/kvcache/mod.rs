//! Paged KV-cache management.
//!
//! * [`block::BlockAllocator`] — a vLLM-style fixed-size block pool with
//!   global capacity accounting (admission control for the scheduler);
//! * [`cache::SeqCache`] — one sequence's compacted post-eviction cache:
//!   host K/V tensors shaped `[L, Hkv, cap, dh]`, per-layer live lengths,
//!   and the slot→absolute-position map needed to interpret decode-time
//!   attention probabilities (GT importance tracking, Table 8);
//! * [`manager::CacheManager`] — ties both together per active sequence.

pub mod block;
pub mod cache;
pub mod manager;

pub use block::BlockAllocator;
pub use cache::SeqCache;
pub use manager::CacheManager;

//! Paged KV-cache management.
//!
//! * [`block::BlockAllocator`] — a vLLM-style fixed-size block pool with
//!   global capacity accounting (admission control for the scheduler);
//! * [`arena::KvArena`] — the *physical* side of the pool: per-block K/V
//!   buffers shared by decode caches, in-flight chunked-prefill state
//!   and prefix-tree nodes, plus the [`arena::KvAccess`] row abstraction
//!   the reference kernels are generic over (dense and paged paths run
//!   the same math, bit for bit);
//! * [`cache::SeqCache`] — one sequence's compacted post-eviction cache
//!   in the dense reference layout: host K/V tensors shaped
//!   `[L, Hkv, cap, dh]`, per-layer live lengths, and the
//!   slot→absolute-position map needed to interpret decode-time
//!   attention probabilities (GT importance tracking, Table 8);
//! * [`paged::PagedSeqCache`] — the serving default: the same cache as a
//!   block table over the arena, built by gather-compaction and grown
//!   block-by-block during decode instead of finishing at a fixed cap;
//! * [`prefix::PrefixCache`] — the cross-request prefix cache: a radix
//!   tree over token-id block chunks whose nodes own ref-counted arena
//!   blocks of *pre-eviction* chunked-prefill state (per-layer KV + the
//!   running H2O score accumulator), enabling prefix-aware prefill
//!   resume;
//! * [`manager::CacheManager`] — ties all of it together over one shared
//!   block pool, with per-owner-class occupancy accounting.

pub mod arena;
pub mod block;
pub mod cache;
pub mod manager;
pub mod paged;
pub mod prefix;

pub use arena::{
    DenseKvRef, KvAccess, KvArena, KvBlock, KvDims, KvDtype, KvPlane, OwnedKv, PagedCtx, Seg,
};
pub use block::{BlockAllocator, BlockId};
pub use cache::SeqCache;
pub use manager::{CacheManager, OwnerClass, RestoreOutcome, SpillStats, SpillStore};
pub use paged::PagedSeqCache;
pub use prefix::{
    BlockRecord, MatchKind, PrefixCache, PrefixCacheConfig, PrefixMatch, PrefixPin, PrefixStats,
};

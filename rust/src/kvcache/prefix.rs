//! Cross-request prefix cache: a radix tree over token-id block chunks
//! whose nodes own ref-counted KV cache blocks.
//!
//! Serving traffic with a long shared system/tool prompt repeats the same
//! prefill work on every request. This tree caches the *pre-eviction*
//! chunked-prefill state — per-layer KV rows plus the running H2O column
//! sums of the score accumulator — at [`BlockAllocator::block_size`]
//! granularity, keyed by the exact token ids of each block. On admission
//! the scheduler matches the longest cached prefix, **pins** its path
//! (ref-counts), and seeds a [`crate::runtime::PrefixSeed`] so the engine
//! resumes prefill mid-prompt ([`crate::runtime::ChunkState::resume`])
//! instead of starting from token 0.
//!
//! Sharing semantics are copy-on-write: tree blocks are immutable once
//! inserted; a resuming request *copies* the pinned rows into its private
//! `ChunkState`, and a prompt that diverges mid-block simply stops
//! matching — divergence at block granularity creates sibling nodes, and
//! no shared block is ever mutated (property-tested below).
//!
//! Interplay with eviction: only **pre-eviction** prefill state is
//! shareable. Eviction/compaction runs at `prefill_finalize` time on
//! full-prompt scores, *per request* (budgets differ), so compacted
//! post-eviction caches are never inserted here — the tree holds the
//! method-independent dense prefix state that every policy's prefill
//! passes through.
//!
//! Memory is shared *physically* with the serving pool: every node owns
//! one [`BlockAllocator`] block (owner [`PREFIX_OWNER`]) whose KV bytes
//! live in the same [`KvArena`] the decode caches and in-flight prefills
//! page into — a tree block and a decode block are interchangeable
//! storage, not separate accounting columns. Under allocator pressure
//! the scheduler reclaims unpinned leaves in LRU order
//! ([`PrefixCache::reclaim`]) before failing an admission, returning
//! both the block and its arena buffers.

use std::collections::HashMap;

use crate::runtime::PrefixSeed;
use crate::util::tensor::TensorF;

use super::arena::{KvArena, KvDims};
use super::block::{BlockAllocator, BlockId};

/// Allocator owner tag for tree-held blocks (sequence ids are small
/// monotonically assigned integers; this can never collide).
pub const PREFIX_OWNER: u64 = u64::MAX;

#[derive(Debug, Clone)]
pub struct PrefixCacheConfig {
    /// Token (= slot) granularity of one tree block. Must equal the
    /// shared allocator's block size.
    pub block_size: usize,
    /// Hard cap on tree-held blocks (`usize::MAX` = bounded only by the
    /// shared pool + LRU reclamation).
    pub max_blocks: usize,
}

/// One recorded block of chunked-prefill state, produced by the engine's
/// recording pass (`engine::chunked`) and inserted via
/// [`PrefixCache::insert`].
#[derive(Debug, Clone)]
pub struct BlockRecord {
    /// Absolute token offset of this block (multiple of `block_size`).
    pub start: usize,
    /// The exact `block_size` token ids this block covers.
    pub tokens: Vec<i32>,
    /// `[L, Hkv, block_size, dh]` KV rows `start..start+block_size`.
    pub k: TensorF,
    pub v: TensorF,
    /// `[L, H, start + block_size]` *cumulative* raw H2O column sums over
    /// query rows `0..start+block_size` (base passes; lookahead passes
    /// record `None`).
    pub h2o: Option<TensorF>,
}

struct Node {
    /// Token offset of this block (depth * block_size).
    start: usize,
    tokens: Vec<i32>,
    /// KV geometry of the arena block (needed to assemble seeds).
    dims: KvDims,
    /// Cumulative raw H2O column sums (small score state; KV bytes live
    /// in the arena block, not here).
    h2o: Option<TensorF>,
    block: BlockId,
    parent: Option<usize>,
    children: HashMap<Vec<i32>, usize>,
    /// Pin count: >0 while an in-flight prefill resumes from this node.
    refs: usize,
    /// LRU tick of the last lookup/insert touching this node.
    last_use: u64,
    /// Owning model tree (needed to unlink depth-0 nodes on reclaim).
    model: String,
}

/// Pinned path handle returned by [`PrefixCache::lookup`]; must be given
/// back via [`PrefixCache::release`] once the resumed prefill finished
/// (or failed). Consuming it by value makes double-release a type error.
#[derive(Debug)]
pub struct PrefixPin {
    nodes: Vec<usize>,
}

impl PrefixPin {
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MatchKind {
    /// No usable cached prefix.
    Miss,
    /// Some, but not all, of the prompt's resumable blocks were cached.
    Partial,
    /// Every resumable block of the prompt was served from the tree.
    Full,
}

/// Result of a longest-prefix match: the seed (when any block matched)
/// plus the pinned path.
pub struct PrefixMatch {
    pub kind: MatchKind,
    /// Prompt tokens covered by `seed` (0 on a miss).
    pub resume_len: usize,
    pub seed: Option<PrefixSeed>,
    pub pin: PrefixPin,
}

#[derive(Debug, Clone, Default)]
pub struct PrefixStats {
    pub nodes: usize,
    pub blocks: usize,
    pub pinned_nodes: usize,
    pub inserted_blocks: u64,
    pub reclaimed_blocks: u64,
}

pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    /// Per-model root children (block tokens -> arena index).
    roots: HashMap<String, HashMap<Vec<i32>, usize>>,
    arena: Vec<Option<Node>>,
    free_slots: Vec<usize>,
    tick: u64,
    n_blocks: usize,
    inserted_blocks: u64,
    reclaimed_blocks: u64,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> PrefixCache {
        assert!(cfg.block_size > 0, "prefix cache block size must be > 0");
        PrefixCache {
            cfg,
            roots: HashMap::new(),
            arena: Vec::new(),
            free_slots: Vec::new(),
            tick: 0,
            n_blocks: 0,
            inserted_blocks: 0,
            reclaimed_blocks: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    fn node(&self, i: usize) -> &Node {
        self.arena[i].as_ref().expect("dangling prefix node index")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.arena[i].as_mut().expect("dangling prefix node index")
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Longest-prefix match for `tokens` under `model`, usable up to
    /// `max_len` tokens (the caller's resume cap — `win_start` for base
    /// passes, `logit_pos` for lookahead passes). `need_scores` restricts
    /// the resume point to nodes carrying H2O sums (base passes). The
    /// matched path is pinned; release it with [`PrefixCache::release`].
    pub fn lookup(
        &mut self,
        arena: &KvArena,
        model: &str,
        tokens: &[i32],
        need_scores: bool,
        max_len: usize,
    ) -> PrefixMatch {
        let b = self.cfg.block_size;
        let tick = self.next_tick();
        // Deepest block boundary the caller could use at all.
        let usable_blocks = (max_len.min(tokens.len()) / b).min(tokens.len() / b);
        let mut path: Vec<usize> = Vec::new();
        let mut best_depth: Option<usize> = None; // index into `path`
        {
            let mut children = match self.roots.get(model) {
                Some(c) => c,
                None => {
                    return PrefixMatch {
                        kind: MatchKind::Miss,
                        resume_len: 0,
                        seed: None,
                        pin: PrefixPin { nodes: Vec::new() },
                    }
                }
            };
            for depth in 0..usable_blocks {
                let key = &tokens[depth * b..(depth + 1) * b];
                let Some(&idx) = children.get(key) else { break };
                path.push(idx);
                let node = self.node(idx);
                if !need_scores || node.h2o.is_some() {
                    best_depth = Some(depth);
                }
                children = &self.node(idx).children;
            }
        }
        let Some(best) = best_depth else {
            // Nothing usable: pin nothing (matched-but-unusable nodes are
            // left reclaimable; the request recomputes from token 0).
            return PrefixMatch {
                kind: MatchKind::Miss,
                resume_len: 0,
                seed: None,
                pin: PrefixPin { nodes: Vec::new() },
            };
        };
        // Pin and LRU-touch exactly the blocks the seed uses.
        path.truncate(best + 1);
        for &i in &path {
            let n = self.node_mut(i);
            n.refs += 1;
            n.last_use = tick;
        }
        let resume_len = (best + 1) * b;
        let seed = self.build_seed(arena, &path, resume_len);
        let kind = if best + 1 == usable_blocks { MatchKind::Full } else { MatchKind::Partial };
        PrefixMatch { kind, resume_len, seed: Some(seed), pin: PrefixPin { nodes: path } }
    }

    /// Concatenate the path's arena KV blocks (and clone the deepest
    /// node's cumulative H2O snapshot) into a private, request-owned
    /// seed — the copy-on-write boundary: tree blocks are never handed
    /// out mutably.
    fn build_seed(&self, arena: &KvArena, path: &[usize], resume_len: usize) -> PrefixSeed {
        let b = self.cfg.block_size;
        let deepest = self.node(*path.last().expect("seed of an empty path"));
        let dims = deepest.dims;
        let (l, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.head_dim);
        let mut k = TensorF::zeros(vec![l, hkv, resume_len, dh]);
        let mut v = TensorF::zeros(vec![l, hkv, resume_len, dh]);
        for (depth, &i) in path.iter().enumerate() {
            let node = self.node(i);
            debug_assert_eq!(node.start, depth * b, "prefix path out of order");
            debug_assert_eq!(node.dims, dims, "prefix path mixes model geometries");
            let (bk, bv) = arena
                .block_kv(node.block)
                .expect("prefix node lost its arena block");
            for li in 0..l {
                for g in 0..hkv {
                    let src = ((li * hkv + g) * b) * dh;
                    let dst = ((li * hkv + g) * resume_len + depth * b) * dh;
                    k.data[dst..dst + b * dh].copy_from_slice(&bk[src..src + b * dh]);
                    v.data[dst..dst + b * dh].copy_from_slice(&bv[src..src + b * dh]);
                }
            }
        }
        let h2o = deepest.h2o.as_ref().map(|t| {
            debug_assert_eq!(t.shape[2], resume_len, "h2o snapshot extent");
            t.clone()
        });
        PrefixSeed { len: resume_len, k, v, h2o }
    }

    /// Unpin a matched path.
    pub fn release(&mut self, pin: PrefixPin) {
        for i in pin.nodes {
            let n = self.node_mut(i);
            assert!(n.refs > 0, "prefix node released more times than pinned");
            n.refs -= 1;
        }
    }

    /// Insert the recorded blocks of one finished prefill pass. `tokens`
    /// is the full pass prompt (used to walk/extend the tree); `records`
    /// hold the newly computed blocks (any already-cached prefix blocks
    /// are absent — they were matched, not recomputed). Existing
    /// KV-only nodes are upgraded in place when a record carries H2O
    /// sums. Returns the number of blocks newly charged to the allocator.
    /// Insertion stops early (never fails) when the allocator — after LRU
    /// reclamation — or `max_blocks` cannot take another block.
    pub fn insert(
        &mut self,
        alloc: &mut BlockAllocator,
        arena: &mut KvArena,
        model: &str,
        tokens: &[i32],
        records: Vec<BlockRecord>,
    ) -> usize {
        let b = self.cfg.block_size;
        debug_assert_eq!(alloc.block_size(), b, "prefix cache / allocator block size mismatch");
        let by_start: HashMap<usize, BlockRecord> =
            records.into_iter().map(|r| (r.start, r)).collect();
        let tick = self.next_tick();
        let mut inserted = 0usize;
        let mut parent: Option<usize> = None;
        // The walked/created chain is temporarily pinned so mid-insert LRU
        // reclamation can never free an ancestor of the node being added.
        let mut path_pins: Vec<usize> = Vec::new();
        for depth in 0..tokens.len() / b {
            let start = depth * b;
            let key = tokens[start..start + b].to_vec();
            let existing = match parent {
                None => self.roots.get(model).and_then(|c| c.get(&key)).copied(),
                Some(p) => self.node(p).children.get(&key).copied(),
            };
            if let Some(idx) = existing {
                let rec_h2o = by_start.get(&start).and_then(|r| r.h2o.clone());
                let node = self.node_mut(idx);
                node.last_use = tick;
                node.refs += 1;
                if node.h2o.is_none() {
                    if let Some(h2o) = rec_h2o {
                        node.h2o = Some(h2o); // upgrade a KV-only (lookahead) node
                    }
                }
                path_pins.push(idx);
                parent = Some(idx);
                continue;
            }
            // New node: need its record and an allocator block.
            let Some(rec) = by_start.get(&start) else { break };
            if self.n_blocks >= self.cfg.max_blocks && self.reclaim(alloc, arena, 1) == 0 {
                break;
            }
            let ids = match alloc.alloc(PREFIX_OWNER, b) {
                Some(ids) => ids,
                None => {
                    // allocator pressure: try to make room from our own
                    // cold leaves before giving up on this insertion
                    if self.reclaim(alloc, arena, 1) == 0 {
                        break;
                    }
                    match alloc.alloc(PREFIX_OWNER, b) {
                        Some(ids) => ids,
                        None => break,
                    }
                }
            };
            debug_assert_eq!(ids.len(), 1);
            debug_assert_eq!(rec.tokens, key, "block record tokens disagree with the prompt");
            // The record's [L, Hkv, b, dh] tensors have exactly the
            // arena's block layout: bind and copy the whole buffers.
            let dims = KvDims {
                n_layers: rec.k.shape[0],
                n_kv_heads: rec.k.shape[1],
                head_dim: rec.k.shape[3],
            };
            debug_assert_eq!(rec.k.shape[2], b, "record rows disagree with the block size");
            arena.bind(&ids, &dims);
            arena.write_block(ids[0], &rec.k.data, &rec.v.data);
            let node = Node {
                start,
                tokens: key.clone(),
                dims,
                h2o: rec.h2o.clone(),
                block: ids[0],
                parent,
                children: HashMap::new(),
                refs: 1, // insertion-path pin, dropped below
                last_use: tick,
                model: model.to_string(),
            };
            let idx = match self.free_slots.pop() {
                Some(slot) => {
                    self.arena[slot] = Some(node);
                    slot
                }
                None => {
                    self.arena.push(Some(node));
                    self.arena.len() - 1
                }
            };
            match parent {
                None => {
                    self.roots.entry(model.to_string()).or_default().insert(key, idx);
                }
                Some(p) => {
                    self.node_mut(p).children.insert(key, idx);
                }
            }
            self.n_blocks += 1;
            self.inserted_blocks += 1;
            inserted += 1;
            path_pins.push(idx);
            parent = Some(idx);
        }
        for i in path_pins {
            self.node_mut(i).refs -= 1;
        }
        inserted
    }

    /// Free up to `want_blocks` unpinned **leaves** back to the
    /// allocator (and their buffers back to the arena), coldest (LRU)
    /// first; interior nodes become reclaimable as their subtrees drain.
    /// Each pass collects every current unpinned leaf in one node-table
    /// scan and drains them in LRU order, so freeing k blocks costs
    /// O(nodes · depth) rather than O(nodes · k). Returns how many
    /// blocks were freed.
    pub fn reclaim(
        &mut self,
        alloc: &mut BlockAllocator,
        arena: &mut KvArena,
        want_blocks: usize,
    ) -> usize {
        let mut freed = 0usize;
        while freed < want_blocks {
            let mut victims: Vec<(u64, usize)> = self
                .arena
                .iter()
                .enumerate()
                .filter_map(|(i, slot)| slot.as_ref().map(|n| (i, n)))
                .filter(|(_, n)| n.refs == 0 && n.children.is_empty())
                .map(|(i, n)| (n.last_use, i))
                .collect();
            if victims.is_empty() {
                break;
            }
            victims.sort_unstable();
            for (_, i) in victims {
                if freed >= want_blocks {
                    break;
                }
                self.remove_leaf(i, alloc, arena);
                freed += 1;
            }
            // freeing leaves may have exposed their parents as new
            // (possibly colder) leaves — the next pass picks them up
        }
        freed
    }

    fn remove_leaf(&mut self, i: usize, alloc: &mut BlockAllocator, arena: &mut KvArena) {
        let node = self.arena[i].take().expect("reclaim victim vanished");
        debug_assert!(node.refs == 0 && node.children.is_empty());
        match node.parent {
            Some(p) => {
                self.node_mut(p).children.remove(&node.tokens);
            }
            None => {
                if let Some(root) = self.roots.get_mut(&node.model) {
                    root.remove(&node.tokens);
                }
            }
        }
        arena.release(&[node.block]);
        alloc.free(&[node.block]);
        self.free_slots.push(i);
        self.n_blocks -= 1;
        self.reclaimed_blocks += 1;
    }

    pub fn stats(&self) -> PrefixStats {
        let live = self.arena.iter().flatten();
        PrefixStats {
            nodes: self.arena.iter().flatten().count(),
            blocks: self.n_blocks,
            pinned_nodes: live.filter(|n| n.refs > 0).count(),
            inserted_blocks: self.inserted_blocks,
            reclaimed_blocks: self.reclaimed_blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::rng::Rng;

    const B: usize = 4; // tokens per block
    const L: usize = 1;
    const HKV: usize = 1;
    const H: usize = 2;
    const DH: usize = 2;

    /// Deterministic per-token synthetic "KV": lets exactness checks
    /// verify *content*, not just lengths.
    fn kv_of(tokens: &[i32]) -> (TensorF, TensorF) {
        let mut k = TensorF::zeros(vec![L, HKV, tokens.len(), DH]);
        let mut v = TensorF::zeros(vec![L, HKV, tokens.len(), DH]);
        for (r, &t) in tokens.iter().enumerate() {
            for e in 0..DH {
                k.data[r * DH + e] = t as f32 + e as f32 * 0.5;
                v.data[r * DH + e] = -(t as f32) - e as f32 * 0.25;
            }
        }
        (k, v)
    }

    fn h2o_of(tokens: &[i32], end: usize) -> TensorF {
        let mut t = TensorF::zeros(vec![L, H, end]);
        for hi in 0..H {
            for j in 0..end {
                t.data[hi * end + j] = tokens[j] as f32 * (hi + 1) as f32;
            }
        }
        t
    }

    /// Records for every full block of `tokens` starting at block
    /// `from_block` (with or without H2O sums).
    fn records(tokens: &[i32], from_block: usize, with_h2o: bool) -> Vec<BlockRecord> {
        (from_block..tokens.len() / B)
            .map(|d| {
                let start = d * B;
                let blk = &tokens[start..start + B];
                let (k, v) = kv_of(blk);
                BlockRecord {
                    start,
                    tokens: blk.to_vec(),
                    k,
                    v,
                    h2o: with_h2o.then(|| h2o_of(tokens, start + B)),
                }
            })
            .collect()
    }

    fn cache() -> (PrefixCache, BlockAllocator, KvArena) {
        (
            PrefixCache::new(PrefixCacheConfig { block_size: B, max_blocks: usize::MAX }),
            BlockAllocator::new(64 * B, B),
            KvArena::new(64, B),
        )
    }

    #[test]
    fn match_after_insert_is_exact() {
        let (mut c, mut a, mut ar) = cache();
        let tokens: Vec<i32> = (0..13).collect(); // 3 full blocks + tail
        let n = c.insert(&mut a, &mut ar, "m", &tokens, records(&tokens, 0, true));
        assert_eq!(n, 3);
        assert_eq!(a.used_blocks(), 3);
        assert_eq!(ar.blocks_bound(), 3, "tree KV must be arena-resident");
        let m = c.lookup(&ar, "m", &tokens, true, tokens.len());
        assert_eq!(m.kind, MatchKind::Full);
        assert_eq!(m.resume_len, 12);
        let seed = m.seed.unwrap();
        let (k_want, v_want) = kv_of(&tokens[..12]);
        assert_eq!(seed.k.data, k_want.data, "seed K must be the inserted rows, bit for bit");
        assert_eq!(seed.v.data, v_want.data);
        assert_eq!(seed.h2o.unwrap().data, h2o_of(&tokens, 12).data);
        c.release(m.pin);
        assert_eq!(c.stats().pinned_nodes, 0);
    }

    #[test]
    fn resume_cap_and_score_requirement_bound_the_match() {
        let (mut c, mut a, mut ar) = cache();
        let tokens: Vec<i32> = (0..16).collect();
        c.insert(&mut a, &mut ar, "m", &tokens, records(&tokens, 0, true));
        // cap of 9 tokens -> only 2 blocks usable
        let m = c.lookup(&ar, "m", &tokens, true, 9);
        assert_eq!(m.resume_len, 8);
        assert_eq!(m.kind, MatchKind::Full); // all cap-usable blocks served
        c.release(m.pin);
        // KV-only tree: base-pass lookups (need_scores) miss entirely
        let (mut c2, mut a2, mut ar2) = cache();
        c2.insert(&mut a2, &mut ar2, "m", &tokens, records(&tokens, 0, false));
        let m2 = c2.lookup(&ar2, "m", &tokens, true, tokens.len());
        assert_eq!(m2.kind, MatchKind::Miss);
        assert!(m2.pin.is_empty());
        // ... but lookahead lookups (no score requirement) hit
        let m3 = c2.lookup(&ar2, "m", &tokens, false, tokens.len());
        assert_eq!(m3.resume_len, 16);
        assert!(m3.seed.as_ref().unwrap().h2o.is_none());
        c2.release(m3.pin);
    }

    #[test]
    fn h2o_upgrade_of_kv_only_nodes() {
        let (mut c, mut a, mut ar) = cache();
        let tokens: Vec<i32> = (0..8).collect();
        c.insert(&mut a, &mut ar, "m", &tokens, records(&tokens, 0, false)); // lookahead pass
        assert_eq!(a.used_blocks(), 2);
        // a base pass over the same prompt recomputed everything and now
        // carries H2O sums: nodes upgrade in place, no new blocks
        let n = c.insert(&mut a, &mut ar, "m", &tokens, records(&tokens, 0, true));
        assert_eq!(n, 0);
        assert_eq!(a.used_blocks(), 2);
        let m = c.lookup(&ar, "m", &tokens, true, tokens.len());
        assert_eq!(m.resume_len, 8);
        c.release(m.pin);
    }

    #[test]
    fn divergent_prompts_become_siblings_and_share_nothing_mutable() {
        let (mut c, mut a, mut ar) = cache();
        let p1: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        c.insert(&mut a, &mut ar, "m", &p1, records(&p1, 0, true));
        // p2 shares block 0, diverges in block 1
        let p2: Vec<i32> = vec![1, 2, 3, 4, 9, 9, 9, 9];
        let m = c.lookup(&ar, "m", &p2, true, p2.len());
        assert_eq!(m.resume_len, 4, "shared first block matches");
        assert_eq!(m.kind, MatchKind::Partial);
        c.release(m.pin);
        c.insert(&mut a, &mut ar, "m", &p2, records(&p2, 1, true));
        assert_eq!(a.used_blocks(), 3); // 2 (p1) + 1 diverged sibling
        // both full prompts still match exactly
        let m1 = c.lookup(&ar, "m", &p1, true, p1.len());
        assert_eq!(m1.resume_len, 8);
        let (k1, _) = kv_of(&p1);
        assert_eq!(m1.seed.as_ref().unwrap().k.data, k1.data, "p1 blocks unchanged by p2");
        let m2 = c.lookup(&ar, "m", &p2, true, p2.len());
        assert_eq!(m2.resume_len, 8);
        c.release(m1.pin);
        c.release(m2.pin);
    }

    #[test]
    fn lru_reclaims_cold_unpinned_leaves_only() {
        let (mut c, mut a, mut ar) = cache();
        let p1: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        let p2: Vec<i32> = vec![10, 11, 12, 13];
        c.insert(&mut a, &mut ar, "m", &p1, records(&p1, 0, true));
        c.insert(&mut a, &mut ar, "m", &p2, records(&p2, 0, true));
        // touch p1 so p2 is the LRU leaf
        let m = c.lookup(&ar, "m", &p1, true, p1.len());
        let freed = c.reclaim(&mut a, &mut ar, 1);
        assert_eq!(freed, 1);
        assert_eq!(c.lookup(&ar, "m", &p2, true, p2.len()).kind, MatchKind::Miss, "p2 reclaimed");
        // p1 is pinned: reclaiming everything must leave it intact
        let freed = c.reclaim(&mut a, &mut ar, 16);
        assert_eq!(freed, 0, "pinned path must never be reclaimed");
        c.release(m.pin);
        // unpinned now: the leaf drains first, then the interior node
        assert_eq!(c.reclaim(&mut a, &mut ar, 16), 2);
        assert_eq!(a.used_blocks(), 0);
        assert_eq!(ar.blocks_bound(), 0, "reclaim must return arena buffers too");
        assert_eq!(ar.bytes_in_use(), 0);
        assert_eq!(c.stats().blocks, 0);
    }

    #[test]
    fn max_blocks_cap_is_enforced_via_reclaim() {
        let mut c = PrefixCache::new(PrefixCacheConfig { block_size: B, max_blocks: 2 });
        let mut a = BlockAllocator::new(64 * B, B);
        let mut ar = KvArena::new(64, B);
        let p1: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
        c.insert(&mut a, &mut ar, "m", &p1, records(&p1, 0, true));
        assert_eq!(c.stats().blocks, 2);
        let p2: Vec<i32> = vec![20, 21, 22, 23, 24, 25, 26, 27];
        c.insert(&mut a, &mut ar, "m", &p2, records(&p2, 0, true));
        assert!(c.stats().blocks <= 2, "cap must hold: {}", c.stats().blocks);
        assert_eq!(a.used_blocks(), c.stats().blocks);
        assert_eq!(ar.blocks_bound(), c.stats().blocks);
    }

    /// Property: any interleaving of insert/lookup/release/reclaim keeps
    /// the tree's invariants — pin accounting balances (no "negative"
    /// refcounts: every release matches a pin and ends at zero), pinned
    /// nodes are never reclaimed, allocator accounting matches the tree,
    /// and a full re-lookup of any inserted prompt is exact.
    #[test]
    fn prop_tree_invariants() {
        check("prefix tree invariants", &Config { cases: 48, max_size: 40, ..Config::new() }, |rng, size| {
            let mut c = PrefixCache::new(PrefixCacheConfig { block_size: B, max_blocks: 24 });
            let mut a = BlockAllocator::new(64 * B, B);
            let mut ar = KvArena::new(64, B);
            let mut prompts: Vec<Vec<i32>> = Vec::new();
            let mut pins: Vec<(PrefixPin, usize)> = Vec::new(); // (pin, path len)
            for _ in 0..size {
                match rng.below(4) {
                    0 => {
                        // insert a prompt from a tiny alphabet (forces
                        // shared prefixes and divergence)
                        let blocks = rng.range(1, 5);
                        let mut t: Vec<i32> = Vec::new();
                        for _ in 0..blocks * B {
                            t.push(rng.below(3) as i32);
                        }
                        c.insert(&mut a, &mut ar, "m", &t, records(&t, 0, rng.chance(0.7)));
                        prompts.push(t);
                    }
                    1 if !prompts.is_empty() => {
                        let t = prompts[rng.below(prompts.len())].clone();
                        let m = c.lookup(&ar, "m", &t, false, t.len());
                        if m.resume_len > 0 {
                            // exactness: the seed is the inserted KV
                            let (k_want, _) = kv_of(&t[..m.resume_len]);
                            assert_eq!(m.seed.as_ref().unwrap().k.data, k_want.data);
                        }
                        let n = m.pin.nodes.len();
                        pins.push((m.pin, n));
                    }
                    2 if !pins.is_empty() => {
                        let (pin, _) = pins.swap_remove(rng.below(pins.len()));
                        c.release(pin);
                    }
                    _ => {
                        c.reclaim(&mut a, &mut ar, rng.range(1, 4));
                    }
                }
                let st = c.stats();
                // allocator, arena and tree accounting match exactly
                assert_eq!(st.blocks, a.used_blocks(), "tree/allocator divergence");
                assert_eq!(st.blocks, ar.blocks_bound(), "tree/arena divergence");
                assert!(st.blocks <= 24, "max_blocks cap violated");
                // pin accounting balances: total refs == total pinned path
                // entries outstanding (never negative, never dangling)
                let outstanding: usize = pins.iter().map(|(_, n)| n).sum();
                let total_refs: usize =
                    c.arena.iter().flatten().map(|n| n.refs).sum();
                assert_eq!(total_refs, outstanding, "pin accounting out of balance");
                // every pinned node is still present (not reclaimed)
                for (pin, _) in &pins {
                    for &i in &pin.nodes {
                        assert!(c.arena[i].is_some(), "pinned node was reclaimed");
                        assert!(c.arena[i].as_ref().unwrap().refs > 0);
                    }
                }
            }
            // draining all pins returns every refcount to exactly zero
            for (pin, _) in pins.drain(..) {
                c.release(pin);
            }
            assert_eq!(c.stats().pinned_nodes, 0);
            // and with nothing pinned, reclaim can always drain the tree
            c.reclaim(&mut a, &mut ar, usize::MAX);
            assert_eq!(c.stats().blocks, 0);
            assert_eq!(a.used_blocks(), 0);
            assert_eq!(ar.bytes_in_use(), 0, "arena bytes leaked by the tree");
        });
    }

    /// Property: COW divergence — extending or diverging from a shared
    /// prefix never mutates the shared blocks' bytes.
    #[test]
    fn prop_cow_divergence_never_mutates_shared_blocks() {
        check("prefix COW", &Config { cases: 32, max_size: 24, ..Config::new() }, |rng, size| {
            let mut c = PrefixCache::new(PrefixCacheConfig { block_size: B, max_blocks: usize::MAX });
            let mut a = BlockAllocator::new(128 * B, B);
            let mut ar = KvArena::new(128, B);
            let shared_blocks = 1 + rng.below(3);
            let shared: Vec<i32> = (0..shared_blocks * B).map(|_| rng.below(4) as i32).collect();
            let mut base = shared.clone();
            base.extend((0..B).map(|_| 100));
            c.insert(&mut a, &mut ar, "m", &base, records(&base, 0, true));
            let snapshot: Vec<(Vec<i32>, Vec<f32>, Vec<f32>)> = c
                .arena
                .iter()
                .flatten()
                .filter(|n| n.start < shared.len())
                .map(|n| {
                    let (bk, bv) = ar.block_kv(n.block).expect("node block unbound");
                    (n.tokens.clone(), bk.to_vec(), bv.to_vec())
                })
                .collect();
            for i in 0..size.min(6) {
                // each iteration: a prompt sharing the prefix, diverging after
                let mut p = shared.clone();
                p.extend((0..B).map(|_| 101 + i as i32));
                let m = c.lookup(&ar, "m", &p, true, p.len());
                let resume_blocks = m.resume_len / B;
                c.insert(&mut a, &mut ar, "m", &p, records(&p, resume_blocks, true));
                c.release(m.pin);
            }
            // shared blocks: same bytes as before any divergence
            for (tokens, k, v) in &snapshot {
                let node = c
                    .arena
                    .iter()
                    .flatten()
                    .find(|n| n.start < shared.len() && &n.tokens == tokens)
                    .expect("shared block vanished");
                let (bk, bv) = ar.block_kv(node.block).expect("node block unbound");
                assert_eq!(bk, &k[..], "shared K block mutated by divergence");
                assert_eq!(bv, &v[..], "shared V block mutated by divergence");
            }
        });
    }
}

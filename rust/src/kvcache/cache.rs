//! Per-sequence compacted KV cache, dense layout (host side of the
//! decode loop). This is the bit-exact *reference* layout; the serving
//! loop defaults to the paged [`super::paged::PagedSeqCache`], which
//! must match it exactly (see `tests/paged.rs`).

use crate::util::tensor::TensorF;

/// One sequence's cache after prefill eviction. `k`/`v` are shaped
/// `[L, Hkv, cap, dh]` matching the decode graph's cache inputs; rows
/// `>= lens[l]` in layer `l` are dead slots.
#[derive(Debug, Clone)]
pub struct SeqCache {
    pub k: TensorF,
    pub v: TensorF,
    /// Live slots per layer (ragged after per-layer budgets, e.g. PyramidKV).
    pub lens: Vec<usize>,
    /// Absolute token position of each live slot, per layer
    /// (slot -> original position; generated tokens append their own).
    pub slot_pos: Vec<Vec<usize>>,
    /// Next absolute RoPE position (continues counting over the *full*
    /// prompt even though the cache is compacted — kept KV retain their
    /// original rotary phases, as in SnapKV-style serving).
    pub next_pos: usize,
    pub cap: usize,
    pub n_layers: usize,
}

impl SeqCache {
    /// Build from per-layer kept indices over full prompt KV
    /// (`[L, Hkv, S, dh]`), compacting into a `cap`-slot cache.
    pub fn from_selection(
        k_full: &TensorF,
        v_full: &TensorF,
        kept: &[Vec<usize>],
        prompt_len: usize,
        cap: usize,
    ) -> SeqCache {
        let (l, hkv, _s, dh) = (
            k_full.shape[0],
            k_full.shape[1],
            k_full.shape[2],
            k_full.shape[3],
        );
        assert_eq!(kept.len(), l);
        let mut k = TensorF::zeros(vec![l, hkv, cap, dh]);
        let mut v = TensorF::zeros(vec![l, hkv, cap, dh]);
        let mut lens = Vec::with_capacity(l);
        let mut slot_pos = Vec::with_capacity(l);
        for (li, idx) in kept.iter().enumerate() {
            assert!(idx.len() <= cap, "layer {li}: {} kept > cap {cap}", idx.len());
            for (slot, &p) in idx.iter().enumerate() {
                for h in 0..hkv {
                    let src_k = k_full.index(&[li, h, p]);
                    let src_v = v_full.index(&[li, h, p]);
                    let off = ((li * hkv + h) * cap + slot) * dh;
                    k.data[off..off + dh].copy_from_slice(src_k);
                    v.data[off..off + dh].copy_from_slice(src_v);
                }
            }
            lens.push(idx.len());
            slot_pos.push(idx.clone());
        }
        SeqCache { k, v, lens, slot_pos, next_pos: prompt_len, cap, n_layers: l }
    }

    /// Record the insertion performed by the decode graph: the new token's
    /// KV landed at slot `lens[l]` in each layer, at absolute `pos`.
    pub fn note_insert(&mut self, pos: usize) {
        for l in 0..self.n_layers {
            assert!(self.lens[l] < self.cap, "cache overflow at layer {l}");
            self.slot_pos[l].push(pos);
            self.lens[l] += 1;
        }
    }

    /// Replace the K/V tensors with the updated ones returned by the
    /// decode graph (the historical per-sequence host round-trip; the
    /// serving loop's paged path appends in place through the arena
    /// instead — see README "Paged KV arena").
    pub fn update_tensors(&mut self, k: TensorF, v: TensorF) {
        debug_assert_eq!(k.shape, self.k.shape);
        self.k = k;
        self.v = v;
    }

    pub fn lens_i32(&self) -> Vec<i32> {
        self.lens.iter().map(|&x| x as i32).collect()
    }

    /// Remaining decode headroom (min across layers).
    pub fn headroom(&self) -> usize {
        self.lens.iter().map(|&l| self.cap - l).min().unwrap_or(0)
    }

    /// Total live slots across layers (memory-accounting unit).
    pub fn live_slots(&self) -> usize {
        self.lens.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_kv(l: usize, hkv: usize, s: usize, dh: usize) -> TensorF {
        TensorF::new(
            vec![l, hkv, s, dh],
            (0..l * hkv * s * dh).map(|x| x as f32).collect(),
        )
    }

    #[test]
    fn compacts_selected_rows() {
        let k = full_kv(2, 2, 8, 4);
        let v = full_kv(2, 2, 8, 4);
        let kept = vec![vec![1, 3, 7], vec![0, 2]];
        let c = SeqCache::from_selection(&k, &v, &kept, 8, 4);
        assert_eq!(c.lens, vec![3, 2]);
        assert_eq!(c.slot_pos[0], vec![1, 3, 7]);
        // layer 0, head 1, slot 2 should hold original row 7
        assert_eq!(c.k.index(&[0, 1, 2]), k.index(&[0, 1, 7]));
        // dead slot is zero
        assert_eq!(c.k.index(&[1, 0, 3]), &[0.0; 4][..]);
        assert_eq!(c.next_pos, 8);
        assert_eq!(c.headroom(), 1);
    }

    #[test]
    fn insert_tracking() {
        let k = full_kv(1, 1, 4, 2);
        let c0 = SeqCache::from_selection(&k, &k, &[vec![0, 2]], 4, 4);
        let mut c = c0;
        c.note_insert(4);
        assert_eq!(c.lens, vec![3]);
        assert_eq!(c.slot_pos[0], vec![0, 2, 4]);
        c.note_insert(5);
        assert_eq!(c.headroom(), 0);
    }

    #[test]
    #[should_panic(expected = "cache overflow")]
    fn overflow_panics() {
        let k = full_kv(1, 1, 4, 2);
        let mut c = SeqCache::from_selection(&k, &k, &[vec![0, 1, 2, 3]], 4, 4);
        c.note_insert(4);
    }
}

//! Per-sequence *paged* decode cache: a block table over the shared
//! [`KvArena`] instead of a dense `[L, Hkv, cap, dh]` tensor pair.
//!
//! Compared with [`super::cache::SeqCache`] (kept as the bit-exact
//! reference layout), a paged cache:
//!
//! * allocates only the blocks its live rows need — a 90-row cache costs
//!   two 64-slot blocks, not a 640-slot decode bucket — so resident KV
//!   bytes track actual occupancy;
//! * is built by **gathering** kept rows straight into freshly allocated
//!   blocks ([`PagedSeqCache::from_arena_selection`] from paged prefill
//!   state, [`PagedSeqCache::from_dense_selection`] from a monolithic
//!   prefill), after which the prompt's blocks are freed immediately;
//! * **grows** one block at a time when decode fills its last slot
//!   ([`PagedSeqCache::grow`]), subject to pool backpressure, instead of
//!   finishing the sequence at a fixed cap.
//!
//! Slot semantics are identical to the dense cache: slot `i` of layer
//! `l` lives at row `i` (block `i / bs`, offset `i % bs`), layers are
//! ragged via `lens`, and `slot_pos` maps slots back to absolute prompt
//! positions for GT tracking.

use anyhow::{Context, Result};

use crate::util::tensor::TensorF;

use super::arena::{KvArena, KvBlock, KvDims};
use super::block::{BlockAllocator, BlockId};
use super::cache::SeqCache;

#[derive(Debug, Clone)]
pub struct PagedSeqCache {
    /// Physical block table: global slot `i` lives in
    /// `blocks[i / block_size]` at offset `i % block_size`.
    pub blocks: Vec<BlockId>,
    pub block_size: usize,
    pub dims: KvDims,
    /// Live slots per layer (ragged after per-layer budgets).
    pub lens: Vec<usize>,
    /// Absolute token position of each live slot, per layer.
    pub slot_pos: Vec<Vec<usize>>,
    /// Next absolute RoPE position (counts over the full prompt).
    pub next_pos: usize,
    /// The decode cap the dense path would have used (reporting parity;
    /// the paged cache is *not* bounded by it — it grows by blocks).
    pub cap: usize,
    pub n_layers: usize,
}

impl PagedSeqCache {
    /// Blocks needed for the kept rows of a selection (the admission
    /// charge of a gather-compaction).
    pub fn blocks_for_selection(kept: &[Vec<usize>], block_size: usize) -> usize {
        let max_rows = kept.iter().map(Vec::len).max().unwrap_or(0).max(1);
        max_rows.div_ceil(block_size)
    }

    /// Gather-compact kept rows of dense full-prompt KV
    /// (`[L, Hkv, S, dh]`) into freshly allocated blocks owned by
    /// `owner`. Fails with "kv pool exhausted" when the pool cannot take
    /// the kept rows.
    #[allow(clippy::too_many_arguments)]
    pub fn from_dense_selection(
        arena: &mut KvArena,
        alloc: &mut BlockAllocator,
        owner: u64,
        dims: KvDims,
        k_full: &TensorF,
        v_full: &TensorF,
        kept: &[Vec<usize>],
        prompt_len: usize,
        cap: usize,
    ) -> Result<PagedSeqCache> {
        anyhow::ensure!(
            k_full.shape.len() == 4
                && k_full.shape[0] == dims.n_layers
                && k_full.shape[1] == dims.n_kv_heads
                && k_full.shape[3] == dims.head_dim,
            "full KV shape {:?} does not match {dims:?}",
            k_full.shape
        );
        anyhow::ensure!(kept.len() == dims.n_layers, "selection layer count mismatch");
        let mut cache = Self::alloc_for(arena, alloc, owner, dims, kept, prompt_len, cap)?;
        let bs = cache.block_size;
        for (li, idx) in kept.iter().enumerate() {
            for (slot, &p) in idx.iter().enumerate() {
                for g in 0..dims.n_kv_heads {
                    arena.write_row(
                        &dims,
                        cache.blocks[slot / bs],
                        li,
                        g,
                        slot % bs,
                        k_full.index(&[li, g, p]),
                        v_full.index(&[li, g, p]),
                    );
                }
            }
        }
        cache.note_selection(kept);
        Ok(cache)
    }

    /// Gather-compact kept rows of *paged* full-prompt KV (the chunked
    /// prefill's block table) into freshly allocated blocks. The source
    /// blocks are left untouched — the caller frees them right after.
    #[allow(clippy::too_many_arguments)]
    pub fn from_arena_selection(
        arena: &mut KvArena,
        alloc: &mut BlockAllocator,
        owner: u64,
        dims: KvDims,
        src_blocks: &[BlockId],
        kept: &[Vec<usize>],
        prompt_len: usize,
        cap: usize,
    ) -> Result<PagedSeqCache> {
        anyhow::ensure!(kept.len() == dims.n_layers, "selection layer count mismatch");
        let bs = arena.block_size();
        let src_slots = src_blocks.len() * bs;
        for idx in kept {
            for &p in idx {
                anyhow::ensure!(p < src_slots, "kept row {p} outside prompt blocks");
            }
        }
        let mut cache = Self::alloc_for(arena, alloc, owner, dims, kept, prompt_len, cap)?;
        // Take the destination blocks out so source reads and destination
        // writes cannot alias (they are distinct blocks by construction).
        let mut dst = match arena.take(&cache.blocks) {
            Ok(d) => d,
            Err(e) => {
                arena.release(&cache.blocks);
                alloc.free(&cache.blocks);
                return Err(e);
            }
        };
        let res = Self::gather_into(arena, &mut dst, dims, bs, src_blocks, kept);
        // Put the destination blocks back unconditionally so the byte
        // accounting stays balanced; a failed gather (e.g. a source block
        // freed out from under the selection) then unwinds the whole
        // allocation instead of leaking half-filled blocks.
        arena.put(&cache.blocks, dst);
        if let Err(e) = res {
            arena.release(&cache.blocks);
            alloc.free(&cache.blocks);
            return Err(e);
        }
        cache.note_selection(kept);
        Ok(cache)
    }

    /// The copy loop of [`Self::from_arena_selection`]: walk the
    /// destination slots one destination block at a time — when every
    /// kept row of a (dest block, layer) span comes from a single source
    /// block, the stored representation is copied verbatim (the u8
    /// segment adopts the source quant params), so compaction only
    /// requantizes when it crosses block boundaries. f32/f16 take the
    /// same split but both paths are lossless for them.
    fn gather_into(
        arena: &KvArena,
        dst: &mut [KvBlock],
        dims: KvDims,
        bs: usize,
        src_blocks: &[BlockId],
        kept: &[Vec<usize>],
    ) -> Result<()> {
        let (hkv, dh) = (dims.n_kv_heads, dims.head_dim);
        let mut scr_k = vec![0.0f32; dh];
        let mut scr_v = vec![0.0f32; dh];
        for (li, idx) in kept.iter().enumerate() {
            let mut slot = 0usize;
            while slot < idx.len() {
                let d = slot / bs;
                let end = ((d + 1) * bs).min(idx.len());
                let one_src = idx[slot..end].iter().all(|&p| p / bs == idx[slot] / bs);
                for s in slot..end {
                    let p = idx[s];
                    let src = arena
                        .block_raw(src_blocks[p / bs])
                        .with_context(|| format!("source block for kept row {p} unbound"))?;
                    for g in 0..hkv {
                        let seg = li * hkv + g;
                        if one_src {
                            dst[d].k.copy_row_from(&src.k, seg, p % bs, seg, s % bs, bs, dh);
                            dst[d].v.copy_row_from(&src.v, seg, p % bs, seg, s % bs, bs, dh);
                        } else {
                            src.k.decode_row(seg, p % bs, bs, dh, &mut scr_k);
                            src.v.decode_row(seg, p % bs, bs, dh, &mut scr_v);
                            dst[d].k.encode_row(seg, s % bs, bs, dh, &scr_k);
                            dst[d].v.encode_row(seg, s % bs, bs, dh, &scr_v);
                        }
                    }
                }
                slot = end;
            }
        }
        Ok(())
    }

    /// Allocate + bind the destination blocks of a gather-compaction.
    fn alloc_for(
        arena: &mut KvArena,
        alloc: &mut BlockAllocator,
        owner: u64,
        dims: KvDims,
        kept: &[Vec<usize>],
        prompt_len: usize,
        cap: usize,
    ) -> Result<PagedSeqCache> {
        let bs = arena.block_size();
        let max_rows = kept.iter().map(Vec::len).max().unwrap_or(0).max(1);
        for (li, idx) in kept.iter().enumerate() {
            anyhow::ensure!(idx.len() <= cap, "layer {li}: {} kept > cap {cap}", idx.len());
        }
        let ids = alloc.alloc(owner, max_rows).context("kv pool exhausted")?;
        arena.bind(&ids, &dims);
        Ok(PagedSeqCache {
            blocks: ids,
            block_size: bs,
            dims,
            lens: vec![0; dims.n_layers],
            slot_pos: vec![Vec::new(); dims.n_layers],
            next_pos: prompt_len,
            cap,
            n_layers: dims.n_layers,
        })
    }

    fn note_selection(&mut self, kept: &[Vec<usize>]) {
        self.lens = kept.iter().map(Vec::len).collect();
        self.slot_pos = kept.to_vec();
    }

    /// Total slots the block table can hold right now.
    pub fn allocated_slots(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    /// Free slots before the next append would need a new block
    /// (min across layers, like the dense cache).
    pub fn headroom(&self) -> usize {
        let max_len = self.lens.iter().copied().max().unwrap_or(0);
        self.allocated_slots() - max_len
    }

    /// Append one more block from the pool; false when the pool is
    /// exhausted (caller decides between reclaim and `kv_exhausted`).
    pub fn grow(&mut self, arena: &mut KvArena, alloc: &mut BlockAllocator, owner: u64) -> bool {
        match alloc.alloc(owner, self.block_size) {
            Some(ids) => {
                arena.bind(&ids, &self.dims);
                self.blocks.extend(ids);
                true
            }
            None => false,
        }
    }

    /// Record the insertion performed by the decode kernel at slot
    /// `lens[l]` of each layer, at absolute `pos`.
    pub fn note_insert(&mut self, pos: usize) {
        let slots = self.allocated_slots();
        for l in 0..self.n_layers {
            assert!(self.lens[l] < slots, "paged cache overflow at layer {l}");
            self.slot_pos[l].push(pos);
            self.lens[l] += 1;
        }
    }

    pub fn lens_i32(&self) -> Vec<i32> {
        self.lens.iter().map(|&x| x as i32).collect()
    }

    /// Total live slots across layers (memory-accounting unit).
    pub fn live_slots(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Materialize a dense [`SeqCache`] copy padded to `cap` slots
    /// (equivalence tests, the default backend's gather fallback).
    pub fn gather_dense(&self, arena: &KvArena, cap: usize) -> Result<SeqCache> {
        let dims = self.dims;
        let (l, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.head_dim);
        let mut k = TensorF::zeros(vec![l, hkv, cap, dh]);
        let mut v = TensorF::zeros(vec![l, hkv, cap, dh]);
        for li in 0..l {
            anyhow::ensure!(self.lens[li] <= cap, "layer {li} has more rows than cap {cap}");
            for g in 0..hkv {
                for slot in 0..self.lens[li] {
                    let blk = arena
                        .block_raw(self.blocks[slot / self.block_size])
                        .context("paged cache block unbound")?;
                    let within = slot % self.block_size;
                    let seg = li * hkv + g;
                    let dst = ((li * hkv + g) * cap + slot) * dh;
                    blk.k.decode_row(seg, within, self.block_size, dh, &mut k.data[dst..dst + dh]);
                    blk.v.decode_row(seg, within, self.block_size, dh, &mut v.data[dst..dst + dh]);
                }
            }
        }
        Ok(SeqCache {
            k,
            v,
            lens: self.lens.clone(),
            slot_pos: self.slot_pos.clone(),
            next_pos: self.next_pos,
            cap,
            n_layers: self.n_layers,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: KvDims = KvDims { n_layers: 2, n_kv_heads: 2, head_dim: 4 };

    fn full_kv(l: usize, hkv: usize, s: usize, dh: usize) -> TensorF {
        TensorF::new(
            vec![l, hkv, s, dh],
            (0..l * hkv * s * dh).map(|x| x as f32).collect(),
        )
    }

    fn pool(n_blocks: usize, bs: usize) -> (KvArena, BlockAllocator) {
        (KvArena::new(n_blocks, bs), BlockAllocator::new(n_blocks * bs, bs))
    }

    #[test]
    fn dense_selection_gathers_and_matches_seq_cache() {
        let (mut arena, mut alloc) = pool(8, 4);
        let k = full_kv(2, 2, 8, 4);
        let v = full_kv(2, 2, 8, 4);
        let kept = vec![vec![1, 3, 7], vec![0, 2]];
        let paged =
            PagedSeqCache::from_dense_selection(&mut arena, &mut alloc, 1, DIMS, &k, &v, &kept, 8, 6)
                .unwrap();
        assert_eq!(paged.lens, vec![3, 2]);
        assert_eq!(paged.slot_pos[0], vec![1, 3, 7]);
        assert_eq!(paged.blocks.len(), 1); // 3 rows -> one 4-slot block
        assert_eq!(paged.headroom(), 1);
        // bit-for-bit the same compaction as the dense reference path
        let dense = SeqCache::from_selection(&k, &v, &kept, 8, 6);
        let roundtrip = paged.gather_dense(&arena, 6).unwrap();
        assert_eq!(roundtrip.k.data, dense.k.data);
        assert_eq!(roundtrip.v.data, dense.v.data);
        assert_eq!(roundtrip.lens, dense.lens);
        assert_eq!(roundtrip.next_pos, dense.next_pos);
    }

    #[test]
    fn arena_selection_matches_dense_selection() {
        let (mut arena, mut alloc) = pool(8, 4);
        let k = full_kv(2, 2, 8, 4);
        let v = full_kv(2, 2, 8, 4);
        // stage the "prompt" KV in arena blocks (2 blocks of 4 rows)
        let src = alloc.alloc(99, 8).unwrap();
        arena.bind(&src, &DIMS);
        arena.scatter_dense(&DIMS, &src, 0, &k, &v).unwrap();
        let kept = vec![vec![0, 4, 5, 6, 7], vec![2, 3]];
        let a = PagedSeqCache::from_arena_selection(
            &mut arena, &mut alloc, 1, DIMS, &src, &kept, 8, 8,
        )
        .unwrap();
        let b = PagedSeqCache::from_dense_selection(
            &mut arena, &mut alloc, 2, DIMS, &k, &v, &kept, 8, 8,
        )
        .unwrap();
        assert_eq!(a.blocks.len(), 2); // 5 rows -> two 4-slot blocks
        let da = a.gather_dense(&arena, 8).unwrap();
        let db = b.gather_dense(&arena, 8).unwrap();
        assert_eq!(da.k.data, db.k.data);
        assert_eq!(da.v.data, db.v.data);
        // freeing the prompt's blocks leaves the gathered cache intact
        arena.release(&src);
        alloc.free(&src);
        let da2 = a.gather_dense(&arena, 8).unwrap();
        assert_eq!(da.k.data, da2.k.data);
    }

    /// On u8 storage, a compaction whose kept rows stay within one
    /// source block per destination block copies codes verbatim — the
    /// decoded selection is *exactly* the decoded source rows. A
    /// selection crossing block boundaries requantizes, staying within
    /// one quantization step of the decoded source.
    #[test]
    fn arena_selection_u8_raw_copy_vs_requantize() {
        use crate::kvcache::arena::KvDtype;
        let mut arena = KvArena::with_dtype(8, 4, KvDtype::U8);
        let mut alloc = BlockAllocator::new(32, 4);
        let k = full_kv(2, 2, 8, 4);
        let v = full_kv(2, 2, 8, 4);
        let src = alloc.alloc(99, 8).unwrap();
        arena.bind(&src, &DIMS);
        arena.scatter_dense(&DIMS, &src, 0, &k, &v).unwrap();
        let src_dense = arena.gather_dense(&DIMS, &src, 8).unwrap();
        // block-aligned kept rows: each 4-slot dest block fills from one
        // src block -> raw copy, bit-exact vs the decoded source
        let kept = vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]];
        let a = PagedSeqCache::from_arena_selection(
            &mut arena, &mut alloc, 1, DIMS, &src, &kept, 8, 8,
        )
        .unwrap();
        let da = a.gather_dense(&arena, 8).unwrap();
        for li in 0..2 {
            for g in 0..2 {
                for (slot, &p) in kept[li].iter().enumerate() {
                    assert_eq!(
                        da.k.index(&[li, g, slot]),
                        src_dense.0.index(&[li, g, p]),
                        "raw copy must be lossless"
                    );
                }
            }
        }
        // boundary-crossing kept rows requantize: bounded drift only
        let kept = vec![vec![1, 2, 5, 6], vec![0, 7]];
        let b = PagedSeqCache::from_arena_selection(
            &mut arena, &mut alloc, 2, DIMS, &src, &kept, 8, 8,
        )
        .unwrap();
        let db = b.gather_dense(&arena, 8).unwrap();
        for li in 0..2 {
            for g in 0..2 {
                for (slot, &p) in kept[li].iter().enumerate() {
                    let got = db.k.index(&[li, g, slot]);
                    let want = src_dense.0.index(&[li, g, p]);
                    for (x, y) in got.iter().zip(want) {
                        assert!((x - y).abs() <= 2.0, "requantize drift {x} vs {y}");
                    }
                }
            }
        }
    }

    #[test]
    fn grow_on_full_appends_blocks() {
        let (mut arena, mut alloc) = pool(3, 4);
        let k = full_kv(2, 2, 8, 4);
        let kept = vec![vec![0, 1, 2, 3], vec![0, 1]];
        let mut c =
            PagedSeqCache::from_dense_selection(&mut arena, &mut alloc, 1, DIMS, &k, &k, &kept, 8, 32)
                .unwrap();
        assert_eq!(c.headroom(), 0);
        assert!(c.grow(&mut arena, &mut alloc, 1));
        assert_eq!(c.headroom(), 4);
        c.note_insert(8);
        assert_eq!(c.lens, vec![5, 3]);
        assert_eq!(c.slot_pos[0], vec![0, 1, 2, 3, 8]);
        // pool exhausted: one block left, then growth fails
        assert!(c.grow(&mut arena, &mut alloc, 1));
        assert!(!c.grow(&mut arena, &mut alloc, 1));
    }

    #[test]
    fn selection_over_cap_is_rejected() {
        let (mut arena, mut alloc) = pool(4, 4);
        let k = full_kv(1, 2, 8, 4);
        let dims = KvDims { n_layers: 1, ..DIMS };
        let kept = vec![vec![0, 1, 2]];
        assert!(PagedSeqCache::from_dense_selection(
            &mut arena, &mut alloc, 1, dims, &k, &k, &kept, 8, 2,
        )
        .is_err());
    }
}

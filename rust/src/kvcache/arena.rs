//! Shared physical KV arena: the block-granular storage behind every
//! paged cache in the system.
//!
//! The [`super::block::BlockAllocator`] decides *who* owns which
//! [`BlockId`]; the [`KvArena`] owns the *bytes* — one pair of K/V
//! planes per bound block, each holding `block_size` token slots laid
//! out `[L, Hkv, block_size, dh]`. Decode caches
//! ([`super::paged::PagedSeqCache`]), in-flight chunked-prefill state
//! ([`crate::runtime::ChunkState`] with a block table) and prefix-tree
//! nodes ([`super::prefix::PrefixCache`]) are all views over the same
//! pool of blocks, so admission control charges actual bound bytes
//! rather than dense-bucket estimates.
//!
//! Blocks store KV in one of three formats ([`KvDtype`]): `f32` (the
//! frozen bit-exact oracle), `f16`, or `u8` with one asymmetric affine
//! scale/zero-point per (layer, KV head, block) segment ([`Seg`]).
//! Quantization happens at write time ([`KvPlane::encode_row`] /
//! [`KvPlane::encode_block`]); kernels read rows either decoded into a
//! caller-held `O(dh)` scratch row ([`KvAccess::k_row`]) or through the
//! fused accessors ([`KvAccess::k_dot`] / [`KvAccess::v_axpy`]) that
//! fold dequantization into the attention row loop — no materialized
//! f32 copy of the cache ever exists. Every path (dense, paged,
//! prefix-resumed) shares this single decode implementation.
//!
//! Buffers are materialized on [`KvArena::bind`] and dropped on
//! [`KvArena::release`], so `bytes_in_use` tracks *resident* KV in
//! dtype-true bytes (a u8 block costs ~¼ of its f32 twin), while
//! `logical_bytes_in_use` reports what the same blocks would cost at
//! f32 — the ratio of the two is the compression factor exported on
//! `GET /metrics`. The arena is dimension-agnostic: callers pass a
//! [`KvDims`] per access, which lets one pool serve models with
//! different layer/head geometry (e.g. the SpecKV draft model).
//!
//! Concurrency: the batched paged decode step temporarily *moves* each
//! sequence's [`KvBlock`]s out of the arena ([`KvArena::take`]), hands
//! the owned buffers to worker threads, and puts them back afterwards
//! ([`KvArena::put`]) — disjointness across sequences is enforced by
//! construction (a block can only be taken once), with no unsafe code.
//! Spill/restore ([`KvArena::spill`]) moves the *stored* representation
//! verbatim, so a spill → restore round trip is bit-exact per dtype.

use anyhow::{Context, Result};

use crate::util::tensor::{dot4, TensorF};

use super::block::{BlockAllocator, BlockId};

/// Storage format of a KV block.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    #[default]
    F32,
    F16,
    U8,
}

impl KvDtype {
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s {
            "f32" | "fp32" | "float32" => Some(KvDtype::F32),
            "f16" | "fp16" | "float16" | "half" => Some(KvDtype::F16),
            "u8" | "uint8" | "int8" | "q8" => Some(KvDtype::U8),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::F16 => "f16",
            KvDtype::U8 => "u8",
        }
    }

    /// Payload bytes per stored element.
    pub fn bytes_per_elem(&self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::F16 => 2,
            KvDtype::U8 => 1,
        }
    }

    /// Exact resident bytes of one bound block (K + V planes, including
    /// u8 quant-parameter segments) — the unit the scheduler's admission
    /// accounting charges.
    pub fn block_bytes(&self, dims: &KvDims, block_size: usize) -> usize {
        let elems = dims.slot_floats() * block_size;
        let seg_bytes = match self {
            KvDtype::U8 => dims.n_layers * dims.n_kv_heads * std::mem::size_of::<Seg>(),
            _ => 0,
        };
        2 * (elems * self.bytes_per_elem() + seg_bytes)
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even (no `half` crate
/// offline, so hand-rolled; property-tested below).
pub fn f16_from_f32(x: f32) -> u16 {
    let b = x.to_bits();
    let sign = ((b >> 16) & 0x8000) as u16;
    let e = ((b >> 23) & 0xff) as i32;
    let m = b & 0x007f_ffff;
    if e == 255 {
        // Inf / NaN (keep NaN payload non-zero)
        return sign | 0x7c00 | if m != 0 { 0x0200 } else { 0 };
    }
    let ne = e - 112; // rebias 127 -> 15
    if ne >= 31 {
        return sign | 0x7c00; // overflow -> inf
    }
    if ne <= 0 {
        if ne < -10 {
            return sign; // underflow -> signed zero
        }
        // subnormal half: shift the implicit-1 mantissa into place
        let full = m | 0x0080_0000;
        let shift = (14 - ne) as u32;
        let half = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            if rem > halfway || (rem == halfway && (half & 1) != 0) { half + 1 } else { half };
        return sign | rounded as u16;
    }
    let half = ((ne as u32) << 10) | (m >> 13);
    let rem = m & 0x1fff;
    // mantissa carry propagates into the exponent (and saturates to inf)
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half & 1) != 0) { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE 754 binary16 bits → f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let e = ((h >> 10) & 0x1f) as u32;
    let m = (h & 0x03ff) as u32;
    let bits = if e == 0 {
        if m == 0 {
            sign
        } else {
            // subnormal: renormalize
            let mut e2 = 113u32; // biased-127 exponent of 2^-14
            let mut m2 = m;
            while m2 & 0x0400 == 0 {
                m2 <<= 1;
                e2 -= 1;
            }
            sign | (e2 << 23) | ((m2 & 0x03ff) << 13)
        }
    } else if e == 31 {
        sign | 0x7f80_0000 | (m << 13)
    } else {
        sign | ((e + 112) << 23) | (m << 13)
    };
    f32::from_bits(bits)
}

/// Per-(layer, KV-head, block) asymmetric affine quantization range for
/// u8 planes: `x ≈ lo + (hi - lo) / 255 * code`. A fresh segment is
/// `EMPTY` (`lo > hi`), so the first written row defines the range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Seg {
    pub lo: f32,
    pub hi: f32,
}

impl Seg {
    pub const EMPTY: Seg = Seg { lo: f32::INFINITY, hi: f32::NEG_INFINITY };

    #[inline(always)]
    pub fn scale(&self) -> f32 {
        if self.hi > self.lo {
            (self.hi - self.lo) / 255.0
        } else {
            0.0
        }
    }
}

#[inline(always)]
fn quantize_u8(x: f32, s: &Seg) -> u8 {
    let sc = s.scale();
    if sc == 0.0 {
        0
    } else {
        ((x - s.lo) / sc).round().clamp(0.0, 255.0) as u8
    }
}

#[inline(always)]
fn dequantize_u8(c: u8, s: &Seg) -> f32 {
    s.lo + s.scale() * c as f32
}

/// One side (K or V) of a bound block in its stored representation. All
/// variants use the same `[L, Hkv, block_size, dh]` element order; u8
/// additionally carries one [`Seg`] per `(layer, KV head)` — segment
/// index `li * Hkv + g`, segment length `block_size * dh` codes.
#[derive(Debug, Clone, PartialEq)]
pub enum KvPlane {
    F32(Vec<f32>),
    F16(Vec<u16>),
    U8 { codes: Vec<u8>, segs: Vec<Seg> },
}

impl KvPlane {
    pub fn zeroed(dtype: KvDtype, elems: usize, n_segs: usize) -> KvPlane {
        match dtype {
            KvDtype::F32 => KvPlane::F32(vec![0.0; elems]),
            KvDtype::F16 => KvPlane::F16(vec![0; elems]),
            KvDtype::U8 => {
                assert!(n_segs > 0 && elems % n_segs == 0, "u8 plane needs uniform segments");
                KvPlane::U8 { codes: vec![0; elems], segs: vec![Seg::EMPTY; n_segs] }
            }
        }
    }

    pub fn dtype(&self) -> KvDtype {
        match self {
            KvPlane::F32(_) => KvDtype::F32,
            KvPlane::F16(_) => KvDtype::F16,
            KvPlane::U8 { .. } => KvDtype::U8,
        }
    }

    /// Stored element count (token slots × dh across layers/heads).
    pub fn len(&self) -> usize {
        match self {
            KvPlane::F32(d) => d.len(),
            KvPlane::F16(d) => d.len(),
            KvPlane::U8 { codes, .. } => codes.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident bytes of the stored representation (payload + u8 quant
    /// parameters).
    pub fn bytes(&self) -> usize {
        match self {
            KvPlane::F32(d) => d.len() * 4,
            KvPlane::F16(d) => d.len() * 2,
            KvPlane::U8 { codes, segs } => codes.len() + segs.len() * std::mem::size_of::<Seg>(),
        }
    }

    /// Raw f32 payload, when this plane is an f32 plane (oracle paths
    /// and tests that assert bit-identity).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            KvPlane::F32(d) => Some(d),
            _ => None,
        }
    }

    pub fn as_f32_mut(&mut self) -> Option<&mut [f32]> {
        match self {
            KvPlane::F32(d) => Some(d),
            _ => None,
        }
    }

    #[inline(always)]
    fn row_off(seg: usize, within: usize, bs: usize, dh: usize) -> usize {
        (seg * bs + within) * dh
    }

    /// Decode one `dh`-element row into `out` — the single dequant
    /// implementation every read path funnels through.
    #[inline]
    pub fn decode_row(&self, seg: usize, within: usize, bs: usize, dh: usize, out: &mut [f32]) {
        let o = Self::row_off(seg, within, bs, dh);
        match self {
            KvPlane::F32(d) => out[..dh].copy_from_slice(&d[o..o + dh]),
            KvPlane::F16(d) => {
                for (y, &h) in out[..dh].iter_mut().zip(&d[o..o + dh]) {
                    *y = f16_to_f32(h);
                }
            }
            KvPlane::U8 { codes, segs } => {
                let s = &segs[seg];
                let (lo, sc) = (s.lo, s.scale());
                for (y, &c) in out[..dh].iter_mut().zip(&codes[o..o + dh]) {
                    *y = lo + sc * c as f32;
                }
            }
        }
    }

    /// Store one row, quantizing at write time. A u8 row that widens its
    /// segment's range deterministically requantizes the whole segment
    /// (decode with the old params, re-encode with the new) before the
    /// row is written.
    pub fn encode_row(&mut self, seg: usize, within: usize, bs: usize, dh: usize, src: &[f32]) {
        let o = Self::row_off(seg, within, bs, dh);
        match self {
            KvPlane::F32(d) => d[o..o + dh].copy_from_slice(&src[..dh]),
            KvPlane::F16(d) => {
                for (y, &x) in d[o..o + dh].iter_mut().zip(src) {
                    *y = f16_from_f32(x);
                }
            }
            KvPlane::U8 { codes, segs } => {
                let mut lo = segs[seg].lo;
                let mut hi = segs[seg].hi;
                for &x in &src[..dh] {
                    lo = lo.min(x);
                    hi = hi.max(x);
                }
                if lo < segs[seg].lo || hi > segs[seg].hi {
                    let old = segs[seg];
                    let new = Seg { lo, hi };
                    if old.hi >= old.lo {
                        let so = seg * bs * dh;
                        for c in &mut codes[so..so + bs * dh] {
                            *c = quantize_u8(dequantize_u8(*c, &old), &new);
                        }
                    }
                    segs[seg] = new;
                }
                let s = segs[seg];
                for (c, &x) in codes[o..o + dh].iter_mut().zip(src) {
                    *c = quantize_u8(x, &s);
                }
            }
        }
    }

    /// Overwrite the whole plane from dense f32 data, re-deriving each
    /// u8 segment's range in a single shot (prefix-tree insertion).
    pub fn encode_block(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.len(), "encode_block: length mismatch");
        match self {
            KvPlane::F32(d) => d.copy_from_slice(src),
            KvPlane::F16(d) => {
                for (y, &x) in d.iter_mut().zip(src) {
                    *y = f16_from_f32(x);
                }
            }
            KvPlane::U8 { codes, segs } => {
                let seg_len = codes.len() / segs.len();
                for (si, sg) in segs.iter_mut().enumerate() {
                    let span = si * seg_len..(si + 1) * seg_len;
                    let mut lo = f32::INFINITY;
                    let mut hi = f32::NEG_INFINITY;
                    for &x in &src[span.clone()] {
                        lo = lo.min(x);
                        hi = hi.max(x);
                    }
                    *sg = Seg { lo, hi };
                    for (c, &x) in codes[span.clone()].iter_mut().zip(&src[span]) {
                        *c = quantize_u8(x, sg);
                    }
                }
            }
        }
    }

    /// Decode the whole plane to dense f32 (prefix-seed assembly, spill
    /// round-trip tests).
    pub fn decode_all(&self) -> Vec<f32> {
        match self {
            KvPlane::F32(d) => d.clone(),
            KvPlane::F16(d) => d.iter().map(|&h| f16_to_f32(h)).collect(),
            KvPlane::U8 { codes, segs } => {
                let seg_len = codes.len() / segs.len();
                let mut out = Vec::with_capacity(codes.len());
                for (si, s) in segs.iter().enumerate() {
                    let (lo, sc) = (s.lo, s.scale());
                    // an untouched (EMPTY) segment decodes as zeros
                    if s.hi < s.lo {
                        out.resize(out.len() + seg_len, 0.0);
                        continue;
                    }
                    out.extend(codes[si * seg_len..(si + 1) * seg_len].iter().map(|&c| lo + sc * c as f32));
                }
                out
            }
        }
    }

    /// `dot(q, row)` with dequantization fused into the loop. The f32
    /// arm is exactly [`dot4`], so the oracle path's numerics are
    /// untouched; the u8 arm uses the affine decomposition
    /// `scale·Σ(qᵢ·cᵢ) + lo·Σqᵢ` — no per-element decode.
    #[inline]
    pub fn row_dot(&self, seg: usize, within: usize, bs: usize, dh: usize, q: &[f32]) -> f32 {
        let o = Self::row_off(seg, within, bs, dh);
        match self {
            KvPlane::F32(d) => dot4(q, &d[o..o + dh]),
            KvPlane::F16(d) => {
                let mut s = 0.0f32;
                for (qi, &h) in q[..dh].iter().zip(&d[o..o + dh]) {
                    s += qi * f16_to_f32(h);
                }
                s
            }
            KvPlane::U8 { codes, segs } => {
                let sg = &segs[seg];
                let mut cd = 0.0f32; // Σ qᵢ·cᵢ
                let mut qs = 0.0f32; // Σ qᵢ
                for (qi, &c) in q[..dh].iter().zip(&codes[o..o + dh]) {
                    cd += qi * c as f32;
                    qs += qi;
                }
                sg.scale() * cd + sg.lo * qs
            }
        }
    }

    /// `out += w · row` with dequantization fused into the loop.
    #[inline]
    pub fn row_axpy(
        &self,
        seg: usize,
        within: usize,
        bs: usize,
        dh: usize,
        w: f32,
        out: &mut [f32],
    ) {
        let o = Self::row_off(seg, within, bs, dh);
        match self {
            KvPlane::F32(d) => {
                for (y, &x) in out[..dh].iter_mut().zip(&d[o..o + dh]) {
                    *y += w * x;
                }
            }
            KvPlane::F16(d) => {
                for (y, &h) in out[..dh].iter_mut().zip(&d[o..o + dh]) {
                    *y += w * f16_to_f32(h);
                }
            }
            KvPlane::U8 { codes, segs } => {
                let sg = &segs[seg];
                let (ws, wl) = (w * sg.scale(), w * sg.lo);
                for (y, &c) in out[..dh].iter_mut().zip(&codes[o..o + dh]) {
                    *y += ws * c as f32 + wl;
                }
            }
        }
    }

    /// Copy one row's *stored representation* verbatim (gather
    /// compaction that does not cross block boundaries — no decode, no
    /// requantization error). The destination u8 segment adopts the
    /// source segment's quant params on first copy; mixing params is a
    /// caller bug.
    pub fn copy_row_from(
        &mut self,
        src: &KvPlane,
        src_seg: usize,
        src_within: usize,
        dst_seg: usize,
        dst_within: usize,
        bs: usize,
        dh: usize,
    ) {
        let so = Self::row_off(src_seg, src_within, bs, dh);
        let po = Self::row_off(dst_seg, dst_within, bs, dh);
        match (self, src) {
            (KvPlane::F32(d), KvPlane::F32(s)) => d[po..po + dh].copy_from_slice(&s[so..so + dh]),
            (KvPlane::F16(d), KvPlane::F16(s)) => d[po..po + dh].copy_from_slice(&s[so..so + dh]),
            (
                KvPlane::U8 { codes, segs },
                KvPlane::U8 { codes: scodes, segs: ssegs },
            ) => {
                let sp = ssegs[src_seg];
                let dsg = &mut segs[dst_seg];
                if dsg.hi < dsg.lo {
                    *dsg = sp;
                }
                assert_eq!(*dsg, sp, "raw row copy requires matching quant params");
                codes[po..po + dh].copy_from_slice(&scodes[so..so + dh]);
            }
            _ => panic!("copy_row_from across KV dtypes"),
        }
    }
}

/// Per-model KV geometry (everything but the sequence axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvDims {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl KvDims {
    pub fn of(meta: &crate::runtime::ModelMeta) -> KvDims {
        KvDims {
            n_layers: meta.n_layers,
            n_kv_heads: meta.n_kv_heads,
            head_dim: meta.head_dim,
        }
    }

    /// Floats per token slot, per side (K or V).
    pub fn slot_floats(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.head_dim
    }
}

/// One bound block's stored buffers: `block_size` slots of K and V in
/// the arena's [`KvDtype`], laid out `[L, Hkv, block_size, dh]` per
/// side.
#[derive(Debug, Clone)]
pub struct KvBlock {
    pub k: KvPlane,
    pub v: KvPlane,
}

impl KvBlock {
    /// Resident bytes of the stored representation (both sides).
    pub fn bytes(&self) -> usize {
        self.k.bytes() + self.v.bytes()
    }

    /// What this block would cost at f32 (compression-ratio accounting).
    pub fn logical_bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// Uniform row-level access to a sequence's KV, whatever its physical
/// layout or dtype. The reference backend's prefill/decode kernels are
/// generic over this trait, so the dense and paged paths run the *same*
/// float operations in the same order — bit-identical by construction
/// at f32, one shared dequant implementation otherwise.
pub trait KvAccess {
    /// Allocated slot capacity visible to the kernel.
    fn n_slots(&self) -> usize;
    /// The `dh`-float K row of `slot` in layer `li`, KV head `g` —
    /// borrowed straight from f32 storage, or dequantized into the
    /// caller's `O(dh)` scratch row.
    fn k_row<'s>(&'s self, li: usize, g: usize, slot: usize, scratch: &'s mut [f32]) -> &'s [f32];
    fn v_row<'s>(&'s self, li: usize, g: usize, slot: usize, scratch: &'s mut [f32]) -> &'s [f32];
    /// Store one slot's K/V rows (decode insertion, prefill append) —
    /// quantizes at write time on low-precision storage.
    fn write_row(&mut self, li: usize, g: usize, slot: usize, k: &[f32], v: &[f32]);
    /// `dot(q, K[slot])` with dequantization fused into the row loop.
    fn k_dot(&self, li: usize, g: usize, slot: usize, q: &[f32]) -> f32;
    /// `out += w · V[slot]` with dequantization fused into the row loop.
    fn v_axpy(&self, li: usize, g: usize, slot: usize, w: f32, out: &mut [f32]);
}

/// [`KvAccess`] over borrowed dense `[L, Hkv, cap, dh]` tensors (the
/// historical cache layout; still the prefill-bucket scratch layout).
/// Always f32 — `--kv-dtype` applies to arena-backed storage only.
pub struct DenseKvRef<'a> {
    k: &'a mut TensorF,
    v: &'a mut TensorF,
    hkv: usize,
    cap: usize,
    dh: usize,
}

impl<'a> DenseKvRef<'a> {
    /// `k`/`v` must be `[L, Hkv, cap, dh]`-shaped (callers validate).
    pub fn new(k: &'a mut TensorF, v: &'a mut TensorF) -> DenseKvRef<'a> {
        debug_assert_eq!(k.shape.len(), 4);
        debug_assert_eq!(k.shape, v.shape);
        let (hkv, cap, dh) = (k.shape[1], k.shape[2], k.shape[3]);
        DenseKvRef { k, v, hkv, cap, dh }
    }

    #[inline(always)]
    fn off(&self, li: usize, g: usize, slot: usize) -> usize {
        ((li * self.hkv + g) * self.cap + slot) * self.dh
    }
}

impl KvAccess for DenseKvRef<'_> {
    #[inline(always)]
    fn n_slots(&self) -> usize {
        self.cap
    }

    #[inline(always)]
    fn k_row<'s>(&'s self, li: usize, g: usize, slot: usize, _scratch: &'s mut [f32]) -> &'s [f32] {
        let o = self.off(li, g, slot);
        &self.k.data[o..o + self.dh]
    }

    #[inline(always)]
    fn v_row<'s>(&'s self, li: usize, g: usize, slot: usize, _scratch: &'s mut [f32]) -> &'s [f32] {
        let o = self.off(li, g, slot);
        &self.v.data[o..o + self.dh]
    }

    #[inline(always)]
    fn write_row(&mut self, li: usize, g: usize, slot: usize, k: &[f32], v: &[f32]) {
        let o = self.off(li, g, slot);
        self.k.data[o..o + self.dh].copy_from_slice(k);
        self.v.data[o..o + self.dh].copy_from_slice(v);
    }

    #[inline(always)]
    fn k_dot(&self, li: usize, g: usize, slot: usize, q: &[f32]) -> f32 {
        let o = self.off(li, g, slot);
        dot4(q, &self.k.data[o..o + self.dh])
    }

    #[inline(always)]
    fn v_axpy(&self, li: usize, g: usize, slot: usize, w: f32, out: &mut [f32]) {
        let o = self.off(li, g, slot);
        for (y, &x) in out[..self.dh].iter_mut().zip(&self.v.data[o..o + self.dh]) {
            *y += w * x;
        }
    }
}

/// [`KvAccess`] over blocks taken out of the arena (the paged layout).
/// Owning the buffers makes it `Send`, so batched decode can fan
/// sequences out onto scoped threads with no aliasing questions.
pub struct OwnedKv {
    blocks: Vec<KvBlock>,
    dims: KvDims,
    block_size: usize,
}

impl OwnedKv {
    pub fn new(blocks: Vec<KvBlock>, dims: KvDims, block_size: usize) -> OwnedKv {
        let want = dims.slot_floats() * block_size;
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(b.k.len(), want, "block {i}: K buffer does not match {dims:?}");
            assert_eq!(b.v.len(), want, "block {i}: V buffer does not match {dims:?}");
        }
        OwnedKv { blocks, dims, block_size }
    }

    pub fn into_blocks(self) -> Vec<KvBlock> {
        self.blocks
    }

    pub fn blocks(&self) -> &[KvBlock] {
        &self.blocks
    }

    #[inline(always)]
    fn seg(&self, li: usize, g: usize) -> usize {
        li * self.dims.n_kv_heads + g
    }
}

impl KvAccess for OwnedKv {
    #[inline(always)]
    fn n_slots(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    #[inline(always)]
    fn k_row<'s>(&'s self, li: usize, g: usize, slot: usize, scratch: &'s mut [f32]) -> &'s [f32] {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        let dh = self.dims.head_dim;
        let plane = &self.blocks[b].k;
        if let KvPlane::F32(d) = plane {
            let o = (self.seg(li, g) * self.block_size + within) * dh;
            return &d[o..o + dh];
        }
        plane.decode_row(self.seg(li, g), within, self.block_size, dh, scratch);
        &scratch[..dh]
    }

    #[inline(always)]
    fn v_row<'s>(&'s self, li: usize, g: usize, slot: usize, scratch: &'s mut [f32]) -> &'s [f32] {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        let dh = self.dims.head_dim;
        let plane = &self.blocks[b].v;
        if let KvPlane::F32(d) = plane {
            let o = (self.seg(li, g) * self.block_size + within) * dh;
            return &d[o..o + dh];
        }
        plane.decode_row(self.seg(li, g), within, self.block_size, dh, scratch);
        &scratch[..dh]
    }

    #[inline(always)]
    fn write_row(&mut self, li: usize, g: usize, slot: usize, k: &[f32], v: &[f32]) {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        let (seg, bs, dh) = (self.seg(li, g), self.block_size, self.dims.head_dim);
        self.blocks[b].k.encode_row(seg, within, bs, dh, k);
        self.blocks[b].v.encode_row(seg, within, bs, dh, v);
    }

    #[inline(always)]
    fn k_dot(&self, li: usize, g: usize, slot: usize, q: &[f32]) -> f32 {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        self.blocks[b].k.row_dot(self.seg(li, g), within, self.block_size, self.dims.head_dim, q)
    }

    #[inline(always)]
    fn v_axpy(&self, li: usize, g: usize, slot: usize, w: f32, out: &mut [f32]) {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        self.blocks[b].v.row_axpy(
            self.seg(li, g),
            within,
            self.block_size,
            self.dims.head_dim,
            w,
            out,
        );
    }
}

/// The shared physical block store. Indexed by [`BlockId`]; one slot per
/// allocator block, `None` until bound (or while temporarily taken).
/// Every bound block stores KV in the arena-wide [`KvDtype`].
#[derive(Debug)]
pub struct KvArena {
    block_size: usize,
    dtype: KvDtype,
    slots: Vec<Option<KvBlock>>,
    bytes: usize,
    logical_bytes: usize,
    peak_bytes: usize,
}

impl KvArena {
    pub fn new(n_blocks: usize, block_size: usize) -> KvArena {
        KvArena::with_dtype(n_blocks, block_size, KvDtype::F32)
    }

    pub fn with_dtype(n_blocks: usize, block_size: usize, dtype: KvDtype) -> KvArena {
        assert!(block_size > 0, "KvArena block_size must be > 0");
        KvArena {
            block_size,
            dtype,
            slots: (0..n_blocks).map(|_| None).collect(),
            bytes: 0,
            logical_bytes: 0,
            peak_bytes: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn n_blocks(&self) -> usize {
        self.slots.len()
    }

    /// Resident KV bytes of every bound block, in *stored* (dtype-true)
    /// bytes — what the memory actually costs.
    pub fn bytes_in_use(&self) -> usize {
        self.bytes
    }

    /// What the same bound blocks would cost at f32. The
    /// resident/logical ratio is the arena's compression factor.
    pub fn logical_bytes_in_use(&self) -> usize {
        self.logical_bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Bound blocks (excludes blocks currently taken by a kernel — stats
    /// are read between engine iterations, never mid-call).
    pub fn blocks_bound(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn idx(&self, b: BlockId) -> usize {
        let i = b.0 as usize;
        assert!(i < self.slots.len(), "block {b:?} outside the arena ({})", self.slots.len());
        i
    }

    /// Materialize zeroed buffers for freshly allocated blocks in the
    /// arena's dtype. `dims` is the owning model's geometry
    /// ([`KvDims`]) — it sizes both the payload and the u8 quant
    /// segments (one per layer × KV head).
    pub fn bind(&mut self, blocks: &[BlockId], dims: &KvDims) {
        let n = dims.slot_floats() * self.block_size;
        assert!(n > 0, "binding zero-sized KV slots");
        let n_segs = dims.n_layers * dims.n_kv_heads;
        let block_bytes = self.dtype.block_bytes(dims, self.block_size);
        for &b in blocks {
            let i = self.idx(b);
            assert!(self.slots[i].is_none(), "binding already-bound block {b:?}");
            self.slots[i] = Some(KvBlock {
                k: KvPlane::zeroed(self.dtype, n, n_segs),
                v: KvPlane::zeroed(self.dtype, n, n_segs),
            });
            self.bytes += block_bytes;
            self.logical_bytes += n * 2 * 4;
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
    }

    /// Drop the buffers of freed blocks. Blocks that were never bound
    /// (accounting-only reservations) are skipped silently.
    pub fn release(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            let i = self.idx(b);
            if let Some(kvb) = self.slots[i].take() {
                self.bytes -= kvb.bytes();
                self.logical_bytes -= kvb.logical_bytes();
            }
        }
    }

    /// Move the blocks' buffers out (for an [`OwnedKv`] view). Fails —
    /// with no side effects — if any block is unbound or already taken,
    /// which also catches overlapping block tables in a batch.
    pub fn take(&mut self, blocks: &[BlockId]) -> Result<Vec<KvBlock>> {
        for &b in blocks {
            let i = self.idx(b);
            anyhow::ensure!(
                self.slots[i].is_some(),
                "arena block {b:?} is unbound or already taken"
            );
        }
        Ok(blocks.iter().map(|&b| self.slots[b.0 as usize].take().unwrap()).collect())
    }

    /// Move the blocks' buffers out of the arena *permanently* (cold
    /// spill tier): unlike [`KvArena::take`], the bytes leave resident
    /// accounting, because the caller is about to free the block ids and
    /// park the buffers host-side. The stored representation moves
    /// verbatim — spilling a u8 block never decodes it. Fails with no
    /// side effects if any block is unbound or currently taken.
    pub fn spill(&mut self, blocks: &[BlockId]) -> Result<Vec<KvBlock>> {
        let kvs = self.take(blocks).context("spill")?;
        for kvb in &kvs {
            self.bytes -= kvb.bytes();
            self.logical_bytes -= kvb.logical_bytes();
        }
        Ok(kvs)
    }

    /// Re-bind spilled buffers to freshly allocated blocks, bringing
    /// their bytes back into resident accounting. The buffers move
    /// verbatim, so a spill → restore round trip is bit-identical on
    /// the stored representation for every dtype.
    pub fn restore(&mut self, blocks: &[BlockId], kvs: Vec<KvBlock>) {
        assert_eq!(blocks.len(), kvs.len(), "restore: table/buffer length mismatch");
        for (&b, kvb) in blocks.iter().zip(kvs) {
            let i = self.idx(b);
            assert!(self.slots[i].is_none(), "restoring into occupied arena slot {b:?}");
            self.bytes += kvb.bytes();
            self.logical_bytes += kvb.logical_bytes();
            self.slots[i] = Some(kvb);
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
    }

    /// Return buffers taken via [`KvArena::take`].
    pub fn put(&mut self, blocks: &[BlockId], kvs: Vec<KvBlock>) {
        assert_eq!(blocks.len(), kvs.len(), "put: table/buffer length mismatch");
        for (&b, kvb) in blocks.iter().zip(kvs) {
            let i = self.idx(b);
            assert!(self.slots[i].is_none(), "putting into occupied arena slot {b:?}");
            self.slots[i] = Some(kvb);
        }
    }

    fn block(&self, b: BlockId) -> &KvBlock {
        self.slots[self.idx(b)].as_ref().unwrap_or_else(|| panic!("reading unbound block {b:?}"))
    }

    /// Read one K row: `slot` is the *global* slot index of a block
    /// table, resolved to `(blocks[slot / bs], slot % bs)` by the
    /// caller. f32 storage returns a borrow; quantized storage decodes
    /// into `scratch` (≥ `dh` floats).
    pub fn k_row<'s>(
        &'s self,
        dims: &KvDims,
        b: BlockId,
        li: usize,
        g: usize,
        within: usize,
        scratch: &'s mut [f32],
    ) -> &'s [f32] {
        let (seg, dh) = (li * dims.n_kv_heads + g, dims.head_dim);
        let plane = &self.block(b).k;
        if let KvPlane::F32(d) = plane {
            let o = (seg * self.block_size + within) * dh;
            return &d[o..o + dh];
        }
        plane.decode_row(seg, within, self.block_size, dh, scratch);
        &scratch[..dh]
    }

    pub fn v_row<'s>(
        &'s self,
        dims: &KvDims,
        b: BlockId,
        li: usize,
        g: usize,
        within: usize,
        scratch: &'s mut [f32],
    ) -> &'s [f32] {
        let (seg, dh) = (li * dims.n_kv_heads + g, dims.head_dim);
        let plane = &self.block(b).v;
        if let KvPlane::F32(d) = plane {
            let o = (seg * self.block_size + within) * dh;
            return &d[o..o + dh];
        }
        plane.decode_row(seg, within, self.block_size, dh, scratch);
        &scratch[..dh]
    }

    /// Write one `dh`-float K/V row pair at `(layer, head, offset)` of a
    /// bound block, quantizing at write time.
    pub fn write_row(
        &mut self,
        dims: &KvDims,
        b: BlockId,
        li: usize,
        g: usize,
        within: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let (seg, bs, dh) = (li * dims.n_kv_heads + g, self.block_size, dims.head_dim);
        let i = self.idx(b);
        let blk = self.slots[i].as_mut().unwrap_or_else(|| panic!("writing unbound block {b:?}"));
        blk.k.encode_row(seg, within, bs, dh, k);
        blk.v.encode_row(seg, within, bs, dh, v);
    }

    /// Copy whole block buffers in (prefix-tree insertion: a
    /// [`super::prefix::BlockRecord`]'s `[L, Hkv, bs, dh]` tensors have
    /// exactly the block layout). On quantized storage this is the
    /// single-shot quantization path: u8 segment ranges are derived from
    /// the full block in one pass.
    pub fn write_block(&mut self, b: BlockId, k: &[f32], v: &[f32]) {
        let i = self.idx(b);
        let blk = self.slots[i].as_mut().unwrap_or_else(|| panic!("writing unbound block {b:?}"));
        assert_eq!(blk.k.len(), k.len(), "write_block: K length mismatch");
        assert_eq!(blk.v.len(), v.len(), "write_block: V length mismatch");
        blk.k.encode_block(k);
        blk.v.encode_block(v);
    }

    /// One bound block's contents, decoded to dense f32 (prefix seed
    /// assembly, tests). Bit-exact at f32; one shared dequant otherwise.
    pub fn block_kv(&self, b: BlockId) -> Option<(Vec<f32>, Vec<f32>)> {
        self.slots[self.idx(b)].as_ref().map(|blk| (blk.k.decode_all(), blk.v.decode_all()))
    }

    /// One bound block's *stored* representation (spill tests, raw-copy
    /// compaction).
    pub fn block_raw(&self, b: BlockId) -> Option<&KvBlock> {
        self.slots[self.idx(b)].as_ref()
    }

    /// Gather rows `0..rows` of a block table into dense
    /// `[L, Hkv, rows, dh]` f32 tensors, decoding as it goes.
    pub fn gather_dense(
        &self,
        dims: &KvDims,
        blocks: &[BlockId],
        rows: usize,
    ) -> Result<(TensorF, TensorF)> {
        anyhow::ensure!(
            rows <= blocks.len() * self.block_size,
            "gather of {rows} rows exceeds the table's {} slots",
            blocks.len() * self.block_size
        );
        let (l, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.head_dim);
        let mut k = TensorF::zeros(vec![l, hkv, rows, dh]);
        let mut v = TensorF::zeros(vec![l, hkv, rows, dh]);
        for li in 0..l {
            for g in 0..hkv {
                let seg = li * hkv + g;
                for r in 0..rows {
                    let blk = self.block(blocks[r / self.block_size]);
                    let within = r % self.block_size;
                    let dst = ((li * hkv + g) * rows + r) * dh;
                    blk.k.decode_row(seg, within, self.block_size, dh, &mut k.data[dst..dst + dh]);
                    blk.v.decode_row(seg, within, self.block_size, dh, &mut v.data[dst..dst + dh]);
                }
            }
        }
        Ok((k, v))
    }

    /// Scatter dense `[L, Hkv, rows, dh]` tensors into rows
    /// `start..start + rows` of a block table (prefix-seed resume, the
    /// default backend's paged write-through), quantizing at write time.
    pub fn scatter_dense(
        &mut self,
        dims: &KvDims,
        blocks: &[BlockId],
        start: usize,
        k: &TensorF,
        v: &TensorF,
    ) -> Result<()> {
        let (l, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.head_dim);
        anyhow::ensure!(
            k.shape.len() == 4 && k.shape[0] == l && k.shape[1] == hkv && k.shape[3] == dh,
            "scatter source shape {:?} does not match {dims:?}",
            k.shape
        );
        anyhow::ensure!(k.shape == v.shape, "scatter K/V shape mismatch");
        let rows = k.shape[2];
        anyhow::ensure!(
            start + rows <= blocks.len() * self.block_size,
            "scatter of rows {start}..{} exceeds the table's {} slots",
            start + rows,
            blocks.len() * self.block_size
        );
        for li in 0..l {
            for g in 0..hkv {
                for r in 0..rows {
                    let slot = start + r;
                    let b = blocks[slot / self.block_size];
                    let within = slot % self.block_size;
                    let src = ((li * hkv + g) * rows + r) * dh;
                    self.write_row(
                        dims,
                        b,
                        li,
                        g,
                        within,
                        &k.data[src..src + dh],
                        &v.data[src..src + dh],
                    );
                }
            }
        }
        Ok(())
    }
}

/// Allocator + arena + owner bundle threaded through paged prefill (one
/// per in-flight request; see `engine::chunked`). Allocation and byte
/// binding always happen together so accounting can never skew.
pub struct PagedCtx<'a> {
    pub arena: &'a mut KvArena,
    pub alloc: &'a mut BlockAllocator,
    /// The shared prefix tree, when enabled: unpinned LRU leaves are
    /// reclaimed before any allocation through this context is allowed
    /// to fail — mid-job pass allocations (lkv+suffix second pass,
    /// LAQ/SpecKV rescore) get the same before-failing-reclaim guarantee
    /// as admission.
    pub prefix: Option<&'a mut super::prefix::PrefixCache>,
    pub owner: u64,
}

impl PagedCtx<'_> {
    /// Allocate and bind enough blocks for `slots` token slots,
    /// LRU-reclaiming unpinned prefix-tree blocks first under pool
    /// pressure. "kv pool exhausted" means genuinely exhausted.
    pub fn alloc_blocks(&mut self, slots: usize, dims: &KvDims) -> Result<Vec<BlockId>> {
        let slots = slots.max(1);
        if let Some(p) = self.prefix.as_deref_mut() {
            while !self.alloc.can_alloc(slots) {
                let need = self
                    .alloc
                    .blocks_for_slots(slots)
                    .saturating_sub(self.alloc.free_blocks())
                    .max(1);
                if p.reclaim(self.alloc, self.arena, need) == 0 {
                    break;
                }
            }
        }
        let ids = self.alloc.alloc(self.owner, slots).context("kv pool exhausted")?;
        self.arena.bind(&ids, dims);
        Ok(ids)
    }

    /// Free blocks back to the pool and drop their buffers.
    pub fn free_blocks(&mut self, ids: &[BlockId]) {
        self.arena.release(ids);
        self.alloc.free(ids);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    const DIMS: KvDims = KvDims { n_layers: 2, n_kv_heads: 2, head_dim: 4 };

    #[test]
    fn bind_take_put_release_accounting() {
        let mut a = KvArena::new(4, 8);
        let ids = [BlockId(0), BlockId(2)];
        a.bind(&ids, &DIMS);
        let per_block = DIMS.slot_floats() * 8 * 2 * 4;
        assert_eq!(a.bytes_in_use(), 2 * per_block);
        assert_eq!(a.logical_bytes_in_use(), 2 * per_block, "f32: resident == logical");
        assert_eq!(a.blocks_bound(), 2);
        let taken = a.take(&ids).unwrap();
        assert_eq!(taken.len(), 2);
        // double-take (aliasing) is an error with no side effects
        assert!(a.take(&[BlockId(0)]).is_err());
        a.put(&ids, taken);
        assert_eq!(a.blocks_bound(), 2);
        a.release(&ids);
        assert_eq!(a.bytes_in_use(), 0);
        assert_eq!(a.logical_bytes_in_use(), 0);
        // releasing never-bound blocks is a no-op (dense reservations)
        a.release(&[BlockId(1)]);
        assert_eq!(a.bytes_in_use(), 0);
    }

    #[test]
    fn dtype_accounting_ratios() {
        for (dtype, max_ratio) in
            [(KvDtype::F32, 1.0), (KvDtype::F16, 0.5), (KvDtype::U8, 0.27)]
        {
            let mut a = KvArena::with_dtype(4, 64, dtype);
            let dims = KvDims { n_layers: 4, n_kv_heads: 2, head_dim: 16 };
            let ids = [BlockId(0), BlockId(1)];
            a.bind(&ids, &dims);
            let ratio = a.bytes_in_use() as f64 / a.logical_bytes_in_use() as f64;
            assert!(
                ratio <= max_ratio,
                "{dtype}: resident/logical {ratio:.4} above the {max_ratio} ceiling"
            );
            assert_eq!(a.bytes_in_use(), 2 * dtype.block_bytes(&dims, 64));
            a.release(&ids);
            assert_eq!(a.bytes_in_use(), 0);
            assert_eq!(a.logical_bytes_in_use(), 0);
        }
    }

    #[test]
    fn rows_roundtrip_through_blocks() {
        let mut a = KvArena::new(2, 4);
        let ids = [BlockId(1), BlockId(0)]; // order of the table, not of ids
        a.bind(&ids, &DIMS);
        let bs = a.block_size();
        // write slots 0..7 through the table, read them back
        for slot in 0..2 * bs {
            let b = ids[slot / bs];
            let within = slot % bs;
            for li in 0..DIMS.n_layers {
                for g in 0..DIMS.n_kv_heads {
                    let val = (slot * 100 + li * 10 + g) as f32;
                    let row = [val; 4];
                    a.write_row(&DIMS, b, li, g, within, &row, &row);
                }
            }
        }
        let mut scr = [0.0f32; 4];
        assert_eq!(a.k_row(&DIMS, ids[1], 1, 0, 2, &mut scr)[0], (6 * 100 + 10) as f32);
        let (k, v) = a.gather_dense(&DIMS, &ids, 7).unwrap();
        assert_eq!(k.shape, vec![2, 2, 7, 4]);
        assert_eq!(k.index(&[0, 1, 5])[0], 501.0);
        assert_eq!(v.index(&[1, 1, 6])[0], 611.0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut a = KvArena::new(3, 4);
        let ids = [BlockId(2), BlockId(0), BlockId(1)];
        a.bind(&ids, &DIMS);
        let rows = 10;
        let n = DIMS.n_layers * DIMS.n_kv_heads * rows * DIMS.head_dim;
        let k = TensorF::new(
            vec![DIMS.n_layers, DIMS.n_kv_heads, rows, DIMS.head_dim],
            (0..n).map(|x| x as f32).collect(),
        );
        let v = TensorF::new(k.shape.clone(), (0..n).map(|x| -(x as f32)).collect());
        a.scatter_dense(&DIMS, &ids, 0, &k, &v).unwrap();
        let (k2, v2) = a.gather_dense(&DIMS, &ids, rows).unwrap();
        assert_eq!(k.data, k2.data);
        assert_eq!(v.data, v2.data);
        // out-of-capacity gathers/scatters error
        assert!(a.gather_dense(&DIMS, &ids, 13).is_err());
    }

    /// Property: slot -> (block, offset) resolution round-trips for any
    /// block size and table permutation — writing each slot through the
    /// mapping and reading it back yields the written row, and distinct
    /// slots never alias.
    #[test]
    fn prop_slot_block_offset_roundtrip() {
        check("slot/block mapping", &Config { cases: 64, max_size: 24, ..Config::new() }, |rng, size| {
            let bs = rng.range(1, 9);
            let n_blocks = rng.range(1, 5 + size.min(8));
            let mut a = KvArena::new(n_blocks, bs);
            // a random permutation of all blocks as the table
            let mut table: Vec<BlockId> = (0..n_blocks as u32).map(BlockId).collect();
            for i in (1..table.len()).rev() {
                let j = rng.below(i + 1);
                table.swap(i, j);
            }
            let dims = KvDims { n_layers: rng.range(1, 3), n_kv_heads: rng.range(1, 3), head_dim: 2 };
            a.bind(&table, &dims);
            let slots = n_blocks * bs;
            for slot in 0..slots {
                let (b, within) = (table[slot / bs], slot % bs);
                for li in 0..dims.n_layers {
                    for g in 0..dims.n_kv_heads {
                        let val = (slot * 1000 + li * 10 + g) as f32;
                        a.write_row(&dims, b, li, g, within, &[val, val + 0.5], &[-val, val]);
                    }
                }
            }
            let mut scr = [0.0f32; 2];
            for slot in 0..slots {
                let (b, within) = (table[slot / bs], slot % bs);
                for li in 0..dims.n_layers {
                    for g in 0..dims.n_kv_heads {
                        let want = (slot * 1000 + li * 10 + g) as f32;
                        assert_eq!(a.k_row(&dims, b, li, g, within, &mut scr), &[want, want + 0.5][..]);
                        assert_eq!(a.v_row(&dims, b, li, g, within, &mut scr), &[-want, want][..]);
                    }
                }
            }
            // OwnedKv sees the same bytes through global slot indices
            let taken = a.take(&table).unwrap();
            let kv = OwnedKv::new(taken, dims, bs);
            for slot in 0..slots {
                let want = (slot * 1000) as f32;
                assert_eq!(kv.k_row(0, 0, slot, &mut scr)[0], want);
                // the fused dot agrees with a scratch-decode dot
                let q = [1.0f32, 2.0];
                let row = kv.k_row(0, 0, slot, &mut scr).to_vec();
                assert_eq!(kv.k_dot(0, 0, slot, &q), dot4(&q, &row));
            }
            a.put(&table, kv.into_blocks());
        });
    }

    #[test]
    fn paged_ctx_allocates_and_frees() {
        let mut arena = KvArena::new(8, 8);
        let mut alloc = BlockAllocator::new(64, 8);
        let mut ctx = PagedCtx { arena: &mut arena, alloc: &mut alloc, prefix: None, owner: 7 };
        let ids = ctx.alloc_blocks(20, &DIMS).unwrap(); // 3 blocks
        assert_eq!(ids.len(), 3);
        assert!(ctx.arena.bytes_in_use() > 0);
        assert_eq!(ctx.alloc.used_blocks(), 3);
        ctx.free_blocks(&ids);
        assert_eq!(ctx.arena.bytes_in_use(), 0);
        assert_eq!(ctx.alloc.used_blocks(), 0);
        // zero-slot requests still pin one block (a live sequence always
        // has at least one block to append into)
        let ids = ctx.alloc_blocks(0, &DIMS).unwrap();
        assert_eq!(ids.len(), 1);
        ctx.free_blocks(&ids);
    }

    /// f16 conversion: f16 → f32 → f16 is the identity on every finite
    /// half bit pattern, and f32 → f16 rounds within half a ULP.
    #[test]
    fn f16_conversion_properties() {
        for h in 0u16..=0xffff {
            let e = (h >> 10) & 0x1f;
            let x = f16_to_f32(h);
            if e == 31 {
                if h & 0x03ff == 0 {
                    assert!(x.is_infinite());
                } else {
                    assert!(x.is_nan());
                    continue; // NaN payloads need not round-trip bit-exactly
                }
            }
            assert_eq!(f16_from_f32(x), h, "half bits {h:#06x} do not round-trip");
        }
        // rounding: max relative error of a f32 -> f16 -> f32 trip is
        // 2^-11 for normal halves
        check("f16 rounding", &Config { cases: 256, ..Config::new() }, |rng, _| {
            let x = (rng.f32() - 0.5) * 100.0;
            let y = f16_to_f32(f16_from_f32(x));
            let tol = x.abs().max(6.1e-5) * (1.0 / 2048.0) + 1e-7;
            assert!((x - y).abs() <= tol, "f16 round of {x} gave {y}");
        });
        // specials
        assert_eq!(f16_from_f32(0.0), 0);
        assert_eq!(f16_from_f32(-0.0), 0x8000);
        assert_eq!(f16_from_f32(f32::INFINITY), 0x7c00);
        assert_eq!(f16_from_f32(1e9), 0x7c00, "overflow saturates to inf");
        assert_eq!(f16_from_f32(1e-12), 0, "underflow flushes to zero");
        assert_eq!(f16_to_f32(f16_from_f32(1.0)), 1.0);
        assert_eq!(f16_to_f32(f16_from_f32(-2.5)), -2.5);
    }

    /// u8 single-shot quantization: constant segments decode exactly;
    /// arbitrary segments decode within half a quantization step.
    #[test]
    fn u8_encode_block_error_bound() {
        check("u8 quantize", &Config { cases: 64, max_size: 12, ..Config::new() }, |rng, _| {
            let (bs, dh, n_segs) = (4usize, 4usize, 3usize);
            let n = n_segs * bs * dh;
            let kind = rng.below(4);
            let data: Vec<f32> = (0..n)
                .map(|i| match kind {
                    0 => 0.0,                                  // all zero: exact
                    1 => 3.25,                                 // constant: exact
                    2 => {
                        // single outlier per segment
                        if i % (bs * dh) == 0 { 1000.0 } else { rng.f32() }
                    }
                    _ => (rng.f32() - 0.5) * 1e-38,            // denormal-range values
                })
                .collect();
            let mut p = KvPlane::zeroed(KvDtype::U8, n, n_segs);
            p.encode_block(&data);
            let dec = p.decode_all();
            for si in 0..n_segs {
                let span = si * bs * dh..(si + 1) * bs * dh;
                let lo = data[span.clone()].iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = data[span.clone()].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let step = ((hi - lo) / 255.0).max(0.0);
                for i in span {
                    let err = (data[i] - dec[i]).abs();
                    assert!(
                        err <= step * 0.5001 + 1e-30,
                        "seg {si} elem {i}: |{} - {}| = {err} > step/2 ({step})",
                        data[i],
                        dec[i]
                    );
                }
            }
        });
    }

    /// u8 running-range writes: later rows that widen the range
    /// requantize earlier rows deterministically, and every live row
    /// stays within a small multiple of the final quantization step.
    #[test]
    fn u8_running_range_expansion() {
        let dims = KvDims { n_layers: 1, n_kv_heads: 1, head_dim: 4 };
        let mut a = KvArena::with_dtype(1, 8, KvDtype::U8);
        a.bind(&[BlockId(0)], &dims);
        let rows: Vec<[f32; 4]> = vec![
            [0.1, 0.2, 0.3, 0.4],
            [-5.0, 0.0, 5.0, 2.0],    // widens both ends
            [100.0, -100.0, 0.0, 1.0], // widens massively
            [0.5, 0.25, -0.25, 0.75],
        ];
        for (i, r) in rows.iter().enumerate() {
            a.write_row(&dims, BlockId(0), 0, 0, i, r, r);
        }
        let step = 200.0 / 255.0; // final range is [-100, 100]
        let mut scr = [0.0f32; 4];
        for (i, r) in rows.iter().enumerate() {
            let got = a.k_row(&dims, BlockId(0), 0, 0, i, &mut scr);
            for (x, y) in r.iter().zip(got) {
                assert!(
                    (x - y).abs() <= 2.0 * step,
                    "row {i}: |{x} - {y}| above the requantization bound"
                );
            }
        }
        // deterministic: the same write sequence reproduces the codes
        let mut b = KvArena::with_dtype(1, 8, KvDtype::U8);
        b.bind(&[BlockId(0)], &dims);
        for (i, r) in rows.iter().enumerate() {
            b.write_row(&dims, BlockId(0), 0, 0, i, r, r);
        }
        let (ka, va) = a.block_kv(BlockId(0)).unwrap();
        let (kb, vb) = b.block_kv(BlockId(0)).unwrap();
        assert_eq!(ka, kb);
        assert_eq!(va, vb);
    }

    /// Spill → restore moves the stored representation verbatim for
    /// every dtype: decoded contents (and u8 codes) are bit-identical.
    #[test]
    fn spill_restore_verbatim_per_dtype() {
        for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::U8] {
            let dims = KvDims { n_layers: 2, n_kv_heads: 1, head_dim: 4 };
            let mut a = KvArena::with_dtype(4, 4, dtype);
            let ids = [BlockId(0), BlockId(3)];
            a.bind(&ids, &dims);
            for slot in 0..8 {
                let (b, w) = (ids[slot / 4], slot % 4);
                for li in 0..2 {
                    let row = [slot as f32 * 0.37 - 1.0 + li as f32; 4];
                    a.write_row(&dims, b, li, 0, w, &row, &row);
                }
            }
            let before: Vec<_> = ids.iter().map(|&b| a.block_kv(b).unwrap()).collect();
            let bytes = a.bytes_in_use();
            let spilled = a.spill(&ids).unwrap();
            assert_eq!(a.bytes_in_use(), 0);
            let new_ids = [BlockId(1), BlockId(2)];
            a.restore(&new_ids, spilled);
            assert_eq!(a.bytes_in_use(), bytes);
            for (nb, want) in new_ids.iter().zip(&before) {
                assert_eq!(&a.block_kv(*nb).unwrap(), want, "{dtype}: spill round trip drifted");
            }
        }
    }

    /// Raw row copy (compaction that stays within one source block)
    /// moves codes verbatim and adopts the source quant params.
    #[test]
    fn u8_copy_row_from_adopts_params() {
        let (bs, dh) = (4usize, 4usize);
        let mut src = KvPlane::zeroed(KvDtype::U8, bs * dh, 1);
        let data: Vec<f32> = (0..bs * dh).map(|i| (i as f32) * 0.5 - 3.0).collect();
        src.encode_block(&data);
        let mut dst = KvPlane::zeroed(KvDtype::U8, bs * dh, 1);
        for w in 0..bs {
            dst.copy_row_from(&src, 0, w, 0, w, bs, dh);
        }
        assert_eq!(src.decode_all(), dst.decode_all(), "raw copy must be lossless");
    }
}

//! Shared physical KV arena: the block-granular storage behind every
//! paged cache in the system.
//!
//! The [`super::block::BlockAllocator`] decides *who* owns which
//! [`BlockId`]; the [`KvArena`] owns the *bytes* — one pair of K/V
//! buffers per bound block, each holding `block_size` token slots laid
//! out `[L, Hkv, block_size, dh]`. Decode caches
//! ([`super::paged::PagedSeqCache`]), in-flight chunked-prefill state
//! ([`crate::runtime::ChunkState`] with a block table) and prefix-tree
//! nodes ([`super::prefix::PrefixCache`]) are all views over the same
//! pool of blocks, so admission control charges actual bound bytes
//! rather than dense-bucket estimates.
//!
//! Buffers are materialized on [`KvArena::bind`] and dropped on
//! [`KvArena::release`], so `bytes_in_use` tracks *resident* KV — a
//! paged cache of 80 live rows costs two 64-slot blocks, not a 640-slot
//! dense bucket. The arena is dimension-agnostic: callers pass a
//! [`KvDims`] per access, which lets one pool serve models with
//! different layer/head geometry (e.g. the SpecKV draft model).
//!
//! Concurrency: the batched paged decode step temporarily *moves* each
//! sequence's [`KvBlock`]s out of the arena ([`KvArena::take`]), hands
//! the owned buffers to worker threads, and puts them back afterwards
//! ([`KvArena::put`]) — disjointness across sequences is enforced by
//! construction (a block can only be taken once), with no unsafe code.

use anyhow::{Context, Result};

use crate::util::tensor::TensorF;

use super::block::{BlockAllocator, BlockId};

/// Per-model KV geometry (everything but the sequence axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvDims {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl KvDims {
    pub fn of(meta: &crate::runtime::ModelMeta) -> KvDims {
        KvDims {
            n_layers: meta.n_layers,
            n_kv_heads: meta.n_kv_heads,
            head_dim: meta.head_dim,
        }
    }

    /// Floats per token slot, per side (K or V).
    pub fn slot_floats(&self) -> usize {
        self.n_layers * self.n_kv_heads * self.head_dim
    }
}

/// One bound block's buffers: `block_size` slots of K and V, laid out
/// `[L, Hkv, block_size, dh]` per side.
#[derive(Debug, Clone)]
pub struct KvBlock {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Uniform row-level access to a sequence's KV, whatever its physical
/// layout. The reference backend's prefill/decode kernels are generic
/// over this trait, so the dense and paged paths run the *same* float
/// operations in the same order — bit-identical by construction.
pub trait KvAccess {
    /// Allocated slot capacity visible to the kernel.
    fn n_slots(&self) -> usize;
    /// The `dh`-float K row of `slot` in layer `li`, KV head `g`.
    fn k_row(&self, li: usize, g: usize, slot: usize) -> &[f32];
    fn v_row(&self, li: usize, g: usize, slot: usize) -> &[f32];
    /// Store one slot's K/V rows (decode insertion, prefill append).
    fn write_row(&mut self, li: usize, g: usize, slot: usize, k: &[f32], v: &[f32]);
}

/// [`KvAccess`] over borrowed dense `[L, Hkv, cap, dh]` tensors (the
/// historical cache layout; still the prefill-bucket scratch layout).
pub struct DenseKvRef<'a> {
    k: &'a mut TensorF,
    v: &'a mut TensorF,
    hkv: usize,
    cap: usize,
    dh: usize,
}

impl<'a> DenseKvRef<'a> {
    /// `k`/`v` must be `[L, Hkv, cap, dh]`-shaped (callers validate).
    pub fn new(k: &'a mut TensorF, v: &'a mut TensorF) -> DenseKvRef<'a> {
        debug_assert_eq!(k.shape.len(), 4);
        debug_assert_eq!(k.shape, v.shape);
        let (hkv, cap, dh) = (k.shape[1], k.shape[2], k.shape[3]);
        DenseKvRef { k, v, hkv, cap, dh }
    }

    #[inline(always)]
    fn off(&self, li: usize, g: usize, slot: usize) -> usize {
        ((li * self.hkv + g) * self.cap + slot) * self.dh
    }
}

impl KvAccess for DenseKvRef<'_> {
    #[inline(always)]
    fn n_slots(&self) -> usize {
        self.cap
    }

    #[inline(always)]
    fn k_row(&self, li: usize, g: usize, slot: usize) -> &[f32] {
        let o = self.off(li, g, slot);
        &self.k.data[o..o + self.dh]
    }

    #[inline(always)]
    fn v_row(&self, li: usize, g: usize, slot: usize) -> &[f32] {
        let o = self.off(li, g, slot);
        &self.v.data[o..o + self.dh]
    }

    #[inline(always)]
    fn write_row(&mut self, li: usize, g: usize, slot: usize, k: &[f32], v: &[f32]) {
        let o = self.off(li, g, slot);
        self.k.data[o..o + self.dh].copy_from_slice(k);
        self.v.data[o..o + self.dh].copy_from_slice(v);
    }
}

/// [`KvAccess`] over blocks taken out of the arena (the paged layout).
/// Owning the buffers makes it `Send`, so batched decode can fan
/// sequences out onto scoped threads with no aliasing questions.
pub struct OwnedKv {
    blocks: Vec<KvBlock>,
    dims: KvDims,
    block_size: usize,
}

impl OwnedKv {
    pub fn new(blocks: Vec<KvBlock>, dims: KvDims, block_size: usize) -> OwnedKv {
        let want = dims.slot_floats() * block_size;
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(b.k.len(), want, "block {i}: K buffer does not match {dims:?}");
            assert_eq!(b.v.len(), want, "block {i}: V buffer does not match {dims:?}");
        }
        OwnedKv { blocks, dims, block_size }
    }

    pub fn into_blocks(self) -> Vec<KvBlock> {
        self.blocks
    }

    #[inline(always)]
    fn off(&self, li: usize, g: usize, within: usize) -> usize {
        ((li * self.dims.n_kv_heads + g) * self.block_size + within) * self.dims.head_dim
    }
}

impl KvAccess for OwnedKv {
    #[inline(always)]
    fn n_slots(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    #[inline(always)]
    fn k_row(&self, li: usize, g: usize, slot: usize) -> &[f32] {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        let o = self.off(li, g, within);
        &self.blocks[b].k[o..o + self.dims.head_dim]
    }

    #[inline(always)]
    fn v_row(&self, li: usize, g: usize, slot: usize) -> &[f32] {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        let o = self.off(li, g, within);
        &self.blocks[b].v[o..o + self.dims.head_dim]
    }

    #[inline(always)]
    fn write_row(&mut self, li: usize, g: usize, slot: usize, k: &[f32], v: &[f32]) {
        let (b, within) = (slot / self.block_size, slot % self.block_size);
        let o = self.off(li, g, within);
        let dh = self.dims.head_dim;
        self.blocks[b].k[o..o + dh].copy_from_slice(k);
        self.blocks[b].v[o..o + dh].copy_from_slice(v);
    }
}

/// The shared physical block store. Indexed by [`BlockId`]; one slot per
/// allocator block, `None` until bound (or while temporarily taken).
#[derive(Debug)]
pub struct KvArena {
    block_size: usize,
    slots: Vec<Option<KvBlock>>,
    bytes: usize,
    peak_bytes: usize,
}

impl KvArena {
    pub fn new(n_blocks: usize, block_size: usize) -> KvArena {
        assert!(block_size > 0, "KvArena block_size must be > 0");
        KvArena {
            block_size,
            slots: (0..n_blocks).map(|_| None).collect(),
            bytes: 0,
            peak_bytes: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn n_blocks(&self) -> usize {
        self.slots.len()
    }

    /// Resident KV bytes (K + V of every bound block).
    pub fn bytes_in_use(&self) -> usize {
        self.bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Bound blocks (excludes blocks currently taken by a kernel — stats
    /// are read between engine iterations, never mid-call).
    pub fn blocks_bound(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn idx(&self, b: BlockId) -> usize {
        let i = b.0 as usize;
        assert!(i < self.slots.len(), "block {b:?} outside the arena ({})", self.slots.len());
        i
    }

    /// Materialize zeroed buffers for freshly allocated blocks.
    /// `slot_floats` is the per-slot float count of the owning model
    /// ([`KvDims::slot_floats`]).
    pub fn bind(&mut self, blocks: &[BlockId], slot_floats: usize) {
        assert!(slot_floats > 0, "binding zero-sized KV slots");
        let n = slot_floats * self.block_size;
        for &b in blocks {
            let i = self.idx(b);
            assert!(self.slots[i].is_none(), "binding already-bound block {b:?}");
            self.slots[i] = Some(KvBlock { k: vec![0.0; n], v: vec![0.0; n] });
            self.bytes += n * 2 * 4;
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
    }

    /// Drop the buffers of freed blocks. Blocks that were never bound
    /// (accounting-only reservations) are skipped silently.
    pub fn release(&mut self, blocks: &[BlockId]) {
        for &b in blocks {
            let i = self.idx(b);
            if let Some(kvb) = self.slots[i].take() {
                self.bytes -= (kvb.k.len() + kvb.v.len()) * 4;
            }
        }
    }

    /// Move the blocks' buffers out (for an [`OwnedKv`] view). Fails —
    /// with no side effects — if any block is unbound or already taken,
    /// which also catches overlapping block tables in a batch.
    pub fn take(&mut self, blocks: &[BlockId]) -> Result<Vec<KvBlock>> {
        for &b in blocks {
            let i = self.idx(b);
            anyhow::ensure!(
                self.slots[i].is_some(),
                "arena block {b:?} is unbound or already taken"
            );
        }
        Ok(blocks.iter().map(|&b| self.slots[b.0 as usize].take().unwrap()).collect())
    }

    /// Move the blocks' buffers out of the arena *permanently* (cold
    /// spill tier): unlike [`KvArena::take`], the bytes leave resident
    /// accounting, because the caller is about to free the block ids and
    /// park the buffers host-side. Fails with no side effects if any
    /// block is unbound or currently taken.
    pub fn spill(&mut self, blocks: &[BlockId]) -> Result<Vec<KvBlock>> {
        let kvs = self.take(blocks).context("spill")?;
        for kvb in &kvs {
            self.bytes -= (kvb.k.len() + kvb.v.len()) * 4;
        }
        Ok(kvs)
    }

    /// Re-bind spilled buffers to freshly allocated blocks, bringing
    /// their bytes back into resident accounting. The buffers move
    /// verbatim, so a spill → restore round trip is bit-identical.
    pub fn restore(&mut self, blocks: &[BlockId], kvs: Vec<KvBlock>) {
        assert_eq!(blocks.len(), kvs.len(), "restore: table/buffer length mismatch");
        for (&b, kvb) in blocks.iter().zip(kvs) {
            let i = self.idx(b);
            assert!(self.slots[i].is_none(), "restoring into occupied arena slot {b:?}");
            self.bytes += (kvb.k.len() + kvb.v.len()) * 4;
            self.slots[i] = Some(kvb);
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
    }

    /// Return buffers taken via [`KvArena::take`].
    pub fn put(&mut self, blocks: &[BlockId], kvs: Vec<KvBlock>) {
        assert_eq!(blocks.len(), kvs.len(), "put: table/buffer length mismatch");
        for (&b, kvb) in blocks.iter().zip(kvs) {
            let i = self.idx(b);
            assert!(self.slots[i].is_none(), "putting into occupied arena slot {b:?}");
            self.slots[i] = Some(kvb);
        }
    }

    fn block(&self, b: BlockId) -> &KvBlock {
        self.slots[self.idx(b)].as_ref().unwrap_or_else(|| panic!("reading unbound block {b:?}"))
    }

    #[inline]
    fn row_off(&self, dims: &KvDims, li: usize, g: usize, within: usize) -> usize {
        ((li * dims.n_kv_heads + g) * self.block_size + within) * dims.head_dim
    }

    /// Read one K row: `slot` is the *global* slot index of a block
    /// table, resolved to `(blocks[slot / bs], slot % bs)` by the caller.
    pub fn k_row(&self, dims: &KvDims, b: BlockId, li: usize, g: usize, within: usize) -> &[f32] {
        let o = self.row_off(dims, li, g, within);
        &self.block(b).k[o..o + dims.head_dim]
    }

    pub fn v_row(&self, dims: &KvDims, b: BlockId, li: usize, g: usize, within: usize) -> &[f32] {
        let o = self.row_off(dims, li, g, within);
        &self.block(b).v[o..o + dims.head_dim]
    }

    /// Write one `dh`-float K/V row pair at `(layer, head, offset)` of a
    /// bound block.
    pub fn write_row(
        &mut self,
        dims: &KvDims,
        b: BlockId,
        li: usize,
        g: usize,
        within: usize,
        k: &[f32],
        v: &[f32],
    ) {
        let o = self.row_off(dims, li, g, within);
        let dh = dims.head_dim;
        let i = self.idx(b);
        let blk = self.slots[i].as_mut().unwrap_or_else(|| panic!("writing unbound block {b:?}"));
        blk.k[o..o + dh].copy_from_slice(k);
        blk.v[o..o + dh].copy_from_slice(v);
    }

    /// Copy whole block buffers in (prefix-tree insertion: a
    /// [`super::prefix::BlockRecord`]'s `[L, Hkv, bs, dh]` tensors have
    /// exactly the block layout).
    pub fn write_block(&mut self, b: BlockId, k: &[f32], v: &[f32]) {
        let i = self.idx(b);
        let blk = self.slots[i].as_mut().unwrap_or_else(|| panic!("writing unbound block {b:?}"));
        assert_eq!(blk.k.len(), k.len(), "write_block: K length mismatch");
        assert_eq!(blk.v.len(), v.len(), "write_block: V length mismatch");
        blk.k.copy_from_slice(k);
        blk.v.copy_from_slice(v);
    }

    /// Raw buffers of one bound block (prefix seed assembly, tests).
    pub fn block_kv(&self, b: BlockId) -> Option<(&[f32], &[f32])> {
        self.slots[self.idx(b)].as_ref().map(|blk| (&blk.k[..], &blk.v[..]))
    }

    /// Gather rows `0..rows` of a block table into dense
    /// `[L, Hkv, rows, dh]` tensors.
    pub fn gather_dense(
        &self,
        dims: &KvDims,
        blocks: &[BlockId],
        rows: usize,
    ) -> Result<(TensorF, TensorF)> {
        anyhow::ensure!(
            rows <= blocks.len() * self.block_size,
            "gather of {rows} rows exceeds the table's {} slots",
            blocks.len() * self.block_size
        );
        let (l, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.head_dim);
        let mut k = TensorF::zeros(vec![l, hkv, rows, dh]);
        let mut v = TensorF::zeros(vec![l, hkv, rows, dh]);
        for li in 0..l {
            for g in 0..hkv {
                for r in 0..rows {
                    let b = blocks[r / self.block_size];
                    let within = r % self.block_size;
                    let dst = ((li * hkv + g) * rows + r) * dh;
                    k.data[dst..dst + dh].copy_from_slice(self.k_row(dims, b, li, g, within));
                    v.data[dst..dst + dh].copy_from_slice(self.v_row(dims, b, li, g, within));
                }
            }
        }
        Ok((k, v))
    }

    /// Scatter dense `[L, Hkv, rows, dh]` tensors into rows
    /// `start..start + rows` of a block table (prefix-seed resume, the
    /// default backend's paged write-through).
    pub fn scatter_dense(
        &mut self,
        dims: &KvDims,
        blocks: &[BlockId],
        start: usize,
        k: &TensorF,
        v: &TensorF,
    ) -> Result<()> {
        let (l, hkv, dh) = (dims.n_layers, dims.n_kv_heads, dims.head_dim);
        anyhow::ensure!(
            k.shape.len() == 4 && k.shape[0] == l && k.shape[1] == hkv && k.shape[3] == dh,
            "scatter source shape {:?} does not match {dims:?}",
            k.shape
        );
        anyhow::ensure!(k.shape == v.shape, "scatter K/V shape mismatch");
        let rows = k.shape[2];
        anyhow::ensure!(
            start + rows <= blocks.len() * self.block_size,
            "scatter of rows {start}..{} exceeds the table's {} slots",
            start + rows,
            blocks.len() * self.block_size
        );
        for li in 0..l {
            for g in 0..hkv {
                for r in 0..rows {
                    let slot = start + r;
                    let b = blocks[slot / self.block_size];
                    let within = slot % self.block_size;
                    let src = ((li * hkv + g) * rows + r) * dh;
                    self.write_row(
                        dims,
                        b,
                        li,
                        g,
                        within,
                        &k.data[src..src + dh],
                        &v.data[src..src + dh],
                    );
                }
            }
        }
        Ok(())
    }
}

/// Allocator + arena + owner bundle threaded through paged prefill (one
/// per in-flight request; see `engine::chunked`). Allocation and byte
/// binding always happen together so accounting can never skew.
pub struct PagedCtx<'a> {
    pub arena: &'a mut KvArena,
    pub alloc: &'a mut BlockAllocator,
    /// The shared prefix tree, when enabled: unpinned LRU leaves are
    /// reclaimed before any allocation through this context is allowed
    /// to fail — mid-job pass allocations (lkv+suffix second pass,
    /// LAQ/SpecKV rescore) get the same before-failing-reclaim guarantee
    /// as admission.
    pub prefix: Option<&'a mut super::prefix::PrefixCache>,
    pub owner: u64,
}

impl PagedCtx<'_> {
    /// Allocate and bind enough blocks for `slots` token slots,
    /// LRU-reclaiming unpinned prefix-tree blocks first under pool
    /// pressure. "kv pool exhausted" means genuinely exhausted.
    pub fn alloc_blocks(&mut self, slots: usize, slot_floats: usize) -> Result<Vec<BlockId>> {
        let slots = slots.max(1);
        if let Some(p) = self.prefix.as_deref_mut() {
            while !self.alloc.can_alloc(slots) {
                let need = self
                    .alloc
                    .blocks_for_slots(slots)
                    .saturating_sub(self.alloc.free_blocks())
                    .max(1);
                if p.reclaim(self.alloc, self.arena, need) == 0 {
                    break;
                }
            }
        }
        let ids = self.alloc.alloc(self.owner, slots).context("kv pool exhausted")?;
        self.arena.bind(&ids, slot_floats);
        Ok(ids)
    }

    /// Free blocks back to the pool and drop their buffers.
    pub fn free_blocks(&mut self, ids: &[BlockId]) {
        self.arena.release(ids);
        self.alloc.free(ids);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};

    const DIMS: KvDims = KvDims { n_layers: 2, n_kv_heads: 2, head_dim: 4 };

    #[test]
    fn bind_take_put_release_accounting() {
        let mut a = KvArena::new(4, 8);
        let ids = [BlockId(0), BlockId(2)];
        a.bind(&ids, DIMS.slot_floats());
        let per_block = DIMS.slot_floats() * 8 * 2 * 4;
        assert_eq!(a.bytes_in_use(), 2 * per_block);
        assert_eq!(a.blocks_bound(), 2);
        let taken = a.take(&ids).unwrap();
        assert_eq!(taken.len(), 2);
        // double-take (aliasing) is an error with no side effects
        assert!(a.take(&[BlockId(0)]).is_err());
        a.put(&ids, taken);
        assert_eq!(a.blocks_bound(), 2);
        a.release(&ids);
        assert_eq!(a.bytes_in_use(), 0);
        // releasing never-bound blocks is a no-op (dense reservations)
        a.release(&[BlockId(1)]);
        assert_eq!(a.bytes_in_use(), 0);
    }

    #[test]
    fn rows_roundtrip_through_blocks() {
        let mut a = KvArena::new(2, 4);
        let ids = [BlockId(1), BlockId(0)]; // order of the table, not of ids
        a.bind(&ids, DIMS.slot_floats());
        let bs = a.block_size();
        // write slots 0..7 through the table, read them back
        for slot in 0..2 * bs {
            let b = ids[slot / bs];
            let within = slot % bs;
            for li in 0..DIMS.n_layers {
                for g in 0..DIMS.n_kv_heads {
                    let val = (slot * 100 + li * 10 + g) as f32;
                    let row = [val; 4];
                    a.write_row(&DIMS, b, li, g, within, &row, &row);
                }
            }
        }
        assert_eq!(a.k_row(&DIMS, ids[1], 1, 0, 2)[0], (6 * 100 + 10) as f32);
        let (k, v) = a.gather_dense(&DIMS, &ids, 7).unwrap();
        assert_eq!(k.shape, vec![2, 2, 7, 4]);
        assert_eq!(k.index(&[0, 1, 5])[0], 501.0);
        assert_eq!(v.index(&[1, 1, 6])[0], 611.0);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut a = KvArena::new(3, 4);
        let ids = [BlockId(2), BlockId(0), BlockId(1)];
        a.bind(&ids, DIMS.slot_floats());
        let rows = 10;
        let n = DIMS.n_layers * DIMS.n_kv_heads * rows * DIMS.head_dim;
        let k = TensorF::new(
            vec![DIMS.n_layers, DIMS.n_kv_heads, rows, DIMS.head_dim],
            (0..n).map(|x| x as f32).collect(),
        );
        let v = TensorF::new(k.shape.clone(), (0..n).map(|x| -(x as f32)).collect());
        a.scatter_dense(&DIMS, &ids, 0, &k, &v).unwrap();
        let (k2, v2) = a.gather_dense(&DIMS, &ids, rows).unwrap();
        assert_eq!(k.data, k2.data);
        assert_eq!(v.data, v2.data);
        // out-of-capacity gathers/scatters error
        assert!(a.gather_dense(&DIMS, &ids, 13).is_err());
    }

    /// Property: slot -> (block, offset) resolution round-trips for any
    /// block size and table permutation — writing each slot through the
    /// mapping and reading it back yields the written row, and distinct
    /// slots never alias.
    #[test]
    fn prop_slot_block_offset_roundtrip() {
        check("slot/block mapping", &Config { cases: 64, max_size: 24, ..Config::new() }, |rng, size| {
            let bs = rng.range(1, 9);
            let n_blocks = rng.range(1, 5 + size.min(8));
            let mut a = KvArena::new(n_blocks, bs);
            // a random permutation of all blocks as the table
            let mut table: Vec<BlockId> = (0..n_blocks as u32).map(BlockId).collect();
            for i in (1..table.len()).rev() {
                let j = rng.below(i + 1);
                table.swap(i, j);
            }
            let dims = KvDims { n_layers: rng.range(1, 3), n_kv_heads: rng.range(1, 3), head_dim: 2 };
            a.bind(&table, dims.slot_floats());
            let slots = n_blocks * bs;
            for slot in 0..slots {
                let (b, within) = (table[slot / bs], slot % bs);
                for li in 0..dims.n_layers {
                    for g in 0..dims.n_kv_heads {
                        let val = (slot * 1000 + li * 10 + g) as f32;
                        a.write_row(&dims, b, li, g, within, &[val, val + 0.5], &[-val, val]);
                    }
                }
            }
            for slot in 0..slots {
                let (b, within) = (table[slot / bs], slot % bs);
                for li in 0..dims.n_layers {
                    for g in 0..dims.n_kv_heads {
                        let want = (slot * 1000 + li * 10 + g) as f32;
                        assert_eq!(a.k_row(&dims, b, li, g, within), &[want, want + 0.5][..]);
                        assert_eq!(a.v_row(&dims, b, li, g, within), &[-want, want][..]);
                    }
                }
            }
            // OwnedKv sees the same bytes through global slot indices
            let taken = a.take(&table).unwrap();
            let kv = OwnedKv::new(taken, dims, bs);
            for slot in 0..slots {
                let want = (slot * 1000) as f32;
                assert_eq!(kv.k_row(0, 0, slot)[0], want);
            }
            a.put(&table, kv.into_blocks());
        });
    }

    #[test]
    fn paged_ctx_allocates_and_frees() {
        let mut arena = KvArena::new(8, 8);
        let mut alloc = BlockAllocator::new(64, 8);
        let mut ctx = PagedCtx { arena: &mut arena, alloc: &mut alloc, prefix: None, owner: 7 };
        let ids = ctx.alloc_blocks(20, DIMS.slot_floats()).unwrap(); // 3 blocks
        assert_eq!(ids.len(), 3);
        assert!(ctx.arena.bytes_in_use() > 0);
        assert_eq!(ctx.alloc.used_blocks(), 3);
        ctx.free_blocks(&ids);
        assert_eq!(ctx.arena.bytes_in_use(), 0);
        assert_eq!(ctx.alloc.used_blocks(), 0);
        // zero-slot requests still pin one block (a live sequence always
        // has at least one block to append into)
        let ids = ctx.alloc_blocks(0, DIMS.slot_floats()).unwrap();
        assert_eq!(ids.len(), 1);
        ctx.free_blocks(&ids);
    }
}

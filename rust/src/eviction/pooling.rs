//! 1D max-pooling over score vectors (SnapKV's clustering trick: smear
//! each hot position over its neighborhood so whole needles survive).

/// Same-length max-pool with odd kernel `k` (k <= 1 is identity).
pub fn maxpool1d(scores: &[f32], k: usize) -> Vec<f32> {
    if k <= 1 || scores.is_empty() {
        return scores.to_vec();
    }
    assert!(k % 2 == 1, "kernel must be odd");
    let half = k / 2;
    let n = scores.len();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let lo = i.saturating_sub(half);
        let hi = (i + half + 1).min(n);
        let m = scores[lo..hi].iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        out.push(m);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel() {
        let v = vec![1.0, 2.0, 3.0];
        assert_eq!(maxpool1d(&v, 1), v);
    }

    #[test]
    fn smears_peak() {
        let v = vec![0.0, 0.0, 9.0, 0.0, 0.0];
        assert_eq!(maxpool1d(&v, 3), vec![0.0, 9.0, 9.0, 9.0, 0.0]);
    }

    #[test]
    fn edges_clamp() {
        let v = vec![5.0, 0.0, 0.0, 7.0];
        assert_eq!(maxpool1d(&v, 3), vec![5.0, 5.0, 7.0, 7.0]);
    }

    #[test]
    fn monotone_under_pool() {
        // pooled values always >= originals
        let mut rng = crate::util::rng::Rng::new(2);
        let v: Vec<f32> = (0..64).map(|_| rng.f32()).collect();
        let p = maxpool1d(&v, 5);
        assert!(v.iter().zip(&p).all(|(a, b)| b >= a));
    }
}

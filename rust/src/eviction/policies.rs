//! Selection rules for every eviction method (pure functions over a
//! [`super::ScoreBundle`]).

use super::pooling::maxpool1d;
use super::scores::{head_mean_per_layer, window_mean_per_layer, window_row_per_layer};
use super::{EvictionConfig, ScoreBundle, Selection};
use crate::util::rng::Rng;
use crate::util::stats::topk_indices;

/// Merge an unconditional keep-range `[lo, hi)` with the top-k of `scores`
/// outside it, returning exactly `min(budget, len)` sorted indices.
fn keep_window_plus_topk(scores: &[f32], len: usize, budget: usize, win: (usize, usize)) -> Vec<usize> {
    let budget = budget.min(len);
    let (lo, hi) = win;
    let win_len = hi.saturating_sub(lo);
    if budget <= win_len {
        // budget smaller than the protected window: keep its most recent part
        return (hi - budget..hi).collect();
    }
    // mask window columns out of the ranking, then take top (budget - win)
    let mut masked: Vec<f32> = scores[..len].to_vec();
    for j in lo..hi {
        masked[j] = f32::NEG_INFINITY;
    }
    let mut kept = topk_indices(&masked, budget - win_len);
    kept.extend(lo..hi);
    kept.sort_unstable();
    kept.dedup();
    debug_assert_eq!(kept.len(), budget);
    kept
}

pub fn full_kv(len: usize, n_layers: usize) -> Selection {
    Selection::uniform((0..len).collect(), n_layers)
}

pub fn random(cfg: &EvictionConfig, n_layers: usize, len: usize, seed: u64) -> Selection {
    let budget = cfg.budget.min(len);
    let mut rng = Rng::new(seed ^ len as u64);
    // always keep the final window so generation stays coherent
    let win_lo = len.saturating_sub(cfg.window.min(budget));
    let mut idx: Vec<usize> = (win_lo..len).collect();
    let mut rest: Vec<usize> = (0..win_lo).collect();
    rng.shuffle(&mut rest);
    idx.extend(rest.into_iter().take(budget - idx.len()));
    idx.sort_unstable();
    Selection::uniform(idx, n_layers)
}

pub fn streaming_llm(cfg: &EvictionConfig, n_layers: usize, len: usize) -> Selection {
    let budget = cfg.budget.min(len);
    let sinks = cfg.sinks.min(budget);
    let recent = budget - sinks;
    let mut idx: Vec<usize> = (0..sinks).collect();
    idx.extend(len.saturating_sub(recent)..len);
    idx.sort_unstable();
    idx.dedup();
    // if sinks and recents overlap (tiny prompts), top up from the front
    let mut next = 0;
    while idx.len() < budget {
        if !idx.contains(&next) {
            idx.push(next);
        }
        next += 1;
    }
    idx.sort_unstable();
    Selection::uniform(idx, n_layers)
}

/// SnapKV-family score vector: suffix-window rows, head-mean, max-pooled.
fn snap_scores(cfg: &EvictionConfig, bundle: &ScoreBundle) -> Vec<Vec<f32>> {
    let ws = bundle
        .window_scores
        .as_ref()
        .expect("snapkv-family selection needs window_scores");
    let w_use = bundle.w_use_override.unwrap_or(cfg.window);
    let per_layer = window_mean_per_layer(ws, bundle.len, bundle.win_start, bundle.win_rows, w_use);
    per_layer.into_iter().map(|s| maxpool1d(&s, cfg.kernel)).collect()
}

/// Unconditionally-kept suffix window `[lo, len)` for SnapKV-family picks.
fn protect_window(cfg: &EvictionConfig, len: usize) -> (usize, usize) {
    (len.saturating_sub(cfg.window), len)
}

pub fn snapkv(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let scores = snap_scores(cfg, bundle);
    let win = protect_window(cfg, bundle.len);
    let per_layer = (0..n_layers)
        .map(|l| keep_window_plus_topk(&scores[l], bundle.len, cfg.budget, win))
        .collect();
    Selection { per_layer }
}

/// Funnel budgets: linearly decaying with depth, mean preserved at
/// `budget` (PyramidKV's pyramidal information funneling).
pub fn pyramid_budgets(budget: usize, n_layers: usize, floor: usize) -> Vec<usize> {
    if n_layers == 1 {
        return vec![budget];
    }
    let total = budget * n_layers;
    let weights: Vec<f64> = (0..n_layers).map(|l| (n_layers - l) as f64).collect();
    let wsum: f64 = weights.iter().sum();
    let mut b: Vec<usize> =
        weights.iter().map(|w| ((total as f64) * w / wsum).floor().max(floor as f64) as usize).collect();
    // fix rounding drift onto the earliest layers, keeping the sum == total
    let mut diff = total as i64 - b.iter().sum::<usize>() as i64;
    let mut l = 0;
    while diff != 0 {
        if diff > 0 {
            b[l % n_layers] += 1;
            diff -= 1;
        } else if b[l % n_layers] > floor {
            b[l % n_layers] -= 1;
            diff += 1;
        }
        l += 1;
    }
    b
}

pub fn pyramidkv(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let scores = snap_scores(cfg, bundle);
    let win = protect_window(cfg, bundle.len);
    let budgets = pyramid_budgets(cfg.budget, n_layers, cfg.window.min(cfg.budget));
    let per_layer = (0..n_layers)
        .map(|l| keep_window_plus_topk(&scores[l], bundle.len, budgets[l], win))
        .collect();
    Selection { per_layer }
}

pub fn h2o(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let hs = bundle.h2o_scores.as_ref().expect("h2o selection needs h2o_scores");
    let scores = head_mean_per_layer(hs, bundle.len);
    let win = protect_window(cfg, bundle.len); // heavy hitters + recents
    let per_layer = (0..n_layers)
        .map(|l| keep_window_plus_topk(&scores[l], bundle.len, cfg.budget, win))
        .collect();
    Selection { per_layer }
}

pub fn tova(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let ws = bundle.window_scores.as_ref().expect("tova needs window_scores");
    let last_row = bundle.win_rows.saturating_sub(1);
    let scores = window_row_per_layer(ws, bundle.len, last_row);
    let per_layer = (0..n_layers)
        .map(|l| {
            // TOVA always keeps the newest token: pin it above any score.
            let mut s = scores[l][..bundle.len].to_vec();
            if let Some(last) = s.last_mut() {
                *last = f32::INFINITY;
            }
            topk_indices(&s, cfg.budget.min(bundle.len))
        })
        .collect();
    Selection { per_layer }
}

pub fn lookaheadkv(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let ls = bundle.lkv_scores.as_ref().expect("lookaheadkv needs lkv_scores");
    let scores = head_mean_per_layer(ls, bundle.len);
    let per_layer = (0..n_layers)
        .map(|l| {
            let pooled = maxpool1d(&scores[l], cfg.kernel);
            topk_indices(&pooled, cfg.budget.min(bundle.len))
        })
        .collect();
    Selection { per_layer }
}

/// Learned importance predictor: per-KV-head MLP scores over pre-RoPE
/// keys, head-averaged, max-pooled and top-k'd with the suffix window
/// protected (same post-processing as H2O/SnapKV so the comparison
/// isolates the score source).
pub fn predictor(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let ps = bundle.pred_scores.as_ref().expect("predictor selection needs pred_scores");
    let scores = head_mean_per_layer(ps, bundle.len);
    let win = protect_window(cfg, bundle.len);
    let per_layer = (0..n_layers)
        .map(|l| {
            let pooled = maxpool1d(&scores[l], cfg.kernel);
            keep_window_plus_topk(&pooled, bundle.len, cfg.budget, win)
        })
        .collect();
    Selection { per_layer }
}

/// Table 7: L1-normalize both the lookahead scores and the suffix-window
/// scores, average them, then select (the paper finds this *hurts*).
pub fn lkv_suffix(cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
    let ls = bundle.lkv_scores.as_ref().expect("lkv+suffix needs lkv_scores");
    let lkv = head_mean_per_layer(ls, bundle.len);
    let snap = snap_scores(cfg, bundle);
    let per_layer = (0..n_layers)
        .map(|l| {
            let mut a = lkv[l].clone();
            let mut b = snap[l].clone();
            crate::util::stats::l1_normalize(&mut a);
            crate::util::stats::l1_normalize(&mut b);
            let avg: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 0.5 * (x + y)).collect();
            let pooled = maxpool1d(&avg, cfg.kernel);
            topk_indices(&pooled, cfg.budget.min(bundle.len))
        })
        .collect();
    Selection { per_layer }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::tensor::TensorF;

    fn bundle_with_peak(len: usize, s: usize, peak: usize) -> ScoreBundle {
        // L=2, H=2, W=4 window scores with a clear peak column
        let (l, h, w) = (2, 2, 4);
        let mut win = vec![0.0f32; l * h * w * s];
        let mut h2o = vec![0.0f32; l * h * s];
        let mut lkv = vec![0.0f32; l * h * s];
        let mut pred = vec![0.0f32; l * h * s];
        for li in 0..l {
            for hi in 0..h {
                for r in 0..w {
                    win[((li * h + hi) * w + r) * s + peak] = 1.0;
                }
                h2o[(li * h + hi) * s + peak] = 1.0;
                lkv[(li * h + hi) * s + peak] = 1.0;
                pred[(li * h + hi) * s + peak] = 1.0;
            }
        }
        ScoreBundle {
            len,
            window_scores: Some(TensorF::new(vec![l, h, w, s], win)),
            win_start: len.saturating_sub(4),
            win_rows: 4,
            h2o_scores: Some(TensorF::new(vec![l, h, s], h2o)),
            lkv_scores: Some(TensorF::new(vec![l, h, s], lkv)),
            pred_scores: Some(TensorF::new(vec![l, h, s], pred)),
            w_use_override: None,
        }
    }

    #[test]
    fn snapkv_keeps_peak_and_window() {
        let cfg = EvictionConfig { budget: 8, window: 4, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(32, 32, 5);
        let sel = snapkv(&cfg, 2, &b);
        for idx in &sel.per_layer {
            assert_eq!(idx.len(), 8);
            assert!(idx.contains(&5), "peak kept: {idx:?}");
            for j in 28..32 {
                assert!(idx.contains(&j), "window kept: {idx:?}");
            }
        }
    }

    #[test]
    fn snapkv_budget_below_window() {
        let cfg = EvictionConfig { budget: 2, window: 4, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(16, 16, 3);
        let sel = snapkv(&cfg, 2, &b);
        assert_eq!(sel.per_layer[0], vec![14, 15]); // most recent part of window
    }

    #[test]
    fn streaming_structure() {
        let cfg = EvictionConfig { budget: 6, window: 4, kernel: 1, sinks: 2 };
        let sel = streaming_llm(&cfg, 1, 100);
        assert_eq!(sel.per_layer[0], vec![0, 1, 96, 97, 98, 99]);
    }

    #[test]
    fn streaming_tiny_prompt() {
        let cfg = EvictionConfig { budget: 8, window: 4, kernel: 1, sinks: 2 };
        let sel = streaming_llm(&cfg, 1, 5);
        assert_eq!(sel.per_layer[0], vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pyramid_budgets_preserve_total() {
        for (c, l) in [(16usize, 4usize), (64, 4), (13, 5), (128, 6)] {
            let b = pyramid_budgets(c, l, 4);
            assert_eq!(b.iter().sum::<usize>(), c * l, "{b:?}");
            // non-increasing with depth
            assert!(b.windows(2).all(|w| w[0] >= w[1]), "{b:?}");
        }
    }

    #[test]
    fn pyramid_layers_differ() {
        let cfg = EvictionConfig { budget: 8, window: 2, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(64, 64, 7);
        let sel = pyramidkv(&cfg, 2, &b);
        assert!(sel.per_layer[0].len() > sel.per_layer[1].len());
        assert!(sel.per_layer.iter().all(|i| i.contains(&7)));
    }

    #[test]
    fn h2o_keeps_heavy_hitter() {
        let cfg = EvictionConfig { budget: 6, window: 2, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(32, 32, 11);
        let sel = h2o(&cfg, 2, &b);
        assert!(sel.per_layer[0].contains(&11));
        assert!(sel.per_layer[0].contains(&31));
    }

    #[test]
    fn tova_keeps_last_token() {
        let cfg = EvictionConfig { budget: 4, window: 2, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(32, 32, 3);
        let sel = tova(&cfg, 2, &b);
        assert!(sel.per_layer[0].contains(&31));
        assert!(sel.per_layer[0].contains(&3));
    }

    #[test]
    fn lookaheadkv_pure_topk() {
        let cfg = EvictionConfig { budget: 4, window: 8, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(32, 32, 13);
        let sel = lookaheadkv(&cfg, 2, &b);
        assert!(sel.per_layer[0].contains(&13));
        assert_eq!(sel.per_layer[0].len(), 4);
    }

    #[test]
    fn predictor_keeps_peak_and_window() {
        let cfg = EvictionConfig { budget: 8, window: 4, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(32, 32, 9);
        let sel = predictor(&cfg, 2, &b);
        for idx in &sel.per_layer {
            assert_eq!(idx.len(), 8);
            assert!(idx.contains(&9), "peak kept: {idx:?}");
            for j in 28..32 {
                assert!(idx.contains(&j), "window kept: {idx:?}");
            }
        }
    }

    #[test]
    fn lkv_suffix_combines() {
        let cfg = EvictionConfig { budget: 4, window: 4, kernel: 1, sinks: 2 };
        let b = bundle_with_peak(32, 32, 13);
        let sel = lkv_suffix(&cfg, 2, &b);
        assert!(sel.per_layer[0].contains(&13));
    }

    #[test]
    fn random_deterministic_and_valid() {
        let cfg = EvictionConfig { budget: 8, window: 4, kernel: 1, sinks: 2 };
        let a = random(&cfg, 2, 100, 42);
        let b = random(&cfg, 2, 100, 42);
        assert_eq!(a, b);
        assert_eq!(a.per_layer[0].len(), 8);
    }

    /// Property: every policy returns exactly min(budget, len) sorted
    /// unique in-range indices per layer, for any budget/len/scores.
    #[test]
    fn prop_selection_invariants() {
        check("selection invariants", &Config { cases: 96, max_size: 64, ..Config::new() }, |rng, size| {
            let len = (size * 2).max(2);
            let s = len.next_multiple_of(8);
            let (l, h, w) = (3usize, 2usize, 4usize);
            let rnd = |rng: &mut crate::util::rng::Rng, n: usize| -> Vec<f32> {
                (0..n).map(|_| rng.f32()).collect()
            };
            let bundle = ScoreBundle {
                len,
                window_scores: Some(TensorF::new(vec![l, h, w, s], rnd(rng, l * h * w * s))),
                win_start: len.saturating_sub(w),
                win_rows: w.min(len),
                h2o_scores: Some(TensorF::new(vec![l, h, s], rnd(rng, l * h * s))),
                lkv_scores: Some(TensorF::new(vec![l, h, s], rnd(rng, l * h * s))),
                pred_scores: Some(TensorF::new(vec![l, h, s], rnd(rng, l * h * s))),
                w_use_override: None,
            };
            let budget = rng.range(1, len + 8);
            let cfg = EvictionConfig { budget, window: rng.range(1, 8), kernel: 3, sinks: 2 };
            for sel in [
                snapkv(&cfg, l, &bundle),
                pyramidkv(&cfg, l, &bundle),
                h2o(&cfg, l, &bundle),
                tova(&cfg, l, &bundle),
                lookaheadkv(&cfg, l, &bundle),
                lkv_suffix(&cfg, l, &bundle),
                predictor(&cfg, l, &bundle),
                streaming_llm(&cfg, l, len),
                random(&cfg, l, len, 7),
            ] {
                for idx in &sel.per_layer {
                    assert!(idx.windows(2).all(|p| p[0] < p[1]), "sorted unique: {idx:?}");
                    assert!(idx.iter().all(|&i| i < len), "in range");
                    assert!(idx.len() <= budget.max(cfg.budget * 2).min(len) + budget, "bounded");
                    assert!(!idx.is_empty());
                }
            }
        });
    }
}

//! Score aggregation helpers: collapse `[L, H, ...]` score tensors into a
//! per-layer `[len]` ranking vector (head-mean reduction, the paper's GQA
//! compatibility choice), with optional suffix-row windows.
//!
//! This module also defines the **online** side of score harvesting: the
//! [`ScoreSink`] trait consumed by the reference backend's streaming
//! attention kernels. Instead of materializing `[H, T, T]` probability
//! tensors and reducing them afterwards (the naive `reducer(layer,
//! probs)` contract), the kernel hands each query row's normalized
//! attention probabilities to a per-(layer, head) sink *as it is
//! computed*, so H2O column sums, SnapKV/TOVA observation-window rows and
//! lkv suffix scores all accumulate inside the attention loop with O(T)
//! scratch. Sinks are built per layer by splitting the bundle's
//! accumulator tensors into disjoint per-head `&mut` slices
//! ([`chunk_head_sinks`] / [`lkv_head_sinks`]), which is what lets the
//! kernel fan heads out across scoped threads with no locking: one head
//! == one worker == one sink, and rows arrive in ascending query order
//! within a head, preserving the exact accumulation order of the
//! monolithic graphs.

use super::ScoreBundle;
use crate::util::tensor::TensorF;

/// Consumes one query row's normalized attention probabilities, online.
///
/// `qi` is the absolute query position; `probs` covers the row's visible
/// columns `0..n_vis` (normalized — each row is a probability
/// distribution over its visible prefix). The kernel calls `row` in
/// ascending `qi` order within a (layer, head), which sinks may rely on
/// (sequential accumulation keeps chunked and monolithic prefill
/// bit-identical).
pub trait ScoreSink {
    fn row(&mut self, qi: usize, probs: &[f32]);
}

/// Base-pass sink for one (layer, head): running H2O column sums plus
/// observation-window row capture — exactly the quantities the
/// `prefill_base` graph exports, accumulated without ever materializing
/// the probability matrix. Either part may be absent (lookahead prompt
/// passes accumulate nothing).
pub struct ChunkHeadSink<'a> {
    /// `[bucket]` running column sums (normalized by `1/len` at finalize).
    h2o: Option<&'a mut [f32]>,
    /// `[window * bucket]` captured rows of the observation window.
    win: Option<&'a mut [f32]>,
    win_start: usize,
    window: usize,
    bucket: usize,
}

impl ScoreSink for ChunkHeadSink<'_> {
    #[inline]
    fn row(&mut self, qi: usize, probs: &[f32]) {
        if let Some(acc) = self.h2o.as_deref_mut() {
            for (a, &p) in acc.iter_mut().zip(probs.iter()) {
                *a += p;
            }
        }
        if let Some(win) = self.win.as_deref_mut() {
            if qi >= self.win_start && qi < self.win_start + self.window {
                let off = (qi - self.win_start) * self.bucket;
                win[off..off + probs.len()].copy_from_slice(probs);
            }
        }
    }
}

/// Split `bundle`'s accumulators for layer `li` into one sink per head.
/// The returned sinks borrow disjoint slices, so they can be moved onto
/// worker threads together. `window`/`bucket` are the shapes the bundle
/// tensors were allocated with (`[L, H, window, bucket]` / `[L, H,
/// bucket]`).
pub fn chunk_head_sinks<'a>(
    bundle: &'a mut ScoreBundle,
    li: usize,
    nh: usize,
    window: usize,
    bucket: usize,
) -> Vec<ChunkHeadSink<'a>> {
    let win_start = bundle.win_start;
    let mut h2o: Vec<Option<&'a mut [f32]>> = match bundle.h2o_scores.as_mut() {
        Some(t) => t.data[li * nh * bucket..(li + 1) * nh * bucket]
            .chunks_mut(bucket)
            .map(Some)
            .collect(),
        None => (0..nh).map(|_| None).collect(),
    };
    let win_span = window * bucket;
    let mut win: Vec<Option<&'a mut [f32]>> = match bundle.window_scores.as_mut() {
        Some(t) if win_span > 0 => t.data[li * nh * win_span..(li + 1) * nh * win_span]
            .chunks_mut(win_span)
            .map(Some)
            .collect(),
        _ => (0..nh).map(|_| None).collect(),
    };
    (0..nh)
        .map(|h| ChunkHeadSink {
            h2o: h2o[h].take(),
            win: win[h].take(),
            win_start,
            window,
            bucket,
        })
        .collect()
}

/// Lookahead-suffix sink for one (layer, head): sums the suffix rows'
/// attention over prompt columns (mean taken by the kernel after the last
/// row, matching the monolithic `prefill_lkv` reducer order).
pub struct LkvHeadSink<'a> {
    acc: &'a mut [f32],
}

impl LkvHeadSink<'_> {
    /// Normalize the accumulated sums into the mean over `n` suffix rows.
    pub fn finish(&mut self, n: usize) {
        let denom = 1.0 / n.max(1) as f32;
        for a in self.acc.iter_mut() {
            *a *= denom;
        }
    }
}

impl ScoreSink for LkvHeadSink<'_> {
    #[inline]
    fn row(&mut self, _qi: usize, probs: &[f32]) {
        for (a, &p) in self.acc.iter_mut().zip(probs.iter()) {
            *a += p;
        }
    }
}

/// Split an `[L, H, bucket]` lkv score tensor into per-head sinks for
/// layer `li`.
pub fn lkv_head_sinks<'a>(
    lkv: &'a mut TensorF,
    li: usize,
    nh: usize,
    bucket: usize,
) -> Vec<LkvHeadSink<'a>> {
    lkv.data[li * nh * bucket..(li + 1) * nh * bucket]
        .chunks_mut(bucket)
        .map(|acc| LkvHeadSink { acc })
        .collect()
}

/// One per-(layer, KV-head) importance-predictor MLP:
/// `Linear(dh→hidden) → ReLU → Linear(hidden→1)` over a pre-RoPE key
/// row. `w1` is `[dh, hidden]` row-major (input-major — the layout
/// `aot.py` exports), `b1`/`w2` are `[hidden]`, `b2` a scalar.
#[derive(Clone, Copy)]
pub struct PredictorMlp<'a> {
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: f32,
}

impl PredictorMlp<'_> {
    pub fn hidden(&self) -> usize {
        self.b1.len()
    }

    /// Score one pre-RoPE key row. `hidden_buf` is caller-provided
    /// scratch of length `hidden()` so the hot loop allocates nothing.
    #[inline]
    pub fn score(&self, key: &[f32], hidden_buf: &mut [f32]) -> f32 {
        let hid = self.b1.len();
        hidden_buf[..hid].copy_from_slice(self.b1);
        for (e, &x) in key.iter().enumerate() {
            let wrow = &self.w1[e * hid..(e + 1) * hid];
            for (h, &w) in hidden_buf[..hid].iter_mut().zip(wrow) {
                *h += x * w;
            }
        }
        let mut out = self.b2;
        for (&w, &h) in self.w2.iter().zip(hidden_buf[..hid].iter()) {
            out += w * h.max(0.0);
        }
        out
    }
}

/// Streaming sink over pre-RoPE **key rows** (not attention probs): each
/// appended row is scored once by the head's MLP and written at its
/// absolute position. The predictor analogue of [`ChunkHeadSink`],
/// driven from the same per-chunk kernel loop, so chunked, monolithic
/// and paged prefill stay bit-identical by construction (a row's score
/// depends only on that row's own key).
pub struct PredictorHeadSink<'a> {
    mlp: PredictorMlp<'a>,
    out: &'a mut [f32],
    hidden: Vec<f32>,
}

impl PredictorHeadSink<'_> {
    #[inline]
    pub fn key_row(&mut self, pos: usize, key: &[f32]) {
        self.out[pos] = self.mlp.score(key, &mut self.hidden);
    }
}

/// Split `bundle.pred_scores` for layer `li` into one sink per KV head,
/// pairing each head's `[bucket]` slice with its MLP.
pub fn pred_head_sinks<'a>(
    bundle: &'a mut ScoreBundle,
    li: usize,
    n_kv: usize,
    bucket: usize,
    mlps: Vec<PredictorMlp<'a>>,
) -> Vec<PredictorHeadSink<'a>> {
    assert_eq!(mlps.len(), n_kv);
    let t = bundle.pred_scores.as_mut().expect("pred_head_sinks needs pred_scores");
    t.data[li * n_kv * bucket..(li + 1) * n_kv * bucket]
        .chunks_mut(bucket)
        .zip(mlps)
        .map(|(out, mlp)| {
            let hidden = vec![0.0; mlp.hidden()];
            PredictorHeadSink { mlp, out, hidden }
        })
        .collect()
}

/// Decode sink for one (layer, head): exports the normalized row into
/// the `[L, H, C]` probs tensor (the decode graph's GT-tracking output).
pub struct ProbsHeadSink<'a> {
    out: &'a mut [f32],
}

impl ScoreSink for ProbsHeadSink<'_> {
    #[inline]
    fn row(&mut self, _qi: usize, probs: &[f32]) {
        self.out[..probs.len()].copy_from_slice(probs);
    }
}

/// Split an `[L, H, C]` decode probs tensor into per-head sinks for
/// layer `li`.
pub fn probs_head_sinks<'a>(
    probs: &'a mut TensorF,
    li: usize,
    nh: usize,
    cap: usize,
) -> Vec<ProbsHeadSink<'a>> {
    probs.data[li * nh * cap..(li + 1) * nh * cap]
        .chunks_mut(cap)
        .map(|out| ProbsHeadSink { out })
        .collect()
}

/// Mean over heads of `[L, H, S]` scores, truncated to `len`: returns
/// per-layer vectors of length `len`.
pub fn head_mean_per_layer(t: &TensorF, len: usize) -> Vec<Vec<f32>> {
    let (l, h, s) = (t.shape[0], t.shape[1], t.shape[2]);
    assert!(len <= s);
    let mut out = Vec::with_capacity(l);
    for li in 0..l {
        let mut acc = vec![0.0f32; len];
        for hi in 0..h {
            let row = t.index(&[li, hi]);
            for j in 0..len {
                acc[j] += row[j];
            }
        }
        for a in acc.iter_mut() {
            *a /= h as f32;
        }
        out.push(acc);
    }
    out
}

/// SnapKV-style aggregation of `window_scores [L, H, W, S]`: mean over the
/// last `w_use` valid rows and all heads, per layer, over columns `0..len`.
///
/// `win_start` is the absolute position of row 0; `win_rows` the number of
/// valid rows (rows are zeroed above `win_rows` by the graph, but we slice
/// precisely anyway).
pub fn window_mean_per_layer(
    t: &TensorF,
    len: usize,
    win_start: usize,
    win_rows: usize,
    w_use: usize,
) -> Vec<Vec<f32>> {
    let (l, h, w, s) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
    assert!(len <= s);
    let rows_used = w_use.min(win_rows).max(1);
    // rows [win_rows - rows_used, win_rows) within the window tensor
    let row_lo = win_rows.saturating_sub(rows_used).min(w.saturating_sub(1));
    let row_hi = win_rows.min(w);
    let _ = win_start;
    let denom = ((row_hi - row_lo) * h) as f32;
    let mut out = Vec::with_capacity(l);
    for li in 0..l {
        let mut acc = vec![0.0f32; len];
        for hi in 0..h {
            for r in row_lo..row_hi {
                let row = t.index(&[li, hi, r]);
                for j in 0..len {
                    acc[j] += row[j];
                }
            }
        }
        for a in acc.iter_mut() {
            *a /= denom.max(1.0);
        }
        out.push(acc);
    }
    out
}

/// Single row `r` of `window_scores`, head-mean (TOVA's last-token view).
pub fn window_row_per_layer(t: &TensorF, len: usize, r: usize) -> Vec<Vec<f32>> {
    let (l, h, w, _s) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
    let r = r.min(w - 1);
    let mut out = Vec::with_capacity(l);
    for li in 0..l {
        let mut acc = vec![0.0f32; len];
        for hi in 0..h {
            let row = t.index(&[li, hi, r]);
            for j in 0..len {
                acc[j] += row[j];
            }
        }
        for a in acc.iter_mut() {
            *a /= h as f32;
        }
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_mean_basic() {
        // L=1, H=2, S=3
        let t = TensorF::new(vec![1, 2, 3], vec![1.0, 2.0, 3.0, 3.0, 2.0, 1.0]);
        let m = head_mean_per_layer(&t, 3);
        assert_eq!(m[0], vec![2.0, 2.0, 2.0]);
        let m2 = head_mean_per_layer(&t, 2);
        assert_eq!(m2[0], vec![2.0, 2.0]);
    }

    #[test]
    fn window_mean_uses_last_rows() {
        // L=1,H=1,W=3,S=2; rows: [1,1], [2,2], [30,40]; win_rows=3
        let t = TensorF::new(vec![1, 1, 3, 2], vec![1.0, 1.0, 2.0, 2.0, 30.0, 40.0]);
        let m = window_mean_per_layer(&t, 2, 0, 3, 2);
        assert_eq!(m[0], vec![16.0, 21.0]); // mean of rows 1,2
        let m1 = window_mean_per_layer(&t, 2, 0, 3, 1);
        assert_eq!(m1[0], vec![30.0, 40.0]);
    }

    #[test]
    fn window_mean_partial_valid_rows() {
        // only first 2 rows valid (draft of 2 tokens), w_use=8 clamps to 2
        let t = TensorF::new(vec![1, 1, 3, 2], vec![1.0, 3.0, 3.0, 5.0, 99.0, 99.0]);
        let m = window_mean_per_layer(&t, 2, 0, 2, 8);
        assert_eq!(m[0], vec![2.0, 4.0]);
    }

    #[test]
    fn window_row_picks_row() {
        let t = TensorF::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 7.0, 8.0]);
        let m = window_row_per_layer(&t, 2, 1);
        assert_eq!(m[0], vec![7.0, 8.0]);
    }

    /// Feeding rows through per-head chunk sinks reproduces the naive
    /// reduction: column sums into h2o, row capture into the window.
    #[test]
    fn chunk_sinks_accumulate_like_the_naive_reducer() {
        let (l, nh, window, bucket) = (2usize, 2usize, 2usize, 4usize);
        let mut bundle = ScoreBundle::empty(3);
        bundle.win_start = 1;
        bundle.window_scores = Some(TensorF::zeros(vec![l, nh, window, bucket]));
        bundle.h2o_scores = Some(TensorF::zeros(vec![l, nh, bucket]));
        for li in 0..l {
            let mut sinks = chunk_head_sinks(&mut bundle, li, nh, window, bucket);
            assert_eq!(sinks.len(), nh);
            for (h, sink) in sinks.iter_mut().enumerate() {
                // three rows of a causal pass: row qi has qi+1 visible cols
                for qi in 0..3usize {
                    let row: Vec<f32> = (0..=qi).map(|j| (h + j + 1) as f32).collect();
                    sink.row(qi, &row);
                }
            }
        }
        let h2o = bundle.h2o_scores.as_ref().unwrap();
        // column 0 summed over rows 0..3 for head 0: 1 + 1 + 1
        assert_eq!(h2o.index(&[0, 0]), &[3.0, 4.0, 3.0, 0.0]);
        assert_eq!(h2o.index(&[1, 1]), &[6.0, 6.0, 4.0, 0.0]);
        let win = bundle.window_scores.as_ref().unwrap();
        // window rows capture qi = 1 and qi = 2 (win_start = 1)
        assert_eq!(win.index(&[0, 0, 0]), &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(win.index(&[0, 0, 1]), &[1.0, 2.0, 3.0, 0.0]);
    }

    /// The predictor MLP is an exact two-layer perceptron: hand-check a
    /// tiny instance (dh=2, hidden=2) including the ReLU clamp, then
    /// check the sink writes at absolute positions per head.
    #[test]
    fn predictor_mlp_and_sinks() {
        // w1 = [[1, -1], [0, 2]] (row-major [dh][hidden]), b1 = [0, -3]
        // key [2, 1] → pre-act [2*1+1*0, 2*(-1)+1*2-3] = [2, -3]
        // ReLU → [2, 0]; w2 = [0.5, 10], b2 = 1 → 0.5*2 + 1 = 2
        let mlp = PredictorMlp {
            w1: &[1.0, -1.0, 0.0, 2.0],
            b1: &[0.0, -3.0],
            w2: &[0.5, 10.0],
            b2: 1.0,
        };
        let mut buf = vec![0.0; 2];
        assert_eq!(mlp.score(&[2.0, 1.0], &mut buf), 2.0);

        let (n_kv, bucket) = (2usize, 4usize);
        let mut bundle = ScoreBundle::empty(3);
        bundle.pred_scores = Some(TensorF::zeros(vec![1, n_kv, bucket]));
        {
            let mlps = vec![mlp, mlp];
            let mut sinks = pred_head_sinks(&mut bundle, 0, n_kv, bucket, mlps);
            sinks[0].key_row(2, &[2.0, 1.0]);
            sinks[1].key_row(0, &[2.0, 1.0]);
        }
        let ps = bundle.pred_scores.as_ref().unwrap();
        assert_eq!(ps.index(&[0, 0]), &[0.0, 0.0, 2.0, 0.0]);
        assert_eq!(ps.index(&[0, 1]), &[2.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn lkv_and_probs_sinks_fill_their_head_slices() {
        let (nh, bucket) = (2usize, 3usize);
        let mut lkv = TensorF::zeros(vec![1, nh, bucket]);
        {
            let mut sinks = lkv_head_sinks(&mut lkv, 0, nh, bucket);
            sinks[1].row(0, &[1.0, 3.0]);
            sinks[1].row(1, &[1.0, 1.0]);
            sinks[1].finish(2);
        }
        assert_eq!(lkv.index(&[0, 0]), &[0.0, 0.0, 0.0]);
        assert_eq!(lkv.index(&[0, 1]), &[1.0, 2.0, 0.0]);
        let mut probs = TensorF::zeros(vec![1, nh, bucket]);
        {
            let mut sinks = probs_head_sinks(&mut probs, 0, nh, bucket);
            sinks[0].row(5, &[0.25, 0.75]);
        }
        assert_eq!(probs.index(&[0, 0]), &[0.25, 0.75, 0.0]);
    }
}

//! Score aggregation helpers: collapse `[L, H, ...]` score tensors into a
//! per-layer `[len]` ranking vector (head-mean reduction, the paper's GQA
//! compatibility choice), with optional suffix-row windows.

use crate::util::tensor::TensorF;

/// Mean over heads of `[L, H, S]` scores, truncated to `len`: returns
/// per-layer vectors of length `len`.
pub fn head_mean_per_layer(t: &TensorF, len: usize) -> Vec<Vec<f32>> {
    let (l, h, s) = (t.shape[0], t.shape[1], t.shape[2]);
    assert!(len <= s);
    let mut out = Vec::with_capacity(l);
    for li in 0..l {
        let mut acc = vec![0.0f32; len];
        for hi in 0..h {
            let row = t.index(&[li, hi]);
            for j in 0..len {
                acc[j] += row[j];
            }
        }
        for a in acc.iter_mut() {
            *a /= h as f32;
        }
        out.push(acc);
    }
    out
}

/// SnapKV-style aggregation of `window_scores [L, H, W, S]`: mean over the
/// last `w_use` valid rows and all heads, per layer, over columns `0..len`.
///
/// `win_start` is the absolute position of row 0; `win_rows` the number of
/// valid rows (rows are zeroed above `win_rows` by the graph, but we slice
/// precisely anyway).
pub fn window_mean_per_layer(
    t: &TensorF,
    len: usize,
    win_start: usize,
    win_rows: usize,
    w_use: usize,
) -> Vec<Vec<f32>> {
    let (l, h, w, s) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
    assert!(len <= s);
    let rows_used = w_use.min(win_rows).max(1);
    // rows [win_rows - rows_used, win_rows) within the window tensor
    let row_lo = win_rows.saturating_sub(rows_used).min(w.saturating_sub(1));
    let row_hi = win_rows.min(w);
    let _ = win_start;
    let denom = ((row_hi - row_lo) * h) as f32;
    let mut out = Vec::with_capacity(l);
    for li in 0..l {
        let mut acc = vec![0.0f32; len];
        for hi in 0..h {
            for r in row_lo..row_hi {
                let row = t.index(&[li, hi, r]);
                for j in 0..len {
                    acc[j] += row[j];
                }
            }
        }
        for a in acc.iter_mut() {
            *a /= denom.max(1.0);
        }
        out.push(acc);
    }
    out
}

/// Single row `r` of `window_scores`, head-mean (TOVA's last-token view).
pub fn window_row_per_layer(t: &TensorF, len: usize, r: usize) -> Vec<Vec<f32>> {
    let (l, h, w, _s) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
    let r = r.min(w - 1);
    let mut out = Vec::with_capacity(l);
    for li in 0..l {
        let mut acc = vec![0.0f32; len];
        for hi in 0..h {
            let row = t.index(&[li, hi, r]);
            for j in 0..len {
                acc[j] += row[j];
            }
        }
        for a in acc.iter_mut() {
            *a /= h as f32;
        }
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_mean_basic() {
        // L=1, H=2, S=3
        let t = TensorF::new(vec![1, 2, 3], vec![1.0, 2.0, 3.0, 3.0, 2.0, 1.0]);
        let m = head_mean_per_layer(&t, 3);
        assert_eq!(m[0], vec![2.0, 2.0, 2.0]);
        let m2 = head_mean_per_layer(&t, 2);
        assert_eq!(m2[0], vec![2.0, 2.0]);
    }

    #[test]
    fn window_mean_uses_last_rows() {
        // L=1,H=1,W=3,S=2; rows: [1,1], [2,2], [30,40]; win_rows=3
        let t = TensorF::new(vec![1, 1, 3, 2], vec![1.0, 1.0, 2.0, 2.0, 30.0, 40.0]);
        let m = window_mean_per_layer(&t, 2, 0, 3, 2);
        assert_eq!(m[0], vec![16.0, 21.0]); // mean of rows 1,2
        let m1 = window_mean_per_layer(&t, 2, 0, 3, 1);
        assert_eq!(m1[0], vec![30.0, 40.0]);
    }

    #[test]
    fn window_mean_partial_valid_rows() {
        // only first 2 rows valid (draft of 2 tokens), w_use=8 clamps to 2
        let t = TensorF::new(vec![1, 1, 3, 2], vec![1.0, 3.0, 3.0, 5.0, 99.0, 99.0]);
        let m = window_mean_per_layer(&t, 2, 0, 2, 8);
        assert_eq!(m[0], vec![2.0, 4.0]);
    }

    #[test]
    fn window_row_picks_row() {
        let t = TensorF::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 7.0, 8.0]);
        let m = window_row_per_layer(&t, 2, 1);
        assert_eq!(m[0], vec![7.0, 8.0]);
    }
}

//! Prefill KV-cache eviction framework (the paper's contribution, §2-§3).
//!
//! Every policy consumes a [`ScoreBundle`] harvested from one (or more)
//! prefill passes and produces a [`Selection`]: per-layer sorted keep-sets
//! over prompt positions, later compacted into a [`crate::kvcache::SeqCache`].
//!
//! Pure selection logic lives here and is exhaustively unit/property
//! tested; the *assembly* of bundles (which graphs to run, draft-loop
//! driving for LAQ/SpecKV) lives in [`crate::engine`].
//!
//! Implemented policies:
//!
//! | method        | score source                                   | paper role |
//! |---------------|------------------------------------------------|------------|
//! | `full`        | — (keep everything)                            | upper bound |
//! | `random`      | seeded uniform                                 | sanity floor |
//! | `streaming`   | positions only (sinks + recents)               | StreamingLLM |
//! | `snapkv`      | suffix-window cross-attention                  | SnapKV |
//! | `pyramidkv`   | snapkv scores, funnel per-layer budgets        | PyramidKV |
//! | `h2o`         | whole-prompt column means + recents            | H2O |
//! | `tova`        | last-token attention row                       | TOVA |
//! | `lookaheadkv` | learned lookahead-token scores (Pallas kernel) | **LookaheadKV** |
//! | `lkv+suffix`  | mean of normalized lookahead + suffix scores   | Table 7 ablation |
//! | `laq`         | draft re-query scores (2-pass, target model)   | Lookahead Q-Cache |
//! | `speckv`      | draft re-query scores (draft model)            | SpecKV |
//! | `predictor`   | learned per-head MLP over pre-RoPE keys        | SmartKV-style learned policy |
//!
//! Policies are constructed through [`spec::PolicySpec`], the structured
//! policy API shared by the CLI, the HTTP server and the eval/bench
//! harnesses; `Method::parse` strings remain supported as a thin
//! compatibility layer over it.

pub mod policies;
pub mod pooling;
pub mod scores;
pub mod spec;

use crate::util::tensor::TensorF;

/// Scores harvested from prefill, in the shapes exported by the AOT graphs.
#[derive(Debug, Clone)]
pub struct ScoreBundle {
    /// Real prompt length (eviction domain is positions `0..len`).
    pub len: usize,
    /// `[L, H, W, S]` attention rows of the last W real positions
    /// (or of the appended draft tokens for LAQ/SpecKV bundles).
    pub window_scores: Option<TensorF>,
    /// Absolute position of `window_scores` row 0.
    pub win_start: usize,
    /// Number of valid rows in `window_scores` (draft bundles may have
    /// fewer than W).
    pub win_rows: usize,
    /// `[L, H, S]` column means over all valid rows (H2O salience).
    pub h2o_scores: Option<TensorF>,
    /// `[L, H, S]` learned lookahead importance scores.
    pub lkv_scores: Option<TensorF>,
    /// `[L, Hkv, S]` learned importance-predictor scores: one per-head
    /// MLP evaluation of each pre-RoPE key row (KV heads, not query
    /// heads — the predictor reads key states).
    pub pred_scores: Option<TensorF>,
    /// Override for how many suffix rows the SnapKV-family aggregation
    /// uses (draft bundles aggregate exactly the draft rows, which may be
    /// fewer than the config window).
    pub w_use_override: Option<usize>,
}

impl ScoreBundle {
    pub fn empty(len: usize) -> ScoreBundle {
        ScoreBundle {
            len,
            window_scores: None,
            win_start: 0,
            win_rows: 0,
            h2o_scores: None,
            lkv_scores: None,
            pred_scores: None,
            w_use_override: None,
        }
    }
}

/// Per-layer keep-sets, each sorted ascending and duplicate-free.
#[derive(Debug, Clone, PartialEq)]
pub struct Selection {
    pub per_layer: Vec<Vec<usize>>,
}

impl Selection {
    pub fn uniform(indices: Vec<usize>, n_layers: usize) -> Selection {
        Selection { per_layer: vec![indices; n_layers] }
    }

    pub fn max_kept(&self) -> usize {
        self.per_layer.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Validate structural invariants (used by tests and debug builds).
    pub fn validate(&self, len: usize, budgets: &[usize]) {
        assert_eq!(self.per_layer.len(), budgets.len());
        for (l, idx) in self.per_layer.iter().enumerate() {
            assert_eq!(idx.len(), budgets[l].min(len), "layer {l} count");
            assert!(idx.windows(2).all(|w| w[0] < w[1]), "layer {l} not sorted-unique");
            assert!(idx.iter().all(|&i| i < len), "layer {l} out of range");
        }
    }
}

/// Tunable eviction knobs (paper's standard configuration, scaled —
/// observation window 32→8, max-pool kernel 7→3, 4 attention sinks→2).
#[derive(Debug, Clone, Copy)]
pub struct EvictionConfig {
    /// Cache budget C: kept KV per layer (PyramidKV redistributes it).
    pub budget: usize,
    /// Suffix observation-window length used by SnapKV-family selection.
    pub window: usize,
    /// 1D max-pooling kernel over scores (odd; 1 = off).
    pub kernel: usize,
    /// StreamingLLM attention sinks.
    pub sinks: usize,
}

impl EvictionConfig {
    pub fn new(budget: usize) -> EvictionConfig {
        EvictionConfig { budget, window: 8, kernel: 3, sinks: 2 }
    }
}

/// Auditable record of one eviction decision: which policy ran, under
/// what budget, what it kept/evicted per layer, and a quantile digest of
/// the score distribution it acted on. Attached to `GenResult` /
/// `Reply` and surfaced in the `POST /generate` response so
/// predictor-vs-heuristic choices can be compared offline.
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionSummary {
    /// Canonical policy name (`Method::name()`), e.g. "LookaheadKV:ctx64".
    pub policy: String,
    /// Configured per-layer cache budget C.
    pub budget: usize,
    /// Prompt length the selection ran over.
    pub prompt_len: usize,
    /// Kept positions summed over layers.
    pub kept_total: usize,
    /// Evicted positions summed over layers.
    pub evicted_total: usize,
    pub kept_per_layer: Vec<usize>,
    /// `[p0, p25, p50, p75, p100]` over per-position mean scores of the
    /// tensor the policy selected on; `None` for score-free policies
    /// (full/random/streaming).
    pub score_quantiles: Option<[f64; 5]>,
}

impl DecisionSummary {
    pub fn new(
        method: &Method,
        cfg: &EvictionConfig,
        sel: &Selection,
        bundle: &ScoreBundle,
    ) -> DecisionSummary {
        let kept_per_layer: Vec<usize> = sel.per_layer.iter().map(Vec::len).collect();
        let kept_total: usize = kept_per_layer.iter().sum();
        let evicted_total = kept_per_layer
            .iter()
            .map(|&k| bundle.len.saturating_sub(k))
            .sum();
        DecisionSummary {
            policy: method.name(),
            budget: cfg.budget,
            prompt_len: bundle.len,
            kept_total,
            evicted_total,
            kept_per_layer,
            score_quantiles: score_quantiles(method, bundle),
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::from_pairs(vec![
            ("policy", self.policy.as_str().into()),
            ("budget", self.budget.into()),
            ("prompt_len", self.prompt_len.into()),
            ("kept_total", self.kept_total.into()),
            ("evicted_total", self.evicted_total.into()),
            ("kept_per_layer", self.kept_per_layer.clone().into()),
        ]);
        match &self.score_quantiles {
            Some(q) => o.set("score_quantiles", q.to_vec().into()),
            None => o.set("score_quantiles", Json::Null),
        }
        o
    }
}

/// `[p0, p25, p50, p75, p100]` over the per-position mean of the score
/// tensor this method selects on (positions `0..len`, averaged over all
/// leading dims). `None` when the method is score-free or the bundle
/// lacks the tensor.
fn score_quantiles(method: &Method, bundle: &ScoreBundle) -> Option<[f64; 5]> {
    let t = match method {
        Method::FullKV | Method::Random { .. } | Method::StreamingLLM => return None,
        Method::H2O => bundle.h2o_scores.as_ref()?,
        Method::LookaheadKV { .. } => bundle.lkv_scores.as_ref()?,
        Method::LkvSuffix { .. } => bundle.lkv_scores.as_ref().or(bundle.window_scores.as_ref())?,
        Method::Predictor => bundle.pred_scores.as_ref()?,
        // SnapKV family (incl. draft-bundle LAQ/SpecKV and PyramidKV/TOVA)
        // selects on the suffix-window attention rows.
        _ => bundle.window_scores.as_ref()?,
    };
    let s = *t.shape.last()?;
    if s == 0 || bundle.len == 0 || t.data.is_empty() {
        return None;
    }
    let rows = t.data.len() / s;
    let n = bundle.len.min(s);
    let mut means = vec![0f64; n];
    for r in 0..rows {
        let row = &t.data[r * s..r * s + n];
        for (m, &x) in means.iter_mut().zip(row) {
            *m += x as f64;
        }
    }
    for m in &mut means {
        *m /= rows as f64;
    }
    means.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let q = |p: f64| means[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Some([q(0.0), q(0.25), q(0.5), q(0.75), q(1.0)])
}

/// Parse `name` or `name:variant` (and nothing else): returns the
/// variant ("main" when unspecified), or None when `s` is not this
/// family — including when `s` merely starts with `name`, which is what
/// made the old `strip_prefix`-only parse order-sensitive.
fn variant_of(s: &str, name: &str) -> Option<String> {
    let rest = s.strip_prefix(name)?;
    if rest.is_empty() {
        Some("main".to_string())
    } else {
        rest.strip_prefix(':').filter(|v| !v.is_empty()).map(str::to_string)
    }
}

/// The eviction method, as selected by CLI/server/eval harnesses.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Method {
    FullKV,
    Random { seed: u64 },
    StreamingLLM,
    SnapKV,
    PyramidKV,
    H2O,
    Tova,
    /// `variant` names a trained module set, e.g. "main", "n4_qv", "ctx64".
    LookaheadKV { variant: String },
    /// Table 7: average LookaheadKV scores with the SnapKV suffix window.
    LkvSuffix { variant: String },
    Laq,
    SpecKV,
    /// Learned importance predictor: a per-head `Linear(dh→64)→ReLU→
    /// Linear(64→1)` MLP over pre-RoPE keys, scored inside the prefill
    /// attention loop (no extra pass, no draft generation).
    Predictor,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        let s = s.trim();
        Some(match s {
            "full" | "fullkv" => Method::FullKV,
            "random" => Method::Random { seed: 0 },
            "streaming" | "streamingllm" => Method::StreamingLLM,
            "snapkv" | "snap" => Method::SnapKV,
            "pyramidkv" | "pyramid" => Method::PyramidKV,
            "h2o" => Method::H2O,
            "tova" => Method::Tova,
            "laq" => Method::Laq,
            "speckv" => Method::SpecKV,
            "predictor" => Method::Predictor,
            _ => {
                // Prefix-parsed families. `variant_of` only accepts an
                // exact name or `name:variant`, so no family can shadow
                // another regardless of the order checked here (e.g. bare
                // "lkv" must never swallow "lkv+suffix" as a variant).
                if let Some(v) = variant_of(s, "lookaheadkv") {
                    Method::LookaheadKV { variant: v }
                } else if let Some(v) = variant_of(s, "lkv+suffix") {
                    Method::LkvSuffix { variant: v }
                } else if let Some(v) = variant_of(s, "lkv") {
                    Method::LookaheadKV { variant: v }
                } else {
                    return None;
                }
            }
        })
    }

    pub fn name(&self) -> String {
        match self {
            Method::FullKV => "FullKV".into(),
            Method::Random { .. } => "Random".into(),
            Method::StreamingLLM => "StreamingLLM".into(),
            Method::SnapKV => "SnapKV".into(),
            Method::PyramidKV => "PyramidKV".into(),
            Method::H2O => "H2O".into(),
            Method::Tova => "TOVA".into(),
            Method::LookaheadKV { variant } if variant == "main" => "LookaheadKV".into(),
            Method::LookaheadKV { variant } => format!("LookaheadKV:{variant}"),
            Method::LkvSuffix { variant } if variant == "main" => "LKV+Suffix".into(),
            Method::LkvSuffix { variant } => format!("LKV+Suffix:{variant}"),
            Method::Laq => "LAQ".into(),
            Method::SpecKV => "SpecKV".into(),
            Method::Predictor => "Predictor".into(),
        }
    }

    /// Does this method run the lookahead prefill graph?
    pub fn lkv_variant(&self) -> Option<&str> {
        match self {
            Method::LookaheadKV { variant } | Method::LkvSuffix { variant } => Some(variant),
            _ => None,
        }
    }

    /// Does this method need a draft pass before selection?
    pub fn needs_draft(&self) -> bool {
        matches!(self, Method::Laq | Method::SpecKV)
    }

    /// Pure selection step given an assembled bundle.
    pub fn select(&self, cfg: &EvictionConfig, n_layers: usize, bundle: &ScoreBundle) -> Selection {
        use policies::*;
        let sel = match self {
            Method::FullKV => full_kv(bundle.len, n_layers),
            Method::Random { seed } => random(cfg, n_layers, bundle.len, *seed),
            Method::StreamingLLM => streaming_llm(cfg, n_layers, bundle.len),
            Method::SnapKV | Method::Laq | Method::SpecKV => snapkv(cfg, n_layers, bundle),
            Method::PyramidKV => pyramidkv(cfg, n_layers, bundle),
            Method::H2O => h2o(cfg, n_layers, bundle),
            Method::Tova => tova(cfg, n_layers, bundle),
            Method::LookaheadKV { .. } => lookaheadkv(cfg, n_layers, bundle),
            Method::LkvSuffix { .. } => lkv_suffix(cfg, n_layers, bundle),
            Method::Predictor => predictor(cfg, n_layers, bundle),
        };
        #[cfg(debug_assertions)]
        {
            let budgets: Vec<usize> = sel.per_layer.iter().map(Vec::len).collect();
            sel.validate(bundle.len, &budgets);
        }
        sel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(Method::parse("snapkv"), Some(Method::SnapKV));
        assert_eq!(
            Method::parse("lookaheadkv:ctx64"),
            Some(Method::LookaheadKV { variant: "ctx64".into() })
        );
        assert_eq!(
            Method::parse("lkv"),
            Some(Method::LookaheadKV { variant: "main".into() })
        );
        assert_eq!(
            Method::parse("lkv+suffix"),
            Some(Method::LkvSuffix { variant: "main".into() })
        );
        assert!(Method::parse("bogus").is_none());
    }

    /// Regression (prefix-matching order hazard): the `lookaheadkv`/`lkv`
    /// arms must never shadow `lkv+suffix`, and a trailing junk suffix is
    /// a parse error, not a variant.
    #[test]
    fn parse_families_never_shadow_each_other() {
        assert_eq!(
            Method::parse("lookaheadkv:ctx64"),
            Some(Method::LookaheadKV { variant: "ctx64".into() })
        );
        assert_eq!(
            Method::parse("lkv:ctx64"),
            Some(Method::LookaheadKV { variant: "ctx64".into() })
        );
        assert_eq!(
            Method::parse("lkv+suffix:ctx64"),
            Some(Method::LkvSuffix { variant: "ctx64".into() })
        );
        // "lkv+suffix" must parse as the suffix family, never as
        // LookaheadKV { variant: "+suffix" } (what a bare strip_prefix
        // of "lkv" would produce if checked first).
        assert_eq!(
            Method::parse("lkv+suffix"),
            Some(Method::LkvSuffix { variant: "main".into() })
        );
        for bad in ["lkvx", "lkv+", "lkv+suffixx", "lkv:", "lookaheadkvx", "lkv+suffix:"] {
            assert_eq!(Method::parse(bad), None, "{bad:?} must not parse");
        }
    }

    #[test]
    fn decision_summary_counts_and_quantiles() {
        use crate::util::tensor::TensorF;
        let len = 8;
        let cfg = EvictionConfig::new(4);
        let mut bundle = ScoreBundle::empty(len);
        // [1, 2, 8]: per-position means 0..7 after averaging the two heads.
        let data: Vec<f32> = (0..16).map(|i| (i % 8) as f32).collect();
        bundle.h2o_scores = Some(TensorF::new(vec![1, 2, 8], data));
        let m = Method::H2O;
        let sel = m.select(&cfg, 2, &bundle);
        let ds = DecisionSummary::new(&m, &cfg, &sel, &bundle);
        assert_eq!(ds.policy, "H2O");
        assert_eq!(ds.prompt_len, 8);
        assert_eq!(ds.kept_per_layer, vec![4, 4]);
        assert_eq!(ds.kept_total, 8);
        assert_eq!(ds.evicted_total, 8);
        let q = ds.score_quantiles.expect("h2o has scores");
        assert_eq!(q[0], 0.0);
        assert_eq!(q[4], 7.0);
        assert!(q[0] <= q[1] && q[1] <= q[2] && q[2] <= q[3] && q[3] <= q[4]);
        // JSON shape round-trips.
        let j = crate::util::json::parse(&ds.to_json().to_string()).unwrap();
        assert_eq!(j.req("policy").as_str(), Some("H2O"));
        assert_eq!(j.req("kept_per_layer").usize_arr(), vec![4, 4]);
        assert_eq!(j.req("score_quantiles").as_arr().unwrap().len(), 5);
    }

    #[test]
    fn decision_summary_score_free_policies_have_no_quantiles() {
        let len = 8;
        let cfg = EvictionConfig::new(4);
        let bundle = ScoreBundle::empty(len);
        for m in [Method::FullKV, Method::Random { seed: 1 }, Method::StreamingLLM] {
            let sel = m.select(&cfg, 2, &bundle);
            let ds = DecisionSummary::new(&m, &cfg, &sel, &bundle);
            assert!(ds.score_quantiles.is_none(), "{}", m.name());
            assert_eq!(ds.to_json().req("score_quantiles"), &crate::util::json::Json::Null);
        }
    }

    #[test]
    fn needs_draft_flags() {
        assert!(Method::Laq.needs_draft());
        assert!(Method::SpecKV.needs_draft());
        assert!(!Method::SnapKV.needs_draft());
        assert!(!Method::Predictor.needs_draft());
    }

    /// `name()` must round-trip through `parse` for every family —
    /// including non-"main" variants, which `LkvSuffix::name()` used to
    /// drop (always rendering "LKV+Suffix", so `lkv+suffix:n4_qv` and
    /// `lkv+suffix:main` were indistinguishable in bench/eval rows).
    #[test]
    fn name_parse_round_trip_every_family() {
        let methods = [
            Method::FullKV,
            Method::Random { seed: 0 },
            Method::StreamingLLM,
            Method::SnapKV,
            Method::PyramidKV,
            Method::H2O,
            Method::Tova,
            Method::LookaheadKV { variant: "main".into() },
            Method::LookaheadKV { variant: "ctx64".into() },
            Method::LkvSuffix { variant: "main".into() },
            Method::LkvSuffix { variant: "n4_qv".into() },
            Method::Laq,
            Method::SpecKV,
            Method::Predictor,
        ];
        for m in methods {
            let name = m.name();
            let parsed = Method::parse(&name.to_lowercase())
                .unwrap_or_else(|| panic!("{name:?} must parse back"));
            assert_eq!(parsed, m, "round trip through {name:?}");
        }
        // The variant now survives the name: distinct variants render
        // distinctly.
        assert_ne!(
            Method::LkvSuffix { variant: "main".into() }.name(),
            Method::LkvSuffix { variant: "n4_qv".into() }.name()
        );
    }
}

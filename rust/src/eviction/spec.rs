//! Structured policy API: [`PolicySpec`] is the single construction path
//! for eviction policies across the CLI (`--method`), per-request HTTP
//! JSON overrides on `/generate`, the eval runner and every bench.
//!
//! A spec names a policy *family* (the canonical slug) plus optional
//! family-specific parameters (trained-variant name, random seed) and
//! per-request knob overrides over the engine's base
//! [`EvictionConfig`] (budget / window / kernel / sinks). It serializes
//! to and from JSON with strict unknown-field rejection, and legacy
//! `Method::parse` strings ("snapkv", "lkv+suffix:n4_qv", ...) remain a
//! thin compatibility parser mapped through [`PolicySpec::parse_str`] —
//! guaranteed to resolve to the identical [`Method`] (and therefore
//! bit-identical selections).

use super::{EvictionConfig, Method};
use crate::util::json::Json;

/// Optional per-request overrides of the engine's base eviction knobs.
/// `None` means "use the engine default".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PolicyKnobs {
    pub window: Option<usize>,
    pub kernel: Option<usize>,
    pub sinks: Option<usize>,
}

impl PolicyKnobs {
    pub fn apply(&self, cfg: &mut EvictionConfig) {
        if let Some(w) = self.window {
            cfg.window = w;
        }
        if let Some(k) = self.kernel {
            cfg.kernel = k;
        }
        if let Some(s) = self.sinks {
            cfg.sinks = s;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_none() && self.kernel.is_none() && self.sinks.is_none()
    }
}

/// A structured, serializable eviction-policy specification.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    /// Canonical family slug (see [`families`]).
    pub family: String,
    /// Trained-module variant for the lookahead families (default "main").
    pub variant: Option<String>,
    /// Seed for the `random` family (default 0).
    pub seed: Option<u64>,
    /// Per-request budget override (kept KV per layer).
    pub budget: Option<usize>,
    pub knobs: PolicyKnobs,
}

/// Static metadata for one policy family — what `GET /policies` reports.
pub struct FamilyInfo {
    pub family: &'static str,
    /// Legacy `Method::parse` strings accepted for this family.
    pub aliases: &'static [&'static str],
    pub takes_variant: bool,
    pub takes_seed: bool,
    /// Runs draft generation before selection (needs a draft model).
    pub needs_draft: bool,
    /// Needs importance-predictor weights (manifest `predictors` entry).
    pub needs_predictor: bool,
    pub summary: &'static str,
}

/// Every policy family, in the order they appear in docs and benches.
pub fn families() -> &'static [FamilyInfo] {
    const NONE: &[&str] = &[];
    &[
        FamilyInfo {
            family: "full",
            aliases: &["fullkv"],
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "keep everything (upper bound)",
        },
        FamilyInfo {
            family: "random",
            aliases: NONE,
            takes_variant: false,
            takes_seed: true,
            needs_draft: false,
            needs_predictor: false,
            summary: "seeded uniform keep-set (sanity floor)",
        },
        FamilyInfo {
            family: "streaming",
            aliases: &["streamingllm"],
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "attention sinks + recents (StreamingLLM)",
        },
        FamilyInfo {
            family: "snapkv",
            aliases: &["snap"],
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "suffix-window cross-attention (SnapKV)",
        },
        FamilyInfo {
            family: "pyramidkv",
            aliases: &["pyramid"],
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "snapkv scores with funnel per-layer budgets (PyramidKV)",
        },
        FamilyInfo {
            family: "h2o",
            aliases: NONE,
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "whole-prompt column means + recents (H2O)",
        },
        FamilyInfo {
            family: "tova",
            aliases: NONE,
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "last-token attention row (TOVA)",
        },
        FamilyInfo {
            family: "lookaheadkv",
            aliases: &["lkv"],
            takes_variant: true,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "learned lookahead-token scores (LookaheadKV)",
        },
        FamilyInfo {
            family: "lkv+suffix",
            aliases: NONE,
            takes_variant: true,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: false,
            summary: "mean of normalized lookahead + suffix scores (Table 7)",
        },
        FamilyInfo {
            family: "laq",
            aliases: NONE,
            takes_variant: false,
            takes_seed: false,
            needs_draft: true,
            needs_predictor: false,
            summary: "draft re-query scores, target model (Lookahead Q-Cache)",
        },
        FamilyInfo {
            family: "speckv",
            aliases: NONE,
            takes_variant: false,
            takes_seed: false,
            needs_draft: true,
            needs_predictor: false,
            summary: "draft re-query scores, draft model (SpecKV)",
        },
        FamilyInfo {
            family: "predictor",
            aliases: NONE,
            takes_variant: false,
            takes_seed: false,
            needs_draft: false,
            needs_predictor: true,
            summary: "learned per-head MLP over pre-RoPE keys (importance predictor)",
        },
    ]
}

fn family_info(family: &str) -> Option<&'static FamilyInfo> {
    families().iter().find(|f| f.family == family)
}

impl PolicySpec {
    /// A bare spec for `family` with no overrides.
    pub fn new(family: &str) -> PolicySpec {
        PolicySpec {
            family: family.to_string(),
            variant: None,
            seed: None,
            budget: None,
            knobs: PolicyKnobs::default(),
        }
    }

    /// The canonical spec of an already-parsed [`Method`].
    pub fn from_method(m: &Method) -> PolicySpec {
        let mut spec = match m {
            Method::FullKV => PolicySpec::new("full"),
            Method::Random { seed } => {
                let mut s = PolicySpec::new("random");
                if *seed != 0 {
                    s.seed = Some(*seed);
                }
                s
            }
            Method::StreamingLLM => PolicySpec::new("streaming"),
            Method::SnapKV => PolicySpec::new("snapkv"),
            Method::PyramidKV => PolicySpec::new("pyramidkv"),
            Method::H2O => PolicySpec::new("h2o"),
            Method::Tova => PolicySpec::new("tova"),
            Method::LookaheadKV { variant } => {
                let mut s = PolicySpec::new("lookaheadkv");
                if variant != "main" {
                    s.variant = Some(variant.clone());
                }
                s
            }
            Method::LkvSuffix { variant } => {
                let mut s = PolicySpec::new("lkv+suffix");
                if variant != "main" {
                    s.variant = Some(variant.clone());
                }
                s
            }
            Method::Laq => PolicySpec::new("laq"),
            Method::SpecKV => PolicySpec::new("speckv"),
            Method::Predictor => PolicySpec::new("predictor"),
        };
        spec.validate().expect("from_method specs are always valid");
        spec
    }

    /// Compatibility parser: every legacy `Method::parse` string maps to
    /// the spec that resolves back to the identical `Method`.
    pub fn parse_str(s: &str) -> Option<PolicySpec> {
        Method::parse(s).map(|m| PolicySpec::from_method(&m))
    }

    /// Structural validation: known family, family-applicable parameters,
    /// sane knob values. Returns a human-readable error for 4xx bodies.
    pub fn validate(&self) -> Result<(), String> {
        let info = family_info(&self.family)
            .ok_or_else(|| format!("unknown policy family {:?}", self.family))?;
        if self.variant.is_some() && !info.takes_variant {
            return Err(format!("policy family {:?} takes no variant", self.family));
        }
        if self.seed.is_some() && !info.takes_seed {
            return Err(format!("policy family {:?} takes no seed", self.family));
        }
        if let Some(v) = &self.variant {
            if v.is_empty() {
                return Err("policy variant must be non-empty".to_string());
            }
        }
        if self.budget == Some(0) {
            return Err("invalid knob budget: must be >= 1".to_string());
        }
        if self.knobs.window == Some(0) {
            return Err("invalid knob window: must be >= 1".to_string());
        }
        match self.knobs.kernel {
            Some(k) if k == 0 || k % 2 == 0 => {
                return Err(format!("invalid knob kernel: must be odd, got {k}"));
            }
            _ => {}
        }
        Ok(())
    }

    /// Resolve to the executable [`Method`].
    pub fn resolve(&self) -> Result<Method, String> {
        self.validate()?;
        let variant = || self.variant.clone().unwrap_or_else(|| "main".to_string());
        Ok(match self.family.as_str() {
            "full" => Method::FullKV,
            "random" => Method::Random { seed: self.seed.unwrap_or(0) },
            "streaming" => Method::StreamingLLM,
            "snapkv" => Method::SnapKV,
            "pyramidkv" => Method::PyramidKV,
            "h2o" => Method::H2O,
            "tova" => Method::Tova,
            "lookaheadkv" => Method::LookaheadKV { variant: variant() },
            "lkv+suffix" => Method::LkvSuffix { variant: variant() },
            "laq" => Method::Laq,
            "speckv" => Method::SpecKV,
            "predictor" => Method::Predictor,
            other => return Err(format!("unknown policy family {other:?}")),
        })
    }

    /// Apply this spec's knob overrides (not the budget) to a config.
    pub fn apply_knobs(&self, cfg: &mut EvictionConfig) {
        self.knobs.apply(cfg);
    }

    /// Strict JSON deserialization: unknown fields are an error (catches
    /// typos like "kernal" instead of silently ignoring them).
    pub fn from_json(v: &Json) -> Result<PolicySpec, String> {
        let obj = v.as_obj().ok_or_else(|| "policy must be a JSON object".to_string())?;
        const KNOWN: &[&str] = &["family", "variant", "seed", "budget", "window", "kernel", "sinks"];
        for k in obj.keys() {
            if !KNOWN.contains(&k.as_str()) {
                return Err(format!("unknown policy field {k:?}"));
            }
        }
        let family = v
            .get("family")
            .and_then(Json::as_str)
            .ok_or_else(|| "policy requires a string \"family\"".to_string())?
            .to_string();
        let usize_field = |name: &str| -> Result<Option<usize>, String> {
            match v.get(name) {
                None => Ok(None),
                Some(j) => j
                    .as_usize()
                    .map(Some)
                    .ok_or_else(|| format!("policy field {name:?} must be a non-negative integer")),
            }
        };
        let spec = PolicySpec {
            family,
            variant: match v.get("variant") {
                None => None,
                Some(j) => Some(
                    j.as_str()
                        .ok_or_else(|| "policy field \"variant\" must be a string".to_string())?
                        .to_string(),
                ),
            },
            seed: usize_field("seed")?.map(|s| s as u64),
            budget: usize_field("budget")?,
            knobs: PolicyKnobs {
                window: usize_field("window")?,
                kernel: usize_field("kernel")?,
                sinks: usize_field("sinks")?,
            },
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize; only present fields are emitted, so
    /// `from_json(to_json(s)) == s` round-trips exactly.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("family", self.family.as_str().into());
        if let Some(v) = &self.variant {
            o.set("variant", v.as_str().into());
        }
        if let Some(s) = self.seed {
            o.set("seed", s.into());
        }
        if let Some(b) = self.budget {
            o.set("budget", b.into());
        }
        if let Some(w) = self.knobs.window {
            o.set("window", w.into());
        }
        if let Some(k) = self.knobs.kernel {
            o.set("kernel", k.into());
        }
        if let Some(s) = self.knobs.sinks {
            o.set("sinks", s.into());
        }
        o
    }
}

/// The `GET /policies` payload: every family with its accepted knobs,
/// the engine's base knob defaults, and whether predictor weights are
/// available for the serving model.
pub fn registry_json(base: &EvictionConfig, predictor_loaded: bool) -> Json {
    let mut fams = Vec::new();
    for f in families() {
        let mut o = Json::obj();
        o.set("family", f.family.into());
        o.set("aliases", f.aliases.iter().map(|a| Json::from(*a)).collect::<Vec<_>>().into());
        let mut knobs = vec!["budget", "window", "kernel", "sinks"];
        if f.takes_variant {
            knobs.push("variant");
        }
        if f.takes_seed {
            knobs.push("seed");
        }
        o.set("knobs", knobs.into_iter().map(Json::from).collect::<Vec<_>>().into());
        o.set("needs_draft", f.needs_draft.into());
        o.set("needs_predictor", f.needs_predictor.into());
        o.set("summary", f.summary.into());
        if f.needs_predictor {
            o.set("available", predictor_loaded.into());
        }
        fams.push(o);
    }
    let defaults = Json::from_pairs(vec![
        ("budget", base.budget.into()),
        ("window", base.window.into()),
        ("kernel", base.kernel.into()),
        ("sinks", base.sinks.into()),
    ]);
    Json::from_pairs(vec![
        ("families", fams.into()),
        ("defaults", defaults),
        ("predictor_loaded", predictor_loaded.into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    /// Every legacy string resolves through PolicySpec to the identical
    /// Method — the compatibility guarantee for bit-identical selection.
    #[test]
    fn every_legacy_string_maps_through_spec() {
        let strings = [
            "full",
            "fullkv",
            "random",
            "streaming",
            "streamingllm",
            "snapkv",
            "snap",
            "pyramidkv",
            "pyramid",
            "h2o",
            "tova",
            "laq",
            "speckv",
            "predictor",
            "lookaheadkv",
            "lookaheadkv:ctx64",
            "lkv",
            "lkv:n4_qv",
            "lkv+suffix",
            "lkv+suffix:n4_qv",
        ];
        for s in strings {
            let m = Method::parse(s).unwrap_or_else(|| panic!("{s:?} must parse"));
            let spec = PolicySpec::parse_str(s).unwrap_or_else(|| panic!("{s:?} must map to a spec"));
            assert_eq!(spec.resolve().unwrap(), m, "resolve({s:?})");
        }
        assert!(PolicySpec::parse_str("bogus").is_none());
    }

    #[test]
    fn json_round_trip() {
        let samples = [
            r#"{"family":"snapkv"}"#,
            r#"{"family":"random","seed":7}"#,
            r#"{"family":"lookaheadkv","variant":"ctx64","budget":48}"#,
            r#"{"family":"predictor","budget":32,"window":4,"kernel":5,"sinks":1}"#,
        ];
        for s in samples {
            let spec = PolicySpec::from_json(&json::parse(s).unwrap()).unwrap();
            let back = PolicySpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back, "{s}");
            // and string-level: to_string → parse → from_json
            let text = spec.to_json().to_string();
            let again = PolicySpec::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(spec, again, "{text}");
        }
    }

    #[test]
    fn unknown_fields_and_families_rejected() {
        let bad = [
            (r#"{"family":"snapkv","kernal":3}"#, "unknown policy field"),
            (r#"{"family":"zoomkv"}"#, "unknown policy family"),
            (r#"{"family":"snapkv","variant":"x"}"#, "takes no variant"),
            (r#"{"family":"h2o","seed":1}"#, "takes no seed"),
            (r#"{"family":"snapkv","kernel":2}"#, "must be odd"),
            (r#"{"family":"snapkv","budget":0}"#, "budget"),
            (r#"{"family":"snapkv","window":0}"#, "window"),
            (r#"{"budget":8}"#, "requires a string \"family\""),
            (r#"[1,2]"#, "must be a JSON object"),
        ];
        for (text, needle) in bad {
            let err = PolicySpec::from_json(&json::parse(text).unwrap())
                .expect_err(&format!("{text} must be rejected"));
            assert!(err.contains(needle), "{text}: {err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn knob_overrides_apply() {
        let spec = PolicySpec::from_json(
            &json::parse(r#"{"family":"h2o","window":4,"kernel":5,"sinks":1}"#).unwrap(),
        )
        .unwrap();
        let mut cfg = EvictionConfig::new(64);
        spec.apply_knobs(&mut cfg);
        assert_eq!((cfg.window, cfg.kernel, cfg.sinks), (4, 5, 1));
        assert_eq!(cfg.budget, 64, "budget is not a knob override");
        // empty knobs leave the config untouched
        let mut cfg2 = EvictionConfig::new(64);
        PolicySpec::new("h2o").apply_knobs(&mut cfg2);
        assert_eq!((cfg2.window, cfg2.kernel, cfg2.sinks), (8, 3, 2));
    }

    #[test]
    fn registry_lists_every_family() {
        let j = registry_json(&EvictionConfig::new(64), true);
        let fams = j.req("families").as_arr().unwrap();
        assert_eq!(fams.len(), families().len());
        let pred = fams
            .iter()
            .find(|f| f.req("family").as_str() == Some("predictor"))
            .expect("predictor listed");
        assert_eq!(pred.req("available").as_bool(), Some(true));
        assert_eq!(j.req("defaults").req("window").as_usize(), Some(8));
        assert_eq!(j.req("predictor_loaded").as_bool(), Some(true));
        // every listed family resolves
        for f in fams {
            let fam = f.req("family").as_str().unwrap();
            assert!(PolicySpec::new(fam).resolve().is_ok(), "{fam}");
        }
    }
}

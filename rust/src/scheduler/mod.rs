//! Request scheduling: admission control, FIFO queue with backpressure,
//! and a continuous-batching engine loop (prefill interleaved with
//! round-robin decode across active sequences — vLLM-style iteration
//! scheduling, executed serially on the single engine thread that owns
//! the PJRT client).

pub mod batcher;
pub mod queue;

pub use batcher::{EngineLoop, LoopConfig};
pub use queue::{Priority, Reply, Request, RequestQueue, SubmitError};

//! Bounded request queue shared between the server front-end and the
//! engine loop.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};

use crate::engine::FinishReason;
use crate::eviction::Method;

/// One generation request, as submitted by a front-end.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub method: Method,
    pub budget: usize,
    pub max_new: usize,
    pub temperature: f32,
    pub reply: Sender<Reply>,
}

/// Completion message.
#[derive(Debug, Clone)]
pub struct Reply {
    pub id: u64,
    pub text: String,
    pub n_tokens: usize,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub kept: usize,
    /// Why generation stopped (`eos` / `length` / `kv_exhausted` / ...);
    /// makes cap- and pool-driven truncation observable.
    pub finish_reason: FinishReason,
    pub error: Option<String>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — caller should shed load (HTTP 429).
    Full,
    /// Queue shut down.
    Closed,
}

/// MPMC bounded FIFO with shutdown; producers are server threads,
/// the single consumer is the engine loop.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    cap: usize,
}

struct Inner {
    q: VecDeque<Request>,
    closed: bool,
}

impl RequestQueue {
    pub fn new(cap: usize) -> RequestQueue {
        RequestQueue { inner: Mutex::new(Inner { q: VecDeque::new(), closed: false }), cv: Condvar::new(), cap }
    }

    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(SubmitError::Closed);
        }
        if inner.q.len() >= self.cap {
            return Err(SubmitError::Full); // backpressure
        }
        inner.q.push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<Request> {
        self.inner.lock().unwrap().q.pop_front()
    }

    /// Blocking pop with timeout; None on timeout or close-with-empty.
    pub fn pop_timeout(&self, timeout: std::time::Duration) -> Option<Request> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(r) = inner.q.pop_front() {
            return Some(r);
        }
        if inner.closed {
            return None;
        }
        let (mut inner, _t) = self.cv.wait_timeout(inner, timeout).unwrap();
        inner.q.pop_front()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::Method;
    use std::sync::mpsc::channel;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Reply>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                prompt: vec![1, 2, 3],
                method: Method::SnapKV,
                budget: 8,
                max_new: 4,
                temperature: 0.0,
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.submit(r1).unwrap();
        q.submit(r2).unwrap();
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
    }

    #[test]
    fn backpressure_full() {
        let q = RequestQueue::new(1);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.submit(r1).unwrap();
        assert_eq!(q.submit(r2).unwrap_err(), SubmitError::Full);
    }

    #[test]
    fn closed_rejects() {
        let q = RequestQueue::new(1);
        q.close();
        let (r, _k) = req(1);
        assert_eq!(q.submit(r).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn prop_queue_never_exceeds_cap() {
        use crate::util::proptest::{check, Config};
        check("queue cap", &Config { cases: 64, max_size: 64, ..Config::new() }, |rng, size| {
            let cap = rng.range(1, 8);
            let q = RequestQueue::new(cap);
            for i in 0..size {
                if rng.chance(0.7) {
                    let (r, _k) = req(i as u64);
                    let _ = q.submit(r);
                } else {
                    let _ = q.try_pop();
                }
                assert!(q.len() <= cap);
            }
        });
    }
}

//! Bounded request queue shared between the server front-end and the
//! engine loop. Requests carry a [`Priority`] class and a tenant id;
//! the queue pops highest-priority-first (FIFO within a class) and
//! supports predicate pops so the engine loop can skip tenants that
//! are over their token quota without reordering anyone else.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use crate::engine::{FinishReason, RequestStats};
use crate::eviction::spec::PolicyKnobs;
use crate::eviction::{DecisionSummary, Method};

/// Scheduling class. Higher classes are admitted first and are the
/// last to be preempted when the KV pool runs out of blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    Low = 0,
    #[default]
    Normal = 1,
    High = 2,
}

impl Priority {
    pub const ALL: [Priority; 3] = [Priority::Low, Priority::Normal, Priority::High];

    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// One generation request, as submitted by a front-end.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub method: Method,
    pub budget: usize,
    pub max_new: usize,
    pub temperature: f32,
    /// Per-request eviction knob overrides (window/kernel/sinks) from an
    /// inline [`crate::eviction::spec::PolicySpec`]; empty = defaults.
    pub knobs: PolicyKnobs,
    /// Tenant this request is billed to (token quotas are per tenant).
    pub tenant: u32,
    pub priority: Priority,
    /// When the front-end submitted the request; queue-wait time is
    /// measured from here to the engine-loop pop.
    pub submitted_at: std::time::Instant,
    /// Wall-clock budget measured from `submitted_at`, in milliseconds;
    /// 0 means no deadline. The engine checks it at chunk/iteration
    /// boundaries and finishes with `FinishReason::Deadline` (keeping
    /// any tokens already generated) when it expires.
    pub deadline_ms: u64,
    /// Cooperative cancellation flag. The server sets it when the client
    /// disconnects; the engine polls it at the same boundaries as the
    /// deadline and finishes with `FinishReason::Cancelled`.
    pub cancel: Arc<AtomicBool>,
    pub reply: Sender<Reply>,
}

impl Request {
    /// Absolute deadline, if the request has one.
    pub fn deadline(&self) -> Option<std::time::Instant> {
        (self.deadline_ms > 0)
            .then(|| self.submitted_at + std::time::Duration::from_millis(self.deadline_ms))
    }

    /// Has the client asked for this request to stop?
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Completion message.
#[derive(Debug, Clone)]
pub struct Reply {
    pub id: u64,
    pub text: String,
    pub n_tokens: usize,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub kept: usize,
    /// Why generation stopped (`eos` / `length` / `kv_exhausted` / ...);
    /// makes cap- and pool-driven truncation observable.
    pub finish_reason: FinishReason,
    pub error: Option<String>,
    /// Per-request lifecycle stats (queue wait, chunks, decode iters,
    /// evictions, arena high-water, spill/restore counts).
    pub stats: RequestStats,
    /// What the eviction policy decided for this request, if it ran.
    pub eviction: Option<DecisionSummary>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity — caller should shed load (HTTP 429).
    Full,
    /// Queue shut down.
    Closed,
}

/// MPMC bounded queue with shutdown; producers are server threads,
/// the single consumer is the engine loop. One FIFO per priority
/// class; pops drain the highest non-empty class first.
pub struct RequestQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    cap: usize,
}

struct Inner {
    classes: [VecDeque<Request>; 3],
    len: usize,
    closed: bool,
}

impl Inner {
    fn pop_where(&mut self, pred: &dyn Fn(&Request) -> bool) -> Option<Request> {
        for class in self.classes.iter_mut().rev() {
            if let Some(i) = class.iter().position(pred) {
                self.len -= 1;
                return class.remove(i);
            }
        }
        None
    }
}

impl RequestQueue {
    pub fn new(cap: usize) -> RequestQueue {
        RequestQueue {
            inner: Mutex::new(Inner { classes: Default::default(), len: 0, closed: false }),
            cv: Condvar::new(),
            cap,
        }
    }

    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(SubmitError::Closed);
        }
        if inner.len >= self.cap {
            return Err(SubmitError::Full); // backpressure
        }
        inner.len += 1;
        inner.classes[req.priority as usize].push_back(req);
        self.cv.notify_one();
        Ok(())
    }

    /// Non-blocking pop: highest priority class first, FIFO within one.
    pub fn try_pop(&self) -> Option<Request> {
        self.try_pop_where(|_| true)
    }

    /// Non-blocking pop of the first request (in priority-then-FIFO
    /// order) satisfying `pred`; requests failing the predicate keep
    /// their position. Lets the loop skip over-quota tenants.
    pub fn try_pop_where(&self, pred: impl Fn(&Request) -> bool) -> Option<Request> {
        self.inner.lock().unwrap().pop_where(&pred)
    }

    /// Priority of the request `try_pop` would return, if any.
    pub fn peek_priority(&self) -> Option<Priority> {
        let inner = self.inner.lock().unwrap();
        for p in Priority::ALL.iter().rev() {
            if !inner.classes[*p as usize].is_empty() {
                return Some(*p);
            }
        }
        None
    }

    /// Blocking pop with timeout; None on timeout or close-with-empty.
    pub fn pop_timeout(&self, timeout: std::time::Duration) -> Option<Request> {
        let mut inner = self.inner.lock().unwrap();
        if let Some(r) = inner.pop_where(&|_| true) {
            return Some(r);
        }
        if inner.closed {
            return None;
        }
        let (mut inner, _t) = self.cv.wait_timeout(inner, timeout).unwrap();
        inner.pop_where(&|_| true)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::Method;
    use std::sync::mpsc::channel;

    fn req(id: u64) -> (Request, std::sync::mpsc::Receiver<Reply>) {
        req_pt(id, Priority::Normal, 0)
    }

    fn req_pt(id: u64, priority: Priority, tenant: u32) -> (Request, std::sync::mpsc::Receiver<Reply>) {
        let (tx, rx) = channel();
        (
            Request {
                id,
                prompt: vec![1, 2, 3],
                method: Method::SnapKV,
                budget: 8,
                max_new: 4,
                temperature: 0.0,
                knobs: PolicyKnobs::default(),
                tenant,
                priority,
                submitted_at: std::time::Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(AtomicBool::new(false)),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn fifo_order() {
        let q = RequestQueue::new(8);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.submit(r1).unwrap();
        q.submit(r2).unwrap();
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 2);
    }

    #[test]
    fn priority_order_fifo_within_class() {
        let q = RequestQueue::new(8);
        let mut keep = Vec::new();
        for (id, p) in [(1, Priority::Low), (2, Priority::High), (3, Priority::Normal), (4, Priority::High)] {
            let (r, k) = req_pt(id, p, 0);
            keep.push(k);
            q.submit(r).unwrap();
        }
        assert_eq!(q.peek_priority(), Some(Priority::High));
        let order: Vec<u64> = std::iter::from_fn(|| q.try_pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
        assert_eq!(q.peek_priority(), None);
    }

    #[test]
    fn predicate_pop_skips_without_reordering() {
        let q = RequestQueue::new(8);
        let mut keep = Vec::new();
        for (id, tenant) in [(1, 0), (2, 1), (3, 0)] {
            let (r, k) = req_pt(id, Priority::Normal, tenant);
            keep.push(k);
            q.submit(r).unwrap();
        }
        // Tenant 0 over quota: first eligible is id 2.
        assert_eq!(q.try_pop_where(|r| r.tenant != 0).unwrap().id, 2);
        // Skipped requests kept their FIFO position.
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.try_pop().unwrap().id, 3);
    }

    #[test]
    fn backpressure_full() {
        let q = RequestQueue::new(1);
        let (r1, _k1) = req(1);
        let (r2, _k2) = req(2);
        q.submit(r1).unwrap();
        assert_eq!(q.submit(r2).unwrap_err(), SubmitError::Full);
    }

    #[test]
    fn closed_rejects() {
        let q = RequestQueue::new(1);
        q.close();
        let (r, _k) = req(1);
        assert_eq!(q.submit(r).unwrap_err(), SubmitError::Closed);
    }

    #[test]
    fn submit_after_close_rejects_even_with_space() {
        let q = RequestQueue::new(8);
        let (r1, _k1) = req(1);
        q.submit(r1).unwrap();
        q.close();
        assert!(q.is_closed());
        let (r2, _k2) = req(2);
        assert_eq!(q.submit(r2).unwrap_err(), SubmitError::Closed);
        // Already-queued work stays drainable after close.
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
    }

    #[test]
    fn pop_timeout_expires_empty() {
        let q = RequestQueue::new(4);
        let t0 = std::time::Instant::now();
        assert!(q.pop_timeout(std::time::Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(25), "{:?}", t0.elapsed());
    }

    #[test]
    fn pop_timeout_wakes_on_submit() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_timeout(std::time::Duration::from_secs(10)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        let (r, _k) = req(7);
        q.submit(r).unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.unwrap().id, 7);
    }

    #[test]
    fn pop_timeout_returns_none_on_close() {
        use std::sync::Arc;
        let q = Arc::new(RequestQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_timeout(std::time::Duration::from_secs(10)));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn concurrent_submitters_full_accounting() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        const CAP: usize = 8;
        const THREADS: usize = 4;
        const PER_THREAD: usize = 16;
        let q = Arc::new(RequestQueue::new(CAP));
        let ok = Arc::new(AtomicUsize::new(0));
        let full = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let (q, ok, full) = (Arc::clone(&q), Arc::clone(&ok), Arc::clone(&full));
                std::thread::spawn(move || {
                    let mut keep = Vec::new();
                    for i in 0..PER_THREAD {
                        let (r, k) = req((t * PER_THREAD + i) as u64);
                        keep.push(k);
                        match q.submit(r) {
                            Ok(()) => ok.fetch_add(1, Ordering::SeqCst),
                            Err(SubmitError::Full) => full.fetch_add(1, Ordering::SeqCst),
                            Err(SubmitError::Closed) => panic!("queue not closed"),
                        };
                    }
                    keep
                })
            })
            .collect();
        let _keep: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Every submit either landed or was refused as Full, and the
        // accepted-but-unpopped count is exactly the queue length (≤ cap).
        assert_eq!(ok.load(Ordering::SeqCst) + full.load(Ordering::SeqCst), THREADS * PER_THREAD);
        assert_eq!(q.len(), ok.load(Ordering::SeqCst).min(CAP));
        assert!(q.len() <= CAP);
        assert_eq!(q.len(), CAP, "cap-many submits must have succeeded");
    }

    #[test]
    fn prop_queue_never_exceeds_cap() {
        use crate::util::proptest::{check, Config};
        check("queue cap", &Config { cases: 64, max_size: 64, ..Config::new() }, |rng, size| {
            let cap = rng.range(1, 8);
            let q = RequestQueue::new(cap);
            for i in 0..size {
                if rng.chance(0.7) {
                    let (r, _k) = req(i as u64);
                    let _ = q.submit(r);
                } else {
                    let _ = q.try_pop();
                }
                assert!(q.len() <= cap);
            }
        });
    }
}

//! Continuous-batching engine loop.
//!
//! Iteration-level scheduling in the Orca/vLLM mold, specialized to the
//! single-stream CPU backends: each loop iteration either (a) admits
//! and prefills one queued request if the KV pool has room, or (b)
//! advances every active sequence by one decode token. Prefill is
//! prioritized while the active set is below `max_active`
//! (prefill-priority keeps TTFT low; decode fairness keeps TPOT flat).
//!
//! Decode dispatch is batched by default: all active sequences advance
//! in **one** backend call per iteration (`Engine::decode_step_batch`),
//! with caches updated in place instead of being
//! serialized to and from the backend every token. Set
//! `LoopConfig::batched_decode = false` for the historical per-sequence
//! round-trip (kept for A/B benchmarking — see `bench_scheduler`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::Engine;
use crate::kvcache::{manager::bytes_per_slot, CacheManager, SeqCache};
use crate::metrics::Metrics;
use crate::model::sampler::Sampler;
use crate::model::tokenizer::{decode_until_eos, EOS_ID};
use crate::scheduler::queue::{Reply, Request, RequestQueue};

#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Max concurrently active (decoding) sequences.
    pub max_active: usize,
    /// Global KV pool in token slots (admission control).
    pub kv_pool_slots: usize,
    pub kv_block_slots: usize,
    /// Advance all active sequences in one backend call per iteration
    /// (vs per-sequence decode round-trips).
    pub batched_decode: bool,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            max_active: 4,
            kv_pool_slots: 16 * 1152,
            kv_block_slots: 64,
            batched_decode: true,
        }
    }
}

struct ActiveSeq {
    id: u64,
    cache: SeqCache,
    sampler: Sampler,
    tokens: Vec<i32>,
    next_token: i32,
    max_new: usize,
    reply: std::sync::mpsc::Sender<Reply>,
    t_start: Instant,
    ttft_ms: f64,
    kept: usize,
}

pub struct EngineLoop {
    engine: Engine,
    cfg: LoopConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
}

impl EngineLoop {
    pub fn new(
        engine: Engine,
        cfg: LoopConfig,
        queue: Arc<RequestQueue>,
        metrics: Arc<Metrics>,
    ) -> EngineLoop {
        EngineLoop { engine, cfg, queue, metrics }
    }

    /// Run until the queue is closed and drained.
    pub fn run(mut self) {
        let model = self.engine.cfg.model.clone();
        let m = self.engine.rt.manifest().model(&model).expect("model");
        let _slot_bytes = bytes_per_slot(m.n_layers, m.n_kv_heads, m.head_dim);
        let mut mgr = CacheManager::new(self.cfg.kv_pool_slots, self.cfg.kv_block_slots);
        let mut active: Vec<ActiveSeq> = Vec::new();

        loop {
            // Admission + prefill (prioritized under max_active).
            while active.len() < self.cfg.max_active {
                let req = if active.is_empty() {
                    match self.queue.pop_timeout(Duration::from_millis(50)) {
                        Some(r) => r,
                        None if self.queue.is_closed() && self.queue.is_empty() => {
                            self.drain(&mut active, &mut mgr);
                            return;
                        }
                        None => break,
                    }
                } else {
                    match self.queue.try_pop() {
                        Some(r) => r,
                        None => break,
                    }
                };
                self.admit(req, &mut active, &mut mgr);
            }

            if active.is_empty() {
                if self.queue.is_closed() && self.queue.is_empty() {
                    return;
                }
                continue;
            }

            // One decode step for every active sequence.
            let mut finished = Vec::new();
            // Sequences whose decode errored: the error Reply has already
            // been sent, so they are torn down without a completion Reply.
            let mut failed = Vec::new();
            let mut stepping: Vec<(usize, &mut ActiveSeq)> = Vec::new();
            for (i, seq) in active.iter_mut().enumerate() {
                let tok = seq.next_token;
                if tok == EOS_ID || seq.tokens.len() >= seq.max_new || seq.cache.headroom() == 0 {
                    finished.push(i);
                } else {
                    stepping.push((i, seq));
                }
            }
            if !stepping.is_empty() {
                if self.cfg.batched_decode {
                    // All sequences in one backend call; caches update
                    // in place (no per-token cache serialization).
                    let tokens: Vec<i32> = stepping.iter().map(|(_, s)| s.next_token).collect();
                    let t0 = Instant::now();
                    let res = {
                        let mut caches: Vec<&mut SeqCache> =
                            stepping.iter_mut().map(|(_, s)| &mut s.cache).collect();
                        self.engine.decode_step_batch(&model, &mut caches, &tokens)
                    };
                    let dt = t0.elapsed().as_secs_f64() * 1e3;
                    match res {
                        Ok(steps) => {
                            self.metrics
                                .observe("decode_step_ms", dt / stepping.len() as f64);
                            self.metrics.observe("decode_batch_ms", dt);
                            for ((_, seq), step) in stepping.iter_mut().zip(steps) {
                                seq.next_token = seq.sampler.sample(&step.logits);
                                seq.tokens.push(seq.next_token);
                            }
                        }
                        Err(e) => {
                            // A batch-level failure fails every stepping
                            // sequence (per-seq errors surface the same
                            // way on the per-sequence path).
                            let err = format!("{e:#}");
                            for (i, seq) in stepping.iter() {
                                let _ = seq.reply.send(Reply {
                                    id: seq.id,
                                    text: String::new(),
                                    n_tokens: 0,
                                    ttft_ms: seq.ttft_ms,
                                    total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
                                    kept: seq.kept,
                                    error: Some(err.clone()),
                                });
                                failed.push(*i);
                            }
                        }
                    }
                } else {
                    for (i, seq) in stepping.iter_mut() {
                        let tok = seq.next_token;
                        let t0 = Instant::now();
                        match self.engine.decode_step(&model, &mut seq.cache, tok) {
                            Ok(step) => {
                                self.metrics
                                    .observe("decode_step_ms", t0.elapsed().as_secs_f64() * 1e3);
                                seq.next_token = seq.sampler.sample(&step.logits);
                                seq.tokens.push(seq.next_token);
                            }
                            Err(e) => {
                                let _ = seq.reply.send(Reply {
                                    id: seq.id,
                                    text: String::new(),
                                    n_tokens: 0,
                                    ttft_ms: seq.ttft_ms,
                                    total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
                                    kept: seq.kept,
                                    error: Some(format!("{e:#}")),
                                });
                                failed.push(*i);
                            }
                        }
                    }
                }
            }
            drop(stepping);
            let mut done: Vec<(usize, bool)> = finished
                .into_iter()
                .map(|i| (i, false))
                .chain(failed.into_iter().map(|i| (i, true)))
                .collect();
            done.sort_unstable();
            for (i, errored) in done.into_iter().rev() {
                let seq = active.swap_remove(i);
                if errored {
                    self.abort(seq, &mut mgr);
                } else {
                    self.complete(seq, &mut mgr);
                }
            }
        }
    }

    fn admit(&mut self, req: Request, active: &mut Vec<ActiveSeq>, mgr: &mut CacheManager) {
        let t0 = Instant::now();
        // prefill + evict + compact
        let res = (|| -> anyhow::Result<(SeqCache, Vec<f32>, usize)> {
            let pre = self.engine.prefill_for_method(&req.prompt, &req.method)?;
            let n_layers = self.engine.n_layers(&self.engine.cfg.model);
            let mut evcfg = self.engine.cfg.eviction;
            evcfg.budget = req.budget;
            let sel = req.method.select(&evcfg, n_layers, &pre.bundle);
            let cap = self
                .engine
                .rt
                .manifest()
                .decode_cap(&self.engine.cfg.model, sel.max_kept() + req.max_new)?;
            anyhow::ensure!(mgr.can_admit(cap), "kv pool exhausted");
            let cache =
                SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, req.prompt.len(), cap);
            Ok((cache, pre.logits, sel.max_kept()))
        })();
        match res {
            Ok((cache, logits, kept)) => {
                let mut sampler = if req.temperature > 0.0 {
                    Sampler::with_temperature(req.temperature, req.id)
                } else {
                    Sampler::greedy()
                };
                let first = sampler.sample(&logits);
                let ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.metrics.observe("ttft_ms", ttft_ms);
                self.metrics.incr("prefills", 1);
                mgr.reserve(req.id, cache.cap); // KV-pool accounting
                active.push(ActiveSeq {
                    id: req.id,
                    cache,
                    sampler,
                    tokens: vec![first],
                    next_token: first,
                    max_new: req.max_new,
                    reply: req.reply,
                    t_start: t0,
                    ttft_ms,
                    kept,
                });
            }
            Err(e) => {
                self.metrics.incr("prefill_errors", 1);
                let _ = req.reply.send(Reply {
                    id: req.id,
                    text: String::new(),
                    n_tokens: 0,
                    ttft_ms: 0.0,
                    total_ms: t0.elapsed().as_secs_f64() * 1e3,
                    kept: 0,
                    error: Some(format!("{e:#}")),
                });
            }
        }
    }

    /// Tear down a sequence whose error Reply was already sent: release
    /// its KV reservation without emitting a completion Reply or
    /// counting it as a completion.
    fn abort(&mut self, seq: ActiveSeq, mgr: &mut CacheManager) {
        mgr.release(seq.id);
        self.metrics.incr("decode_errors", 1);
    }

    fn complete(&mut self, seq: ActiveSeq, mgr: &mut CacheManager) {
        mgr.release(seq.id);
        self.metrics.incr("completions", 1);
        self.metrics.incr("generated_tokens", seq.tokens.len() as u64);
        let _ = seq.reply.send(Reply {
            id: seq.id,
            text: decode_until_eos(&seq.tokens),
            n_tokens: seq.tokens.len(),
            ttft_ms: seq.ttft_ms,
            total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
            kept: seq.kept,
            error: None,
        });
    }

    fn drain(&mut self, active: &mut Vec<ActiveSeq>, mgr: &mut CacheManager) {
        for seq in active.drain(..) {
            self.complete(seq, mgr);
        }
    }
}

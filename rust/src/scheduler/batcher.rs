//! Continuous-batching engine loop.
//!
//! Iteration-level scheduling in the Orca/vLLM mold, specialized to the
//! single-stream CPU PJRT backend: each loop iteration either (a) admits
//! and prefills one queued request if the KV pool has room, or (b)
//! advances every active sequence by one decode token, round-robin.
//! Prefill is prioritized while the active set is below `max_active`
//! (prefill-priority keeps TTFT low; decode fairness keeps TPOT flat).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{Engine, GenOptions};
use crate::kvcache::{manager::bytes_per_slot, CacheManager, SeqCache};
use crate::metrics::Metrics;
use crate::model::sampler::Sampler;
use crate::model::tokenizer::{decode_until_eos, EOS_ID};
use crate::scheduler::queue::{Reply, Request, RequestQueue};

#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Max concurrently active (decoding) sequences.
    pub max_active: usize,
    /// Global KV pool in token slots (admission control).
    pub kv_pool_slots: usize,
    pub kv_block_slots: usize,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig { max_active: 4, kv_pool_slots: 16 * 1152, kv_block_slots: 64 }
    }
}

struct ActiveSeq {
    id: u64,
    cache: SeqCache,
    sampler: Sampler,
    tokens: Vec<i32>,
    next_token: i32,
    max_new: usize,
    reply: std::sync::mpsc::Sender<Reply>,
    t_start: Instant,
    ttft_ms: f64,
    kept: usize,
}

pub struct EngineLoop {
    engine: Engine,
    cfg: LoopConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
}

impl EngineLoop {
    pub fn new(
        engine: Engine,
        cfg: LoopConfig,
        queue: Arc<RequestQueue>,
        metrics: Arc<Metrics>,
    ) -> EngineLoop {
        EngineLoop { engine, cfg, queue, metrics }
    }

    /// Run until the queue is closed and drained.
    pub fn run(mut self) {
        let model = self.engine.cfg.model.clone();
        let m = self.engine.rt.manifest().model(&model).expect("model");
        let _slot_bytes = bytes_per_slot(m.n_layers, m.n_kv_heads, m.head_dim);
        let mut mgr = CacheManager::new(self.cfg.kv_pool_slots, self.cfg.kv_block_slots);
        let mut active: Vec<ActiveSeq> = Vec::new();

        loop {
            // Admission + prefill (prioritized under max_active).
            while active.len() < self.cfg.max_active {
                let req = if active.is_empty() {
                    match self.queue.pop_timeout(Duration::from_millis(50)) {
                        Some(r) => r,
                        None if self.queue.is_closed() && self.queue.is_empty() => {
                            self.drain(&mut active, &mut mgr);
                            return;
                        }
                        None => break,
                    }
                } else {
                    match self.queue.try_pop() {
                        Some(r) => r,
                        None => break,
                    }
                };
                self.admit(req, &mut active, &mut mgr);
            }

            if active.is_empty() {
                if self.queue.is_closed() && self.queue.is_empty() {
                    return;
                }
                continue;
            }

            // One decode step for every active sequence (round-robin).
            let mut finished = Vec::new();
            for (i, seq) in active.iter_mut().enumerate() {
                let tok = seq.next_token;
                if tok == EOS_ID || seq.tokens.len() >= seq.max_new || seq.cache.headroom() == 0 {
                    finished.push(i);
                    continue;
                }
                let t0 = Instant::now();
                match self.engine.decode_step(&model, &mut seq.cache, tok) {
                    Ok(step) => {
                        self.metrics.observe("decode_step_ms", t0.elapsed().as_secs_f64() * 1e3);
                        seq.next_token = seq.sampler.sample(&step.logits);
                        seq.tokens.push(seq.next_token);
                    }
                    Err(e) => {
                        let _ = seq.reply.send(Reply {
                            id: seq.id,
                            text: String::new(),
                            n_tokens: 0,
                            ttft_ms: seq.ttft_ms,
                            total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
                            kept: seq.kept,
                            error: Some(format!("{e:#}")),
                        });
                        finished.push(i);
                    }
                }
            }
            for i in finished.into_iter().rev() {
                let seq = active.swap_remove(i);
                self.complete(seq, &mut mgr);
            }
        }
    }

    fn admit(&mut self, req: Request, active: &mut Vec<ActiveSeq>, mgr: &mut CacheManager) {
        let t0 = Instant::now();
        // prefill + evict + compact
        let res = (|| -> anyhow::Result<(SeqCache, Vec<f32>, usize)> {
            let pre = self.engine.prefill_for_method(&req.prompt, &req.method)?;
            let n_layers = self.engine.n_layers(&self.engine.cfg.model);
            let mut evcfg = self.engine.cfg.eviction;
            evcfg.budget = req.budget;
            let sel = req.method.select(&evcfg, n_layers, &pre.bundle);
            let cap = self
                .engine
                .rt
                .manifest()
                .decode_cap(&self.engine.cfg.model, sel.max_kept() + req.max_new)?;
            anyhow::ensure!(mgr.can_admit(cap), "kv pool exhausted");
            let cache =
                SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, req.prompt.len(), cap);
            Ok((cache, pre.logits, sel.max_kept()))
        })();
        match res {
            Ok((cache, logits, kept)) => {
                let mut sampler = if req.temperature > 0.0 {
                    Sampler::with_temperature(req.temperature, req.id)
                } else {
                    Sampler::greedy()
                };
                let first = sampler.sample(&logits);
                let ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.metrics.observe("ttft_ms", ttft_ms);
                self.metrics.incr("prefills", 1);
                mgr.reserve(req.id, cache.cap); // KV-pool accounting
                active.push(ActiveSeq {
                    id: req.id,
                    cache,
                    sampler,
                    tokens: vec![first],
                    next_token: first,
                    max_new: req.max_new,
                    reply: req.reply,
                    t_start: t0,
                    ttft_ms,
                    kept,
                });
            }
            Err(e) => {
                self.metrics.incr("prefill_errors", 1);
                let _ = req.reply.send(Reply {
                    id: req.id,
                    text: String::new(),
                    n_tokens: 0,
                    ttft_ms: 0.0,
                    total_ms: t0.elapsed().as_secs_f64() * 1e3,
                    kept: 0,
                    error: Some(format!("{e:#}")),
                });
            }
        }
    }

    fn complete(&mut self, seq: ActiveSeq, mgr: &mut CacheManager) {
        mgr.release(seq.id);
        self.metrics.incr("completions", 1);
        self.metrics.incr("generated_tokens", seq.tokens.len() as u64);
        let _ = seq.reply.send(Reply {
            id: seq.id,
            text: decode_until_eos(&seq.tokens),
            n_tokens: seq.tokens.len(),
            ttft_ms: seq.ttft_ms,
            total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
            kept: seq.kept,
            error: None,
        });
    }

    fn drain(&mut self, active: &mut Vec<ActiveSeq>, mgr: &mut CacheManager) {
        for seq in active.drain(..) {
            self.complete(seq, mgr);
        }
    }
}

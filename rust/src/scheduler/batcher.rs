//! Continuous-batching engine loop.
//!
//! Iteration-level scheduling in the Orca/vLLM mold, specialized to the
//! single-stream CPU backends: each loop iteration advances every active
//! sequence by one decode token *and* — with chunked prefill enabled —
//! at most one pending prompt by `prefill_chunk_tokens` tokens (mixed
//! prefill/decode batching). A long prompt therefore stalls active
//! decodes for one chunk per iteration instead of its whole prefill;
//! eviction/compaction is deferred to the final chunk so selection sees
//! full-prompt scores (bit-identical to monolithic prefill — see
//! `engine::chunked`). With `prefill_chunk_tokens = 0`, or on backends
//! without chunked-prefill support, admission falls back to monolithic
//! prefill: admit and fully prefill queued requests while the active set
//! is below `max_active`.
//!
//! KV memory is **paged** by default (`LoopConfig::paged_kv`): prompt
//! KV, decode caches and prefix-tree nodes are all block tables over one
//! shared [`crate::kvcache::KvArena`]. Compaction gathers kept rows into
//! freshly allocated blocks and frees the prompt's blocks immediately;
//! decode appends write only the tail block in place; a sequence that
//! fills its blocks mid-decode *grows* by another block (reclaiming
//! unpinned prefix-tree blocks first) instead of finishing early.
//! Admission charges actual allocated blocks, not dense-bucket
//! estimates. Set `paged_kv = false` (CLI `--dense-kv`) for the
//! historical dense caches — bit-identical outputs, more resident
//! memory (see `tests/paged.rs` and `bench_decode`).
//!
//! **Multi-tenant scheduling.** Requests carry a [`Priority`] class and
//! a tenant id; the queue pops highest class first. With
//! `quota_tokens > 0` each tenant's in-flight tokens (prompt + max_new)
//! are capped — over-quota tenants' requests wait in place without
//! blocking anyone else. Under KV pool pressure, instead of truncating,
//! the loop **preempts** the lowest-priority (then most recently
//! started) running sequence whose priority is strictly below the
//! requester's: its arena blocks move verbatim into a host-side
//! [`crate::kvcache::SpillStore`] and are restored bit-identically once
//! the pool has room (preempted sequences resume before new admissions,
//! unless a strictly higher-priority request is queued). Only when no
//! victim exists does a sequence finish with `finish_reason =
//! "kv_exhausted"` (+ `decode_truncated_total`), so single-priority
//! workloads behave exactly as before. With `stall_slo_ms > 0`,
//! admission of new prefill work is deferred while the recent
//! per-iteration decode stall p99 exceeds the SLO (`decode_stall_ms`
//! keeps recording either way; deferrals count in
//! `admission_deferred_total`).
//!
//! Decode dispatch is batched by default: all active sequences advance
//! in **one** backend call per iteration, with caches updated in place
//! instead of being serialized to and from the backend every token. Set
//! `LoopConfig::batched_decode = false` for the historical per-sequence
//! round-trip (kept for A/B benchmarking — see `bench_scheduler`).
//!
//! **Robustness.** Per-request failures degrade gracefully instead of
//! poisoning the loop: backend/compaction errors become
//! `finish_reason = "error"` replies, and every exit path — completion,
//! rejection, error, deadline, cancellation, shutdown — releases the
//! sequence's arena blocks, prefix pins, spill entries, and tenant
//! quota. Requests may carry a `deadline_ms` (checked at chunk and
//! decode-iteration boundaries; expiry finishes with
//! `finish_reason = "deadline"`, keeping any tokens already generated)
//! and a cooperative cancel flag set by the server on client disconnect
//! (`finish_reason = "cancelled"`). Transient spill-restore failures
//! retry with capped exponential backoff
//! (`restore_retry_base_ms`/`restore_retries`) and finally fall back to
//! a cold recompute — deterministic re-prefill plus token replay, which
//! rebuilds the exact pre-preemption KV state. A deterministic
//! [`FaultPlan`] (`LoopConfig::faults`, CLI `--fault-plan`, env
//! `LKV_FAULTS`) injects failures at each of these seams for chaos
//! testing; when unset every seam is a single null check. Counters:
//! `engine_errors_total`, `cancellations_total`,
//! `deadline_expired_total`, `restore_retries_total`,
//! `restore_cold_recomputes_total`; gauge: `quota_tokens_in_flight`.
//!
//! Exported latency metrics: `decode_stall_ms` (per-iteration decode
//! stall imposed by prefill work — one chunk, plus the final chunk's
//! deferred eviction/compaction, when chunked; a whole admission when
//! monolithic), `prefill_chunk_ms` (per-chunk cost), the
//! chunked-TTFT breakdown `chunked_ttft_ms` = `chunked_ttft_work_ms`
//! (this request's own prefill work) + `chunked_ttft_interleave_ms`
//! (time spent advancing other sequences' decodes between chunks),
//! `restore_ms` (spill-tier resume cost), and — with `tenants > 1` —
//! per-tenant `ttft_ms_tenant_<t>` histograms. Counters:
//! `preemptions_total`, `spill_blocks_total`, `restores_total`,
//! `restore_blocks_total`; gauges: `kv_spill_{seqs,blocks,bytes}`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{ChunkedPrefill, Engine, FinishReason, PrefillOutput, PrefixPlan, RequestStats};
use crate::eviction::spec::PolicyKnobs;
use crate::eviction::{DecisionSummary, Method};
use crate::faults::{FaultPlan, FaultSite};
use crate::kvcache::{
    manager::{bytes_per_slot, bytes_per_slot_dtype},
    CacheManager, KvDims, KvDtype, MatchKind, OwnerClass, PagedSeqCache, PrefixPin,
    RestoreOutcome, SeqCache,
};
use crate::metrics::Metrics;
use crate::model::sampler::Sampler;
use crate::model::tokenizer::{decode_until_eos, EOS_ID};
use crate::scheduler::queue::{Priority, Reply, Request, RequestQueue};
use crate::trace::{Phase, Tracer};

/// Recent-stall window length for the SLO admission gate.
const STALL_WINDOW: usize = 64;

/// Restore retry backoff ceiling (exponential from
/// `LoopConfig::restore_retry_base_ms`, capped here).
const RESTORE_BACKOFF_CAP_MS: u64 = 100;

/// Fault-plan *attempt* offset for decode-iteration seams. Prefill
/// seams use the chunk index directly (attempt `0..chunks`); decode
/// seams use `DECODE_FAULT_BASE + iteration` so the two never reuse a
/// roll for prompts under 100 chunks. `FaultPlan::touches(id, n)` with
/// `n ≥ DECODE_FAULT_BASE + max_new` covers both.
const DECODE_FAULT_BASE: u64 = 100;

fn ms_between(a: Instant, b: Instant) -> f64 {
    b.saturating_duration_since(a).as_secs_f64() * 1e3
}

fn past_deadline(deadline: Option<Instant>, now: Instant) -> bool {
    deadline.is_some_and(|d| now >= d)
}

#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Max concurrently active (decoding) sequences.
    pub max_active: usize,
    /// Global KV pool in token slots (admission control).
    pub kv_pool_slots: usize,
    pub kv_block_slots: usize,
    /// Page all KV (prompt, decode, prefix tree) through the shared
    /// block arena (vs dense per-sequence cap-sized tensors). Requires
    /// backend support; falls back to dense (with a warning) otherwise.
    pub paged_kv: bool,
    /// Advance all active sequences in one backend call per iteration
    /// (vs per-sequence decode round-trips).
    pub batched_decode: bool,
    /// Max prompt tokens prefilled per loop iteration (iteration-level
    /// mixed prefill/decode batching). 0 = monolithic prefill. Backends
    /// without chunked-prefill support fall back to monolithic
    /// regardless.
    pub prefill_chunk_tokens: usize,
    /// Cross-request prefix cache (radix-tree KV reuse over shared
    /// prompt prefixes). Requires chunked prefill; ignored (with a
    /// warning) when `prefill_chunk_tokens == 0` or the backend has no
    /// chunked-prefill support.
    pub prefix_cache: bool,
    /// KV-slot cap for the prefix tree out of the shared pool
    /// (0 = bounded only by the pool + LRU reclamation).
    pub prefix_cache_slots: usize,
    /// Declared tenant count (CLI `--tenants`). Only used for the
    /// per-tenant TTFT breakdown (`ttft_ms_tenant_<t>`): quotas apply
    /// to whatever tenant ids requests actually carry. 1 = the
    /// single-tenant default (no per-tenant histograms).
    pub tenants: usize,
    /// Per-tenant cap on in-flight tokens (`prompt + max_new`, CLI
    /// `--quota-tokens`); 0 = unlimited. A request larger than the
    /// whole quota is rejected outright rather than left to clog the
    /// queue.
    pub quota_tokens: usize,
    /// Defer admitting new prefill work while the recent per-iteration
    /// decode-stall p99 exceeds this (milliseconds); 0 = off.
    pub stall_slo_ms: f64,
    /// Preempt lower-priority sequences (KV spill-to-host) instead of
    /// truncating with `kv_exhausted` under pool pressure. Only strictly
    /// lower-priority victims are eligible, so single-priority
    /// workloads never preempt regardless of this flag.
    pub preemption: bool,
    /// Storage dtype of the paged KV arena (CLI `--kv-dtype`):
    /// `F32` (the bit-exact oracle, default), `F16`, or `U8` with
    /// per-(layer, KV-head, block) scale/zero-point. Dense caches
    /// (`--dense-kv`) stay f32 regardless.
    pub kv_dtype: KvDtype,
    /// Deterministic fault schedule (CLI `--fault-plan`, env
    /// `LKV_FAULTS`). None (the default) keeps every injection seam a
    /// single null check.
    pub faults: Option<Arc<FaultPlan>>,
    /// Spill-restore retries after a transient restore failure before
    /// falling back to cold recompute.
    pub restore_retries: u32,
    /// Base of the restore retry backoff (doubles per attempt, capped
    /// at [`RESTORE_BACKOFF_CAP_MS`]).
    pub restore_retry_base_ms: u64,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            max_active: 4,
            kv_pool_slots: 16 * 1152,
            kv_block_slots: 64,
            paged_kv: true,
            batched_decode: true,
            prefill_chunk_tokens: 0,
            prefix_cache: false,
            prefix_cache_slots: 0,
            tenants: 1,
            quota_tokens: 0,
            stall_slo_ms: 0.0,
            preemption: true,
            kv_dtype: KvDtype::F32,
            faults: None,
            restore_retries: 4,
            restore_retry_base_ms: 1,
        }
    }
}

/// One request's in-flight chunked prefill (at most one per loop).
struct PendingPrefill {
    req: Request,
    job: ChunkedPrefill,
    t_start: Instant,
    /// Cumulative prefill work time; TTFT minus this is the time this
    /// request spent waiting while decode steps were interleaved.
    work_ms: f64,
    /// Pinned prefix-tree path this job resumes from (released once the
    /// job finishes, after its new blocks are inserted).
    pin: Option<PrefixPin>,
    /// End of this request's last recorded span — the next span starts
    /// here, so spans tile the request's lifetime exactly.
    mark: Instant,
    /// Chunks stepped so far.
    chunks: usize,
    /// Submit → engine-loop pop.
    queue_ms: f64,
}

/// An active sequence's KV, in whichever layout the loop runs.
enum ActiveKv {
    Dense(SeqCache),
    Paged(PagedSeqCache),
}

impl ActiveKv {
    fn headroom(&self) -> usize {
        match self {
            ActiveKv::Dense(c) => c.headroom(),
            ActiveKv::Paged(c) => c.headroom(),
        }
    }
}

/// Everything needed to rebuild a sequence's KV from scratch when its
/// spilled blocks are unrecoverable: deterministic re-prefill +
/// re-selection, then a replay of the already-generated tokens.
struct RecomputeSpec {
    prompt: Vec<i32>,
    method: Method,
    budget: usize,
    knobs: PolicyKnobs,
}

/// The slice of a request `select_compact` needs — borrowed from a
/// live [`Request`] at admission, or from a sequence's
/// [`RecomputeSpec`] during a cold recompute.
struct SelectParams<'a> {
    id: u64,
    prompt_len: usize,
    method: &'a Method,
    budget: usize,
    knobs: &'a PolicyKnobs,
    max_new: usize,
    priority: Priority,
}

impl<'a> SelectParams<'a> {
    fn of(req: &'a Request) -> SelectParams<'a> {
        SelectParams {
            id: req.id,
            prompt_len: req.prompt.len(),
            method: &req.method,
            budget: req.budget,
            knobs: &req.knobs,
            max_new: req.max_new,
            priority: req.priority,
        }
    }
}

struct ActiveSeq {
    id: u64,
    cache: ActiveKv,
    sampler: Sampler,
    tokens: Vec<i32>,
    next_token: i32,
    max_new: usize,
    reply: std::sync::mpsc::Sender<Reply>,
    t_start: Instant,
    ttft_ms: f64,
    kept: usize,
    tenant: u32,
    priority: Priority,
    /// Tokens charged against the tenant's quota at admission
    /// (`prompt + max_new`), released when the sequence leaves.
    charge: usize,
    /// End of this sequence's last recorded span (lifecycle tiling).
    mark: Instant,
    /// Absolute deadline (from the request's `deadline_ms`); checked at
    /// decode-iteration boundaries and while parked in the spill tier.
    deadline: Option<Instant>,
    /// Cooperative cancel flag shared with the server front-end.
    cancel: Arc<AtomicBool>,
    /// Failed restore attempts since this sequence was last preempted.
    restore_attempts: u32,
    /// Earliest next restore try (exponential backoff after a
    /// transient restore failure); None = retry immediately.
    next_restore_at: Option<Instant>,
    recompute: RecomputeSpec,
    stats: RequestStats,
    eviction: Option<DecisionSummary>,
}

impl ActiveSeq {
    fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Lowest-priority (then most recently started) active paged sequence
/// strictly below `pri` — the preemption victim order. `exclude` is the
/// requesting sequence's index; `gone`/`finished` are ids logically
/// removed this iteration (already-picked victims, finishing sequences).
fn pick_victim(
    active: &[ActiveSeq],
    exclude: Option<usize>,
    gone: &[u64],
    finished: &[(u64, FinishReason)],
    pri: Priority,
) -> Option<usize> {
    active
        .iter()
        .enumerate()
        .filter(|(j, s)| {
            Some(*j) != exclude
                && s.priority < pri
                && matches!(s.cache, ActiveKv::Paged(_))
                && !gone.contains(&s.id)
                && !finished.iter().any(|(id, _)| *id == s.id)
        })
        .min_by(|(_, a), (_, b)| a.priority.cmp(&b.priority).then(b.t_start.cmp(&a.t_start)))
        .map(|(j, _)| j)
}

pub struct EngineLoop {
    engine: Engine,
    cfg: LoopConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
    /// Lifecycle span sink (`--trace-out` / `GET /trace/<id>`); None =
    /// tracing off, spans are skipped entirely.
    tracer: Option<Arc<Tracer>>,
    /// Resolved at `run`: `cfg.paged_kv` and the backend supports it.
    paged: bool,
    /// Last `STALL_WINDOW` per-iteration decode-stall values (zeros
    /// included, so the SLO gate recovers once prefill pressure stops).
    stall_window: VecDeque<f64>,
    /// In-flight quota tokens per tenant (only tracked with
    /// `quota_tokens > 0`).
    tenant_used: HashMap<u32, usize>,
    /// Resident bytes of one arena block in the configured `kv_dtype`
    /// (quantized payload + per-block scale/zero-point for u8).
    /// Resolved at `run` from the model's KV dims.
    block_bytes: usize,
    /// Resident bytes of one dense f32 KV slot (dense caches ignore
    /// `kv_dtype`).
    dense_slot_bytes: usize,
}

impl EngineLoop {
    pub fn new(
        engine: Engine,
        cfg: LoopConfig,
        queue: Arc<RequestQueue>,
        metrics: Arc<Metrics>,
    ) -> EngineLoop {
        EngineLoop {
            engine,
            cfg,
            queue,
            metrics,
            tracer: None,
            paged: false,
            stall_window: VecDeque::new(),
            tenant_used: HashMap::new(),
            block_bytes: 0,
            dense_slot_bytes: 0,
        }
    }

    /// Record request-lifecycle spans into `tracer`.
    pub fn with_tracer(mut self, tracer: Arc<Tracer>) -> EngineLoop {
        self.tracer = Some(tracer);
        self
    }

    fn span(&self, request_id: u64, phase: Phase, start: Instant, end: Instant) {
        if let Some(t) = &self.tracer {
            t.record(request_id, phase, start, end);
        }
    }

    fn note_stall(&mut self, ms: f64) {
        if self.stall_window.len() >= STALL_WINDOW {
            self.stall_window.pop_front();
        }
        self.stall_window.push_back(ms);
    }

    fn stall_p99(&self) -> f64 {
        if self.stall_window.is_empty() {
            return 0.0;
        }
        let mut v: Vec<f64> = self.stall_window.iter().copied().collect();
        v.sort_by(f64::total_cmp);
        let idx = ((v.len() as f64 * 0.99).ceil() as usize).clamp(1, v.len()) - 1;
        v[idx]
    }

    /// May a *new* request be admitted this iteration? Preempted
    /// sequences get their memory back first unless a strictly
    /// higher-priority request is waiting, and the stall SLO (when set)
    /// defers new prefill work while recent stalls are over budget.
    fn admit_gate(&self, active: &[ActiveSeq], preempted: &[ActiveSeq]) -> bool {
        if let Some(bp) = preempted.iter().map(|s| s.priority).max() {
            if !self.queue.peek_priority().is_some_and(|qp| qp > bp) {
                return false;
            }
        }
        if self.cfg.stall_slo_ms > 0.0
            && !active.is_empty()
            && self.stall_p99() > self.cfg.stall_slo_ms
        {
            if !self.queue.is_empty() {
                self.metrics.incr("admission_deferred_total", 1);
            }
            return false;
        }
        true
    }

    /// Pop the next admissible request (quota-aware: over-quota tenants
    /// are skipped without losing their place). The blocking form is
    /// only used when nothing is in flight — all quota charges are zero
    /// then, so the plain priority pop is equivalent.
    fn pop_ready(&self, timeout: Option<Duration>) -> Option<Request> {
        let quota = self.cfg.quota_tokens;
        if quota > 0 {
            let popped = self.queue.try_pop_where(|r| {
                let charge = r.prompt.len() + r.max_new;
                charge > quota
                    || self.tenant_used.get(&r.tenant).copied().unwrap_or(0) + charge <= quota
            });
            if popped.is_some() {
                return popped;
            }
        } else if let Some(r) = self.queue.try_pop() {
            return Some(r);
        }
        timeout.and_then(|t| self.queue.pop_timeout(t))
    }

    /// Charge the request against its tenant's quota; a request larger
    /// than the whole quota is rejected here (it could never run).
    fn charge_or_reject(&mut self, req: Request) -> Option<Request> {
        let quota = self.cfg.quota_tokens;
        if quota == 0 {
            return Some(req);
        }
        let charge = req.prompt.len() + req.max_new;
        *self.tenant_used.entry(req.tenant).or_default() += charge;
        if charge > quota {
            let t0 = Instant::now();
            self.span(req.id, Phase::Queue, req.submitted_at, t0);
            self.reject(
                req,
                t0,
                t0,
                anyhow::anyhow!("request needs {charge} tokens, over the per-tenant quota {quota}"),
            );
            return None;
        }
        Some(req)
    }

    fn release_tenant(&mut self, tenant: u32, charge: usize) {
        if self.cfg.quota_tokens == 0 {
            return;
        }
        if let Some(used) = self.tenant_used.get_mut(&tenant) {
            *used = used.saturating_sub(charge);
            if *used == 0 {
                self.tenant_used.remove(&tenant);
            }
        }
    }

    /// Admission-time gate, after the quota charge but before any
    /// prefill work: injected disconnects, cooperative cancellation,
    /// and already-expired deadlines. Returns `None` when the request
    /// was finished here.
    fn precheck_queued(&mut self, req: Request) -> Option<Request> {
        if let Some(plan) = &self.cfg.faults {
            if plan.fires(FaultSite::Disconnect, req.id, 0) {
                req.cancel.store(true, Ordering::Relaxed);
            }
        }
        let reason = if req.cancelled() {
            Some(FinishReason::Cancelled)
        } else if past_deadline(req.deadline(), Instant::now()) {
            Some(FinishReason::Deadline)
        } else {
            None
        };
        match reason {
            Some(r) => {
                self.finish_unstarted(req, r);
                None
            }
            None => Some(req),
        }
    }

    /// Terminate a request that never started prefilling (cancelled or
    /// expired while queued): release its quota charge and reply with
    /// the terminal reason — no error, no tokens.
    fn finish_unstarted(&mut self, req: Request, reason: FinishReason) {
        self.release_tenant(req.tenant, req.prompt.len() + req.max_new);
        match reason {
            FinishReason::Cancelled => self.metrics.incr("cancellations_total", 1),
            FinishReason::Deadline => self.metrics.incr("deadline_expired_total", 1),
            _ => {}
        }
        let now = Instant::now();
        self.span(req.id, Phase::Queue, req.submitted_at, now);
        self.span(req.id, Phase::Cancel, now, now);
        let _ = req.reply.send(Reply {
            id: req.id,
            text: String::new(),
            n_tokens: 0,
            ttft_ms: 0.0,
            total_ms: ms_between(req.submitted_at, now),
            kept: 0,
            finish_reason: reason,
            error: None,
            stats: RequestStats {
                queue_ms: ms_between(req.submitted_at, now),
                ..Default::default()
            },
            eviction: None,
        });
    }

    /// Spill strictly-lower-priority victims until `slots` are
    /// allocatable (admission-side preemption). Returns whether the
    /// pool can now satisfy the allocation.
    fn preempt_for(
        &self,
        mgr: &mut CacheManager,
        active: &mut Vec<ActiveSeq>,
        preempted: &mut Vec<ActiveSeq>,
        slots: usize,
        pri: Priority,
    ) -> bool {
        if !self.cfg.preemption || !self.paged {
            return mgr.can_admit(slots);
        }
        while !mgr.can_admit(slots) {
            let Some(j) = pick_victim(active, None, &[], &[], pri) else {
                return false;
            };
            let vid = active[j].id;
            let ActiveKv::Paged(c) = &active[j].cache else { unreachable!() };
            match mgr.spill_seq(vid, c) {
                Ok(n) => {
                    self.metrics.incr("preemptions_total", 1);
                    self.metrics.incr("spill_blocks_total", n as u64);
                    active[j].stats.spills += 1;
                    preempted.push(active.swap_remove(j));
                }
                Err(e) => {
                    log::warn!("preemption spill of seq {vid} failed: {e:#}");
                    return false;
                }
            }
        }
        true
    }

    /// Run until the queue is closed and drained.
    pub fn run(mut self) {
        let model = self.engine.cfg.model.clone();
        // A misconfigured model name is request-controlled input on the
        // server path (`--model`): it must fail the requests, never
        // abort the process.
        let dims = match self.engine.rt.manifest().model(&model) {
            Ok(m) => KvDims {
                n_layers: m.n_layers,
                n_kv_heads: m.n_kv_heads,
                head_dim: m.head_dim,
            },
            Err(e) => {
                let msg = format!("{e:#}");
                log::error!("engine loop cannot start: {msg}");
                self.metrics.incr("engine_errors_total", 1);
                self.queue.close();
                while let Some(req) = self.queue.try_pop() {
                    let t0 = Instant::now();
                    self.span(req.id, Phase::Queue, req.submitted_at, t0);
                    self.reject(req, t0, t0, anyhow::anyhow!("engine unavailable: {msg}"));
                }
                return;
            }
        };
        let dtype = self.cfg.kv_dtype;
        self.block_bytes = dtype.block_bytes(&dims, self.cfg.kv_block_slots.max(1));
        self.dense_slot_bytes = bytes_per_slot(dims.n_layers, dims.n_kv_heads, dims.head_dim);
        // Admission accounting is slot-denominated; the byte-denominated
        // capacity gauges must charge dtype-true stored bytes (including
        // the u8 per-block scale/zero-point overhead), not f32 sizes.
        let slot_bytes =
            bytes_per_slot_dtype(dims.n_layers, dims.n_kv_heads, dims.head_dim, dtype);
        self.metrics.set_gauge("kv_slot_bytes", slot_bytes as f64);
        let pool_blocks = self.cfg.kv_pool_slots.div_ceil(self.cfg.kv_block_slots.max(1));
        self.metrics.set_gauge("kv_pool_bytes", (pool_blocks * self.block_bytes) as f64);
        self.metrics.set_info("kv_cache_info", &[("kv_dtype", dtype.as_str())]);
        let mut mgr =
            CacheManager::with_dtype(self.cfg.kv_pool_slots, self.cfg.kv_block_slots, dtype);
        if let Some(plan) = &self.cfg.faults {
            mgr.set_faults(plan.clone());
            log::info!("fault injection enabled: {}", plan.source());
        }
        let mut active: Vec<ActiveSeq> = Vec::new();
        let mut preempted: Vec<ActiveSeq> = Vec::new();
        let mut pending: Option<PendingPrefill> = None;
        let chunked = self.cfg.prefill_chunk_tokens > 0
            && self.engine.rt.supports_chunked_prefill();
        // Logged once per run, not per admission: a chunked-prefill
        // request on a backend without support (e.g. the pjrt stub)
        // silently degrading every prompt would otherwise be invisible.
        if self.cfg.prefill_chunk_tokens > 0 && !chunked {
            log::warn!(
                "backend {} does not support chunked prefill; \
                 falling back to monolithic prefill for every request",
                self.engine.rt.backend_name()
            );
        }
        // Published once so the HTTP front-end can answer `/policies`
        // (and reject predictor requests early) without a manifest hop.
        self.metrics.set_gauge(
            "policy_predictor_loaded",
            if self.engine.rt.manifest().predictor(&model).is_some() { 1.0 } else { 0.0 },
        );
        self.paged = self.cfg.paged_kv && self.engine.rt.supports_paged_kv();
        if self.cfg.paged_kv && !self.paged {
            log::warn!(
                "backend {} does not support paged KV; \
                 falling back to dense per-sequence caches",
                self.engine.rt.backend_name()
            );
        }
        if !self.paged && dtype != KvDtype::F32 {
            log::warn!(
                "--kv-dtype {dtype} requires paged KV; \
                 dense per-sequence caches stay f32"
            );
        }
        if self.cfg.prefix_cache {
            if chunked {
                mgr.enable_prefix_cache(self.cfg.prefix_cache_slots);
            } else {
                log::warn!(
                    "prefix cache requires chunked prefill \
                     (--prefill-chunk > 0 and backend support); disabled"
                );
            }
        }

        loop {
            // Reap preempted sequences whose client vanished or whose
            // deadline passed while parked in the spill tier — they must
            // not wait on pool space to terminate.
            if !preempted.is_empty() {
                let now = Instant::now();
                let mut k = 0;
                while k < preempted.len() {
                    let reason = if preempted[k].cancelled() {
                        Some(FinishReason::Cancelled)
                    } else if past_deadline(preempted[k].deadline, now) {
                        Some(FinishReason::Deadline)
                    } else {
                        None
                    };
                    match reason {
                        Some(r) => {
                            let seq = preempted.remove(k);
                            self.complete(seq, r, &mut mgr);
                        }
                        None => k += 1,
                    }
                }
            }

            // Resume preempted sequences before admitting anything new:
            // they already paid their prefill, and restoring is a
            // verbatim host-buffer re-bind. Highest priority (then
            // oldest) first; stop at the first that doesn't fit. A
            // transient restore I/O failure backs off exponentially and
            // falls back to a cold recompute after `restore_retries`.
            if !preempted.is_empty() && active.len() < self.cfg.max_active {
                preempted
                    .sort_by(|a, b| b.priority.cmp(&a.priority).then(a.t_start.cmp(&b.t_start)));
                let mut k = 0;
                while active.len() < self.cfg.max_active && k < preempted.len() {
                    let t0 = Instant::now();
                    if preempted[k].next_restore_at.is_some_and(|at| t0 < at) {
                        k += 1; // still backing off after a failed restore
                        continue;
                    }
                    let id = preempted[k].id;
                    let outcome = match &mut preempted[k].cache {
                        ActiveKv::Paged(c) => mgr.try_restore_seq(id, c),
                        ActiveKv::Dense(_) => RestoreOutcome::NotSpilled,
                    };
                    match outcome {
                        RestoreOutcome::Restored(n) => {
                            let now = Instant::now();
                            self.metrics.observe("restore_ms", ms_between(t0, now));
                            self.metrics.incr("restores_total", 1);
                            self.metrics.incr("restore_blocks_total", n as u64);
                            let seq = &mut preempted[k];
                            // Parked-in-spill time tiles up to the restore.
                            self.span(id, Phase::Spill, seq.mark, t0);
                            self.span(id, Phase::Restore, t0, now);
                            seq.mark = now;
                            seq.stats.restores += 1;
                            seq.restore_attempts = 0;
                            seq.next_restore_at = None;
                            active.push(preempted.remove(k));
                        }
                        RestoreOutcome::NoSpace => break,
                        // Defensive: a sequence that was never actually
                        // spilled just rejoins the active set.
                        RestoreOutcome::NotSpilled => active.push(preempted.remove(k)),
                        RestoreOutcome::IoError => {
                            self.metrics.incr("restore_retries_total", 1);
                            let seq = &mut preempted[k];
                            seq.restore_attempts += 1;
                            if seq.restore_attempts > self.cfg.restore_retries {
                                log::warn!(
                                    "restore of seq {id} failed {} times; \
                                     falling back to cold recompute",
                                    seq.restore_attempts
                                );
                                let seq = preempted.remove(k);
                                self.cold_recompute(seq, &mut mgr, &mut active);
                            } else {
                                let shift = (seq.restore_attempts - 1).min(16);
                                let backoff = (self.cfg.restore_retry_base_ms << shift)
                                    .min(RESTORE_BACKOFF_CAP_MS);
                                seq.next_restore_at =
                                    Some(t0 + Duration::from_millis(backoff));
                                k += 1;
                            }
                        }
                    }
                }
                self.publish_cache_stats(&mgr);
            }

            // Admission. Chunked mode starts at most one incremental
            // prefill job; monolithic mode admits (fully prefills)
            // queued requests while the active set is below max_active.
            if chunked {
                if pending.is_none()
                    && active.len() < self.cfg.max_active
                    && self.admit_gate(&active, &preempted)
                {
                    let idle = active.is_empty() && preempted.is_empty();
                    let req = if idle {
                        self.pop_ready(Some(Duration::from_millis(50)))
                    } else {
                        self.pop_ready(None)
                    };
                    match req {
                        Some(req) => {
                            if let Some(req) = self.charge_or_reject(req) {
                                if let Some(req) = self.precheck_queued(req) {
                                    pending = self.begin_prefill(
                                        req,
                                        &mut mgr,
                                        &mut active,
                                        &mut preempted,
                                    );
                                }
                            }
                        }
                        None if idle && self.queue.is_closed() && self.queue.is_empty() => {
                            self.drain(&mut active, &mut preempted, &mut mgr);
                            return;
                        }
                        None => {}
                    }
                }
            } else {
                let stalling_before = !active.is_empty();
                let t_adm = Instant::now();
                let mut admitted = false;
                while active.len() < self.cfg.max_active && self.admit_gate(&active, &preempted) {
                    let idle = active.is_empty() && preempted.is_empty();
                    let req = if idle {
                        match self.pop_ready(Some(Duration::from_millis(50))) {
                            Some(r) => r,
                            None if self.queue.is_closed() && self.queue.is_empty() => {
                                self.drain(&mut active, &mut preempted, &mut mgr);
                                return;
                            }
                            None => break,
                        }
                    } else {
                        match self.pop_ready(None) {
                            Some(r) => r,
                            None => break,
                        }
                    };
                    if let Some(req) = self.charge_or_reject(req) {
                        if let Some(req) = self.precheck_queued(req) {
                            self.admit(req, &mut active, &mut preempted, &mut mgr);
                            admitted = true;
                        }
                    }
                }
                self.note_stall(if stalling_before && admitted {
                    t_adm.elapsed().as_secs_f64() * 1e3
                } else {
                    0.0
                });
            }

            // Reap an in-flight prefill whose client disconnected or
            // whose deadline expired — no more chunks are worth paying
            // for a reply nobody will read.
            if let Some(p) = pending.as_ref() {
                let now = Instant::now();
                let reason = if p.req.cancelled() {
                    Some(FinishReason::Cancelled)
                } else if past_deadline(p.req.deadline(), now) {
                    Some(FinishReason::Deadline)
                } else {
                    None
                };
                if let Some(r) = reason {
                    let p = pending.take().expect("pending just checked");
                    self.cancel_pending(p, r, &mut mgr);
                }
            }

            // Advance the in-flight prefill by one chunk; the decode step
            // below still runs this iteration (mixed batching).
            let stepped = match pending.as_mut() {
                Some(p) => {
                    let t0 = Instant::now();
                    let faulted = self.cfg.faults.as_ref().is_some_and(|f| {
                        f.fires(FaultSite::Backend, p.req.id, p.chunks as u64)
                    });
                    let stepped = if faulted {
                        Err(anyhow::anyhow!(
                            "injected backend fault (prefill chunk {})",
                            p.chunks
                        ))
                    } else if p.job.is_paged() {
                        let mut ctx = mgr.paged_ctx(p.req.id);
                        p.job.step_paged(&self.engine, &mut ctx)
                    } else {
                        p.job.step(&self.engine)
                    };
                    let now = Instant::now();
                    let dt = ms_between(t0, now);
                    p.work_ms += dt;
                    p.chunks += 1;
                    // The chunk span starts at the previous mark, so it
                    // also absorbs the interleaved decode time since the
                    // last chunk (lifecycle tiling; `work_ms` keeps the
                    // pure-work number for the TTFT breakdown).
                    self.span(p.req.id, Phase::PrefillChunk, p.mark, now);
                    p.mark = now;
                    self.metrics.observe("prefill_chunk_ms", dt);
                    Some((stepped, dt))
                }
                None => None,
            };
            // Per-iteration decode stall = this iteration's prefill work,
            // including the final chunk's deferred eviction/compaction —
            // symmetric with the monolithic path, which counts its whole
            // admission. Sequences activated this iteration don't count
            // as stalled.
            let stalling = !active.is_empty();
            match stepped {
                None => {
                    if chunked {
                        self.note_stall(0.0);
                    }
                }
                Some((Ok(false), dt)) => {
                    if stalling {
                        self.metrics.observe("decode_stall_ms", dt);
                    }
                    self.note_stall(if stalling { dt } else { 0.0 });
                }
                Some((Ok(true), dt)) => {
                    let p = pending.take().expect("pending job just stepped");
                    let t0 = Instant::now();
                    self.finish_chunked(p, &mut active, &mut preempted, &mut mgr);
                    let total = dt + t0.elapsed().as_secs_f64() * 1e3;
                    if stalling {
                        self.metrics.observe("decode_stall_ms", total);
                    }
                    self.note_stall(if stalling { total } else { 0.0 });
                }
                Some((Err(e), dt)) => {
                    let PendingPrefill { req, t_start, pin, mark, .. } =
                        pending.take().expect("pending job just stepped");
                    // Owner-scoped cleanup: frees every arena block the
                    // failed job charged to this request.
                    mgr.release(req.id);
                    if let Some(pin) = pin {
                        mgr.prefix_release(pin);
                    }
                    self.reject(req, t_start, mark, e);
                    if stalling {
                        self.metrics.observe("decode_stall_ms", dt);
                    }
                    self.note_stall(if stalling { dt } else { 0.0 });
                }
            }

            if active.is_empty() {
                if pending.is_none()
                    && preempted.is_empty()
                    && self.queue.is_closed()
                    && self.queue.is_empty()
                {
                    return;
                }
                // Nothing decodable and nothing restorable right now
                // (restore reported NoSpace, or admission is gated):
                // yield instead of spinning on the restore check.
                if pending.is_none() && !preempted.is_empty() {
                    std::thread::sleep(Duration::from_millis(2));
                }
                continue;
            }

            // Growth/finish pre-pass, by id (preemption moves sequences
            // out of `active`, so indices are assigned afterwards). A
            // sequence out of slots grows by a block; if the pool is dry
            // it preempts a strictly-lower-priority victim before being
            // given up on with `kv_exhausted`.
            let now_iter = Instant::now();
            let mut finished_ids: Vec<(u64, FinishReason)> = Vec::new();
            let mut victim_ids: Vec<u64> = Vec::new();
            // Sequences hit by an injected per-sequence backend fault:
            // torn down with an error Reply before the batch call, so
            // co-batched sequences' compute is untouched.
            let mut faulted_ids: Vec<u64> = Vec::new();
            let mut i = 0;
            while i < active.len() {
                let id = active[i].id;
                if victim_ids.contains(&id) {
                    i += 1;
                    continue;
                }
                let attempt = DECODE_FAULT_BASE + active[i].stats.decode_iters as u64;
                if let Some(plan) = &self.cfg.faults {
                    // Injected client disconnect flips the same
                    // cooperative flag the HTTP front-end sets, so it
                    // exercises the identical cancellation path.
                    if plan.fires(FaultSite::Disconnect, id, attempt) {
                        active[i].cancel.store(true, Ordering::Relaxed);
                    }
                }
                let tok = active[i].next_token;
                let done = if tok == EOS_ID {
                    Some(FinishReason::Eos)
                } else if active[i].tokens.len() >= active[i].max_new {
                    Some(FinishReason::Length)
                } else if active[i].cancelled() {
                    Some(FinishReason::Cancelled)
                } else if past_deadline(active[i].deadline, now_iter) {
                    Some(FinishReason::Deadline)
                } else if self
                    .cfg
                    .faults
                    .as_ref()
                    .is_some_and(|f| f.fires(FaultSite::Backend, id, attempt))
                {
                    faulted_ids.push(id);
                    i += 1;
                    continue;
                } else if active[i].cache.headroom() == 0 {
                    // An injected allocator failure fails the growth
                    // outright — no preemption rescue — so the request
                    // finishes `kv_exhausted` with what it generated.
                    if self
                        .cfg
                        .faults
                        .as_ref()
                        .is_some_and(|f| f.fires(FaultSite::Alloc, id, attempt))
                    {
                        Some(FinishReason::KvExhausted)
                    } else {
                        loop {
                            let grown = match &mut active[i].cache {
                                ActiveKv::Paged(c) => mgr.grow_paged(id, c),
                                ActiveKv::Dense(_) => false,
                            };
                            if grown {
                                if let ActiveKv::Paged(c) = &active[i].cache {
                                    let bs = mgr.block_size();
                                    let blocks = c.allocated_slots().div_ceil(bs);
                                    let s = &mut active[i].stats;
                                    s.peak_arena_blocks = s.peak_arena_blocks.max(blocks);
                                }
                                break None;
                            }
                            if !self.cfg.preemption
                                || !matches!(active[i].cache, ActiveKv::Paged(_))
                            {
                                break Some(FinishReason::KvExhausted);
                            }
                            let pri = active[i].priority;
                            let Some(j) =
                                pick_victim(&active, Some(i), &victim_ids, &finished_ids, pri)
                            else {
                                break Some(FinishReason::KvExhausted);
                            };
                            let vid = active[j].id;
                            let ActiveKv::Paged(vc) = &active[j].cache else { unreachable!() };
                            match mgr.spill_seq(vid, vc) {
                                Ok(n) => {
                                    self.metrics.incr("preemptions_total", 1);
                                    self.metrics.incr("spill_blocks_total", n as u64);
                                    active[j].stats.spills += 1;
                                    victim_ids.push(vid);
                                }
                                Err(e) => {
                                    log::warn!("preemption spill of seq {vid} failed: {e:#}");
                                    break Some(FinishReason::KvExhausted);
                                }
                            }
                        }
                    }
                } else {
                    None
                };
                if let Some(reason) = done {
                    if reason == FinishReason::KvExhausted {
                        self.metrics.incr("decode_truncated_total", 1);
                    }
                    finished_ids.push((id, reason));
                }
                i += 1;
            }
            if !victim_ids.is_empty() {
                for vid in &victim_ids {
                    let j = active.iter().position(|s| s.id == *vid).expect("victim in active");
                    preempted.push(active.swap_remove(j));
                }
                self.publish_cache_stats(&mgr);
            }
            if !faulted_ids.is_empty() {
                for fid in &faulted_ids {
                    // Tolerate a sequence that was also picked as a
                    // preemption victim this iteration.
                    let seq = if let Some(j) = active.iter().position(|s| s.id == *fid) {
                        active.swap_remove(j)
                    } else if let Some(j) = preempted.iter().position(|s| s.id == *fid) {
                        preempted.swap_remove(j)
                    } else {
                        continue;
                    };
                    self.fail_active(
                        seq,
                        anyhow::anyhow!("injected backend fault (decode)"),
                        &mut mgr,
                    );
                }
                self.publish_cache_stats(&mgr);
            }

            // One decode step for every remaining sequence.
            let mut finished: Vec<(usize, FinishReason)> = Vec::new();
            // Sequences whose decode errored: the error Reply has already
            // been sent, so they are torn down without a completion Reply.
            let mut failed = Vec::new();
            let mut stepping: Vec<(usize, &mut ActiveSeq)> = Vec::new();
            for (i, seq) in active.iter_mut().enumerate() {
                match finished_ids.iter().find(|(id, _)| *id == seq.id) {
                    Some((_, r)) => finished.push((i, *r)),
                    None => stepping.push((i, seq)),
                }
            }
            if !stepping.is_empty() {
                // Injected decode latency: perturbs timing only, never
                // tokens (the soak's identity check relies on this).
                if let Some(plan) = &self.cfg.faults {
                    let delay: u64 = stepping
                        .iter()
                        .map(|(_, s)| {
                            plan.delay_ms(s.id, DECODE_FAULT_BASE + s.stats.decode_iters as u64)
                        })
                        .sum();
                    if delay > 0 {
                        std::thread::sleep(Duration::from_millis(delay));
                    }
                }
                if self.cfg.batched_decode || self.paged {
                    // All sequences in one backend call; caches update
                    // in place (no per-token cache serialization). The
                    // paged path always dispatches batched — per-block
                    // writes make the per-sequence round-trip pointless.
                    let tokens: Vec<i32> = stepping.iter().map(|(_, s)| s.next_token).collect();
                    let t0 = Instant::now();
                    let res = if self.paged {
                        let mut caches: Vec<&mut PagedSeqCache> = stepping
                            .iter_mut()
                            .map(|(_, s)| match &mut s.cache {
                                ActiveKv::Paged(c) => c,
                                ActiveKv::Dense(_) => unreachable!("dense cache in paged loop"),
                            })
                            .collect();
                        let (arena, _) = mgr.paged_parts();
                        self.engine.decode_step_batch_paged(&model, arena, &mut caches, &tokens)
                    } else {
                        let mut caches: Vec<&mut SeqCache> = stepping
                            .iter_mut()
                            .map(|(_, s)| match &mut s.cache {
                                ActiveKv::Dense(c) => c,
                                ActiveKv::Paged(_) => unreachable!("paged cache in dense loop"),
                            })
                            .collect();
                        self.engine.decode_step_batch(&model, &mut caches, &tokens)
                    };
                    let dt = t0.elapsed().as_secs_f64() * 1e3;
                    match res {
                        Ok(steps) => {
                            self.metrics
                                .observe("decode_step_ms", dt / stepping.len() as f64);
                            self.metrics.observe("decode_batch_ms", dt);
                            let now = Instant::now();
                            for ((_, seq), step) in stepping.iter_mut().zip(steps) {
                                seq.next_token = seq.sampler.sample(&step.logits);
                                seq.tokens.push(seq.next_token);
                                seq.stats.decode_iters += 1;
                                self.span(seq.id, Phase::Decode, seq.mark, now);
                                seq.mark = now;
                            }
                        }
                        Err(e) => {
                            // A batch-level failure fails every stepping
                            // sequence (per-seq errors surface the same
                            // way on the per-sequence path).
                            let err = format!("{e:#}");
                            let now = Instant::now();
                            for (i, seq) in stepping.iter() {
                                self.span(seq.id, Phase::Error, seq.mark, now);
                                let _ = seq.reply.send(Reply {
                                    id: seq.id,
                                    text: String::new(),
                                    n_tokens: 0,
                                    ttft_ms: seq.ttft_ms,
                                    total_ms: ms_between(seq.t_start, now),
                                    kept: seq.kept,
                                    finish_reason: FinishReason::Error,
                                    error: Some(err.clone()),
                                    stats: seq.stats.clone(),
                                    eviction: seq.eviction.clone(),
                                });
                                failed.push(*i);
                            }
                        }
                    }
                } else {
                    for (i, seq) in stepping.iter_mut() {
                        let tok = seq.next_token;
                        let ActiveKv::Dense(cache) = &mut seq.cache else {
                            unreachable!("paged cache in dense loop")
                        };
                        let t0 = Instant::now();
                        match self.engine.decode_step(&model, cache, tok) {
                            Ok(step) => {
                                let now = Instant::now();
                                self.metrics.observe("decode_step_ms", ms_between(t0, now));
                                seq.next_token = seq.sampler.sample(&step.logits);
                                seq.tokens.push(seq.next_token);
                                seq.stats.decode_iters += 1;
                                self.span(seq.id, Phase::Decode, seq.mark, now);
                                seq.mark = now;
                            }
                            Err(e) => {
                                let now = Instant::now();
                                self.span(seq.id, Phase::Error, seq.mark, now);
                                let _ = seq.reply.send(Reply {
                                    id: seq.id,
                                    text: String::new(),
                                    n_tokens: 0,
                                    ttft_ms: seq.ttft_ms,
                                    total_ms: ms_between(seq.t_start, now),
                                    kept: seq.kept,
                                    finish_reason: FinishReason::Error,
                                    error: Some(format!("{e:#}")),
                                    stats: seq.stats.clone(),
                                    eviction: seq.eviction.clone(),
                                });
                                failed.push(*i);
                            }
                        }
                    }
                }
            }
            drop(stepping);
            let mut done: Vec<(usize, Option<FinishReason>)> = finished
                .into_iter()
                .map(|(i, r)| (i, Some(r)))
                .chain(failed.into_iter().map(|i| (i, None)))
                .collect();
            done.sort_unstable_by_key(|&(i, _)| i);
            for (i, reason) in done.into_iter().rev() {
                let seq = active.swap_remove(i);
                match reason {
                    Some(r) => self.complete(seq, r, &mut mgr),
                    None => self.abort(seq, &mut mgr),
                }
            }
        }
    }

    /// Monolithic admission: prefill + evict + compact in one blocking
    /// call (stalls every active decode for the whole prompt).
    fn admit(
        &mut self,
        req: Request,
        active: &mut Vec<ActiveSeq>,
        preempted: &mut Vec<ActiveSeq>,
        mgr: &mut CacheManager,
    ) {
        let stalling = !active.is_empty();
        let t0 = Instant::now();
        self.span(req.id, Phase::Queue, req.submitted_at, t0);
        let queue_ms = ms_between(req.submitted_at, t0);
        let injected = match &self.cfg.faults {
            Some(p) if p.fires(FaultSite::Backend, req.id, 0) => Some("backend"),
            Some(p) if p.fires(FaultSite::Alloc, req.id, 0) => Some("alloc"),
            _ => None,
        };
        if let Some(site) = injected {
            self.reject(req, t0, t0, anyhow::anyhow!("injected {site} fault (prefill)"));
            return;
        }
        // Split at the prefill/selection boundary so the Admission and
        // Eviction spans tile the blocking admission.
        let res = match self.engine.prefill_for_method(&req.prompt, &req.method) {
            Ok(pre) => {
                let t_mid = Instant::now();
                self.span(req.id, Phase::Admission, t0, t_mid);
                self.select_compact(&SelectParams::of(&req), pre, mgr, active, preempted)
                    .map(|ok| (ok, t_mid))
                    .map_err(|e| (e, t_mid))
            }
            Err(e) => Err((e, t0)),
        };
        if stalling {
            // every active decode waited for this entire admission
            self.metrics.observe("decode_stall_ms", t0.elapsed().as_secs_f64() * 1e3);
        }
        match res {
            Ok(((cache, logits, kept, decision), t_mid)) => {
                let stats = RequestStats { queue_ms, prefill_chunks: 1, ..Default::default() };
                self.activate(req, cache, logits, kept, t0, None, t_mid, stats, decision, active, mgr)
            }
            Err((e, mark)) => self.reject(req, t0, mark, e),
        }
        self.publish_cache_stats(mgr);
    }

    /// Start a chunked prefill job for `req` (None on immediate failure,
    /// after sending the error reply). With the prefix cache enabled,
    /// this is where admission matches the longest cached prefix, pins
    /// its blocks, and hands the engine a resume seed. Paged jobs charge
    /// the prompt's blocks to the request up front (reclaiming unpinned
    /// tree blocks, then preempting lower-priority sequences, under pool
    /// pressure).
    fn begin_prefill(
        &mut self,
        req: Request,
        mgr: &mut CacheManager,
        active: &mut Vec<ActiveSeq>,
        preempted: &mut Vec<ActiveSeq>,
    ) -> Option<PendingPrefill> {
        let t_start = Instant::now();
        self.span(req.id, Phase::Queue, req.submitted_at, t_start);
        if self.cfg.faults.as_ref().is_some_and(|f| f.fires(FaultSite::Alloc, req.id, 0)) {
            self.reject(
                req,
                t_start,
                t_start,
                anyhow::anyhow!("injected alloc fault (prefill admission)"),
            );
            return None;
        }
        let mut pin = None;
        let plan = if mgr.prefix_enabled() {
            match self.engine.prefix_pass_info(req.prompt.len(), &req.method) {
                Ok(info) => {
                    let m = mgr
                        .prefix_lookup(&info.model, &req.prompt, info.need_scores, info.resume_cap)
                        .expect("prefix cache enabled");
                    match m.kind {
                        MatchKind::Full => self.metrics.incr("prefix_hits", 1),
                        MatchKind::Partial => self.metrics.incr("prefix_partial_hits", 1),
                        MatchKind::Miss => self.metrics.incr("prefix_misses", 1),
                    }
                    if m.resume_len > 0 {
                        self.metrics.observe("prefix_resume_tokens", m.resume_len as f64);
                    }
                    if !m.pin.is_empty() {
                        pin = Some(m.pin);
                    }
                    Some(PrefixPlan { block_size: self.cfg.kv_block_slots, seed: m.seed })
                }
                // Unresumable request (e.g. a one-token prompt): record
                // anyway so future requests can match it? No — too short
                // to hold a single block either. Run it cold.
                Err(_) => None,
            }
        } else {
            None
        };
        let seeded = plan.as_ref().is_some_and(|p| p.seed.is_some());
        let begun = if self.paged {
            // Make room for the prompt's in-flight blocks before starting.
            if !mgr.can_admit(req.prompt.len()) {
                let freed = mgr.prefix_reclaim_for(req.prompt.len());
                if freed > 0 {
                    self.metrics.incr("prefix_reclaimed_blocks", freed as u64);
                }
                self.preempt_for(mgr, active, preempted, req.prompt.len(), req.priority);
            }
            mgr.tag(req.id, OwnerClass::Prefill);
            self.engine.chunked_prefill_begin_paged(
                &req.prompt,
                &req.method,
                self.cfg.prefill_chunk_tokens,
                plan,
                &mut mgr.paged_ctx(req.id),
            )
        } else {
            self.engine.chunked_prefill_begin_with_prefix(
                &req.prompt,
                &req.method,
                self.cfg.prefill_chunk_tokens,
                plan,
            )
        };
        let begun = match begun {
            // A seed the engine rejects (cache/engine contract drift)
            // must degrade to a cold prefill, not fail the request.
            Err(e) if seeded => {
                log::warn!("prefix-seeded prefill begin failed ({e:#}); retrying cold");
                if let Some(pin) = pin.take() {
                    mgr.prefix_release(pin);
                }
                if self.paged {
                    self.engine.chunked_prefill_begin_paged(
                        &req.prompt,
                        &req.method,
                        self.cfg.prefill_chunk_tokens,
                        None,
                        &mut mgr.paged_ctx(req.id),
                    )
                } else {
                    self.engine.chunked_prefill_begin(
                        &req.prompt,
                        &req.method,
                        self.cfg.prefill_chunk_tokens,
                    )
                }
            }
            other => other,
        };
        match begun {
            Ok(job) => {
                let now = Instant::now();
                self.span(req.id, Phase::Admission, t_start, now);
                let queue_ms = ms_between(req.submitted_at, t_start);
                Some(PendingPrefill {
                    req,
                    job,
                    t_start,
                    work_ms: 0.0,
                    pin,
                    mark: now,
                    chunks: 0,
                    queue_ms,
                })
            }
            Err(e) => {
                mgr.release(req.id);
                if let Some(pin) = pin {
                    mgr.prefix_release(pin);
                }
                self.reject(req, t_start, t_start, e);
                None
            }
        }
    }

    /// A chunked prefill finished its last chunk: evict + compact
    /// (deferred until now so selection sees full-prompt scores),
    /// activate the sequence, then insert the pass's newly recorded
    /// blocks into the prefix tree — never the compacted post-eviction
    /// cache — and unpin the matched path.
    fn finish_chunked(
        &mut self,
        p: PendingPrefill,
        active: &mut Vec<ActiveSeq>,
        preempted: &mut Vec<ActiveSeq>,
        mgr: &mut CacheManager,
    ) {
        let PendingPrefill { req, mut job, t_start, work_ms, pin, mark, chunks, queue_ms } = p;
        let records = job.take_prefix_records();
        let prompt = req.prompt.clone();
        let res = (|| -> anyhow::Result<(ActiveKv, Vec<f32>, usize, DecisionSummary)> {
            let pre = job.into_output()?;
            self.select_compact(&SelectParams::of(&req), pre, mgr, active, preempted)
        })();
        match res {
            Ok((cache, logits, kept, decision)) => {
                let stats =
                    RequestStats { queue_ms, prefill_chunks: chunks, ..Default::default() };
                self.activate(
                    req,
                    cache,
                    logits,
                    kept,
                    t_start,
                    Some(work_ms),
                    mark,
                    stats,
                    decision,
                    active,
                    mgr,
                );
                // Insert after the sequence reserved its own KV so the
                // tree only grows into genuinely spare pool space.
                if let Some(recs) = records {
                    let n = mgr.prefix_insert(&recs.model, &prompt, recs.records);
                    if n > 0 {
                        self.metrics.incr("prefix_inserted_blocks", n as u64);
                    }
                }
            }
            Err(e) => {
                // Owner-scoped cleanup (paged prompt blocks the failed
                // compaction may have left charged to this request).
                mgr.release(req.id);
                self.reject(req, t_start, mark, e);
            }
        }
        if let Some(pin) = pin {
            mgr.prefix_release(pin);
        }
        self.publish_cache_stats(mgr);
    }

    /// Shared post-prefill tail: selection with the request's budget,
    /// decode-cap sizing, KV-pool admission check (reclaiming unpinned
    /// prefix-tree blocks, then preempting lower-priority sequences,
    /// before failing), compaction. Paged mode gathers kept rows into
    /// freshly allocated blocks — straight from the prompt's arena
    /// blocks when the prefill was paged — and frees the prompt's
    /// blocks immediately; admission charges the blocks actually
    /// allocated, not the dense cap.
    fn select_compact(
        &self,
        req: &SelectParams<'_>,
        pre: PrefillOutput,
        mgr: &mut CacheManager,
        active: &mut Vec<ActiveSeq>,
        preempted: &mut Vec<ActiveSeq>,
    ) -> anyhow::Result<(ActiveKv, Vec<f32>, usize, DecisionSummary)> {
        let n_layers = self.engine.n_layers(&self.engine.cfg.model);
        let mut evcfg = self.engine.cfg.eviction;
        evcfg.budget = req.budget;
        req.knobs.apply(&mut evcfg);
        let sel = req.method.select(&evcfg, n_layers, &pre.bundle);
        let decision = DecisionSummary::new(req.method, &evcfg, &sel, &pre.bundle);
        let cap = self
            .engine
            .rt
            .manifest()
            .decode_cap(&self.engine.cfg.model, sel.max_kept() + req.max_new)?;
        if self.paged {
            let need = PagedSeqCache::blocks_for_selection(&sel.per_layer, mgr.block_size())
                * mgr.block_size();
            if !mgr.can_admit(need) {
                let freed = mgr.prefix_reclaim_for(need);
                if freed > 0 {
                    self.metrics.incr("prefix_reclaimed_blocks", freed as u64);
                }
                self.preempt_for(mgr, active, preempted, need, req.priority);
            }
            let dims = self.engine.kv_dims(&self.engine.cfg.model)?;
            let src_blocks = pre.blocks;
            let t_q = Instant::now();
            let res = {
                let (arena, alloc) = mgr.paged_parts();
                match &src_blocks {
                    Some(src) => PagedSeqCache::from_arena_selection(
                        arena,
                        alloc,
                        req.id,
                        dims,
                        src,
                        &sel.per_layer,
                        req.prompt_len,
                        cap,
                    ),
                    None => PagedSeqCache::from_dense_selection(
                        arena,
                        alloc,
                        req.id,
                        dims,
                        &pre.k,
                        &pre.v,
                        &sel.per_layer,
                        req.prompt_len,
                        cap,
                    ),
                }
            };
            // Tag the compaction's quantization work when the arena is
            // low-precision: a paged gather decodes source rows and
            // re-encodes them against destination block params
            // (dequant-requantize); a dense prefill output quantizes at
            // write time. Informational spans — they overlap the
            // enclosing Eviction span, so they are only recorded when a
            // low-precision dtype is actually in play (the f32 tiling
            // invariants in `tests/trace.rs` / `bench_serve` never see
            // them).
            if self.cfg.kv_dtype != KvDtype::F32 {
                let phase = if src_blocks.is_some() {
                    Phase::Requantize
                } else {
                    Phase::Quantize
                };
                self.span(req.id, phase, t_q, Instant::now());
            }
            // Free the prompt's blocks immediately, gather or no gather.
            if let Some(src) = src_blocks {
                mgr.paged_ctx(req.id).free_blocks(&src);
            }
            let cache = res?;
            mgr.tag(req.id, OwnerClass::Decode);
            Ok((ActiveKv::Paged(cache), pre.logits, sel.max_kept(), decision))
        } else {
            debug_assert!(pre.blocks.is_none(), "paged prefill output in a dense loop");
            if !mgr.can_admit(cap) {
                let freed = mgr.prefix_reclaim_for(cap);
                if freed > 0 {
                    self.metrics.incr("prefix_reclaimed_blocks", freed as u64);
                }
            }
            anyhow::ensure!(mgr.can_admit(cap), "kv pool exhausted");
            let cache =
                SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, req.prompt_len, cap);
            Ok((ActiveKv::Dense(cache), pre.logits, sel.max_kept(), decision))
        }
    }

    /// Mirror the pool + arena + prefix-tree + spill-tier occupancy into
    /// `/metrics` gauges.
    fn publish_cache_stats(&self, mgr: &CacheManager) {
        let s = mgr.stats();
        self.metrics.set_gauge("kv_active_seqs", s.active_seqs as f64);
        self.metrics.set_gauge("kv_live_slots", s.live_slots as f64);
        self.metrics.set_gauge("kv_used_blocks", s.used_blocks as f64);
        self.metrics.set_gauge("kv_free_blocks", s.free_blocks as f64);
        self.metrics.set_gauge("kv_peak_used_blocks", s.peak_used_blocks as f64);
        // Physical arena occupancy: resident bytes and the per-owner
        // breakdown (active decode vs prefix tree vs in-flight prefill).
        self.metrics.set_gauge("kv_arena_blocks_used", s.arena_blocks as f64);
        self.metrics.set_gauge("kv_arena_bytes", s.arena_bytes as f64);
        self.metrics.set_gauge("kv_arena_peak_bytes", s.arena_peak_bytes as f64);
        // Stored (dtype-true) vs logical (f32-equivalent) occupancy:
        // identical for `--kv-dtype f32`, resident ≈ 0.5×/0.26× logical
        // for f16/u8.
        self.metrics.set_gauge("kv_arena_bytes_resident", s.arena_bytes as f64);
        self.metrics.set_gauge("kv_arena_bytes_logical", s.arena_logical_bytes as f64);
        self.metrics.set_gauge("kv_arena_blocks_decode", s.blocks_decode as f64);
        self.metrics.set_gauge("kv_arena_blocks_prefix", s.blocks_prefix as f64);
        self.metrics.set_gauge("kv_arena_blocks_prefill", s.blocks_prefill as f64);
        // In-flight quota tokens across all tenants — must drain to
        // zero when nothing is running (leak canary for the soak).
        self.metrics.set_gauge(
            "quota_tokens_in_flight",
            self.tenant_used.values().sum::<usize>() as f64,
        );
        // Cold spill tier: preempted sequences parked host-side.
        let sp = mgr.spill_stats();
        self.metrics.set_gauge("kv_spill_seqs", sp.seqs as f64);
        self.metrics.set_gauge("kv_spill_blocks", sp.blocks as f64);
        self.metrics.set_gauge("kv_spill_bytes", sp.bytes as f64);
        self.metrics.set_gauge("kv_spill_peak_bytes", sp.peak_bytes as f64);
        // Backend kernel gauges: streaming-suite thread fan-out and the
        // peak per-call scratch estimate (O(T) on the default path; the
        // naive oracle's dense [H, T, T] probs dominate it instead).
        if let Some(ks) = self.engine.rt.kernel_stats() {
            self.metrics.set_gauge("prefill_threads_used", ks.threads as f64);
            self.metrics.set_gauge("prefill_scratch_peak_bytes", ks.peak_scratch_bytes as f64);
        }
        if let Some(p) = mgr.prefix_stats() {
            self.metrics.set_gauge("prefix_nodes", p.nodes as f64);
            self.metrics.set_gauge("prefix_blocks", p.blocks as f64);
            self.metrics.set_gauge("prefix_pinned_nodes", p.pinned_nodes as f64);
            // Tree-side cumulative totals: unlike the loop counters these
            // include blocks the tree reclaimed *internally* (insert-time
            // LRU eviction under its own --prefix-cache-slots cap).
            self.metrics.set_gauge("prefix_inserted_blocks_total", p.inserted_blocks as f64);
            self.metrics.set_gauge("prefix_reclaimed_blocks_total", p.reclaimed_blocks as f64);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn activate(
        &mut self,
        req: Request,
        cache: ActiveKv,
        logits: Vec<f32>,
        kept: usize,
        t_start: Instant,
        chunk_work_ms: Option<f64>,
        evict_start: Instant,
        mut stats: RequestStats,
        decision: DecisionSummary,
        active: &mut Vec<ActiveSeq>,
        mgr: &mut CacheManager,
    ) {
        let mut sampler = if req.temperature > 0.0 {
            Sampler::with_temperature(req.temperature, req.id)
        } else {
            Sampler::greedy()
        };
        let first = sampler.sample(&logits);
        let t_act = Instant::now();
        // Selection + compaction + activation tile from the end of the
        // last prefill span to the first-token instant.
        self.span(req.id, Phase::Eviction, evict_start, t_act);
        let ttft_ms = ms_between(t_start, t_act);
        stats.ttft_ms = ttft_ms;
        stats.evicted_per_layer = decision
            .kept_per_layer
            .iter()
            .map(|&k| decision.prompt_len.saturating_sub(k))
            .collect();
        match &cache {
            ActiveKv::Paged(c) => {
                stats.peak_arena_blocks = c.allocated_slots().div_ceil(mgr.block_size());
                stats.kv_dtype = mgr.kv_dtype().as_str().to_string();
                stats.resident_kv_bytes = stats.peak_arena_blocks * self.block_bytes;
            }
            ActiveKv::Dense(c) => {
                stats.kv_dtype = "f32".to_string();
                stats.resident_kv_bytes = c.cap * self.dense_slot_bytes;
            }
        }
        self.metrics.observe("ttft_ms", ttft_ms);
        if self.cfg.tenants > 1 {
            self.metrics.observe(&format!("ttft_ms_tenant_{}", req.tenant), ttft_ms);
        }
        self.metrics.incr("prefills", 1);
        if let Some(work) = chunk_work_ms {
            // chunked-TTFT breakdown: own prefill work vs time spent
            // interleaved with other sequences' decode steps
            self.metrics.incr("chunked_prefills", 1);
            self.metrics.observe("chunked_ttft_ms", ttft_ms);
            self.metrics.observe("chunked_ttft_work_ms", work);
            self.metrics.observe("chunked_ttft_interleave_ms", (ttft_ms - work).max(0.0));
        }
        if let ActiveKv::Dense(c) = &cache {
            // Dense caches are owned host tensors: charge the pool with
            // an accounting-only reservation of the full cap. (Paged
            // caches already charged their actual blocks at gather.)
            mgr.reserve(req.id, c.cap);
        }
        let deadline = req.deadline();
        let recompute = RecomputeSpec {
            prompt: req.prompt.clone(),
            method: req.method.clone(),
            budget: req.budget,
            knobs: req.knobs,
        };
        active.push(ActiveSeq {
            id: req.id,
            cache,
            sampler,
            tokens: vec![first],
            next_token: first,
            max_new: req.max_new,
            charge: req.prompt.len() + req.max_new,
            reply: req.reply,
            t_start,
            ttft_ms,
            kept,
            tenant: req.tenant,
            priority: req.priority,
            mark: t_act,
            deadline,
            cancel: req.cancel,
            restore_attempts: 0,
            next_restore_at: None,
            recompute,
            stats,
            eviction: Some(decision),
        });
    }

    /// Send the error reply for a request that never activated (also
    /// releases its tenant-quota charge). `mark` is the end of the
    /// request's last recorded span; the Finish span covers [mark, now]
    /// so even failed requests' spans tile their lifetime.
    fn reject(&mut self, req: Request, t_start: Instant, mark: Instant, e: anyhow::Error) {
        self.release_tenant(req.tenant, req.prompt.len() + req.max_new);
        self.metrics.incr("prefill_errors", 1);
        self.metrics.incr("engine_errors_total", 1);
        let now = Instant::now();
        self.span(req.id, Phase::Error, mark, now);
        let stats = RequestStats {
            queue_ms: ms_between(req.submitted_at, t_start),
            ..Default::default()
        };
        let _ = req.reply.send(Reply {
            id: req.id,
            text: String::new(),
            n_tokens: 0,
            ttft_ms: 0.0,
            total_ms: ms_between(t_start, now),
            kept: 0,
            finish_reason: FinishReason::Error,
            error: Some(format!("{e:#}")),
            stats,
            eviction: None,
        });
    }

    /// Tear down a sequence whose error Reply was already sent: release
    /// its KV without emitting a completion Reply or counting it as a
    /// completion.
    fn abort(&mut self, seq: ActiveSeq, mgr: &mut CacheManager) {
        mgr.drop_spilled(seq.id);
        mgr.release(seq.id);
        self.release_tenant(seq.tenant, seq.charge);
        self.metrics.incr("decode_errors", 1);
        self.metrics.incr("engine_errors_total", 1);
        self.publish_cache_stats(mgr);
    }

    /// Send the error Reply for an in-flight sequence, then tear it
    /// down. The `Phase::Error` span keeps failed lifecycles tiling.
    fn fail_active(&mut self, seq: ActiveSeq, e: anyhow::Error, mgr: &mut CacheManager) {
        let now = Instant::now();
        self.span(seq.id, Phase::Error, seq.mark, now);
        let _ = seq.reply.send(Reply {
            id: seq.id,
            text: String::new(),
            n_tokens: 0,
            ttft_ms: seq.ttft_ms,
            total_ms: ms_between(seq.t_start, now),
            kept: seq.kept,
            finish_reason: FinishReason::Error,
            error: Some(format!("{e:#}")),
            stats: seq.stats.clone(),
            eviction: seq.eviction.clone(),
        });
        self.abort(seq, mgr);
    }

    /// Abandon an in-flight chunked prefill (client disconnected or the
    /// deadline expired): release its prompt blocks, prefix pin, and
    /// quota charge, and answer with the terminal reason.
    fn cancel_pending(&mut self, p: PendingPrefill, reason: FinishReason, mgr: &mut CacheManager) {
        let PendingPrefill { req, t_start, pin, mark, queue_ms, .. } = p;
        mgr.release(req.id);
        if let Some(pin) = pin {
            mgr.prefix_release(pin);
        }
        self.release_tenant(req.tenant, req.prompt.len() + req.max_new);
        match reason {
            FinishReason::Cancelled => self.metrics.incr("cancellations_total", 1),
            FinishReason::Deadline => self.metrics.incr("deadline_expired_total", 1),
            _ => {}
        }
        let now = Instant::now();
        self.span(req.id, Phase::Cancel, mark, now);
        let _ = req.reply.send(Reply {
            id: req.id,
            text: String::new(),
            n_tokens: 0,
            ttft_ms: 0.0,
            total_ms: ms_between(t_start, now),
            kept: 0,
            finish_reason: reason,
            error: None,
            stats: RequestStats { queue_ms, ..Default::default() },
            eviction: None,
        });
        self.publish_cache_stats(mgr);
    }

    /// Rebuild a preempted sequence whose spilled KV is unrecoverable:
    /// drop the dead spill entry, re-run the deterministic prefill +
    /// selection (bit-identical to the original admission), then replay
    /// the already-generated tokens through single-sequence decode
    /// steps. The sampler state is untouched — replay feeds known
    /// tokens and discards logits — so future sampling continues
    /// exactly as if the restore had succeeded.
    fn cold_recompute(
        &mut self,
        mut seq: ActiveSeq,
        mgr: &mut CacheManager,
        active: &mut Vec<ActiveSeq>,
    ) {
        self.metrics.incr("restore_cold_recomputes_total", 1);
        let t0 = Instant::now();
        let id = seq.id;
        mgr.drop_spilled(id);
        mgr.release(id);
        let model = self.engine.cfg.model.clone();
        let rebuilt = self
            .engine
            .prefill_for_method(&seq.recompute.prompt, &seq.recompute.method)
            .and_then(|pre| {
                let params = SelectParams {
                    id,
                    prompt_len: seq.recompute.prompt.len(),
                    method: &seq.recompute.method,
                    budget: seq.recompute.budget,
                    knobs: &seq.recompute.knobs,
                    max_new: seq.max_new,
                    priority: seq.priority,
                };
                // Recompute may not preempt others to make room: pass
                // empty active/preempted sets so a dry pool fails here.
                let (cache, _logits, _kept, _decision) =
                    self.select_compact(&params, pre, mgr, &mut Vec::new(), &mut Vec::new())?;
                Ok(cache)
            });
        match rebuilt {
            Ok(cache) => seq.cache = cache,
            Err(e) => {
                self.fail_active(seq, e.context("cold recompute prefill"), mgr);
                return;
            }
        }
        // Replay every token that was already fed to the backend. The
        // last element of `tokens` is sampled-but-not-yet-fed, so it is
        // excluded — the next loop iteration feeds it as usual.
        let n_replay = seq.tokens.len().saturating_sub(1);
        for t in 0..n_replay {
            let tok = seq.tokens[t];
            if seq.cache.headroom() == 0 {
                let grown = match &mut seq.cache {
                    ActiveKv::Paged(c) => mgr.grow_paged(id, c),
                    ActiveKv::Dense(_) => false,
                };
                if !grown {
                    self.fail_active(
                        seq,
                        anyhow::anyhow!("kv pool exhausted during cold recompute"),
                        mgr,
                    );
                    return;
                }
            }
            let step = match &mut seq.cache {
                ActiveKv::Paged(c) => {
                    let (arena, _) = mgr.paged_parts();
                    let mut caches = vec![&mut *c];
                    self.engine
                        .decode_step_batch_paged(&model, arena, &mut caches, &[tok])
                        .map(|_| ())
                }
                ActiveKv::Dense(c) => self.engine.decode_step(&model, c, tok).map(|_| ()),
            };
            if let Err(e) = step {
                self.fail_active(seq, e.context("cold recompute replay"), mgr);
                return;
            }
        }
        let now = Instant::now();
        // The parked time tiles as Spill, the rebuild as Restore — the
        // same shape a successful restore records.
        self.span(id, Phase::Spill, seq.mark, t0);
        self.span(id, Phase::Restore, t0, now);
        seq.mark = now;
        seq.stats.restores += 1;
        seq.restore_attempts = 0;
        seq.next_restore_at = None;
        if let ActiveKv::Paged(c) = &seq.cache {
            let blocks = c.allocated_slots().div_ceil(mgr.block_size());
            seq.stats.peak_arena_blocks = seq.stats.peak_arena_blocks.max(blocks);
        }
        self.metrics.observe("restore_ms", ms_between(t0, now));
        active.push(seq);
    }

    fn complete(&mut self, mut seq: ActiveSeq, reason: FinishReason, mgr: &mut CacheManager) {
        if let ActiveKv::Paged(c) = &seq.cache {
            let blocks = c.allocated_slots().div_ceil(mgr.block_size());
            seq.stats.peak_arena_blocks = seq.stats.peak_arena_blocks.max(blocks);
            seq.stats.resident_kv_bytes = seq
                .stats
                .resident_kv_bytes
                .max(seq.stats.peak_arena_blocks * self.block_bytes);
        }
        mgr.drop_spilled(seq.id);
        mgr.release(seq.id);
        self.release_tenant(seq.tenant, seq.charge);
        self.publish_cache_stats(mgr);
        self.metrics.incr("completions", 1);
        self.metrics.incr("generated_tokens", seq.tokens.len() as u64);
        match reason {
            FinishReason::Cancelled => self.metrics.incr("cancellations_total", 1),
            FinishReason::Deadline => self.metrics.incr("deadline_expired_total", 1),
            _ => {}
        }
        let now = Instant::now();
        // Deadline/cancel exits replace the Finish span with Cancel so
        // successful lifecycles keep exactly one Finish.
        let phase = match reason {
            FinishReason::Deadline | FinishReason::Cancelled => Phase::Cancel,
            _ => Phase::Finish,
        };
        self.span(seq.id, phase, seq.mark, now);
        let _ = seq.reply.send(Reply {
            id: seq.id,
            text: decode_until_eos(&seq.tokens),
            n_tokens: seq.tokens.len(),
            ttft_ms: seq.ttft_ms,
            total_ms: ms_between(seq.t_start, now),
            kept: seq.kept,
            finish_reason: reason,
            error: None,
            stats: seq.stats,
            eviction: seq.eviction,
        });
    }

    fn drain(
        &mut self,
        active: &mut Vec<ActiveSeq>,
        preempted: &mut Vec<ActiveSeq>,
        mgr: &mut CacheManager,
    ) {
        for seq in active.drain(..).chain(preempted.drain(..)) {
            self.complete(seq, FinishReason::Stopped, mgr);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineConfig;
    use crate::model::tokenizer::encode;
    use crate::runtime::artifacts::default_artifacts_dir;
    use crate::util::proptest;
    use std::sync::mpsc::{channel, Receiver};

    const ALL_REASONS: [FinishReason; 7] = [
        FinishReason::Eos,
        FinishReason::Length,
        FinishReason::KvExhausted,
        FinishReason::Stopped,
        FinishReason::Deadline,
        FinishReason::Cancelled,
        FinishReason::Error,
    ];

    fn engine() -> Engine {
        Engine::new(&default_artifacts_dir(), EngineConfig::new("lkv-tiny")).expect("engine")
    }

    fn test_loop() -> EngineLoop {
        let queue = Arc::new(RequestQueue::new(8));
        let metrics = Arc::new(Metrics::new());
        let cfg = LoopConfig { quota_tokens: 1 << 20, ..LoopConfig::default() };
        let mut el = EngineLoop::new(engine(), cfg, queue, metrics);
        el.paged = true;
        el
    }

    /// Build an in-flight sequence the way admission does — real
    /// prefill, real selection/compaction into `mgr`'s arena, tenant
    /// quota charged — so teardown paths are tested against genuinely
    /// allocated state.
    fn make_seq(
        el: &mut EngineLoop,
        mgr: &mut CacheManager,
        id: u64,
        max_new: usize,
    ) -> (ActiveSeq, Receiver<Reply>) {
        let prompt = encode("lorem;ipsum;dolor;sit;amet;A7K=Q2Z;consectetur;A7K=", true, false);
        let method = Method::SnapKV;
        let pre = el.engine.prefill_for_method(&prompt, &method).expect("prefill");
        let knobs = PolicyKnobs::default();
        let params = SelectParams {
            id,
            prompt_len: prompt.len(),
            method: &method,
            budget: 16,
            knobs: &knobs,
            max_new,
            priority: Priority::Normal,
        };
        let (cache, logits, kept, decision) = el
            .select_compact(&params, pre, mgr, &mut Vec::new(), &mut Vec::new())
            .expect("select_compact");
        let charge = prompt.len() + max_new;
        *el.tenant_used.entry(id as u32).or_default() += charge;
        let mut sampler = Sampler::greedy();
        let first = sampler.sample(&logits);
        let (tx, rx) = channel();
        let now = Instant::now();
        let seq = ActiveSeq {
            id,
            cache,
            sampler,
            tokens: vec![first],
            next_token: first,
            max_new,
            reply: tx,
            t_start: now,
            ttft_ms: 0.0,
            kept,
            tenant: id as u32,
            priority: Priority::Normal,
            charge,
            mark: now,
            deadline: None,
            cancel: Arc::new(AtomicBool::new(false)),
            restore_attempts: 0,
            next_restore_at: None,
            recompute: RecomputeSpec {
                prompt: prompt.clone(),
                method: method.clone(),
                budget: 16,
                knobs: PolicyKnobs::default(),
            },
            stats: RequestStats::default(),
            eviction: Some(decision),
        };
        (seq, rx)
    }

    /// Leak property: whatever reason a sequence exits with, the pool
    /// returns to its pre-request block count and the tenant's quota
    /// charge is fully released — across randomized pool shapes.
    #[test]
    fn every_finish_reason_releases_blocks_and_quota() {
        let cfg = proptest::Config { cases: 5, max_size: 48, ..proptest::Config::new() };
        // RefCell: the harness only unwinds on assertion failure, never
        // mid-borrow (same pattern as tests/chunked.rs).
        let el_ref = std::panic::AssertUnwindSafe(std::cell::RefCell::new(test_loop()));
        proptest::check("finish reasons leak nothing", &cfg, move |rng, _size| {
            let el = &mut *el_ref.0.borrow_mut();
            let block = 1 + (rng.next_u64() as usize) % 32;
            let pool = 1024 + (rng.next_u64() as usize) % 1024;
            let mut mgr = CacheManager::new(pool, block);
            for (i, reason) in ALL_REASONS.iter().enumerate() {
                let (seq, rx) = make_seq(el, &mut mgr, i as u64, 4);
                assert!(mgr.stats().used_blocks > 0, "selection allocated no blocks");
                match reason {
                    FinishReason::Error => {
                        el.fail_active(seq, anyhow::anyhow!("injected test failure"), &mut mgr)
                    }
                    r => el.complete(seq, *r, &mut mgr),
                }
                let reply = rx.recv().expect("reply");
                assert_eq!(reply.finish_reason, *reason);
                let s = mgr.stats();
                assert_eq!(s.used_blocks, 0, "{reason:?} leaked pool blocks");
                assert_eq!(s.arena_blocks, 0, "{reason:?} leaked arena blocks");
                assert_eq!(mgr.spill_stats().blocks, 0, "{reason:?} leaked spill blocks");
                assert!(el.tenant_used.is_empty(), "{reason:?} leaked tenant quota");
            }
        });
    }

    /// Regression (satellite of the robustness PR): a misconfigured
    /// model name used to `expect()` in `run()` and abort the process;
    /// it must instead fail each queued request with an error reply and
    /// return cleanly.
    #[test]
    fn unknown_model_fails_requests_without_aborting() {
        let mut engine = engine();
        engine.cfg.model = "no-such-model".into();
        let queue = Arc::new(RequestQueue::new(4));
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = channel();
        queue
            .submit(Request {
                id: 0,
                prompt: encode("a;b;c", true, false),
                method: Method::SnapKV,
                budget: 8,
                max_new: 4,
                temperature: 0.0,
                knobs: PolicyKnobs::default(),
                tenant: 0,
                priority: Priority::Normal,
                submitted_at: Instant::now(),
                deadline_ms: 0,
                cancel: Arc::new(AtomicBool::new(false)),
                reply: tx,
            })
            .expect("submit");
        EngineLoop::new(engine, LoopConfig::default(), Arc::clone(&queue), Arc::clone(&metrics))
            .run();
        let reply = rx.recv().expect("reply");
        assert_eq!(reply.finish_reason, FinishReason::Error);
        let msg = reply.error.expect("error message");
        assert!(msg.contains("engine unavailable"), "unexpected error: {msg}");
        assert!(queue.is_closed(), "run() must close the queue on startup failure");
        assert!(metrics.counter("engine_errors_total") >= 1);
    }

    /// Deadlines and cancellation are honored before any prefill work:
    /// a request whose deadline expired in the queue finishes with
    /// `deadline`, a pre-cancelled one with `cancelled` — neither is an
    /// error, and the loop exits normally.
    #[test]
    fn queued_deadline_and_cancel_finish_cleanly() {
        let queue = Arc::new(RequestQueue::new(4));
        let metrics = Arc::new(Metrics::new());
        let stale = Instant::now()
            .checked_sub(Duration::from_millis(50))
            .unwrap_or_else(Instant::now);
        let cancelled = Arc::new(AtomicBool::new(true));
        let mut receivers = Vec::new();
        for (id, submitted_at, deadline_ms, cancel) in [
            (0u64, stale, 1u64, Arc::new(AtomicBool::new(false))),
            (1u64, Instant::now(), 0u64, Arc::clone(&cancelled)),
        ] {
            let (tx, rx) = channel();
            receivers.push(rx);
            queue
                .submit(Request {
                    id,
                    prompt: encode("a;b;c;d;e", true, false),
                    method: Method::SnapKV,
                    budget: 8,
                    max_new: 4,
                    temperature: 0.0,
                    knobs: PolicyKnobs::default(),
                    tenant: 0,
                    priority: Priority::Normal,
                    submitted_at,
                    deadline_ms,
                    cancel,
                    reply: tx,
                })
                .expect("submit");
        }
        queue.close();
        EngineLoop::new(engine(), LoopConfig::default(), Arc::clone(&queue), metrics).run();
        let expect = [FinishReason::Deadline, FinishReason::Cancelled];
        for (rx, want) in receivers.iter().zip(expect) {
            let reply = rx.recv().expect("reply");
            assert_eq!(reply.finish_reason, want);
            assert!(reply.error.is_none(), "terminal reasons are not errors");
            assert_eq!(reply.n_tokens, 0);
        }
    }
}

//! Continuous-batching engine loop.
//!
//! Iteration-level scheduling in the Orca/vLLM mold, specialized to the
//! single-stream CPU backends: each loop iteration advances every active
//! sequence by one decode token *and* — with chunked prefill enabled —
//! at most one pending prompt by `prefill_chunk_tokens` tokens (mixed
//! prefill/decode batching). A long prompt therefore stalls active
//! decodes for one chunk per iteration instead of its whole prefill;
//! eviction/compaction is deferred to the final chunk so selection sees
//! full-prompt scores (bit-identical to monolithic prefill — see
//! `engine::chunked`). With `prefill_chunk_tokens = 0`, or on backends
//! without chunked-prefill support, admission falls back to monolithic
//! prefill: admit and fully prefill queued requests while the active set
//! is below `max_active`.
//!
//! Decode dispatch is batched by default: all active sequences advance
//! in **one** backend call per iteration (`Engine::decode_step_batch`),
//! with caches updated in place instead of being
//! serialized to and from the backend every token. Set
//! `LoopConfig::batched_decode = false` for the historical per-sequence
//! round-trip (kept for A/B benchmarking — see `bench_scheduler`).
//!
//! Exported latency metrics: `decode_stall_ms` (per-iteration decode
//! stall imposed by prefill work — one chunk, plus the final chunk's
//! deferred eviction/compaction, when chunked; a whole admission when
//! monolithic), `prefill_chunk_ms` (per-chunk cost), and the
//! chunked-TTFT breakdown `chunked_ttft_ms` = `chunked_ttft_work_ms`
//! (this request's own prefill work) + `chunked_ttft_interleave_ms`
//! (time spent advancing other sequences' decodes between chunks).

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::engine::{ChunkedPrefill, Engine, PrefillOutput, PrefixPlan};
use crate::kvcache::{manager::bytes_per_slot, CacheManager, MatchKind, PrefixPin, SeqCache};
use crate::metrics::Metrics;
use crate::model::sampler::Sampler;
use crate::model::tokenizer::{decode_until_eos, EOS_ID};
use crate::scheduler::queue::{Reply, Request, RequestQueue};

#[derive(Debug, Clone)]
pub struct LoopConfig {
    /// Max concurrently active (decoding) sequences.
    pub max_active: usize,
    /// Global KV pool in token slots (admission control).
    pub kv_pool_slots: usize,
    pub kv_block_slots: usize,
    /// Advance all active sequences in one backend call per iteration
    /// (vs per-sequence decode round-trips).
    pub batched_decode: bool,
    /// Max prompt tokens prefilled per loop iteration (iteration-level
    /// mixed prefill/decode batching). 0 = monolithic prefill. Backends
    /// without chunked-prefill support fall back to monolithic
    /// regardless.
    pub prefill_chunk_tokens: usize,
    /// Cross-request prefix cache (radix-tree KV reuse over shared
    /// prompt prefixes). Requires chunked prefill; ignored (with a
    /// warning) when `prefill_chunk_tokens == 0` or the backend has no
    /// chunked-prefill support.
    pub prefix_cache: bool,
    /// KV-slot cap for the prefix tree out of the shared pool
    /// (0 = bounded only by the pool + LRU reclamation).
    pub prefix_cache_slots: usize,
}

impl Default for LoopConfig {
    fn default() -> Self {
        LoopConfig {
            max_active: 4,
            kv_pool_slots: 16 * 1152,
            kv_block_slots: 64,
            batched_decode: true,
            prefill_chunk_tokens: 0,
            prefix_cache: false,
            prefix_cache_slots: 0,
        }
    }
}

/// One request's in-flight chunked prefill (at most one per loop).
struct PendingPrefill {
    req: Request,
    job: ChunkedPrefill,
    t_start: Instant,
    /// Cumulative prefill work time; TTFT minus this is the time this
    /// request spent waiting while decode steps were interleaved.
    work_ms: f64,
    /// Pinned prefix-tree path this job resumes from (released once the
    /// job finishes, after its new blocks are inserted).
    pin: Option<PrefixPin>,
}

struct ActiveSeq {
    id: u64,
    cache: SeqCache,
    sampler: Sampler,
    tokens: Vec<i32>,
    next_token: i32,
    max_new: usize,
    reply: std::sync::mpsc::Sender<Reply>,
    t_start: Instant,
    ttft_ms: f64,
    kept: usize,
}

pub struct EngineLoop {
    engine: Engine,
    cfg: LoopConfig,
    queue: Arc<RequestQueue>,
    metrics: Arc<Metrics>,
}

impl EngineLoop {
    pub fn new(
        engine: Engine,
        cfg: LoopConfig,
        queue: Arc<RequestQueue>,
        metrics: Arc<Metrics>,
    ) -> EngineLoop {
        EngineLoop { engine, cfg, queue, metrics }
    }

    /// Run until the queue is closed and drained.
    pub fn run(mut self) {
        let model = self.engine.cfg.model.clone();
        let m = self.engine.rt.manifest().model(&model).expect("model");
        let _slot_bytes = bytes_per_slot(m.n_layers, m.n_kv_heads, m.head_dim);
        let mut mgr = CacheManager::new(self.cfg.kv_pool_slots, self.cfg.kv_block_slots);
        let mut active: Vec<ActiveSeq> = Vec::new();
        let mut pending: Option<PendingPrefill> = None;
        let chunked = self.cfg.prefill_chunk_tokens > 0
            && self.engine.rt.supports_chunked_prefill();
        // Logged once per run, not per admission: a chunked-prefill
        // request on a backend without support (e.g. the pjrt stub)
        // silently degrading every prompt would otherwise be invisible.
        if self.cfg.prefill_chunk_tokens > 0 && !chunked {
            log::warn!(
                "backend {} does not support chunked prefill; \
                 falling back to monolithic prefill for every request",
                self.engine.rt.backend_name()
            );
        }
        if self.cfg.prefix_cache {
            if chunked {
                mgr.enable_prefix_cache(self.cfg.prefix_cache_slots);
            } else {
                log::warn!(
                    "prefix cache requires chunked prefill \
                     (--prefill-chunk > 0 and backend support); disabled"
                );
            }
        }

        loop {
            // Admission. Chunked mode starts at most one incremental
            // prefill job; monolithic mode admits (fully prefills) as
            // many queued requests as fit under max_active.
            if chunked {
                if pending.is_none() && active.len() < self.cfg.max_active {
                    let idle = active.is_empty();
                    let req = if idle {
                        self.queue.pop_timeout(Duration::from_millis(50))
                    } else {
                        self.queue.try_pop()
                    };
                    match req {
                        Some(req) => pending = self.begin_prefill(req, &mut mgr),
                        None if idle && self.queue.is_closed() && self.queue.is_empty() => {
                            self.drain(&mut active, &mut mgr);
                            return;
                        }
                        None => {}
                    }
                }
            } else {
                while active.len() < self.cfg.max_active {
                    let req = if active.is_empty() {
                        match self.queue.pop_timeout(Duration::from_millis(50)) {
                            Some(r) => r,
                            None if self.queue.is_closed() && self.queue.is_empty() => {
                                self.drain(&mut active, &mut mgr);
                                return;
                            }
                            None => break,
                        }
                    } else {
                        match self.queue.try_pop() {
                            Some(r) => r,
                            None => break,
                        }
                    };
                    self.admit(req, &mut active, &mut mgr);
                }
            }

            // Advance the in-flight prefill by one chunk; the decode step
            // below still runs this iteration (mixed batching).
            let stepped = match pending.as_mut() {
                Some(p) => {
                    let t0 = Instant::now();
                    let stepped = p.job.step(&self.engine);
                    let dt = t0.elapsed().as_secs_f64() * 1e3;
                    p.work_ms += dt;
                    self.metrics.observe("prefill_chunk_ms", dt);
                    Some((stepped, dt))
                }
                None => None,
            };
            // Per-iteration decode stall = this iteration's prefill work,
            // including the final chunk's deferred eviction/compaction —
            // symmetric with the monolithic path, which counts its whole
            // admission. Sequences activated this iteration don't count
            // as stalled.
            let stalling = !active.is_empty();
            match stepped {
                None => {}
                Some((Ok(false), dt)) => {
                    if stalling {
                        self.metrics.observe("decode_stall_ms", dt);
                    }
                }
                Some((Ok(true), dt)) => {
                    let p = pending.take().expect("pending job just stepped");
                    let t0 = Instant::now();
                    self.finish_chunked(p, &mut active, &mut mgr);
                    if stalling {
                        let total = dt + t0.elapsed().as_secs_f64() * 1e3;
                        self.metrics.observe("decode_stall_ms", total);
                    }
                }
                Some((Err(e), dt)) => {
                    let p = pending.take().expect("pending job just stepped");
                    if let Some(pin) = p.pin {
                        mgr.prefix_release(pin);
                    }
                    self.reject(p.req, p.t_start, e);
                    if stalling {
                        self.metrics.observe("decode_stall_ms", dt);
                    }
                }
            }

            if active.is_empty() {
                if pending.is_none() && self.queue.is_closed() && self.queue.is_empty() {
                    return;
                }
                continue;
            }

            // One decode step for every active sequence.
            let mut finished = Vec::new();
            // Sequences whose decode errored: the error Reply has already
            // been sent, so they are torn down without a completion Reply.
            let mut failed = Vec::new();
            let mut stepping: Vec<(usize, &mut ActiveSeq)> = Vec::new();
            for (i, seq) in active.iter_mut().enumerate() {
                let tok = seq.next_token;
                if tok == EOS_ID || seq.tokens.len() >= seq.max_new || seq.cache.headroom() == 0 {
                    finished.push(i);
                } else {
                    stepping.push((i, seq));
                }
            }
            if !stepping.is_empty() {
                if self.cfg.batched_decode {
                    // All sequences in one backend call; caches update
                    // in place (no per-token cache serialization).
                    let tokens: Vec<i32> = stepping.iter().map(|(_, s)| s.next_token).collect();
                    let t0 = Instant::now();
                    let res = {
                        let mut caches: Vec<&mut SeqCache> =
                            stepping.iter_mut().map(|(_, s)| &mut s.cache).collect();
                        self.engine.decode_step_batch(&model, &mut caches, &tokens)
                    };
                    let dt = t0.elapsed().as_secs_f64() * 1e3;
                    match res {
                        Ok(steps) => {
                            self.metrics
                                .observe("decode_step_ms", dt / stepping.len() as f64);
                            self.metrics.observe("decode_batch_ms", dt);
                            for ((_, seq), step) in stepping.iter_mut().zip(steps) {
                                seq.next_token = seq.sampler.sample(&step.logits);
                                seq.tokens.push(seq.next_token);
                            }
                        }
                        Err(e) => {
                            // A batch-level failure fails every stepping
                            // sequence (per-seq errors surface the same
                            // way on the per-sequence path).
                            let err = format!("{e:#}");
                            for (i, seq) in stepping.iter() {
                                let _ = seq.reply.send(Reply {
                                    id: seq.id,
                                    text: String::new(),
                                    n_tokens: 0,
                                    ttft_ms: seq.ttft_ms,
                                    total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
                                    kept: seq.kept,
                                    error: Some(err.clone()),
                                });
                                failed.push(*i);
                            }
                        }
                    }
                } else {
                    for (i, seq) in stepping.iter_mut() {
                        let tok = seq.next_token;
                        let t0 = Instant::now();
                        match self.engine.decode_step(&model, &mut seq.cache, tok) {
                            Ok(step) => {
                                self.metrics
                                    .observe("decode_step_ms", t0.elapsed().as_secs_f64() * 1e3);
                                seq.next_token = seq.sampler.sample(&step.logits);
                                seq.tokens.push(seq.next_token);
                            }
                            Err(e) => {
                                let _ = seq.reply.send(Reply {
                                    id: seq.id,
                                    text: String::new(),
                                    n_tokens: 0,
                                    ttft_ms: seq.ttft_ms,
                                    total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
                                    kept: seq.kept,
                                    error: Some(format!("{e:#}")),
                                });
                                failed.push(*i);
                            }
                        }
                    }
                }
            }
            drop(stepping);
            let mut done: Vec<(usize, bool)> = finished
                .into_iter()
                .map(|i| (i, false))
                .chain(failed.into_iter().map(|i| (i, true)))
                .collect();
            done.sort_unstable();
            for (i, errored) in done.into_iter().rev() {
                let seq = active.swap_remove(i);
                if errored {
                    self.abort(seq, &mut mgr);
                } else {
                    self.complete(seq, &mut mgr);
                }
            }
        }
    }

    /// Monolithic admission: prefill + evict + compact in one blocking
    /// call (stalls every active decode for the whole prompt).
    fn admit(&mut self, req: Request, active: &mut Vec<ActiveSeq>, mgr: &mut CacheManager) {
        let stalling = !active.is_empty();
        let t0 = Instant::now();
        let res = (|| -> anyhow::Result<(SeqCache, Vec<f32>, usize)> {
            let pre = self.engine.prefill_for_method(&req.prompt, &req.method)?;
            self.select_compact(&req, pre, mgr)
        })();
        if stalling {
            // every active decode waited for this entire admission
            self.metrics.observe("decode_stall_ms", t0.elapsed().as_secs_f64() * 1e3);
        }
        match res {
            Ok((cache, logits, kept)) => {
                self.activate(req, cache, logits, kept, t0, None, active, mgr)
            }
            Err(e) => self.reject(req, t0, e),
        }
        self.publish_cache_stats(mgr);
    }

    /// Start a chunked prefill job for `req` (None on immediate failure,
    /// after sending the error reply). With the prefix cache enabled,
    /// this is where admission matches the longest cached prefix, pins
    /// its blocks, and hands the engine a resume seed.
    fn begin_prefill(&mut self, req: Request, mgr: &mut CacheManager) -> Option<PendingPrefill> {
        let t_start = Instant::now();
        let mut pin = None;
        let plan = if mgr.prefix_enabled() {
            match self.engine.prefix_pass_info(req.prompt.len(), &req.method) {
                Ok(info) => {
                    let m = mgr
                        .prefix_lookup(&info.model, &req.prompt, info.need_scores, info.resume_cap)
                        .expect("prefix cache enabled");
                    match m.kind {
                        MatchKind::Full => self.metrics.incr("prefix_hits", 1),
                        MatchKind::Partial => self.metrics.incr("prefix_partial_hits", 1),
                        MatchKind::Miss => self.metrics.incr("prefix_misses", 1),
                    }
                    if m.resume_len > 0 {
                        self.metrics.observe("prefix_resume_tokens", m.resume_len as f64);
                    }
                    if !m.pin.is_empty() {
                        pin = Some(m.pin);
                    }
                    Some(PrefixPlan { block_size: self.cfg.kv_block_slots, seed: m.seed })
                }
                // Unresumable request (e.g. a one-token prompt): record
                // anyway so future requests can match it? No — too short
                // to hold a single block either. Run it cold.
                Err(_) => None,
            }
        } else {
            None
        };
        let seeded = plan.as_ref().is_some_and(|p| p.seed.is_some());
        let begun = self.engine.chunked_prefill_begin_with_prefix(
            &req.prompt,
            &req.method,
            self.cfg.prefill_chunk_tokens,
            plan,
        );
        let begun = match begun {
            // A seed the engine rejects (cache/engine contract drift)
            // must degrade to a cold prefill, not fail the request.
            Err(e) if seeded => {
                log::warn!("prefix-seeded prefill begin failed ({e:#}); retrying cold");
                if let Some(pin) = pin.take() {
                    mgr.prefix_release(pin);
                }
                self.engine.chunked_prefill_begin(
                    &req.prompt,
                    &req.method,
                    self.cfg.prefill_chunk_tokens,
                )
            }
            other => other,
        };
        match begun {
            Ok(job) => Some(PendingPrefill { req, job, t_start, work_ms: 0.0, pin }),
            Err(e) => {
                if let Some(pin) = pin {
                    mgr.prefix_release(pin);
                }
                self.reject(req, t_start, e);
                None
            }
        }
    }

    /// A chunked prefill finished its last chunk: evict + compact
    /// (deferred until now so selection sees full-prompt scores),
    /// activate the sequence, then insert the pass's newly recorded
    /// blocks into the prefix tree — never the compacted post-eviction
    /// cache — and unpin the matched path.
    fn finish_chunked(
        &mut self,
        p: PendingPrefill,
        active: &mut Vec<ActiveSeq>,
        mgr: &mut CacheManager,
    ) {
        let PendingPrefill { req, mut job, t_start, work_ms, pin } = p;
        let records = job.take_prefix_records();
        let prompt = req.prompt.clone();
        let res = (|| -> anyhow::Result<(SeqCache, Vec<f32>, usize)> {
            let pre = job.into_output()?;
            self.select_compact(&req, pre, mgr)
        })();
        match res {
            Ok((cache, logits, kept)) => {
                self.activate(req, cache, logits, kept, t_start, Some(work_ms), active, mgr);
                // Insert after the sequence reserved its own KV so the
                // tree only grows into genuinely spare pool space.
                if let Some(recs) = records {
                    let n = mgr.prefix_insert(&recs.model, &prompt, recs.records);
                    if n > 0 {
                        self.metrics.incr("prefix_inserted_blocks", n as u64);
                    }
                }
            }
            Err(e) => self.reject(req, t_start, e),
        }
        if let Some(pin) = pin {
            mgr.prefix_release(pin);
        }
        self.publish_cache_stats(mgr);
    }

    /// Shared post-prefill tail: selection with the request's budget,
    /// decode-cap sizing, KV-pool admission check (reclaiming unpinned
    /// prefix-tree blocks before failing), compaction.
    fn select_compact(
        &self,
        req: &Request,
        pre: PrefillOutput,
        mgr: &mut CacheManager,
    ) -> anyhow::Result<(SeqCache, Vec<f32>, usize)> {
        let n_layers = self.engine.n_layers(&self.engine.cfg.model);
        let mut evcfg = self.engine.cfg.eviction;
        evcfg.budget = req.budget;
        let sel = req.method.select(&evcfg, n_layers, &pre.bundle);
        let cap = self
            .engine
            .rt
            .manifest()
            .decode_cap(&self.engine.cfg.model, sel.max_kept() + req.max_new)?;
        if !mgr.can_admit(cap) {
            let freed = mgr.prefix_reclaim_for(cap);
            if freed > 0 {
                self.metrics.incr("prefix_reclaimed_blocks", freed as u64);
            }
        }
        anyhow::ensure!(mgr.can_admit(cap), "kv pool exhausted");
        let cache =
            SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, req.prompt.len(), cap);
        Ok((cache, pre.logits, sel.max_kept()))
    }

    /// Mirror the pool + prefix-tree occupancy into `/metrics` gauges.
    fn publish_cache_stats(&self, mgr: &CacheManager) {
        let s = mgr.stats();
        self.metrics.set_gauge("kv_active_seqs", s.active_seqs as f64);
        self.metrics.set_gauge("kv_live_slots", s.live_slots as f64);
        self.metrics.set_gauge("kv_used_blocks", s.used_blocks as f64);
        self.metrics.set_gauge("kv_free_blocks", s.free_blocks as f64);
        self.metrics.set_gauge("kv_peak_used_blocks", s.peak_used_blocks as f64);
        if let Some(p) = mgr.prefix_stats() {
            self.metrics.set_gauge("prefix_nodes", p.nodes as f64);
            self.metrics.set_gauge("prefix_blocks", p.blocks as f64);
            self.metrics.set_gauge("prefix_pinned_nodes", p.pinned_nodes as f64);
            // Tree-side cumulative totals: unlike the loop counters these
            // include blocks the tree reclaimed *internally* (insert-time
            // LRU eviction under its own --prefix-cache-slots cap).
            self.metrics.set_gauge("prefix_inserted_blocks_total", p.inserted_blocks as f64);
            self.metrics.set_gauge("prefix_reclaimed_blocks_total", p.reclaimed_blocks as f64);
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn activate(
        &mut self,
        req: Request,
        cache: SeqCache,
        logits: Vec<f32>,
        kept: usize,
        t_start: Instant,
        chunk_work_ms: Option<f64>,
        active: &mut Vec<ActiveSeq>,
        mgr: &mut CacheManager,
    ) {
        let mut sampler = if req.temperature > 0.0 {
            Sampler::with_temperature(req.temperature, req.id)
        } else {
            Sampler::greedy()
        };
        let first = sampler.sample(&logits);
        let ttft_ms = t_start.elapsed().as_secs_f64() * 1e3;
        self.metrics.observe("ttft_ms", ttft_ms);
        self.metrics.incr("prefills", 1);
        if let Some(work) = chunk_work_ms {
            // chunked-TTFT breakdown: own prefill work vs time spent
            // interleaved with other sequences' decode steps
            self.metrics.incr("chunked_prefills", 1);
            self.metrics.observe("chunked_ttft_ms", ttft_ms);
            self.metrics.observe("chunked_ttft_work_ms", work);
            self.metrics.observe("chunked_ttft_interleave_ms", (ttft_ms - work).max(0.0));
        }
        mgr.reserve(req.id, cache.cap); // KV-pool accounting
        active.push(ActiveSeq {
            id: req.id,
            cache,
            sampler,
            tokens: vec![first],
            next_token: first,
            max_new: req.max_new,
            reply: req.reply,
            t_start,
            ttft_ms,
            kept,
        });
    }

    /// Send the error reply for a request that never activated.
    fn reject(&mut self, req: Request, t_start: Instant, e: anyhow::Error) {
        self.metrics.incr("prefill_errors", 1);
        let _ = req.reply.send(Reply {
            id: req.id,
            text: String::new(),
            n_tokens: 0,
            ttft_ms: 0.0,
            total_ms: t_start.elapsed().as_secs_f64() * 1e3,
            kept: 0,
            error: Some(format!("{e:#}")),
        });
    }

    /// Tear down a sequence whose error Reply was already sent: release
    /// its KV reservation without emitting a completion Reply or
    /// counting it as a completion.
    fn abort(&mut self, seq: ActiveSeq, mgr: &mut CacheManager) {
        mgr.release(seq.id);
        self.metrics.incr("decode_errors", 1);
    }

    fn complete(&mut self, seq: ActiveSeq, mgr: &mut CacheManager) {
        mgr.release(seq.id);
        self.publish_cache_stats(mgr);
        self.metrics.incr("completions", 1);
        self.metrics.incr("generated_tokens", seq.tokens.len() as u64);
        let _ = seq.reply.send(Reply {
            id: seq.id,
            text: decode_until_eos(&seq.tokens),
            n_tokens: seq.tokens.len(),
            ttft_ms: seq.ttft_ms,
            total_ms: seq.t_start.elapsed().as_secs_f64() * 1e3,
            kept: seq.kept,
            error: None,
        });
    }

    fn drain(&mut self, active: &mut Vec<ActiveSeq>, mgr: &mut CacheManager) {
        for seq in active.drain(..) {
            self.complete(seq, mgr);
        }
    }
}

//! # LookaheadKV — serving-stack reproduction
//!
//! Reproduction of *LookaheadKV: Fast and Accurate KV Cache Eviction by
//! Glimpsing into the Future without Generation* (Samsung Research, 2026)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the serving coordinator: request scheduling,
//!   continuous batching, a paged KV-cache manager, and the paper's
//!   contribution — a pluggable prefill KV-eviction framework
//!   ([`eviction`]) with LookaheadKV plus seven baseline policies.
//! * **L2/L1 (build-time Python, `python/compile/`)** — JAX transformer
//!   graphs with Pallas importance-score kernels, AOT-lowered to HLO text
//!   and executed through a pluggable [`runtime::Backend`]: the pure-Rust
//!   reference backend (default; offline, artifact-free) or PJRT
//!   (`pjrt` cargo feature).
//!
//! Python is never on the request path: the default build serves entirely
//! from the in-process reference backend; with artifacts built
//! (`make artifacts`) and the `pjrt` feature, the `lkv` binary serves the
//! AOT graphs instead.
//!
//! See `README.md` for the system inventory (backend feature matrix,
//! serving flags, bench/CI workflows) and `ROADMAP.md` for the
//! experiment index and open items.

// Host-tensor math is index-heavy by design, and the config builders
// intentionally mirror the Python dataclasses (no Default).
#![allow(clippy::needless_range_loop, clippy::new_without_default, clippy::too_many_arguments)]

pub mod costmodel;
pub mod engine;
pub mod eval;
pub mod eviction;
pub mod faults;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod trace;
pub mod util;
pub mod workload;

//! `bench_gate` — the CI bench-regression gate.
//!
//! Compares this run's `results/BENCH_*.json` artifacts against the
//! committed `baselines/BENCH_*.json` copies and exits non-zero when any
//! tracked benchmark regressed more than `--threshold` (default 25%)
//! beyond the run's median slowdown (the median-ratio calibration makes
//! the committed baselines meaningful across machines of different
//! absolute speed — see `util::bench::gate_compare`).
//!
//!   cargo run --release --bin bench_gate -- \
//!       --baseline-dir rust/baselines --results-dir rust/results \
//!       --threshold 0.25 --out rust/results/bench_gate_report.json
//!
//! `--inject <substring> --inject-factor 2.0` multiplies the matching
//! current entries before comparing — the self-test knob used to verify
//! the gate actually fails on a regression:
//!
//!   cargo run --release --bin bench_gate -- ... --inject select/SnapKV
//!
//! Refreshing baselines after an intentional perf change:
//!   LKV_BENCH_SMOKE=1 cargo bench --bench bench_eviction (…prefill, …scheduler)
//!   cp rust/results/BENCH_*.json rust/baselines/

use std::path::PathBuf;

use lookaheadkv::util::bench::{gate_compare, load_bench_entries, worst_rows_markdown, GateReport};
use lookaheadkv::util::cli::Args;
use lookaheadkv::util::json::Json;

fn main() {
    let args = Args::from_env(&["help"]);
    if args.has("help") {
        println!(
            "bench_gate --baseline-dir <dir> --results-dir <dir> [--threshold 0.25]\n\
             \x20          [--floor-ms 0.5] [--out report.json]\n\
             \x20          [--inject <name-substring> --inject-factor 2.0]"
        );
        return;
    }
    let baseline_dir = PathBuf::from(args.get_or("baseline-dir", "baselines"));
    let results_dir = PathBuf::from(args.get_or("results-dir", "results"));
    let threshold = args.f64("threshold", 0.25);
    let floor_ms = args.f64("floor-ms", 0.5);
    let inject = args.get("inject").map(str::to_string);
    let inject_factor = args.f64("inject-factor", 2.0);
    let out = args.get_or("out", "").to_string();

    let mut baseline_files: Vec<String> = match std::fs::read_dir(&baseline_dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect(),
        Err(e) => {
            eprintln!("bench_gate: cannot read {}: {e}", baseline_dir.display());
            std::process::exit(2);
        }
    };
    baseline_files.sort();
    if baseline_files.is_empty() {
        eprintln!("bench_gate: no BENCH_*.json baselines in {}", baseline_dir.display());
        std::process::exit(2);
    }

    let mut failed = false;
    let mut report = Json::obj();
    let mut reports: Vec<(String, GateReport)> = Vec::new();
    for file in &baseline_files {
        let base = match load_bench_entries(&baseline_dir.join(file)) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("bench_gate: {e:#}");
                failed = true;
                continue;
            }
        };
        let cur_path = results_dir.join(file);
        let mut cur = match load_bench_entries(&cur_path) {
            Ok(c) => c,
            Err(e) => {
                // a tracked bench that did not run at all is a failure,
                // not a silent pass
                eprintln!("bench_gate: {file}: current run missing ({e:#})");
                failed = true;
                continue;
            }
        };
        if let Some(pat) = &inject {
            for (name, ms) in cur.iter_mut() {
                if name.contains(pat.as_str()) {
                    println!("bench_gate: injecting {inject_factor}x into {name}");
                    *ms *= inject_factor;
                }
            }
        }
        let rep = gate_compare(&base, &cur, threshold, floor_ms);
        print_report(file, &rep);
        failed |= rep.failed();
        report.set(file, rep.to_json());
        reports.push((file.clone(), rep));
    }

    if !out.is_empty() {
        if let Some(dir) = PathBuf::from(&out).parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        match std::fs::write(&out, report.to_string()) {
            Ok(()) => println!("bench_gate: wrote {out}"),
            Err(e) => eprintln!("bench_gate: writing {out}: {e}"),
        }
    }
    if failed {
        // Surface the worst regressing rows where CI reviewers look
        // first: the job's step summary. Best-effort — absent or
        // unwritable $GITHUB_STEP_SUMMARY (e.g. a local run) is fine.
        if let Ok(summary) = std::env::var("GITHUB_STEP_SUMMARY") {
            if !summary.is_empty() {
                let md = worst_rows_markdown(&reports, 10);
                let write = std::fs::OpenOptions::new()
                    .create(true)
                    .append(true)
                    .open(&summary)
                    .and_then(|mut f| std::io::Write::write_all(&mut f, md.as_bytes()));
                match write {
                    Ok(()) => println!("bench_gate: appended worst rows to {summary}"),
                    Err(e) => eprintln!("bench_gate: step summary {summary}: {e}"),
                }
            }
        }
        eprintln!("bench_gate: FAILED (regression beyond {:.0}%)", threshold * 100.0);
        std::process::exit(1);
    }
    println!("bench_gate: OK ({} baseline files)", baseline_files.len());
}

fn print_report(file: &str, rep: &GateReport) {
    println!(
        "== {file}: {} tracked, calibration {:.3}x, threshold {:.0}% ==",
        rep.rows.len(),
        rep.calibration,
        rep.threshold * 100.0
    );
    for r in &rep.rows {
        let status = if r.regressed {
            "REGRESSED"
        } else if r.below_floor {
            "ok (sub-floor)"
        } else {
            "ok"
        };
        println!(
            "  {:<48} base {:>9.3} ms  cur {:>9.3} ms  norm {:>5.2}x  {status}",
            r.name, r.base_ms, r.cur_ms, r.norm_ratio
        );
    }
    for m in &rep.missing {
        println!("  {m:<48} WARNING: missing from current run");
    }
}

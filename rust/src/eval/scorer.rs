//! Scoring: exact match for retrieval-style answers, field-level F1 for
//! long-form extraction (LongProc).

use crate::workload::spec::{Sample, TaskFamily};

/// Score one generation in [0, 1]: exact-prefix match scores 1.0; partial
/// credit is per-character positional accuracy (the sub-answer analog of
/// LongBench's graded metrics, needed for resolution at this model scale).
pub fn score_sample(sample: &Sample, generated: &str) -> f64 {
    match sample.family {
        TaskFamily::LongProc => field_f1(&sample.answer, generated),
        _ => exact_prefix(&sample.answer, generated),
    }
}

fn exact_prefix(answer: &str, generated: &str) -> f64 {
    let g = generated.trim_end();
    if g.starts_with(answer) {
        return 1.0;
    }
    char_positional(answer, g)
}

/// Fraction of answer characters reproduced at the right position.
pub fn char_positional(answer: &str, generated: &str) -> f64 {
    if answer.is_empty() {
        return 0.0;
    }
    let a: Vec<char> = answer.chars().collect();
    let g: Vec<char> = generated.chars().collect();
    let hits = a.iter().zip(g.iter()).filter(|(x, y)| x == y).count();
    hits as f64 / a.len() as f64
}

/// F1 over `NAME\tVAL;` fields (order-insensitive multiset match).
pub fn field_f1(answer: &str, generated: &str) -> f64 {
    let want: Vec<&str> = answer.split(';').filter(|s| !s.is_empty()).collect();
    let got: Vec<&str> = generated.split(';').filter(|s| !s.is_empty()).collect();
    if want.is_empty() {
        return if got.is_empty() { 1.0 } else { 0.0 };
    }
    if got.is_empty() {
        return 0.0;
    }
    let mut remaining = want.clone();
    let mut hits = 0usize;
    for g in &got {
        if let Some(i) = remaining.iter().position(|w| w == g) {
            remaining.swap_remove(i);
            hits += 1;
        }
    }
    let p = hits as f64 / got.len() as f64;
    let r = hits as f64 / want.len() as f64;
    if p + r == 0.0 {
        0.0
    } else {
        2.0 * p * r / (p + r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::TaskFamily;

    fn kv_sample(ans: &str) -> Sample {
        Sample {
            family: TaskFamily::Kv,
            context: String::new(),
            query: String::new(),
            answer: ans.to_string(),
            turns: vec![],
        }
    }

    #[test]
    fn exact_match_scores() {
        assert_eq!(score_sample(&kv_sample("Q2Z"), "Q2Z"), 1.0);
        assert_eq!(score_sample(&kv_sample("Q2Z"), "Q2Zextra"), 1.0);
        let partial = score_sample(&kv_sample("Q2Z"), "Q2X");
        assert!((partial - 2.0 / 3.0).abs() < 1e-9, "{partial}");
        assert_eq!(score_sample(&kv_sample("Q2Z"), "xyz"), 0.0);
    }

    #[test]
    fn char_positional_basics() {
        assert_eq!(char_positional("ABC", "ABC"), 1.0);
        assert_eq!(char_positional("ABC", "AXC"), 2.0 / 3.0);
        assert_eq!(char_positional("ABC", ""), 0.0);
    }

    #[test]
    fn f1_partial_credit() {
        let ans = "A1B\tX2Y;C3D\tZ4W;";
        assert_eq!(field_f1(ans, "A1B\tX2Y;C3D\tZ4W;"), 1.0);
        let half = field_f1(ans, "A1B\tX2Y;");
        assert!((half - 2.0 / 3.0).abs() < 1e-9, "{half}");
        assert_eq!(field_f1(ans, "nope"), 0.0);
    }

    #[test]
    fn f1_order_insensitive() {
        let ans = "A\t1;B\t2;";
        assert_eq!(field_f1(ans, "B\t2;A\t1;"), 1.0);
    }
}

//! Paper-style table printing + JSON result persistence.

use std::fmt::Write as _;

use super::runner::MethodScore;
use crate::util::json::Json;

/// Render a score grid: rows = methods, cols = the sweep variable.
pub fn score_grid(
    title: &str,
    col_label: &str,
    cols: &[String],
    rows: &[(String, Vec<f64>)],
) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "== {title} ==");
    let _ = write!(out, "{:<18}", format!("method \\ {col_label}"));
    for c in cols {
        let _ = write!(out, "{c:>10}");
    }
    let _ = writeln!(out);
    for (name, vals) in rows {
        let _ = write!(out, "{name:<18}");
        for v in vals {
            let _ = write!(out, "{v:>10.3}");
        }
        let _ = writeln!(out);
    }
    out
}

pub fn results_to_json(scores: &[MethodScore]) -> Json {
    Json::Arr(
        scores
            .iter()
            .map(|s| {
                let mut fams = Json::obj();
                for (f, v) in &s.per_family {
                    fams.set(f, (*v).into());
                }
                Json::from_pairs(vec![
                    ("method", s.method.as_str().into()),
                    ("suite", s.suite.as_str().into()),
                    ("budget", s.budget.into()),
                    ("score", s.score.into()),
                    ("per_family", fams),
                    ("ttft_ms", s.ttft_ms_mean.into()),
                    ("forward_ms", s.forward_ms_mean.into()),
                    ("overhead_ms", s.overhead_ms_mean.into()),
                    ("decode_ms_per_tok", s.decode_ms_per_tok.into()),
                    ("n", s.n.into()),
                ])
            })
            .collect(),
    )
}

/// Write a results JSON file under `results/`.
pub fn save_results(name: &str, value: &Json) {
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{name}.json");
    if std::fs::write(&path, value.to_string()).is_ok() {
        println!("[results] wrote {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_renders() {
        let s = score_grid(
            "t",
            "budget",
            &["16".into(), "32".into()],
            &[("SnapKV".into(), vec![0.5, 0.75])],
        );
        assert!(s.contains("SnapKV"));
        assert!(s.contains("0.750"));
    }
}

//! Suite runner: evaluate methods × budgets over a workload suite.

use anyhow::Result;

use super::scorer::score_sample;
use crate::engine::{Engine, GenOptions};
use crate::eviction::Method;
use crate::model::tokenizer::encode;
use crate::util::stats::summarize;
use crate::workload::Suite;

#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub budget: usize,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
}

impl EvalConfig {
    pub fn new(budget: usize) -> EvalConfig {
        EvalConfig { budget, max_new: 16, temperature: 0.0, seed: 0 }
    }
}

#[derive(Debug, Clone)]
pub struct MethodScore {
    pub method: String,
    pub suite: String,
    pub budget: usize,
    pub score: f64,
    pub per_family: Vec<(String, f64)>,
    pub ttft_ms_mean: f64,
    pub forward_ms_mean: f64,
    pub overhead_ms_mean: f64,
    pub decode_ms_per_tok: f64,
    pub n: usize,
}

/// Evaluate one method over a suite. Multi-turn samples re-prefill with
/// the accumulated history per turn (each turn's score averaged in).
pub fn run_suite(
    engine: &Engine,
    suite: &Suite,
    method: &Method,
    cfg: &EvalConfig,
) -> Result<MethodScore> {
    let mut scores: Vec<(String, f64)> = Vec::new();
    let mut ttfts = Vec::new();
    let mut fwd = Vec::new();
    let mut ovh = Vec::new();
    let mut dec = Vec::new();
    for (i, sample) in suite.samples.iter().enumerate() {
        let max_new = (sample.answer.len() + 4).max(cfg.max_new);
        let opts = GenOptions {
            budget: cfg.budget,
            max_new,
            temperature: cfg.temperature,
            seed: cfg.seed ^ i as u64,
            collect_gt: false,
            knobs: Default::default(),
        };
        let prompt = encode(&sample.prompt(), true, false);
        let res = engine.generate(&prompt, method, &opts)?;
        let mut s = score_sample(sample, &res.text);
        ttfts.push(res.ttft_ms);
        fwd.push(res.forward_ms);
        ovh.push(res.eviction_overhead_ms);
        dec.push(res.decode_ms_per_token());
        // extra conversation turns: history = ctx + q1 + a1(ref) + q2 ...
        if !sample.turns.is_empty() {
            let mut history = sample.prompt();
            history.push_str(&sample.answer);
            history.push(';');
            let mut tscores = vec![s];
            for (q, a) in &sample.turns {
                history.push_str(q);
                let prompt2 = encode(&history, true, false);
                let res2 = engine.generate(&prompt2, method, &opts)?;
                tscores
                    .push(if res2.text.trim_end().starts_with(a.as_str()) { 1.0 } else { 0.0 });
                history.push_str(a);
                history.push(';');
            }
            s = tscores.iter().sum::<f64>() / tscores.len() as f64;
        }
        scores.push((sample.family.name().to_string(), s));
    }
    // per-family averages
    let mut fams: Vec<String> = scores.iter().map(|(f, _)| f.clone()).collect();
    fams.sort();
    fams.dedup();
    let per_family: Vec<(String, f64)> = fams
        .into_iter()
        .map(|f| {
            let xs: Vec<f64> =
                scores.iter().filter(|(g, _)| *g == f).map(|(_, s)| *s).collect();
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            (f, mean)
        })
        .collect();
    let avg = per_family.iter().map(|(_, s)| s).sum::<f64>() / per_family.len().max(1) as f64;
    Ok(MethodScore {
        method: method.name(),
        suite: suite.name.clone(),
        budget: cfg.budget,
        score: avg,
        per_family,
        ttft_ms_mean: summarize(&ttfts).mean,
        forward_ms_mean: summarize(&fwd).mean,
        overhead_ms_mean: summarize(&ovh).mean,
        decode_ms_per_tok: summarize(&dec).mean,
        n: suite.samples.len(),
    })
}

//! Evaluation harness: run suites through the engine under each eviction
//! method, score the generations, and print paper-style tables.

pub mod runner;
pub mod scorer;
pub mod tables;

pub use runner::{run_suite, EvalConfig, MethodScore};
pub use scorer::score_sample;

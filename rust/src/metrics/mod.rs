//! Low-overhead serving metrics: atomic counters/gauges and fixed
//! log-bucket latency histograms, exported as JSON (`GET /metrics`) and
//! Prometheus text exposition (`GET /metrics?format=prometheus`).
//!
//! The hot path (engine-loop `incr`/`observe`/`set_gauge`) is a
//! read-locked registry lookup plus one or two atomic RMW ops — no
//! global mutex, no allocation after a metric's first touch. Histograms
//! use fixed √2-power buckets (1 µs … ~35 min) with an exact total
//! count and sum, so `count`/`mean` never underreport no matter how many
//! observations land (the old implementation decimated a 4096-sample
//! reservoir with a deterministic-biased overwrite and summarized only
//! the survivors). Percentiles are bucket-interpolated and clamped to
//! the observed `[min, max]`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// Number of finite histogram buckets; bucket `i` covers
/// `(bound(i-1), bound(i)]` with `bound(i) = 0.001 · 2^(i/2)` ms, i.e.
/// √2-power steps from 1 µs. The last bucket is open-ended (+Inf).
const N_BUCKETS: usize = 64;

/// Upper bound (ms) of finite bucket `i`.
fn bucket_bound_ms(i: usize) -> f64 {
    0.001 * 2f64.powf(i as f64 / 2.0)
}

/// Index of the bucket an observation lands in.
fn bucket_for(ms: f64) -> usize {
    if ms <= 0.001 {
        return 0;
    }
    // Smallest i with 0.001·2^(i/2) >= ms.
    let i = (2.0 * (ms / 0.001).log2()).ceil() as usize;
    i.min(N_BUCKETS - 1)
}

fn atomic_f64_add(cell: &AtomicU64, x: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + x).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

fn atomic_f64_extreme(cell: &AtomicU64, x: f64, keep_min: bool) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let cur_f = f64::from_bits(cur);
        let better = if keep_min { x < cur_f } else { x > cur_f };
        if !better {
            return;
        }
        match cell.compare_exchange_weak(cur, x.to_bits(), Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Fixed log-bucket latency histogram with exact count/sum/sum-of-squares
/// and observed min/max. Concurrent `record` is lock-free; readers see a
/// consistent-enough snapshot (each field is individually atomic).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_bits: AtomicU64,
    sumsq_bits: AtomicU64,
    min_bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0f64.to_bits()),
            sumsq_bits: AtomicU64::new(0f64.to_bits()),
            min_bits: AtomicU64::new(f64::INFINITY.to_bits()),
            max_bits: AtomicU64::new(f64::NEG_INFINITY.to_bits()),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&self, ms: f64) {
        if !ms.is_finite() {
            return;
        }
        let ms = ms.max(0.0); // latencies; a negative clock skew clamps to 0
        self.buckets[bucket_for(ms)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.sum_bits, ms);
        atomic_f64_add(&self.sumsq_bits, ms * ms);
        atomic_f64_extreme(&self.min_bits, ms, true);
        atomic_f64_extreme(&self.max_bits, ms, false);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ms(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    fn min(&self) -> f64 {
        f64::from_bits(self.min_bits.load(Ordering::Relaxed))
    }

    fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Cumulative bucket counts up to each finite bound (Prometheus `le`
    /// semantics; the total count doubles as the `+Inf` bucket).
    fn bucket_snapshot(&self) -> [u64; N_BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Bucket-interpolated percentile, clamped to the observed range.
    pub fn percentile(&self, q: f64) -> f64 {
        let counts = self.bucket_snapshot();
        let n: u64 = counts.iter().sum();
        if n == 0 {
            return 0.0;
        }
        let (min, max) = (self.min(), self.max());
        let rank = (q.clamp(0.0, 1.0) * n as f64).max(1.0);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= rank {
                let lo = if i == 0 { 0.0 } else { bucket_bound_ms(i - 1) };
                let hi = bucket_bound_ms(i).min(max);
                let frac = (rank - cum as f64) / c as f64;
                return (lo + frac * (hi - lo)).clamp(min, max);
            }
            cum += c;
        }
        max
    }

    /// Summary over everything ever observed (exact n/mean/std/min/max,
    /// bucket-interpolated percentiles).
    pub fn summary(&self) -> Summary {
        let n = self.count();
        if n == 0 {
            return Summary::default();
        }
        let mean = self.sum_ms() / n as f64;
        let sumsq = f64::from_bits(self.sumsq_bits.load(Ordering::Relaxed));
        let var = (sumsq / n as f64 - mean * mean).max(0.0);
        Summary {
            n: n as usize,
            mean,
            std: var.sqrt(),
            min: self.min(),
            max: self.max(),
            p50: self.percentile(0.50),
            p90: self.percentile(0.90),
            p99: self.percentile(0.99),
        }
    }

    pub fn to_json(&self) -> Json {
        let s = self.summary();
        Json::from_pairs(vec![
            ("count", s.n.into()),
            ("mean_ms", s.mean.into()),
            ("p50_ms", s.p50.into()),
            ("p90_ms", s.p90.into()),
            ("p99_ms", s.p99.into()),
            ("max_ms", s.max.into()),
        ])
    }
}

/// Global metrics registry (engine thread writes, HTTP threads read).
///
/// Registries are `RwLock`-guarded name→`Arc` maps: steady-state writes
/// take the read lock and an atomic op; the write lock is only held the
/// first time a name appears. [`Metrics::noop`] builds a disabled sink
/// whose write paths return immediately — the A/B baseline for the
/// instrumentation-overhead bench.
#[derive(Debug, Default)]
pub struct Metrics {
    disabled: bool,
    counters: RwLock<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: RwLock<BTreeMap<String, Arc<AtomicU64>>>, // f64 bit patterns
    histograms: RwLock<BTreeMap<String, Arc<Histogram>>>,
    /// Info-style gauges: constant-`1` samples whose payload is the
    /// label set (the Prometheus `foo_info{bar="baz"} 1` idiom). Cold
    /// path only — set once at startup (e.g. `kv_cache_info{kv_dtype}`).
    infos: RwLock<BTreeMap<String, Vec<(String, String)>>>,
}

fn handle<T>(reg: &RwLock<BTreeMap<String, Arc<T>>>, name: &str, init: impl Fn() -> T) -> Arc<T> {
    if let Some(h) = reg.read().unwrap().get(name) {
        return Arc::clone(h);
    }
    let mut w = reg.write().unwrap();
    Arc::clone(w.entry(name.to_string()).or_insert_with(|| Arc::new(init())))
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// A disabled sink: `incr`/`observe`/`set_gauge` are no-ops. Used to
    /// measure instrumentation overhead (see `bench_scheduler`).
    pub fn noop() -> Metrics {
        Metrics { disabled: true, ..Metrics::default() }
    }

    pub fn incr(&self, name: &str, by: u64) {
        if self.disabled {
            return;
        }
        handle(&self.counters, name, || AtomicU64::new(0)).fetch_add(by, Ordering::Relaxed);
    }

    pub fn observe(&self, name: &str, ms: f64) {
        if self.disabled {
            return;
        }
        handle(&self.histograms, name, Histogram::new).record(ms);
    }

    /// Set a point-in-time gauge (current KV pool occupancy, prefix-tree
    /// size, ...). Unlike counters these overwrite rather than add.
    pub fn set_gauge(&self, name: &str, value: f64) {
        if self.disabled {
            return;
        }
        handle(&self.gauges, name, || AtomicU64::new(0))
            .store(value.to_bits(), Ordering::Relaxed);
    }

    /// Set an info-style gauge: a constant `1` sample whose payload is
    /// its label set (`kv_cache_info{kv_dtype="u8"} 1`). Re-setting the
    /// same name replaces the labels. Not for hot paths — each call
    /// takes the write lock and allocates.
    pub fn set_info(&self, name: &str, labels: &[(&str, &str)]) {
        if self.disabled {
            return;
        }
        let labels: Vec<(String, String)> =
            labels.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect();
        self.infos.write().unwrap().insert(name.to_string(), labels);
    }

    /// Label set of an info gauge (None when never set).
    pub fn info(&self, name: &str) -> Option<Vec<(String, String)>> {
        self.infos.read().unwrap().get(name).cloned()
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges
            .read()
            .unwrap()
            .get(name)
            .map(|g| f64::from_bits(g.load(Ordering::Relaxed)))
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .read()
            .unwrap()
            .get(name)
            .map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// Summary stats of one latency histogram (None when never observed).
    /// Lets benches/tests read e.g. the max per-iteration decode stall
    /// without round-tripping through JSON.
    pub fn latency_summary(&self, name: &str) -> Option<Summary> {
        self.histograms.read().unwrap().get(name).map(|h| h.summary())
    }

    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in self.counters.read().unwrap().iter() {
            counters.set(k, v.load(Ordering::Relaxed).into());
        }
        let mut hists = Json::obj();
        for (k, h) in self.histograms.read().unwrap().iter() {
            hists.set(k, h.to_json());
        }
        let mut gauges = Json::obj();
        for (k, v) in self.gauges.read().unwrap().iter() {
            gauges.set(k, f64::from_bits(v.load(Ordering::Relaxed)).into());
        }
        let mut infos = Json::obj();
        for (k, labels) in self.infos.read().unwrap().iter() {
            let mut l = Json::obj();
            for (lk, lv) in labels {
                l.set(lk, lv.as_str().into());
            }
            infos.set(k, l);
        }
        Json::from_pairs(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("info", infos),
            ("latency", hists),
        ])
    }

    /// Prometheus text exposition (format 0.0.4). Metric names are
    /// mangled into valid Prometheus identifiers (`ttft_ms_tenant_0`
    /// stays as-is, `stall/mixed/chunk64` becomes
    /// `stall_mixed_chunk64`); when two source names mangle to the same
    /// identifier the first (in BTreeMap order) wins and the duplicate
    /// is noted in a comment rather than emitted twice.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut seen = std::collections::BTreeSet::new();
        let mut emit_name = |out: &mut String, orig: &str| -> Option<String> {
            let name = prometheus_name(orig);
            if !seen.insert(name.clone()) {
                out.push_str(&format!("# duplicate after mangling, skipped: {orig}\n"));
                return None;
            }
            Some(name)
        };
        for (k, v) in self.counters.read().unwrap().iter() {
            let Some(name) = emit_name(&mut out, k) else { continue };
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.read().unwrap().iter() {
            let Some(name) = emit_name(&mut out, k) else { continue };
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {}\n", fmt_f64(f64::from_bits(v.load(Ordering::Relaxed)))));
        }
        for (k, labels) in self.infos.read().unwrap().iter() {
            let Some(name) = emit_name(&mut out, k) else { continue };
            out.push_str(&format!("# TYPE {name} gauge\n"));
            let rendered: Vec<String> = labels
                .iter()
                .map(|(lk, lv)| {
                    let lv = lv.replace('\\', "\\\\").replace('"', "\\\"");
                    format!("{}=\"{lv}\"", prometheus_name(lk))
                })
                .collect();
            out.push_str(&format!("{name}{{{}}} 1\n", rendered.join(",")));
        }
        for (k, h) in self.histograms.read().unwrap().iter() {
            let Some(name) = emit_name(&mut out, k) else { continue };
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let counts = h.bucket_snapshot();
            let total = h.count();
            // Emit cumulative buckets up to the last non-empty one (the
            // remaining finite bounds all equal the total), then +Inf.
            let last = counts.iter().rposition(|&c| c > 0).unwrap_or(0);
            let mut cum = 0u64;
            for (i, &c) in counts.iter().enumerate().take(last + 1) {
                cum += c;
                out.push_str(&format!(
                    "{name}_bucket{{le=\"{}\"}} {cum}\n",
                    fmt_f64(bucket_bound_ms(i))
                ));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {total}\n"));
            out.push_str(&format!("{name}_sum {}\n", fmt_f64(h.sum_ms())));
            out.push_str(&format!("{name}_count {total}\n"));
        }
        out
    }
}

/// Shortest round-trippable float rendering Prometheus accepts.
fn fmt_f64(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Mangle an internal metric name into a valid Prometheus identifier:
/// every character outside `[a-zA-Z0-9_]` becomes `_`, and a leading
/// digit gets a `_` prefix.
pub fn prometheus_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 1);
    for (i, ch) in name.chars().enumerate() {
        if ch.is_ascii_alphanumeric() || ch == '_' {
            if i == 0 && ch.is_ascii_digit() {
                out.push('_');
            }
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

fn valid_metric_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Line-level linter for the text exposition format: every line must be
/// a comment, blank, or a `name[{labels}] value` sample with a valid
/// metric name, well-formed labels and a parseable value; `# TYPE` lines
/// must be well-formed and unique per metric. Histogram `_bucket` series
/// must be cumulative with a final `+Inf` bucket equal to `_count`.
/// Returns the first violation. Used by unit tests (so malformed names
/// fail CI, not scrapes) and the HTTP round-trip test.
pub fn lint_exposition(text: &str) -> Result<(), String> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    // histogram base -> (last cumulative bucket, inf bucket, count)
    let mut hist: BTreeMap<String, (Option<u64>, Option<u64>, Option<u64>)> = BTreeMap::new();
    for (ln, line) in text.lines().enumerate() {
        let err = |msg: String| Err(format!("line {}: {msg}: {line:?}", ln + 1));
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let (Some(name), Some(kind), None) = (parts.next(), parts.next(), parts.next()) else {
                return err("malformed TYPE line".into());
            };
            if !valid_metric_name(name) {
                return err(format!("invalid metric name {name:?}"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return err(format!("unknown metric type {kind:?}"));
            }
            if types.insert(name.to_string(), kind.to_string()).is_some() {
                return err(format!("duplicate TYPE for {name:?}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // other comments (HELP, collision notes)
        }
        // Sample line: name[{labels}] value
        let (name_part, value_part) = match line.find(|c| c == '{') {
            Some(brace) => {
                let close = match line.rfind('}') {
                    Some(c) if c > brace => c,
                    _ => return err("unbalanced label braces".into()),
                };
                let labels = &line[brace + 1..close];
                for pair in labels.split(',').filter(|p| !p.is_empty()) {
                    let Some((k, v)) = pair.split_once('=') else {
                        return err(format!("malformed label {pair:?}"));
                    };
                    if !valid_label_name(k) {
                        return err(format!("invalid label name {k:?}"));
                    }
                    if !(v.len() >= 2 && v.starts_with('"') && v.ends_with('"')) {
                        return err(format!("unquoted label value {v:?}"));
                    }
                }
                (&line[..brace], line[close + 1..].trim())
            }
            None => {
                let Some((n, v)) = line.split_once(' ') else {
                    return err("sample line without value".into());
                };
                (n, v.trim())
            }
        };
        if !valid_metric_name(name_part) {
            return err(format!("invalid metric name {name_part:?}"));
        }
        let value = match value_part {
            "+Inf" => f64::INFINITY,
            "-Inf" => f64::NEG_INFINITY,
            "NaN" => f64::NAN,
            v => match v.parse::<f64>() {
                Ok(x) => x,
                Err(_) => return err(format!("unparseable value {value_part:?}")),
            },
        };
        // Histogram series bookkeeping.
        for (suffix, slot) in [("_bucket", 0usize), ("_count", 2)] {
            if let Some(base) = name_part.strip_suffix(suffix) {
                if types.get(base).map(String::as_str) == Some("histogram") {
                    let entry = hist.entry(base.to_string()).or_default();
                    let v = value as u64;
                    match slot {
                        0 => {
                            if line.contains("le=\"+Inf\"") {
                                entry.1 = Some(v);
                            } else {
                                if entry.0.is_some_and(|prev| v < prev) {
                                    return err(format!(
                                        "non-cumulative bucket for {base:?}"
                                    ));
                                }
                                entry.0 = Some(v);
                            }
                        }
                        _ => entry.2 = Some(v),
                    }
                }
            }
        }
    }
    for (base, (last, inf, count)) in &hist {
        let (Some(inf), Some(count)) = (inf, count) else {
            return Err(format!("histogram {base:?} missing +Inf bucket or _count"));
        };
        if inf != count {
            return Err(format!("histogram {base:?}: +Inf bucket {inf} != _count {count}"));
        }
        if last.is_some_and(|l| l > *inf) {
            return Err(format!("histogram {base:?}: finite bucket above +Inf"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        m.observe("ttft", 10.0);
        m.observe("ttft", 20.0);
        assert_eq!(m.counter("requests"), 3);
        let j = m.to_json();
        assert_eq!(j.req("latency").req("ttft").req("count").as_usize(), Some(2));
        assert_eq!(j.req("counters").req("requests").as_usize(), Some(3));
    }

    #[test]
    fn gauges_overwrite_and_export() {
        let m = Metrics::new();
        m.set_gauge("kv_free_blocks", 8.0);
        m.set_gauge("kv_free_blocks", 5.0);
        assert_eq!(m.gauge("kv_free_blocks"), Some(5.0));
        assert_eq!(m.gauge("missing"), None);
        let j = m.to_json();
        assert_eq!(j.req("gauges").req("kv_free_blocks").as_f64(), Some(5.0));
    }

    /// Info gauges render as labeled constant-1 samples, pass the
    /// linter, and surface their labels in the JSON export.
    #[test]
    fn info_gauge_labeled_exposition() {
        let m = Metrics::new();
        m.set_info("kv_cache_info", &[("kv_dtype", "u8")]);
        let text = m.to_prometheus();
        lint_exposition(&text).unwrap();
        assert!(text.contains("# TYPE kv_cache_info gauge"));
        assert!(text.contains("kv_cache_info{kv_dtype=\"u8\"} 1"));
        // Re-set replaces the label set.
        m.set_info("kv_cache_info", &[("kv_dtype", "f16")]);
        assert_eq!(m.info("kv_cache_info"), Some(vec![("kv_dtype".into(), "f16".into())]));
        let j = m.to_json();
        assert_eq!(j.req("info").req("kv_cache_info").req("kv_dtype").as_str(), Some("f16"));
        let noop = Metrics::noop();
        noop.set_info("kv_cache_info", &[("kv_dtype", "u8")]);
        assert!(noop.info("kv_cache_info").is_none());
    }

    /// Regression for the reservoir-era honesty bugs: the old histogram
    /// decimated past 4096 samples and summarized only the survivors, so
    /// `count` and `mean` underreported. The fixed-bucket histogram must
    /// keep the exact total count and sum at any volume.
    #[test]
    fn histogram_exact_count_and_sum_past_4096() {
        let h = Histogram::new();
        let n = 10_000u64;
        let mut sum = 0.0;
        for i in 0..n {
            let ms = (i % 100) as f64 + 0.5;
            h.record(ms);
            sum += ms;
        }
        assert_eq!(h.count(), n);
        assert!((h.sum_ms() - sum).abs() < 1e-6 * sum);
        let s = h.summary();
        assert_eq!(s.n, n as usize);
        assert!((s.mean - sum / n as f64).abs() < 1e-9);
        assert_eq!(s.min, 0.5);
        assert_eq!(s.max, 99.5);
    }

    #[test]
    fn histogram_percentiles_bucket_accurate() {
        let h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        // √2 buckets: relative error per bucket is at most ~41%.
        let p50 = h.percentile(0.50);
        assert!((350.0..=720.0).contains(&p50), "p50 {p50}");
        let p99 = h.percentile(0.99);
        assert!((700.0..=1000.0).contains(&p99), "p99 {p99}");
        // Clamped to observed extremes.
        assert!(h.percentile(0.0) >= 1.0);
        assert!(h.percentile(1.0) <= 1000.0);
    }

    #[test]
    fn histogram_concurrent_records_exact_count() {
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..2500 {
                        h.record((t * 2500 + i) as f64 * 0.01);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 10_000);
        let expect: f64 = (0..10_000).map(|i| i as f64 * 0.01).sum();
        assert!((h.sum_ms() - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn noop_sink_records_nothing() {
        let m = Metrics::noop();
        m.incr("requests", 5);
        m.observe("ttft", 10.0);
        m.set_gauge("g", 1.0);
        assert_eq!(m.counter("requests"), 0);
        assert!(m.latency_summary("ttft").is_none());
        assert_eq!(m.gauge("g"), None);
    }

    #[test]
    fn name_mangling() {
        assert_eq!(prometheus_name("ttft_ms_tenant_0"), "ttft_ms_tenant_0");
        assert_eq!(prometheus_name("stall/mixed/chunk64"), "stall_mixed_chunk64");
        assert_eq!(prometheus_name("serve/bursty/ttft_p99_high_ms"), "serve_bursty_ttft_p99_high_ms");
        assert_eq!(prometheus_name("lkv+suffix"), "lkv_suffix");
        assert_eq!(prometheus_name("9lives"), "_9lives");
        assert_eq!(prometheus_name(""), "_");
    }

    /// Every line the exposition emits — including mangled slash/plus
    /// names and full histogram series — must pass the linter.
    #[test]
    fn exposition_lints_clean() {
        let m = Metrics::new();
        m.incr("completions", 3);
        m.incr("stall/mixed/chunk64_total", 1);
        m.set_gauge("kv_free_blocks", 5.0);
        m.set_gauge("9starts_with_digit", 1.5);
        for i in 0..5000 {
            m.observe("ttft_ms_tenant_0", (i % 50) as f64 + 0.25);
        }
        m.observe("decode_stall_ms", 3.5);
        let text = m.to_prometheus();
        lint_exposition(&text).unwrap();
        assert!(text.contains("# TYPE completions counter"));
        assert!(text.contains("# TYPE kv_free_blocks gauge"));
        assert!(text.contains("# TYPE ttft_ms_tenant_0 histogram"));
        assert!(text.contains("stall_mixed_chunk64_total 1"));
        assert!(text.contains("_9starts_with_digit 1.5"));
        assert!(text.contains("ttft_ms_tenant_0_count 5000"));
        assert!(text.contains("ttft_ms_tenant_0_bucket{le=\"+Inf\"} 5000"));
    }

    #[test]
    fn exposition_agrees_with_json() {
        let m = Metrics::new();
        m.incr("completions", 7);
        m.set_gauge("kv_free_blocks", 4.0);
        for i in 0..100 {
            m.observe("ttft_ms", i as f64);
        }
        let j = m.to_json();
        let p = m.to_prometheus();
        assert!(p.contains(&format!(
            "completions {}",
            j.req("counters").req("completions").as_usize().unwrap()
        )));
        assert!(p.contains(&format!(
            "ttft_ms_count {}",
            j.req("latency").req("ttft_ms").req("count").as_usize().unwrap()
        )));
    }

    #[test]
    fn linter_rejects_malformed() {
        assert!(lint_exposition("bad name 1\n").is_err());
        assert!(lint_exposition("metric{le=unquoted} 1\n").is_err());
        assert!(lint_exposition("metric notanumber\n").is_err());
        assert!(lint_exposition("# TYPE m bogus\n").is_err());
        assert!(lint_exposition("# TYPE m counter\n# TYPE m counter\nm 1\n").is_err());
        assert!(lint_exposition(
            "# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"+Inf\"} 3\nh_sum 1\nh_count 3\n"
        )
        .is_err());
        assert!(lint_exposition("ok_metric 1.5\n# comment\n").is_ok());
    }

    #[test]
    fn mangling_collision_emitted_once() {
        let m = Metrics::new();
        m.incr("a/b", 1);
        m.incr("a_b", 2);
        let text = m.to_prometheus();
        lint_exposition(&text).unwrap();
        assert_eq!(text.matches("# TYPE a_b counter").count(), 1);
        assert!(text.contains("# duplicate after mangling, skipped"));
    }
}

//! Serving metrics: counters + latency histograms, exported as JSON by
//! the server's `/metrics` endpoint and by the bench harnesses.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};

/// Log-scaled latency histogram (microsecond buckets, powers of √2).
#[derive(Debug, Default)]
pub struct Histogram {
    samples: Vec<f64>, // ms; bounded reservoir
}

const RESERVOIR: usize = 4096;

impl Histogram {
    pub fn record(&mut self, ms: f64) {
        if self.samples.len() < RESERVOIR {
            self.samples.push(ms);
        } else {
            // reservoir decimation: overwrite pseudo-randomly
            let i = (self.samples.len() * 31 + ms.to_bits() as usize) % RESERVOIR;
            self.samples[i] = ms;
        }
    }

    pub fn to_json(&self) -> Json {
        let s = summarize(&self.samples);
        Json::from_pairs(vec![
            ("count", s.n.into()),
            ("mean_ms", s.mean.into()),
            ("p50_ms", s.p50.into()),
            ("p90_ms", s.p90.into()),
            ("p99_ms", s.p99.into()),
            ("max_ms", s.max.into()),
        ])
    }
}

/// Global metrics registry (server-side; engine thread writes, HTTP
/// threads read snapshots).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
    gauges: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe(&self, name: &str, ms: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.histograms.entry(name.to_string()).or_default().record(ms);
    }

    /// Set a point-in-time gauge (current KV pool occupancy, prefix-tree
    /// size, ...). Unlike counters these overwrite rather than add.
    pub fn set_gauge(&self, name: &str, value: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.gauges.insert(name.to_string(), value);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Summary stats of one latency histogram (None when never observed).
    /// Lets benches/tests read e.g. the max per-iteration decode stall
    /// without round-tripping through JSON.
    pub fn latency_summary(&self, name: &str) -> Option<Summary> {
        let inner = self.inner.lock().unwrap();
        inner.histograms.get(name).map(|h| summarize(&h.samples))
    }

    pub fn to_json(&self) -> Json {
        let inner = self.inner.lock().unwrap();
        let mut counters = Json::obj();
        for (k, v) in &inner.counters {
            counters.set(k, (*v).into());
        }
        let mut hists = Json::obj();
        for (k, h) in &inner.histograms {
            hists.set(k, h.to_json());
        }
        let mut gauges = Json::obj();
        for (k, v) in &inner.gauges {
            gauges.set(k, (*v).into());
        }
        Json::from_pairs(vec![("counters", counters), ("gauges", gauges), ("latency", hists)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::new();
        m.incr("requests", 1);
        m.incr("requests", 2);
        m.observe("ttft", 10.0);
        m.observe("ttft", 20.0);
        assert_eq!(m.counter("requests"), 3);
        let j = m.to_json();
        assert_eq!(j.req("latency").req("ttft").req("count").as_usize(), Some(2));
        assert_eq!(j.req("counters").req("requests").as_usize(), Some(3));
    }

    #[test]
    fn gauges_overwrite_and_export() {
        let m = Metrics::new();
        m.set_gauge("kv_free_blocks", 8.0);
        m.set_gauge("kv_free_blocks", 5.0);
        assert_eq!(m.gauge("kv_free_blocks"), Some(5.0));
        assert_eq!(m.gauge("missing"), None);
        let j = m.to_json();
        assert_eq!(j.req("gauges").req("kv_free_blocks").as_f64(), Some(5.0));
    }

    #[test]
    fn histogram_reservoir_bounded() {
        let mut h = Histogram::default();
        for i in 0..10_000 {
            h.record(i as f64);
        }
        assert!(h.samples.len() <= RESERVOIR);
    }
}

//! `lkv` — the LookaheadKV serving coordinator CLI.
//!
//! Subcommands:
//!   serve      start the HTTP server (engine loop + scheduler)
//!   generate   one-shot generation from the command line
//!   eval       run a workload suite under one or more eviction methods
//!   cost       print the analytical TTFT cost table (paper Table 3/15)
//!   graphs     list artifact graphs and compile-check them

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;
use lookaheadkv::costmodel::{self, methods::CostConfig, profiles};
use lookaheadkv::engine::{Engine, EngineConfig, GenOptions};
use lookaheadkv::eval::{runner, tables};
use lookaheadkv::eviction::spec::PolicySpec;
use lookaheadkv::eviction::Method;
use lookaheadkv::faults::FaultPlan;
use lookaheadkv::metrics::Metrics;
use lookaheadkv::model::tokenizer::encode;
use lookaheadkv::runtime::artifacts::default_artifacts_dir;
use lookaheadkv::scheduler::{EngineLoop, LoopConfig, RequestQueue};
use lookaheadkv::server::{serve, ServerConfig};
use lookaheadkv::trace::Tracer;
use lookaheadkv::util::cli::Args;
use lookaheadkv::workload;

fn main() {
    let args = Args::from_env(&[
        "help",
        "verbose",
        "compile",
        "per-seq-decode",
        "prefix-cache",
        "dense-kv",
        "ref-naive",
        "no-preemption",
    ]);
    apply_kernel_flags(&args);
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "eval" => cmd_eval(&args),
        "cost" => cmd_cost(&args),
        "graphs" => cmd_graphs(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "lkv — LookaheadKV serving coordinator\n\
         \n\
         usage: lkv <command> [options]\n\
         \n\
         commands:\n\
         \x20 serve     --addr 127.0.0.1:8080 --model lkv-tiny --max-active 4 \\\n\
         \x20           [--prefill-chunk 256] [--per-seq-decode] \\\n\
         \x20           [--kv-pool SLOTS] [--kv-block SLOTS] [--dense-kv] \\\n\
         \x20           [--kv-dtype f32|f16|u8]   (arena storage precision; u8 packs\n\
         \x20                                      ~3.9x more KV per byte, f32 default) \\\n\
         \x20           [--prefix-cache] [--prefix-cache-slots N] \\\n\
         \x20           [--tenants N] [--quota-tokens N] [--stall-slo-ms MS] \\\n\
         \x20           [--no-preemption] [--threads N] [--ref-naive] \\\n\
         \x20           [--trace-out PATH]   (Chrome trace-event JSON on shutdown;\n\
         \x20                                 spans also served at GET /trace/<id>) \\\n\
         \x20           [--deadline-ms MS]        (default per-request compute deadline;\n\
         \x20                                      0 = none; body deadline_ms overrides) \\\n\
         \x20           [--reply-timeout-ms MS]   (front-end 504 timeout, 0 = wait forever) \\\n\
         \x20           [--restore-retries N] [--restore-retry-base-ms MS] \\\n\
         \x20           [--fault-plan SPEC]       (deterministic fault injection, e.g.\n\
         \x20                                      \"seed=7;backend:rate=0.05;restore:rate=0.2\";\n\
         \x20                                      env LKV_FAULTS when flag absent)\n\
         \x20 generate  --prompt <text> --method lookaheadkv --budget 64 --max-new 32\n\
         \x20 eval      --suite ruler|longbench|qasper|longproc|mtbench --methods snapkv,lookaheadkv \\\n\
         \x20           --budgets 16,32 --ctx 256 --n 8\n\
         \x20 cost      [--contexts 4096,8192,16384,32768]   (paper Table 3/15)\n\
         \x20 graphs    [--compile]                           (artifact inventory)\n\
         \n\
         methods: full random streaming snapkv pyramidkv h2o tova laq speckv\n\
         \x20        predictor lookaheadkv[:variant] lkv+suffix[:variant]\n\
         \x20        (all routed through the structured PolicySpec; see\n\
         \x20        GET /policies or README \"Eviction policies\")\n\
         \n\
         backend: LKV_BACKEND=reference|pjrt|auto (default auto: pjrt when\n\
         \x20        compiled in and artifacts exist, else pure-Rust reference)\n\
         kernels: --threads N (LKV_THREADS) caps kernel worker threads;\n\
         \x20        --ref-naive (LKV_REF_NAIVE=1) runs the frozen naive oracle\n\
         \x20        instead of the streaming tiled suite; LKV_TILE_K tunes the\n\
         \x20        attention column tile (never changes results)"
    );
}

fn artifacts(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(default_artifacts_dir)
}

/// Reference-backend kernel knobs, applied before any engine exists:
/// `--ref-naive` selects the frozen naive kernel suite (the streaming
/// A/B oracle), `--threads N` caps kernel worker threads. Both map onto
/// the env vars the backend reads at construction (`LKV_REF_NAIVE`,
/// `LKV_THREADS`) so the engine thread inherits them.
fn apply_kernel_flags(args: &Args) {
    if args.has("ref-naive") {
        std::env::set_var("LKV_REF_NAIVE", "1");
    }
    if let Some(t) = args.get("threads") {
        std::env::set_var("LKV_THREADS", t);
    }
}

fn engine_from_args(args: &Args) -> Result<Engine> {
    let model = args.get_or("model", "lkv-tiny");
    let mut cfg = EngineConfig::new(model);
    cfg.draft_tokens = args.usize("draft-tokens", 8);
    Engine::new(&artifacts(args), cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Backend handles may not be Send (PJRT): construct the Engine
    // *inside* the engine thread and keep it there for the process
    // lifetime.
    let queue = Arc::new(RequestQueue::new(args.usize("queue-cap", 64)));
    let metrics = Arc::new(Metrics::new());
    let defaults = LoopConfig::default();
    let loop_cfg = LoopConfig {
        max_active: args.usize("max-active", 4),
        // Shared KV pool: --kv-pool is the global slot budget (the
        // GPU-KV-memory analog), --kv-block the paging granularity, and
        // --dense-kv opts out of the paged arena back into dense
        // cap-sized per-sequence caches (see README "Paged KV arena").
        kv_pool_slots: args.usize("kv-pool", defaults.kv_pool_slots),
        kv_block_slots: args.usize_clamped("kv-block", defaults.kv_block_slots, 1, 4096),
        paged_kv: !args.has("dense-kv"),
        // Arena storage dtype: f32 (bit-exact oracle, default), f16, or
        // u8 with per-(layer, KV-head, block) scale/zero-point.
        kv_dtype: match args.get("kv-dtype") {
            None => defaults.kv_dtype,
            Some(s) => lookaheadkv::kvcache::KvDtype::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown --kv-dtype {s} (f32|f16|u8)"))?,
        },
        batched_decode: !args.has("per-seq-decode"),
        // 0 = monolithic prefill; 64-256 interleaves decode steps between
        // prompt chunks (see README "Chunked prefill").
        prefill_chunk_tokens: args.usize_clamped("prefill-chunk", 0, 0, 1024),
        // Cross-request prefix cache (requires --prefill-chunk > 0);
        // --prefix-cache-slots caps the tree's share of the KV pool
        // (0 = bounded only by the pool + LRU reclamation).
        prefix_cache: args.has("prefix-cache"),
        prefix_cache_slots: args.usize("prefix-cache-slots", 0),
        // Multi-tenant scheduling: --tenants sizes per-tenant TTFT
        // metrics, --quota-tokens caps each tenant's in-flight tokens,
        // --stall-slo-ms defers new admissions while recent decode
        // stalls exceed the SLO, and --no-preemption reverts pool
        // pressure to kv_exhausted truncation instead of spilling
        // lower-priority sequences to host (see README "Multi-tenant
        // serving").
        tenants: args.usize_clamped("tenants", defaults.tenants, 1, 4096),
        quota_tokens: args.usize("quota-tokens", defaults.quota_tokens),
        stall_slo_ms: args.f64("stall-slo-ms", defaults.stall_slo_ms),
        preemption: !args.has("no-preemption"),
        // Deterministic fault injection: --fault-plan takes precedence
        // over LKV_FAULTS; an invalid plan is a startup error, not a
        // silently-disabled one (see README "Robustness & fault
        // injection").
        faults: match args.get("fault-plan") {
            Some(s) => Some(Arc::new(
                FaultPlan::parse(s).map_err(|e| anyhow::anyhow!("--fault-plan: {e}"))?,
            )),
            None => FaultPlan::from_env()
                .map_err(|e| anyhow::anyhow!("LKV_FAULTS: {e}"))?
                .map(Arc::new),
        },
        restore_retries: args.usize("restore-retries", defaults.restore_retries as usize) as u32,
        restore_retry_base_ms: args
            .usize("restore-retry-base-ms", defaults.restore_retry_base_ms as usize)
            as u64,
    };
    // Request-lifecycle tracing: always queryable via GET /trace/<id>;
    // --trace-out PATH additionally writes a Chrome trace-event JSON
    // (Perfetto-loadable) when the server shuts down.
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let tracer = Arc::new(Tracer::new());
    let q2 = Arc::clone(&queue);
    let m2 = Arc::clone(&metrics);
    let t2 = Arc::clone(&tracer);
    let model = args.get_or("model", "lkv-tiny").to_string();
    let draft_tokens = args.usize("draft-tokens", 8);
    let art = artifacts(args);
    let engine_thread = std::thread::Builder::new().name("engine".into()).spawn(move || {
        let mut cfg = EngineConfig::new(&model);
        cfg.draft_tokens = draft_tokens;
        // Engine construction can fail (missing artifacts, bad model
        // name): close the queue so the front-end answers with clean
        // errors instead of leaving a panicked engine behind 504s.
        match Engine::new(&art, cfg) {
            Ok(engine) => EngineLoop::new(engine, loop_cfg, q2, m2).with_tracer(t2).run(),
            Err(e) => {
                log::error!("engine init failed: {e:#}");
                q2.close();
            }
        }
    })?;
    let server_cfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:8080").to_string(),
        workers: args.usize("workers", 4),
        queue_cap: args.usize("queue-cap", 64),
        read_timeout_ms: args.usize("read-timeout-ms", 10_000) as u64,
        write_timeout_ms: args.usize("write-timeout-ms", 10_000) as u64,
        // How long the front-end waits for the engine's reply before
        // answering 504 (and cancelling the in-flight request); 0 waits
        // forever. --deadline-ms is the default per-request compute
        // deadline applied when the body omits `deadline_ms` (0 = none).
        reply_timeout_ms: args.usize("reply-timeout-ms", 120_000) as u64,
        default_deadline_ms: args.usize("deadline-ms", 0) as u64,
    };
    serve(server_cfg, queue, metrics, Some(Arc::clone(&tracer)))?;
    let _ = engine_thread.join();
    if let Some(path) = trace_out {
        tracer.write_chrome_trace(&path)?;
        println!("wrote Chrome trace ({} spans) to {}", tracer.snapshot().len(), path.display());
    }
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let engine = engine_from_args(args)?;
    let prompt_text = args.get_or("prompt", "A7K=Q2Z;lorem;ipsum;dolor;A7K=");
    // `--method` strings go through the structured PolicySpec — the same
    // construction path the HTTP policy API uses.
    let method_name = args.get_or("method", "lookaheadkv");
    let spec = PolicySpec::parse_str(method_name)
        .ok_or_else(|| anyhow::anyhow!("unknown method {method_name}"))?;
    let method = spec.resolve().map_err(|e| anyhow::anyhow!(e))?;
    let opts = GenOptions {
        budget: spec.budget.unwrap_or_else(|| args.usize("budget", 64)),
        max_new: args.usize("max-new", 32),
        temperature: args.f64("temperature", 0.0) as f32,
        seed: args.usize("seed", 0) as u64,
        collect_gt: false,
        knobs: spec.knobs,
    };
    let res = engine.generate(&encode(prompt_text, true, false), &method, &opts)?;
    println!("text: {}", res.text);
    println!(
        "prompt={} tokens, kept/layer={:?}, cap={}",
        res.prompt_len, res.kept_per_layer, res.cache_cap
    );
    println!(
        "ttft={:.2} ms (forward {:.2} + eviction {:.2}), decode {:.2} ms/tok x {}",
        res.ttft_ms,
        res.forward_ms,
        res.eviction_overhead_ms,
        res.decode_ms_per_token(),
        res.n_decode_steps
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let engine = engine_from_args(args)?;
    let suite_name = args.get_or("suite", "ruler");
    let ctx = args.usize("ctx", 256);
    let n = args.usize("n", 8);
    let seed = args.usize("seed", 0) as u64;
    let suite = match suite_name {
        "ruler" => workload::ruler_suite(seed, n, ctx),
        "longbench" => workload::longbench_suite(seed, n, ctx),
        "qasper" => workload::qasper_suite(seed, n * 4, ctx),
        "longproc" => workload::longproc_suite(seed, n * 2, ctx, args.usize("records", 4)),
        "mtbench" => workload::mtbench_suite(seed, n * 4, ctx),
        other => anyhow::bail!("unknown suite {other}"),
    };
    let methods: Vec<Method> = args
        .list("methods", &["full", "streaming", "snapkv", "lookaheadkv"])
        .iter()
        .map(|m| {
            PolicySpec::parse_str(m)
                .ok_or_else(|| anyhow::anyhow!("unknown method {m}"))
                .and_then(|s| s.resolve().map_err(|e| anyhow::anyhow!(e)))
        })
        .collect::<Result<_>>()?;
    let budgets = args.usize_list("budgets", &[32]);
    let mut rows = Vec::new();
    let mut all = Vec::new();
    for method in &methods {
        let mut vals = Vec::new();
        for &b in &budgets {
            let cfg = runner::EvalConfig {
                budget: b,
                max_new: args.usize("max-new", 16),
                temperature: args.f64("temperature", 0.0) as f32,
                seed,
            };
            let score = runner::run_suite(&engine, &suite, method, &cfg)?;
            println!(
                "{:<16} budget={:<5} score={:.3} ttft={:.1}ms (+{:.1}ms evict)",
                score.method, b, score.score, score.ttft_ms_mean, score.overhead_ms_mean
            );
            vals.push(score.score);
            all.push(score);
        }
        rows.push((method.name(), vals));
    }
    let cols: Vec<String> = budgets.iter().map(|b| b.to_string()).collect();
    println!("\n{}", tables::score_grid(&suite.name, "budget", &cols, &rows));
    tables::save_results(&format!("eval_{suite_name}_{ctx}"), &tables::results_to_json(&all));
    Ok(())
}

fn cmd_cost(args: &Args) -> Result<()> {
    let contexts = args.usize_list("contexts", &[4096, 8192, 16384, 32768]);
    let cfg = CostConfig::default();
    println!(
        "Analytical TTFT (paper §B config: LLaMA3.1-8B, H100-80GB, C={}, window/lookahead/draft=32)",
        cfg.budget as usize
    );
    println!(
        "{:<8} {:<18} {:>10} {:>12} {:>10} {:>14}",
        "context", "method", "TFLOPs", "traffic(GB)", "TTFT(ms)", "overhead(ms)"
    );
    for &ctx in &contexts {
        for m in costmodel::MethodKind::all() {
            let row = costmodel::method_cost(
                m,
                &profiles::LLAMA31_8B,
                &profiles::LLAMA32_1B,
                &profiles::H100,
                ctx,
                &cfg,
            );
            println!(
                "{:<8} {:<18} {:>10.0} {:>12.1} {:>10.0} {:>14.2}",
                ctx,
                row.method.label(),
                row.tflops,
                row.traffic_gb,
                row.ttft_ms,
                row.overhead_ms
            );
        }
        println!();
    }
    Ok(())
}

fn cmd_graphs(args: &Args) -> Result<()> {
    let engine = engine_from_args(args)?;
    let m = engine.rt.manifest();
    println!(
        "backend={}: {} graphs, {} models, {} lkv variants",
        engine.rt.backend_name(),
        m.graphs.len(),
        m.models.len(),
        m.variants.len()
    );
    for (key, g) in &m.graphs {
        println!("  {:<44} kind={:<12} model={}", key, g.kind, g.model);
    }
    if args.has("compile") {
        for key in m.graphs.keys().cloned().collect::<Vec<_>>() {
            let t0 = std::time::Instant::now();
            engine.rt.prepare(&key)?;
            println!("prepared {key} in {:.0} ms", t0.elapsed().as_secs_f64() * 1e3);
        }
    }
    Ok(())
}

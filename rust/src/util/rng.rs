//! Deterministic PRNG (SplitMix64 seeding an xoshiro256**) plus the small
//! sampling surface the coordinator needs: uniforms, ranges, shuffles,
//! categorical sampling over logits, and Gaussian noise.
//!
//! Every workload generator, scheduler fuzz test and sampler in the crate
//! routes through this so runs are reproducible from a single `u64` seed.

/// xoshiro256** with SplitMix64 initialization.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream (for per-request / per-worker rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n - 1);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample from softmax(logits / temperature). `temperature == 0` is argmax.
    pub fn categorical(&mut self, logits: &[f32], temperature: f32) -> usize {
        if temperature <= 0.0 {
            return argmax(logits);
        }
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut cum = Vec::with_capacity(logits.len());
        let mut total = 0.0f64;
        for &l in logits {
            total += (((l - max) / temperature) as f64).exp();
            cum.push(total);
        }
        let r = self.f64() * total;
        match cum.binary_search_by(|x| x.partial_cmp(&r).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(logits.len() - 1),
        }
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(100, 30);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 30);
    }

    #[test]
    fn categorical_zero_temp_is_argmax() {
        let mut r = Rng::new(5);
        let logits = [0.1, 2.5, -1.0, 2.4];
        for _ in 0..10 {
            assert_eq!(r.categorical(&logits, 0.0), 1);
        }
    }

    #[test]
    fn categorical_respects_distribution() {
        let mut r = Rng::new(13);
        let logits = [0.0f32, 3.0, 0.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.categorical(&logits, 1.0)] += 1;
        }
        assert!(counts[1] > counts[0] * 5 && counts[1] > counts[2] * 5, "{counts:?}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(1);
        let mut x = a.fork(1);
        let mut y = a.fork(2);
        assert_ne!(x.next_u64(), y.next_u64());
    }
}

//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Covers the full JSON grammar minus exotic escapes we never emit; used
//! for the artifact manifest, HTTP request/response bodies, metrics dumps
//! and result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for required manifest fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key).unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    }

    pub fn str_arr(&self) -> Vec<String> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
            .unwrap_or_default()
    }

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    // -- serialization -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

pub fn parse(text: &str) -> Result<Json, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.req("a").as_usize(), Some(1));
        assert_eq!(v.req("b").as_arr().unwrap().len(), 3);
        assert_eq!(v.req("c").req("d").as_f64(), Some(-2500.0));
        // serialize then reparse is identity
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""aA\t\"\\""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\t\"\\"));
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo → ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ok"));
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", "lkv".into());
        o.set("n", 3usize.into());
        o.set("xs", vec![1usize, 2, 3].into());
        let s = o.to_string();
        let v = parse(&s).unwrap();
        assert_eq!(v.req("name").as_str(), Some("lkv"));
        assert_eq!(v.req("xs").usize_arr(), vec![1, 2, 3]);
    }
}

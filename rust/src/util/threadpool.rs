//! Fixed-size thread pool over std channels (tokio is unavailable offline;
//! the serving hot path is CPU-bound PJRT execution, so blocking worker
//! threads are the right model anyway).
//!
//! Used by the HTTP server for connection handling and by the bench
//! harness for load generation.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx) }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool closed").send(Box::new(f)).expect("pool closed");
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Await-able single-value slot (a poor man's oneshot future).
pub struct WaitGroup {
    counter: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl WaitGroup {
    pub fn new(n: usize) -> Self {
        WaitGroup { counter: Arc::new((Mutex::new(n), std::sync::Condvar::new())) }
    }

    pub fn done_handle(&self) -> impl Fn() + Send + 'static {
        let c = Arc::clone(&self.counter);
        move || {
            let (lock, cv) = &*c;
            let mut n = lock.lock().unwrap();
            *n = n.saturating_sub(1);
            if *n == 0 {
                cv.notify_all();
            }
        }
    }

    pub fn wait(&self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let count = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(100);
        for _ in 0..100 {
            let c = Arc::clone(&count);
            let done = wg.done_handle();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                done();
            });
        }
        wg.wait();
        assert_eq!(count.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2, "d");
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&count);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must wait for queued jobs' workers to exit
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }
}

//! Fixed-size thread pool over std channels (tokio is unavailable offline;
//! the serving hot path is CPU-bound kernel execution, so blocking worker
//! threads are the right model anyway), plus the scoped data-parallel
//! helpers the reference backend's streaming kernels fan out on.
//!
//! Used by the HTTP server for connection handling, by the bench harness
//! for load generation, and by `runtime::reference` (via
//! [`parallel_items`] / [`parallel_chunks_mut`]) for per-head and
//! query-row-tile kernel parallelism.
//!
//! Panic safety: a job that panics is caught at the worker (`catch_unwind`
//! + a panic counter) and never kills the worker thread or wedges a
//! [`WaitGroup`] — completion is counted by RAII [`WgGuard`]s that
//! decrement on drop, including during unwinding.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Error returned by [`ThreadPool::execute`] once the pool has been
/// [`ThreadPool::shutdown`] (or its sender is otherwise gone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolClosed;

impl std::fmt::Display for PoolClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "thread pool is closed")
    }
}

impl std::error::Error for PoolClosed {}

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
    panics: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let panics = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let panics = Arc::clone(&panics);
                thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                // A panicking job must not take the worker
                                // down (or poison anything): count it and
                                // keep serving.
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                                if r.is_err() {
                                    panics.fetch_add(1, Ordering::SeqCst);
                                    log::warn!("thread pool: worker job panicked");
                                }
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, tx: Some(tx), panics }
    }

    /// Submit a job. Returns [`PoolClosed`] (instead of panicking) when
    /// the pool no longer accepts work.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), PoolClosed> {
        match self.tx.as_ref() {
            Some(tx) => tx.send(Box::new(f)).map_err(|_| PoolClosed),
            None => Err(PoolClosed),
        }
    }

    /// Stop accepting new jobs; already-queued jobs still run. Idempotent.
    /// (Workers are joined on drop.)
    pub fn shutdown(&mut self) {
        drop(self.tx.take());
    }

    /// Number of jobs that have panicked so far.
    pub fn panics(&self) -> usize {
        self.panics.load(Ordering::SeqCst)
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Data-parallel helper over the pool's persistent workers: run
    /// `f(i)` for every `i < n` and wait for all of them. Completion is
    /// counted by RAII guards, so panicking iterations (counted in
    /// [`ThreadPool::panics`]) never wedge the wait. Requires a `'static`
    /// closure; kernels with borrowed data use the scoped
    /// [`parallel_items`] / [`parallel_chunks_mut`] free functions
    /// instead.
    pub fn parallel_for<F>(&self, n: usize, f: F) -> Result<(), PoolClosed>
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let wg = WaitGroup::new(n);
        for i in 0..n {
            let f = Arc::clone(&f);
            let guard = wg.guard();
            self.execute(move || {
                let _g = guard;
                f(i);
            })?;
        }
        wg.wait();
        Ok(())
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Counted completion barrier. Prefer [`WaitGroup::guard`] (RAII —
/// panic-safe) over [`WaitGroup::done_handle`] for new code.
pub struct WaitGroup {
    counter: Arc<(Mutex<usize>, Condvar)>,
}

/// RAII completion token of a [`WaitGroup`]: decrements the count when
/// dropped — including while unwinding from a panic — so
/// [`WaitGroup::wait`] can never wedge on a failed job.
pub struct WgGuard {
    counter: Arc<(Mutex<usize>, Condvar)>,
}

impl Drop for WgGuard {
    fn drop(&mut self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        *n = n.saturating_sub(1);
        if *n == 0 {
            cv.notify_all();
        }
    }
}

impl WaitGroup {
    pub fn new(n: usize) -> Self {
        WaitGroup { counter: Arc::new((Mutex::new(n), Condvar::new())) }
    }

    /// One RAII completion token (see [`WgGuard`]).
    pub fn guard(&self) -> WgGuard {
        WgGuard { counter: Arc::clone(&self.counter) }
    }

    /// Closure-style completion (legacy; not panic-safe — if the job
    /// panics before calling it, the count is only released if the
    /// closure itself is dropped with the job).
    pub fn done_handle(&self) -> impl Fn() + Send + 'static {
        let c = Arc::clone(&self.counter);
        move || {
            let (lock, cv) = &*c;
            let mut n = lock.lock().unwrap();
            *n = n.saturating_sub(1);
            if *n == 0 {
                cv.notify_all();
            }
        }
    }

    pub fn wait(&self) {
        let (lock, cv) = &*self.counter;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// Scoped data-parallel helpers (borrow-friendly; used by kernels)
// ---------------------------------------------------------------------------

/// Distribute an iterator's items over up to `threads` scoped workers;
/// `f(i, item)` receives each item with its enumeration index. Items are
/// handed out one at a time under a mutex, so `Iterator::Item` may hold
/// `&mut` borrows (e.g. `chunks_mut` windows zipped with per-head score
/// sinks) with no unsafe code. Blocks until every item is processed.
pub fn parallel_items<I, F>(threads: usize, items: I, f: F)
where
    I: Iterator + Send,
    I::Item: Send,
    F: Fn(usize, I::Item) + Sync,
{
    if threads <= 1 {
        for (i, item) in items.enumerate() {
            f(i, item);
        }
        return;
    }
    let it = Mutex::new(items.enumerate());
    thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let next = { it.lock().unwrap().next() };
                match next {
                    Some((i, item)) => f(i, item),
                    None => break,
                }
            });
        }
    });
}

/// [`parallel_items`] over `chunk`-sized mutable windows of `data`:
/// `f(ci, window)` gets the `ci`-th window (the last one may be short).
/// The per-window work must not depend on the partition for results to
/// be thread-count invariant — true for row-partitioned GEMM.
pub fn parallel_chunks_mut<T, F>(threads: usize, data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let chunk = chunk.max(1);
    parallel_items(threads, data.chunks_mut(chunk), f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4, "t");
        let count = Arc::new(AtomicUsize::new(0));
        let wg = WaitGroup::new(100);
        for _ in 0..100 {
            let c = Arc::clone(&count);
            let guard = wg.guard();
            pool.execute(move || {
                let _g = guard;
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        wg.wait();
        assert_eq!(count.load(Ordering::SeqCst), 100);
        assert_eq!(pool.panics(), 0);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2, "d");
        let count = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&count);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        drop(pool); // must wait for queued jobs' workers to exit
        assert_eq!(count.load(Ordering::SeqCst), 10);
    }

    /// A panicking job must neither wedge `WaitGroup::wait` nor take the
    /// worker down — the pool keeps serving afterwards.
    #[test]
    fn panicking_job_does_not_wedge_or_poison() {
        let pool = ThreadPool::new(2, "p");
        let wg = WaitGroup::new(3);
        for i in 0..3 {
            let guard = wg.guard();
            pool.execute(move || {
                let _g = guard;
                if i == 1 {
                    panic!("job {i} exploded");
                }
            })
            .unwrap();
        }
        wg.wait(); // must return despite the panic
        assert_eq!(pool.panics(), 1);
        // pool still functional
        let done = Arc::new(AtomicUsize::new(0));
        let wg2 = WaitGroup::new(4);
        for _ in 0..4 {
            let d = Arc::clone(&done);
            let guard = wg2.guard();
            pool.execute(move || {
                let _g = guard;
                d.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        wg2.wait();
        assert_eq!(done.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn execute_on_closed_pool_is_an_error() {
        let mut pool = ThreadPool::new(1, "c");
        pool.execute(|| {}).unwrap();
        pool.shutdown();
        assert_eq!(pool.execute(|| {}), Err(PoolClosed));
        pool.shutdown(); // idempotent
        assert_eq!(pool.parallel_for(3, |_| {}), Err(PoolClosed));
    }

    #[test]
    fn pool_parallel_for_covers_all_indices() {
        let pool = ThreadPool::new(3, "pf");
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let h = Arc::clone(&hits);
        pool.parallel_for(50, move |i| {
            h[i].fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn scoped_parallel_items_borrows_and_covers() {
        let data: Vec<usize> = (0..97).collect();
        let sum = AtomicUsize::new(0);
        parallel_items(4, data.iter(), |_, v| {
            sum.fetch_add(*v, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 97 * 96 / 2);
        // serial path gives the same coverage
        let sum1 = AtomicUsize::new(0);
        parallel_items(1, data.iter(), |_, v| {
            sum1.fetch_add(*v, Ordering::SeqCst);
        });
        assert_eq!(sum1.load(Ordering::SeqCst), 97 * 96 / 2);
    }

    #[test]
    fn parallel_chunks_mut_partitions_disjointly() {
        let mut data = vec![0usize; 103]; // non-dividing chunk size
        parallel_chunks_mut(4, &mut data, 16, |ci, chunk| {
            for (k, v) in chunk.iter_mut().enumerate() {
                *v = ci * 16 + k + 1;
            }
        });
        let want: Vec<usize> = (1..=103).collect();
        assert_eq!(data, want);
    }
}

//! Host-side dense tensors (f32 / i32) with the small operation surface
//! the coordinator needs: shape bookkeeping, slicing along the leading
//! axes, and gather along a middle axis (the eviction compaction step) —
//! plus the blocked GEMM microkernel suite ([`PackedMat`],
//! [`gemm_acc_packed`], [`gemm_acc_packed_par`]) behind the reference
//! backend's streaming kernels.
//!
//! These mirror `xla::Literal` contents; conversion lives in
//! `runtime::literal`.

#[derive(Debug, Clone, PartialEq)]
pub struct TensorF {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorI {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape {shape:?} vs {} elems", data.len());
        TensorF { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        TensorF { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// View of the sub-tensor at leading indices `idx` (e.g. `[l, h]` of an
    /// `[L, H, S]` tensor returns the `[S]` slice).
    pub fn index(&self, idx: &[usize]) -> &[f32] {
        let strides = self.strides();
        assert!(idx.len() <= self.shape.len());
        let mut off = 0;
        for (d, &i) in idx.iter().enumerate() {
            assert!(i < self.shape[d], "index {i} out of bounds for dim {d} ({})", self.shape[d]);
            off += i * strides[d];
        }
        let span: usize = self.shape[idx.len()..].iter().product();
        &self.data[off..off + span]
    }

    /// Gather along axis `axis`, keeping rows `indices` (in order).
    /// E.g. compacting `[L, Hkv, S, dh]` caches with axis=2.
    pub fn gather(&self, axis: usize, indices: &[usize]) -> TensorF {
        assert!(axis < self.shape.len());
        let outer: usize = self.shape[..axis].iter().product();
        let mid = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = indices.len();
        let mut out = Vec::with_capacity(outer * indices.len() * inner);
        for o in 0..outer {
            let base = o * mid * inner;
            for &i in indices {
                assert!(i < mid, "gather index {i} out of bounds ({mid})");
                out.extend_from_slice(&self.data[base + i * inner..base + (i + 1) * inner]);
            }
        }
        TensorF::new(shape, out)
    }

    /// Scatter rows of `self` (axis `axis`) into a zero tensor with the
    /// given axis size, placing row j at `indices[j]`. Inverse of gather.
    pub fn scatter_rows(&self, axis: usize, indices: &[usize], new_size: usize) -> TensorF {
        assert_eq!(self.shape[axis], indices.len());
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = new_size;
        let mut out = vec![0.0f32; outer * new_size * inner];
        for o in 0..outer {
            for (j, &i) in indices.iter().enumerate() {
                assert!(i < new_size);
                let src = (o * indices.len() + j) * inner;
                let dst = (o * new_size + i) * inner;
                out[dst..dst + inner].copy_from_slice(&self.data[src..src + inner]);
            }
        }
        TensorF::new(shape, out)
    }

    /// Pad (or truncate) axis `axis` to `new_size` with zeros at the end.
    pub fn resize_axis(&self, axis: usize, new_size: usize) -> TensorF {
        let outer: usize = self.shape[..axis].iter().product();
        let mid = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = new_size;
        let mut out = vec![0.0f32; outer * new_size * inner];
        let copy = mid.min(new_size);
        for o in 0..outer {
            let src = o * mid * inner;
            let dst = o * new_size * inner;
            out[dst..dst + copy * inner].copy_from_slice(&self.data[src..src + copy * inner]);
        }
        TensorF::new(shape, out)
    }
}

impl TensorI {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len());
        TensorI { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        TensorI { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(v: Vec<i32>) -> Self {
        TensorI { shape: vec![v.len()], data: v }
    }
}

// ---------------------------------------------------------------------------
// Blocked GEMM microkernel (packed weight panels + register tiling)
// ---------------------------------------------------------------------------

/// Column register tile of the GEMM microkernel (independent accumulator
/// lanes — SIMD-friendly without float reassociation).
pub const GEMM_NR: usize = 16;
/// Row register tile (query rows advanced together per panel sweep).
pub const GEMM_MR: usize = 4;
/// Output rows per parallel work item of [`gemm_acc_packed_par`].
pub const GEMM_ROW_TILE: usize = 16;

/// A weight matrix pre-packed into `GEMM_NR`-column panels: panel `p`
/// stores `w[k][p*NR + c]` at `panels[(p*n_in + k)*NR + c]`, so the
/// microkernel streams one contiguous `NR`-wide row slice per `k` step
/// regardless of `n_out`. The last panel is zero-padded (the pad lanes
/// accumulate into scratch that is never written back).
///
/// Packing is done once per weight at model-synthesis time; the kernel
/// itself has no per-element branches (the naive `matmul_acc`'s
/// zero-skip branch is the thing this replaces).
#[derive(Debug, Clone)]
pub struct PackedMat {
    pub n_in: usize,
    pub n_out: usize,
    panels: Vec<f32>,
}

impl PackedMat {
    /// Pack a row-major `[n_in, n_out]` weight matrix.
    pub fn pack(w: &TensorF) -> PackedMat {
        assert_eq!(w.shape.len(), 2, "PackedMat::pack wants [n_in, n_out]");
        let (n_in, n_out) = (w.shape[0], w.shape[1]);
        let n_panels = n_out.div_ceil(GEMM_NR).max(1);
        let mut panels = vec![0.0f32; n_panels * n_in * GEMM_NR];
        for p in 0..n_panels {
            let j0 = p * GEMM_NR;
            let cols = n_out.saturating_sub(j0).min(GEMM_NR);
            for k in 0..n_in {
                let src = &w.data[k * n_out + j0..k * n_out + j0 + cols];
                panels[(p * n_in + k) * GEMM_NR..(p * n_in + k) * GEMM_NR + cols]
                    .copy_from_slice(src);
            }
        }
        PackedMat { n_in, n_out, panels }
    }

    /// Bytes held by the packed panels (scratch accounting).
    pub fn packed_bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<f32>()
    }
}

/// `out[t, n_out] += x[t, n_in] @ w` through the packed panels:
/// `GEMM_MR x GEMM_NR` register tiles, `k` innermost and strictly
/// ascending per output element — so results are independent of row
/// grouping (full vs remainder tiles) and therefore of how callers
/// partition rows across chunks or threads.
pub fn gemm_acc_packed(x: &[f32], t: usize, n_in: usize, w: &PackedMat, out: &mut [f32]) {
    debug_assert_eq!(x.len(), t * n_in);
    debug_assert_eq!(w.n_in, n_in);
    debug_assert_eq!(out.len(), t * w.n_out);
    let n_out = w.n_out;
    let n_panels = n_out.div_ceil(GEMM_NR).max(1);
    let mut i0 = 0usize;
    while i0 < t {
        let mr = (t - i0).min(GEMM_MR);
        for p in 0..n_panels {
            let j0 = p * GEMM_NR;
            let jn = n_out.saturating_sub(j0).min(GEMM_NR);
            let panel = &w.panels[p * n_in * GEMM_NR..(p + 1) * n_in * GEMM_NR];
            let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
            for k in 0..n_in {
                let wrow = &panel[k * GEMM_NR..(k + 1) * GEMM_NR];
                for r in 0..mr {
                    let xv = x[(i0 + r) * n_in + k];
                    let a = &mut acc[r];
                    for c in 0..GEMM_NR {
                        a[c] += xv * wrow[c];
                    }
                }
            }
            for r in 0..mr {
                let orow = &mut out[(i0 + r) * n_out + j0..(i0 + r) * n_out + j0 + jn];
                for (o, &a) in orow.iter_mut().zip(acc[r].iter()) {
                    *o += a;
                }
            }
        }
        i0 += mr;
    }
}

/// Row-parallel [`gemm_acc_packed`]: output rows are partitioned into
/// [`GEMM_ROW_TILE`]-row tiles fanned out over scoped workers. Each row
/// is computed by exactly one worker with the same per-element op order
/// as the serial kernel, so results are bit-identical for any thread
/// count or row partition.
pub fn gemm_acc_packed_par(
    threads: usize,
    x: &[f32],
    t: usize,
    n_in: usize,
    w: &PackedMat,
    out: &mut [f32],
) {
    if threads <= 1 || t < 2 * GEMM_ROW_TILE {
        gemm_acc_packed(x, t, n_in, w, out);
        return;
    }
    let n_out = w.n_out;
    crate::util::threadpool::parallel_chunks_mut(
        threads,
        out,
        GEMM_ROW_TILE * n_out,
        |ci, chunk| {
            let r0 = ci * GEMM_ROW_TILE;
            let rows = chunk.len() / n_out;
            gemm_acc_packed(&x[r0 * n_in..(r0 + rows) * n_in], rows, n_in, w, chunk);
        },
    );
}

/// Unpacked `out[t, n_out] += x[t, n_in] @ w` (row-major `w`), k-outer
/// with independent column accumulator lanes and no per-element branch.
/// Used where packing isn't worth it (tiny LoRA factors).
pub fn gemm_acc(x: &[f32], t: usize, n_in: usize, w: &[f32], n_out: usize, out: &mut [f32]) {
    debug_assert_eq!(x.len(), t * n_in);
    debug_assert_eq!(w.len(), n_in * n_out);
    debug_assert!(out.len() >= t * n_out);
    for i in 0..t {
        let xrow = &x[i * n_in..(i + 1) * n_in];
        let orow = &mut out[i * n_out..(i + 1) * n_out];
        for (k, &xv) in xrow.iter().enumerate() {
            let wrow = &w[k * n_out..(k + 1) * n_out];
            for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                *o += xv * wv;
            }
        }
    }
}

/// `dot(a, b)` over the common prefix, with four independent
/// accumulator lanes. This is *the* row-dot of the codebase: the
/// streaming attention kernels and the KV arena's fused-dequant
/// accessors both call it, so dense and paged f32 paths run identical
/// float operations in identical order.
#[inline(always)]
pub fn dot4(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let m = n & !3;
    let mut acc = [0.0f32; 4];
    let mut i = 0;
    while i < m {
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t234() -> TensorF {
        TensorF::new(vec![2, 3, 4], (0..24).map(|x| x as f32).collect())
    }

    #[test]
    fn index_views() {
        let t = t234();
        assert_eq!(t.index(&[1]), &(12..24).map(|x| x as f32).collect::<Vec<_>>()[..]);
        assert_eq!(t.index(&[0, 2]), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(t.index(&[1, 0]), &[12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn gather_middle_axis() {
        let t = t234();
        let g = t.gather(1, &[2, 0]);
        assert_eq!(g.shape, vec![2, 2, 4]);
        assert_eq!(g.index(&[0, 0]), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(g.index(&[0, 1]), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(g.index(&[1, 0]), &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn gather_then_scatter_roundtrip_subset() {
        let t = t234();
        let idx = [1usize, 2];
        let g = t.gather(1, &idx);
        let s = g.scatter_rows(1, &idx, 3);
        assert_eq!(s.index(&[0, 1]), t.index(&[0, 1]));
        assert_eq!(s.index(&[0, 2]), t.index(&[0, 2]));
        assert_eq!(s.index(&[0, 0]), &[0.0; 4][..]);
    }

    #[test]
    fn resize_axis_pads_and_truncates() {
        let t = t234();
        let p = t.resize_axis(1, 5);
        assert_eq!(p.shape, vec![2, 5, 4]);
        assert_eq!(p.index(&[0, 2]), t.index(&[0, 2]));
        assert_eq!(p.index(&[0, 4]), &[0.0; 4][..]);
        let tr = t.resize_axis(1, 2);
        assert_eq!(tr.shape, vec![2, 2, 4]);
        assert_eq!(tr.index(&[1, 1]), t.index(&[1, 1]));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        TensorF::new(vec![2, 2], vec![0.0; 3]);
    }

    /// Reference scalar matmul for the GEMM equivalence checks.
    fn matmul_ref(x: &[f32], t: usize, n_in: usize, w: &TensorF) -> Vec<f32> {
        let n_out = w.shape[1];
        let mut out = vec![0.0f32; t * n_out];
        for i in 0..t {
            for k in 0..n_in {
                let xv = x[i * n_in + k];
                for j in 0..n_out {
                    out[i * n_out + j] += xv * w.data[k * n_out + j];
                }
            }
        }
        out
    }

    fn pseudo(n: usize, seed: u64) -> Vec<f32> {
        // tiny deterministic LCG; values in [-1, 1)
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).max(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) - 1.0
            })
            .collect()
    }

    /// Packed GEMM matches the scalar reference over shapes that do and
    /// do not divide the register tiles.
    #[test]
    fn packed_gemm_matches_reference_over_odd_shapes() {
        for &(t, n_in, n_out) in
            &[(1usize, 3usize, 5usize), (4, 16, 16), (7, 13, 33), (19, 64, 17), (33, 5, 1)]
        {
            let x = pseudo(t * n_in, (t * 131 + n_in) as u64);
            let w = TensorF::new(vec![n_in, n_out], pseudo(n_in * n_out, n_out as u64 + 7));
            let want = matmul_ref(&x, t, n_in, &w);
            let packed = PackedMat::pack(&w);
            let mut got = vec![0.0f32; t * n_out];
            gemm_acc_packed(&x, t, n_in, &packed, &mut got);
            for (a, b) in want.iter().zip(got.iter()) {
                assert!(
                    (a - b).abs() <= 1e-5 * a.abs().max(1.0),
                    "({t},{n_in},{n_out}): {a} vs {b}"
                );
            }
            // unpacked branch-free kernel too
            let mut got2 = vec![0.0f32; t * n_out];
            gemm_acc(&x, t, n_in, &w.data, n_out, &mut got2);
            for (a, b) in want.iter().zip(got2.iter()) {
                assert!((a - b).abs() <= 1e-5 * a.abs().max(1.0));
            }
        }
    }

    /// Row-parallel GEMM must be bit-identical to the serial kernel for
    /// any thread count (each row is computed by exactly one worker with
    /// the same op order).
    #[test]
    fn parallel_gemm_is_bit_identical_to_serial() {
        let (t, n_in, n_out) = (70usize, 24usize, 21usize);
        let x = pseudo(t * n_in, 3);
        let w = TensorF::new(vec![n_in, n_out], pseudo(n_in * n_out, 4));
        let packed = PackedMat::pack(&w);
        let mut serial = vec![0.0f32; t * n_out];
        gemm_acc_packed(&x, t, n_in, &packed, &mut serial);
        for threads in [2usize, 3, 5] {
            let mut par = vec![0.0f32; t * n_out];
            gemm_acc_packed_par(threads, &x, t, n_in, &packed, &mut par);
            assert_eq!(serial, par, "threads={threads}");
        }
    }

    #[test]
    fn gemm_accumulates_into_existing_output() {
        let w = TensorF::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]); // identity
        let packed = PackedMat::pack(&w);
        let mut out = vec![10.0f32, 20.0, 30.0, 40.0];
        gemm_acc_packed(&[1.0, 2.0, 3.0, 4.0], 2, 2, &packed, &mut out);
        assert_eq!(out, vec![11.0, 22.0, 33.0, 44.0]);
        assert!(packed.packed_bytes() >= 2 * 2 * 4);
    }
}

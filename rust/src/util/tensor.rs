//! Host-side dense tensors (f32 / i32) with the small operation surface
//! the coordinator needs: shape bookkeeping, slicing along the leading
//! axes, and gather along a middle axis (the eviction compaction step).
//!
//! These mirror `xla::Literal` contents; conversion lives in
//! `runtime::literal`.

#[derive(Debug, Clone, PartialEq)]
pub struct TensorF {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorI {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

impl TensorF {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(numel(&shape), data.len(), "shape {shape:?} vs {} elems", data.len());
        TensorF { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = numel(&shape);
        TensorF { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// View of the sub-tensor at leading indices `idx` (e.g. `[l, h]` of an
    /// `[L, H, S]` tensor returns the `[S]` slice).
    pub fn index(&self, idx: &[usize]) -> &[f32] {
        let strides = self.strides();
        assert!(idx.len() <= self.shape.len());
        let mut off = 0;
        for (d, &i) in idx.iter().enumerate() {
            assert!(i < self.shape[d], "index {i} out of bounds for dim {d} ({})", self.shape[d]);
            off += i * strides[d];
        }
        let span: usize = self.shape[idx.len()..].iter().product();
        &self.data[off..off + span]
    }

    /// Gather along axis `axis`, keeping rows `indices` (in order).
    /// E.g. compacting `[L, Hkv, S, dh]` caches with axis=2.
    pub fn gather(&self, axis: usize, indices: &[usize]) -> TensorF {
        assert!(axis < self.shape.len());
        let outer: usize = self.shape[..axis].iter().product();
        let mid = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = indices.len();
        let mut out = Vec::with_capacity(outer * indices.len() * inner);
        for o in 0..outer {
            let base = o * mid * inner;
            for &i in indices {
                assert!(i < mid, "gather index {i} out of bounds ({mid})");
                out.extend_from_slice(&self.data[base + i * inner..base + (i + 1) * inner]);
            }
        }
        TensorF::new(shape, out)
    }

    /// Scatter rows of `self` (axis `axis`) into a zero tensor with the
    /// given axis size, placing row j at `indices[j]`. Inverse of gather.
    pub fn scatter_rows(&self, axis: usize, indices: &[usize], new_size: usize) -> TensorF {
        assert_eq!(self.shape[axis], indices.len());
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = new_size;
        let mut out = vec![0.0f32; outer * new_size * inner];
        for o in 0..outer {
            for (j, &i) in indices.iter().enumerate() {
                assert!(i < new_size);
                let src = (o * indices.len() + j) * inner;
                let dst = (o * new_size + i) * inner;
                out[dst..dst + inner].copy_from_slice(&self.data[src..src + inner]);
            }
        }
        TensorF::new(shape, out)
    }

    /// Pad (or truncate) axis `axis` to `new_size` with zeros at the end.
    pub fn resize_axis(&self, axis: usize, new_size: usize) -> TensorF {
        let outer: usize = self.shape[..axis].iter().product();
        let mid = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut shape = self.shape.clone();
        shape[axis] = new_size;
        let mut out = vec![0.0f32; outer * new_size * inner];
        let copy = mid.min(new_size);
        for o in 0..outer {
            let src = o * mid * inner;
            let dst = o * new_size * inner;
            out[dst..dst + copy * inner].copy_from_slice(&self.data[src..src + copy * inner]);
        }
        TensorF::new(shape, out)
    }
}

impl TensorI {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(numel(&shape), data.len());
        TensorI { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        TensorI { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(v: Vec<i32>) -> Self {
        TensorI { shape: vec![v.len()], data: v }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t234() -> TensorF {
        TensorF::new(vec![2, 3, 4], (0..24).map(|x| x as f32).collect())
    }

    #[test]
    fn index_views() {
        let t = t234();
        assert_eq!(t.index(&[1]), &(12..24).map(|x| x as f32).collect::<Vec<_>>()[..]);
        assert_eq!(t.index(&[0, 2]), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(t.index(&[1, 0]), &[12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn gather_middle_axis() {
        let t = t234();
        let g = t.gather(1, &[2, 0]);
        assert_eq!(g.shape, vec![2, 2, 4]);
        assert_eq!(g.index(&[0, 0]), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(g.index(&[0, 1]), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(g.index(&[1, 0]), &[20.0, 21.0, 22.0, 23.0]);
    }

    #[test]
    fn gather_then_scatter_roundtrip_subset() {
        let t = t234();
        let idx = [1usize, 2];
        let g = t.gather(1, &idx);
        let s = g.scatter_rows(1, &idx, 3);
        assert_eq!(s.index(&[0, 1]), t.index(&[0, 1]));
        assert_eq!(s.index(&[0, 2]), t.index(&[0, 2]));
        assert_eq!(s.index(&[0, 0]), &[0.0; 4][..]);
    }

    #[test]
    fn resize_axis_pads_and_truncates() {
        let t = t234();
        let p = t.resize_axis(1, 5);
        assert_eq!(p.shape, vec![2, 5, 4]);
        assert_eq!(p.index(&[0, 2]), t.index(&[0, 2]));
        assert_eq!(p.index(&[0, 4]), &[0.0; 4][..]);
        let tr = t.resize_axis(1, 2);
        assert_eq!(tr.shape, vec![2, 2, 4]);
        assert_eq!(tr.index(&[1, 1]), t.index(&[1, 1]));
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        TensorF::new(vec![2, 2], vec![0.0; 3]);
    }
}

//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw args (excluding argv[0]). `flag_names` lists options that
    /// take no value.
    pub fn parse(raw: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if i + 1 < raw.len() {
                    out.options.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw, flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `usize` option clamped into `[lo, hi]` (0 stays 0 when `lo` is 0 —
    /// used for "0 = disabled" knobs like `--prefill-chunk`).
    pub fn usize_clamped(&self, key: &str, default: usize, lo: usize, hi: usize) -> usize {
        self.usize(key, default).clamp(lo, hi)
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    /// Comma-separated list option.
    pub fn list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(str::to_string).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    pub fn usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            Some(v) => v.split(',').filter_map(|s| s.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&s(&["serve", "--port", "8080", "--verbose", "--x=1,2"]), &["verbose"]);
        assert_eq!(a.positional, vec!["serve"]);
        assert_eq!(a.usize("port", 0), 8080);
        assert!(a.has("verbose"));
        assert_eq!(a.usize_list("x", &[]), vec![1, 2]);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&s(&[]), &[]);
        assert_eq!(a.get_or("model", "lkv-tiny"), "lkv-tiny");
        assert_eq!(a.f64("t", 0.5), 0.5);
        assert_eq!(a.list("methods", &["a", "b"]), vec!["a", "b"]);
    }

    #[test]
    fn trailing_key_becomes_flag() {
        let a = Args::parse(&s(&["--end"]), &[]);
        assert!(a.has("end"));
    }

    #[test]
    fn usize_clamped_bounds() {
        let a = Args::parse(&s(&["--prefill-chunk", "100000"]), &[]);
        assert_eq!(a.usize_clamped("prefill-chunk", 0, 0, 1024), 1024);
        let a = Args::parse(&s(&[]), &[]);
        assert_eq!(a.usize_clamped("prefill-chunk", 0, 0, 1024), 0);
        let a = Args::parse(&s(&["--prefill-chunk=64"]), &[]);
        assert_eq!(a.usize_clamped("prefill-chunk", 0, 0, 1024), 64);
    }
}

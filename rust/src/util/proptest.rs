//! Minimal property-based testing driver (proptest is unavailable
//! offline): run a property over many seeded random cases and, on
//! failure, report the failing seed so the case is reproducible.
//!
//! Shrinking is seed-based: the harness retries the property with a
//! sequence of "smaller" size hints for the failing seed and reports the
//! smallest size that still fails.

use crate::util::rng::Rng;

pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// Maximum size hint passed to the generator.
    pub max_size: usize,
}

pub const DEFAULT_SEED: u64 = 0x1001_cafe_f00d;

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: DEFAULT_SEED, max_size: 128 }
    }
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }
}

/// Run `prop(rng, size)` for `cfg.cases` random cases. The property panics
/// (e.g. via assert!) to signal failure.
pub fn check<F: Fn(&mut Rng, usize) + std::panic::RefUnwindSafe>(name: &str, cfg: &Config, prop: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64);
        let size = 1 + (case * cfg.max_size) / cfg.cases.max(1);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng, size);
        });
        if let Err(err) = result {
            // shrink: find the smallest size that still fails for this seed
            let mut min_fail = size;
            for s in 1..size {
                let r = std::panic::catch_unwind(|| {
                    let mut rng = Rng::new(case_seed);
                    prop(&mut rng, s);
                });
                if r.is_err() {
                    min_fail = s;
                    break;
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {case_seed:#x}, size {size}, \
                 min failing size {min_fail}): {err:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check("sort idempotent", &Config { cases: 64, ..Config::new() }, |rng, size| {
            let mut v: Vec<u64> = (0..size).map(|_| rng.next_u64() % 100).collect();
            v.sort_unstable();
            let w = v.clone();
            v.sort_unstable();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failure_with_seed() {
        check("always fails at size>=3", &Config { cases: 16, ..Config::new() }, |_rng, size| {
            assert!(size < 3, "too big");
        });
    }
}

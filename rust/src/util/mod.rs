//! Infrastructure utilities.
//!
//! The offline vendor tree only carries the `xla` crate's dependency
//! closure, so the roles usually played by serde/clap/criterion/tokio/
//! proptest/rand are covered by the small, dependency-free modules here
//! (exercised by the README "Tier-1 verify" workflow).

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod threadpool;

//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations with early stop on time budget, summary stats, and a
//! JSON line per benchmark appended to `results/bench.jsonl` so the paper
//! tables can cite exact runs.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_time: Duration::from_secs(10),
        }
    }
}

/// Smoke mode (`LKV_BENCH_SMOKE=1`): clamp every benchmark to a couple of
/// iterations so CI can exercise the whole bench surface in seconds while
/// still producing comparable `BENCH_*.json` artifacts.
pub fn smoke_mode() -> bool {
    std::env::var("LKV_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

impl BenchConfig {
    fn effective(&self) -> BenchConfig {
        if smoke_mode() {
            BenchConfig {
                warmup_iters: self.warmup_iters.min(1),
                min_iters: self.min_iters.min(2),
                max_iters: self.max_iters.min(2),
                max_time: self.max_time.min(Duration::from_secs(2)),
            }
        } else {
            self.clone()
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wallclock in milliseconds.
    pub ms: Summary,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", self.name.as_str().into()),
            ("iters", self.iters.into()),
            ("mean_ms", self.ms.mean.into()),
            ("std_ms", self.ms.std.into()),
            ("p50_ms", self.ms.p50.into()),
            ("p90_ms", self.ms.p90.into()),
            ("p99_ms", self.ms.p99.into()),
            ("min_ms", self.ms.min.into()),
            ("max_ms", self.ms.max.into()),
        ])
    }
}

pub fn run_bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    let cfg = cfg.effective();
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || start.elapsed() < cfg.max_time)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let res = BenchResult { name: name.to_string(), iters: samples.len(), ms: summarize(&samples) };
    println!(
        "bench {:<48} {:>8.3} ms/iter  (p50 {:.3}, p99 {:.3}, n={})",
        res.name, res.ms.mean, res.ms.p50, res.ms.p99, res.iters
    );
    res
}

/// Append results to `results/bench.jsonl` (best-effort).
pub fn record(results: &[BenchResult]) {
    let _ = std::fs::create_dir_all("results");
    let mut lines = String::new();
    for r in results {
        lines.push_str(&r.to_json().to_string());
        lines.push('\n');
    }
    use std::io::Write;
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open("results/bench.jsonl")
    {
        let _ = f.write_all(lines.as_bytes());
    }
}

/// Record to the rolling jsonl *and* overwrite
/// `results/BENCH_<bench>.json` with this run's full result array — the
/// per-bench artifact CI uploads so the perf trajectory accumulates.
pub fn record_named(bench: &str, results: &[BenchResult]) {
    record(results);
    let arr = Json::Arr(results.iter().map(BenchResult::to_json).collect());
    let path = format!("results/BENCH_{bench}.json");
    if std::fs::write(&path, arr.to_string()).is_ok() {
        println!("wrote {path} ({} benchmarks)", results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            max_time: Duration::from_secs(1),
        };
        let r = run_bench("sleep1ms", &cfg, || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(r.iters, 5);
        assert!(r.ms.mean >= 0.9, "mean {:.3}", r.ms.mean);
    }
}

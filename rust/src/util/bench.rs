//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed iterations with early stop on time budget, summary stats, and a
//! JSON line per benchmark appended to `results/bench.jsonl` so the paper
//! tables can cite exact runs.
//!
//! The bench-regression gate lives here too (`gate_compare` +
//! `load_bench_entries`, driven by the `bench_gate` bin): it compares a
//! run's `BENCH_*.json` against the committed `rust/baselines/` copies,
//! normalizing by the run's **median cur/base ratio** so absolute machine
//! speed cancels out — only benchmarks that got slower *relative to the
//! rest of the run* fail the gate.

use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::{self, Json};
use crate::util::stats::{percentile_sorted, summarize, Summary};

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 200,
            max_time: Duration::from_secs(10),
        }
    }
}

/// Smoke mode (`LKV_BENCH_SMOKE=1`): clamp every benchmark to a couple of
/// iterations so CI can exercise the whole bench surface in seconds while
/// still producing comparable `BENCH_*.json` artifacts.
pub fn smoke_mode() -> bool {
    std::env::var("LKV_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

impl BenchConfig {
    fn effective(&self) -> BenchConfig {
        if smoke_mode() {
            BenchConfig {
                warmup_iters: self.warmup_iters.min(1),
                min_iters: self.min_iters.min(2),
                max_iters: self.max_iters.min(2),
                max_time: self.max_time.min(Duration::from_secs(2)),
            }
        } else {
            self.clone()
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration wallclock in milliseconds.
    pub ms: Summary,
    /// Extra named columns serialized alongside the timing stats (e.g.
    /// `prefill_scratch_bytes`). Never read by the regression gate —
    /// informational artifact columns only.
    pub extras: Vec<(String, f64)>,
}

impl BenchResult {
    /// Attach an extra named column (builder-style).
    pub fn with_extra(mut self, name: &str, value: f64) -> BenchResult {
        self.extras.push((name.to_string(), value));
        self
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs(vec![
            ("name", self.name.as_str().into()),
            ("iters", self.iters.into()),
            ("mean_ms", self.ms.mean.into()),
            ("std_ms", self.ms.std.into()),
            ("p50_ms", self.ms.p50.into()),
            ("p90_ms", self.ms.p90.into()),
            ("p99_ms", self.ms.p99.into()),
            ("min_ms", self.ms.min.into()),
            ("max_ms", self.ms.max.into()),
        ]);
        for (k, v) in &self.extras {
            j.set(k, (*v).into());
        }
        j
    }
}

pub fn run_bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    let cfg = cfg.effective();
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let start = Instant::now();
    let mut samples = Vec::new();
    while samples.len() < cfg.max_iters
        && (samples.len() < cfg.min_iters || start.elapsed() < cfg.max_time)
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let res = BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        ms: summarize(&samples),
        extras: Vec::new(),
    };
    println!(
        "bench {:<48} {:>8.3} ms/iter  (p50 {:.3}, p99 {:.3}, n={})",
        res.name, res.ms.mean, res.ms.p50, res.ms.p99, res.iters
    );
    res
}

/// Append results to `results/bench.jsonl` (best-effort).
pub fn record(results: &[BenchResult]) {
    let _ = std::fs::create_dir_all("results");
    let mut lines = String::new();
    for r in results {
        lines.push_str(&r.to_json().to_string());
        lines.push('\n');
    }
    use std::io::Write;
    if let Ok(mut f) =
        std::fs::OpenOptions::new().create(true).append(true).open("results/bench.jsonl")
    {
        let _ = f.write_all(lines.as_bytes());
    }
}

/// Record to the rolling jsonl *and* overwrite
/// `results/BENCH_<bench>.json` with this run's full result array — the
/// per-bench artifact CI uploads so the perf trajectory accumulates.
pub fn record_named(bench: &str, results: &[BenchResult]) {
    record(results);
    let arr = Json::Arr(results.iter().map(BenchResult::to_json).collect());
    let path = format!("results/BENCH_{bench}.json");
    if std::fs::write(&path, arr.to_string()).is_ok() {
        println!("wrote {path} ({} benchmarks)", results.len());
    }
}

// ---------------------------------------------------------------------------
// Bench-regression gate
// ---------------------------------------------------------------------------

/// One tracked benchmark compared against its committed baseline.
#[derive(Debug, Clone)]
pub struct GateRow {
    pub name: String,
    pub base_ms: f64,
    pub cur_ms: f64,
    /// `cur / base`.
    pub ratio: f64,
    /// `ratio` divided by the run's median ratio (machine-speed
    /// calibration: a uniformly slower host shifts every ratio equally
    /// and cancels out).
    pub norm_ratio: f64,
    /// Baseline below the noise floor — reported, never failed.
    pub below_floor: bool,
    pub regressed: bool,
}

/// The result of gating one `BENCH_*.json` pair.
#[derive(Debug, Clone, Default)]
pub struct GateReport {
    pub rows: Vec<GateRow>,
    /// Baseline entries with no counterpart in the current run
    /// (coverage rot — reported as warnings).
    pub missing: Vec<String>,
    /// Median cur/base ratio used as the machine-speed calibration.
    pub calibration: f64,
    pub threshold: f64,
    pub floor_ms: f64,
}

impl GateReport {
    pub fn failed(&self) -> bool {
        self.rows.iter().any(|r| r.regressed)
    }

    pub fn to_json(&self) -> Json {
        let rows = self
            .rows
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("name", r.name.as_str().into()),
                    ("base_ms", r.base_ms.into()),
                    ("cur_ms", r.cur_ms.into()),
                    ("ratio", r.ratio.into()),
                    ("norm_ratio", r.norm_ratio.into()),
                    ("below_floor", r.below_floor.into()),
                    ("regressed", r.regressed.into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("failed", self.failed().into()),
            ("calibration", self.calibration.into()),
            ("threshold", self.threshold.into()),
            ("floor_ms", self.floor_ms.into()),
            ("missing", Json::Arr(self.missing.iter().map(|m| m.as_str().into()).collect())),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Compare a run against its baseline. Entries are `(name, min_ms)` —
/// min-of-iterations is the most noise-robust point of a short smoke
/// run. A tracked metric **regresses** when its cur/base ratio exceeds
/// both `1 + threshold` outright *and* the run's median ratio by more
/// than `threshold` (e.g. 0.25 = 25%) — the median normalization cancels
/// machine speed without letting a broadly-improved run flag its
/// untouched benchmarks. Baselines faster than `floor_ms` never fail the
/// gate: sub-floor smoke timings are dominated by scheduler noise.
pub fn gate_compare(
    baseline: &[(String, f64)],
    current: &[(String, f64)],
    threshold: f64,
    floor_ms: f64,
) -> GateReport {
    use std::collections::BTreeMap;
    let cur: BTreeMap<&str, f64> = current.iter().map(|(n, v)| (n.as_str(), *v)).collect();
    let mut rows = Vec::new();
    let mut missing = Vec::new();
    let mut ratios = Vec::new();
    for (name, base) in baseline {
        match cur.get(name.as_str()) {
            Some(&c) if *base > 0.0 && c > 0.0 => {
                let ratio = c / *base;
                ratios.push(ratio);
                rows.push(GateRow {
                    name: name.clone(),
                    base_ms: *base,
                    cur_ms: c,
                    ratio,
                    norm_ratio: ratio,
                    below_floor: *base < floor_ms,
                    regressed: false,
                });
            }
            _ => missing.push(name.clone()),
        }
    }
    ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut calibration = percentile_sorted(&ratios, 0.5);
    if !calibration.is_finite() || calibration <= 0.0 {
        calibration = 1.0;
    }
    for r in rows.iter_mut() {
        r.norm_ratio = r.ratio / calibration;
        // Both conditions must hold: slower than the rest of the run
        // (norm) AND slower than its own baseline (raw) — otherwise a PR
        // that genuinely speeds up most benches would shift the median
        // below 1 and flag the untouched ones.
        r.regressed = !r.below_floor
            && r.norm_ratio > 1.0 + threshold
            && r.ratio > 1.0 + threshold;
    }
    GateReport { rows, missing, calibration, threshold, floor_ms }
}

/// Render the worst regressing rows across a set of gate reports as a
/// GitHub-flavored-markdown fragment (what CI appends to
/// `$GITHUB_STEP_SUMMARY` when the gate fails). Rows above the noise
/// floor and slower than their calibrated baseline (`norm_ratio > 1`)
/// are sorted worst-first and truncated to `limit`; baseline entries
/// missing from the current run are appended as warnings.
pub fn worst_rows_markdown(reports: &[(String, GateReport)], limit: usize) -> String {
    let mut rows: Vec<(&str, &GateRow)> = reports
        .iter()
        .flat_map(|(file, rep)| {
            rep.rows
                .iter()
                .filter(|r| !r.below_floor && r.norm_ratio > 1.0)
                .map(move |r| (file.as_str(), r))
        })
        .collect();
    rows.sort_by(|a, b| b.1.norm_ratio.total_cmp(&a.1.norm_ratio));
    rows.truncate(limit);
    let mut md = String::from("## Bench gate: worst regressing rows\n\n");
    if rows.is_empty() {
        md.push_str("No current row is slower than its calibrated baseline.\n");
    } else {
        md.push_str("| file | benchmark | base ms | cur ms | norm ratio | status |\n");
        md.push_str("|---|---|---:|---:|---:|---|\n");
        for (file, r) in rows {
            let status = if r.regressed { "**REGRESSED**" } else { "ok" };
            md.push_str(&format!(
                "| {file} | {} | {:.3} | {:.3} | {:.2}x | {status} |\n",
                r.name, r.base_ms, r.cur_ms, r.norm_ratio
            ));
        }
    }
    let missing: Vec<String> = reports
        .iter()
        .flat_map(|(file, rep)| rep.missing.iter().map(move |m| format!("`{file}`: {m}")))
        .collect();
    if !missing.is_empty() {
        md.push_str("\n**Tracked benchmarks missing from the current run:**\n\n");
        for m in &missing {
            md.push_str(&format!("- {m}\n"));
        }
    }
    md
}

/// Read the `(name, min_ms)` entries of one `BENCH_*.json` artifact (the
/// array format written by [`record_named`]).
pub fn load_bench_entries(path: &Path) -> Result<Vec<(String, f64)>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    let v = json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
    let arr = v.as_arr().with_context(|| format!("{}: not a JSON array", path.display()))?;
    let mut out = Vec::with_capacity(arr.len());
    for item in arr {
        let name = item
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("{}: entry without a name", path.display()))?;
        let ms = item
            .get("min_ms")
            .and_then(Json::as_f64)
            .with_context(|| format!("{}: {name} has no min_ms", path.display()))?;
        out.push((name.to_string(), ms));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 5,
            max_time: Duration::from_secs(1),
        };
        let r = run_bench("sleep1ms", &cfg, || std::thread::sleep(Duration::from_millis(1)));
        assert_eq!(r.iters, 5);
        assert!(r.ms.mean >= 0.9, "mean {:.3}", r.ms.mean);
    }

    fn entries(v: &[(&str, f64)]) -> Vec<(String, f64)> {
        v.iter().map(|(n, x)| (n.to_string(), *x)).collect()
    }

    #[test]
    fn gate_passes_on_identical_runs() {
        let base = entries(&[("a", 10.0), ("b", 20.0), ("c", 5.0)]);
        let rep = gate_compare(&base, &base, 0.25, 0.5);
        assert!(!rep.failed());
        assert!(rep.missing.is_empty());
        assert!((rep.calibration - 1.0).abs() < 1e-9);
        assert!(rep.rows.iter().all(|r| !r.regressed && (r.norm_ratio - 1.0).abs() < 1e-9));
    }

    /// A uniformly slower host shifts every ratio equally — the median
    /// calibration cancels it and the gate stays green.
    #[test]
    fn gate_calibrates_out_machine_speed() {
        let base = entries(&[("a", 10.0), ("b", 20.0), ("c", 5.0), ("d", 40.0)]);
        let cur = entries(&[("a", 30.0), ("b", 60.0), ("c", 15.0), ("d", 120.0)]);
        let rep = gate_compare(&base, &cur, 0.25, 0.5);
        assert!(!rep.failed(), "uniform 3x slowdown must calibrate away");
        assert!((rep.calibration - 3.0).abs() < 1e-9);
    }

    /// A run that genuinely speeds up most benches shifts the median
    /// below 1 — the untouched benches must NOT be flagged (their raw
    /// ratio is still 1.0).
    #[test]
    fn gate_ignores_untouched_benches_when_others_improve() {
        let base = entries(&[("a", 10.0), ("b", 20.0), ("c", 40.0), ("d", 8.0), ("e", 16.0)]);
        let cur = entries(&[("a", 5.0), ("b", 10.0), ("c", 20.0), ("d", 8.0), ("e", 16.0)]);
        let rep = gate_compare(&base, &cur, 0.25, 0.5);
        assert!(!rep.failed(), "a pure-improvement run must pass: {:?}", rep.rows);
    }

    /// An injected >25% regression on one benchmark fails the gate — the
    /// scenario the CI bench-smoke job is built to catch.
    #[test]
    fn gate_fails_on_injected_regression() {
        let base = entries(&[("a", 10.0), ("b", 20.0), ("c", 5.0), ("d", 40.0), ("e", 8.0)]);
        let mut cur = base.clone();
        cur[1].1 *= 2.0; // inject: "b" got 2x slower
        let rep = gate_compare(&base, &cur, 0.25, 0.5);
        assert!(rep.failed());
        let bad: Vec<&str> =
            rep.rows.iter().filter(|r| r.regressed).map(|r| r.name.as_str()).collect();
        assert_eq!(bad, vec!["b"]);
        assert!(rep.to_json().req("failed").as_bool().unwrap());
    }

    #[test]
    fn gate_respects_noise_floor_and_reports_missing() {
        // "tiny" is below the 0.5ms floor: 10x slower but never failed
        let base = entries(&[("tiny", 0.01), ("a", 10.0), ("b", 20.0), ("gone", 7.0)]);
        let cur = entries(&[("tiny", 0.1), ("a", 10.0), ("b", 20.0), ("new", 3.0)]);
        let rep = gate_compare(&base, &cur, 0.25, 0.5);
        assert!(!rep.failed());
        let tiny = rep.rows.iter().find(|r| r.name == "tiny").unwrap();
        assert!(tiny.below_floor && !tiny.regressed);
        assert_eq!(rep.missing, vec!["gone".to_string()]);
    }

    /// The step-summary table leads with the worst offender, bolds only
    /// genuinely regressed rows, and drops sub-floor noise. Ratios here:
    /// c/d/e 1.0, a 1.3, b 2.0, tiny 20 (sub-floor) — calibration is the
    /// interpolated median 1.15, so a (norm 1.13) is slow-but-ok and b
    /// (norm 1.74) is the only regression.
    #[test]
    fn worst_rows_markdown_ranks_and_flags() {
        let base = entries(&[
            ("a", 10.0),
            ("b", 20.0),
            ("c", 5.0),
            ("d", 8.0),
            ("e", 16.0),
            ("tiny", 0.01),
            ("gone", 4.0),
        ]);
        let cur = entries(&[
            ("a", 13.0),
            ("b", 40.0),
            ("c", 5.0),
            ("d", 8.0),
            ("e", 16.0),
            ("tiny", 0.2),
        ]);
        let rep = gate_compare(&base, &cur, 0.25, 0.5);
        assert!(rep.failed());
        let md = worst_rows_markdown(&[("BENCH_demo.json".to_string(), rep)], 10);
        let lines: Vec<&str> = md.lines().collect();
        let b_at = lines.iter().position(|l| l.contains("| b |")).expect("b row");
        let a_at = lines.iter().position(|l| l.contains("| a |")).expect("a row");
        assert!(b_at < a_at, "rows must be sorted worst-first:\n{md}");
        assert!(lines[b_at].contains("**REGRESSED**"), "{md}");
        assert!(lines[a_at].contains("| ok |"), "{md}");
        assert!(!md.contains("| c |"), "at-calibration rows must not appear:\n{md}");
        assert!(!md.contains("tiny"), "sub-floor rows must not appear:\n{md}");
        assert!(md.contains("gone"), "missing baselines must be warned about:\n{md}");
    }

    /// Ratios 4/3/2/1 calibrate to 2.5: a (1.6) and b (1.2) are above
    /// calibration, and `limit = 1` keeps only the worst.
    #[test]
    fn worst_rows_markdown_truncates_and_handles_empty() {
        let base = entries(&[("a", 10.0), ("b", 10.0), ("c", 10.0), ("d", 10.0)]);
        let cur = entries(&[("a", 40.0), ("b", 30.0), ("c", 20.0), ("d", 10.0)]);
        let rep = gate_compare(&base, &cur, 0.25, 0.5);
        let md = worst_rows_markdown(&[("BENCH_x.json".to_string(), rep)], 1);
        assert!(md.contains("| a |") && !md.contains("| b |"), "limit must truncate:\n{md}");
        let clean = gate_compare(&base, &base, 0.25, 0.5);
        let md = worst_rows_markdown(&[("BENCH_x.json".to_string(), clean)], 10);
        assert!(md.contains("No current row"), "{md}");
    }

    #[test]
    fn gate_roundtrips_bench_artifacts() {
        let dir = std::env::temp_dir().join(format!("lkv_gate_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_demo.json");
        let results = vec![
            BenchResult { name: "x".into(), iters: 2, ms: summarize(&[1.0, 2.0]), extras: vec![] }
                .with_extra("prefill_scratch_bytes", 1024.0),
            BenchResult { name: "y".into(), iters: 2, ms: summarize(&[3.0, 5.0]), extras: vec![] },
        ];
        let arr = Json::Arr(results.iter().map(BenchResult::to_json).collect());
        std::fs::write(&path, arr.to_string()).unwrap();
        let entries = load_bench_entries(&path).unwrap();
        assert_eq!(entries, vec![("x".to_string(), 1.0), ("y".to_string(), 3.0)]);
        let rep = gate_compare(&entries, &entries, 0.25, 0.5);
        assert!(!rep.failed());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

//! Descriptive statistics and ranking metrics used across the eval and
//! bench harnesses: summary stats, percentiles, top-k selection, recall@k
//! and Kendall's tau (Table 8).

/// Summary of a sample of f64 observations.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: percentile_sorted(&sorted, 0.50),
        p90: percentile_sorted(&sorted, 0.90),
        p99: percentile_sorted(&sorted, 0.99),
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Indices of the k largest values (ties broken toward lower index),
/// returned sorted ascending by index. O(n log k).
pub fn topk_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    // (score, negated index) min-heap of size k keeps the k best.
    use std::cmp::Ordering;
    use std::collections::BinaryHeap;

    #[derive(PartialEq)]
    struct Entry(f32, usize);
    impl Eq for Entry {}
    impl PartialOrd for Entry {
        fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
            Some(self.cmp(o))
        }
    }
    impl Ord for Entry {
        fn cmp(&self, o: &Self) -> Ordering {
            // Reverse: BinaryHeap is a max-heap, we want min at top.
            o.0.partial_cmp(&self.0)
                .unwrap_or(Ordering::Equal)
                .then(self.1.cmp(&o.1)) // prefer evicting higher index on ties
        }
    }

    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &s) in scores.iter().enumerate() {
        heap.push(Entry(s, i));
        if heap.len() > k {
            heap.pop();
        }
    }
    let mut idx: Vec<usize> = heap.into_iter().map(|e| e.1).collect();
    idx.sort_unstable();
    idx
}

/// recall@k between two score vectors: |topk(a) ∩ topk(b)| / k.
pub fn recall_at_k(a: &[f32], b: &[f32], k: usize) -> f64 {
    let ka = topk_indices(a, k);
    let kb = topk_indices(b, k);
    let set: std::collections::HashSet<usize> = ka.into_iter().collect();
    let inter = kb.iter().filter(|i| set.contains(i)).count();
    inter as f64 / k.min(a.len()).max(1) as f64
}

/// Kendall's tau-a rank correlation. O(n^2); fine for n <= ~2k.
pub fn kendall_tau(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n < 2 {
        return 1.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            let da = a[i] - a[j];
            let db = b[i] - b[j];
            let s = (da * db).signum();
            if s > 0.0 {
                concordant += 1;
            } else if s < 0.0 {
                discordant += 1;
            }
        }
    }
    let total = (n * (n - 1) / 2) as f64;
    (concordant - discordant) as f64 / total
}

/// L1-normalize in place; returns the original sum.
pub fn l1_normalize(xs: &mut [f32]) -> f32 {
    let sum: f32 = xs.iter().sum();
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.p50 - 2.5).abs() < 1e-12);
    }

    #[test]
    fn topk_matches_naive_sort() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..50 {
            let n = rng.range(1, 200);
            let k = rng.range(0, n);
            let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let got = topk_indices(&scores, k);
            // naive oracle
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&i, &j| {
                scores[j].partial_cmp(&scores[i]).unwrap().then(i.cmp(&j))
            });
            let mut want: Vec<usize> = order[..k].to_vec();
            want.sort_unstable();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn recall_self_is_one() {
        let v = vec![0.1f32, 0.9, 0.3, 0.5];
        assert_eq!(recall_at_k(&v, &v, 2), 1.0);
    }

    #[test]
    fn kendall_extremes() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0];
        let rev: Vec<f32> = a.iter().rev().cloned().collect();
        assert!((kendall_tau(&a, &a) - 1.0).abs() < 1e-12);
        assert!((kendall_tau(&a, &rev) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn l1_norm() {
        let mut v = vec![1.0f32, 3.0];
        let s = l1_normalize(&mut v);
        assert_eq!(s, 4.0);
        assert!((v[0] - 0.25).abs() < 1e-7);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 0.5) - 5.0).abs() < 1e-12);
    }
}

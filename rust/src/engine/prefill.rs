//! Prefill paths: base, lookahead, and the draft-augmented LAQ/SpecKV
//! pipelines, each producing KV + first-token logits + a score bundle.
//! Decode lives here too: the per-sequence `decode_step` (one backend
//! round-trip per sequence per token) and the batched `decode_step_batch`
//! (all active sequences advanced in one backend call, caches updated in
//! place).

use std::time::Instant;

use anyhow::{Context, Result};

use super::Engine;
use crate::eviction::{Method, ScoreBundle};
use crate::kvcache::{KvArena, KvDims, PagedSeqCache, SeqCache};
use crate::model::tokenizer::pad_to;
use crate::runtime::backend::decode_seq_via_execute;
use crate::runtime::{DecodeSeq, PagedDecodeSeq, Value};
use crate::util::rng::argmax;
use crate::util::tensor::TensorF;

/// Wallclock breakdown of one prefill+eviction (drives Fig. 2 / Table 3).
#[derive(Debug, Clone, Default)]
pub struct PrefillBreakdown {
    /// Main prefill graph (the "forward pass only" baseline component).
    pub forward_ms: f64,
    /// Draft generation (LAQ: target decode; SpecKV: draft model).
    pub draft_ms: f64,
    /// Second scoring pass over [prompt; draft] (LAQ/SpecKV).
    pub rescore_ms: f64,
    /// Score aggregation + top-k selection.
    pub select_ms: f64,
    /// KV gather/compaction into the decode cache.
    pub compact_ms: f64,
}

impl PrefillBreakdown {
    pub fn total_ms(&self) -> f64 {
        self.forward_ms + self.draft_ms + self.rescore_ms + self.select_ms + self.compact_ms
    }

    /// Eviction overhead = everything beyond the bare forward pass.
    pub fn overhead_ms(&self) -> f64 {
        self.total_ms() - self.forward_ms
    }
}

/// Raw prefill artifacts before selection.
pub struct PrefillOutput {
    /// Dense full-prompt KV (`[L, Hkv, bucket, dh]`) — empty placeholder
    /// tensors when `blocks` is set.
    pub k: TensorF,
    pub v: TensorF,
    pub logits: Vec<f32>,
    pub bundle: ScoreBundle,
    pub bucket: usize,
    pub breakdown: PrefillBreakdown,
    /// Arena block table holding the prompt KV of a *paged* chunked
    /// prefill (owned by the request; the scheduler frees it right after
    /// gather-compaction). `None` for the dense paths.
    pub blocks: Option<Vec<crate::kvcache::BlockId>>,
}

struct RawPrefill {
    k: TensorF,
    v: TensorF,
    logits: Vec<f32>,
    window_scores: TensorF,
    h2o_scores: TensorF,
}

impl Engine {
    /// Run `prefill_base` for `model` over `tokens` (padded to a bucket),
    /// reporting logits at `logit_pos`.
    fn run_prefill_base(
        &self,
        model: &str,
        tokens: &[i32],
        length: usize,
        logit_pos: usize,
    ) -> Result<(RawPrefill, usize)> {
        let m = self.rt.manifest();
        let bucket = m.prefill_bucket(length)?;
        let key = m.graph_key_prefill_base(model, bucket);
        let inputs = vec![
            Value::vec_i32(pad_to(tokens, bucket)),
            Value::scalar_i32(length as i32),
            Value::scalar_i32(logit_pos as i32),
        ];
        let out = self.rt.execute(&key, None, &inputs)?;
        anyhow::ensure!(out.len() == 5, "prefill graph {key}: {} outputs, want 5", out.len());
        // outputs: k, v, logits, window_scores, h2o_scores (manifest order)
        let mut it = out.into_iter();
        Ok((
            RawPrefill {
                k: it.next().unwrap().into_f32()?,
                v: it.next().unwrap().into_f32()?,
                logits: it.next().unwrap().into_vec_f32().context("logits")?,
                window_scores: it.next().unwrap().into_f32()?,
                h2o_scores: it.next().unwrap().into_f32()?,
            },
            bucket,
        ))
    }

    /// Run `prefill_pred` (base prefill plus the streamed per-KV-head
    /// importance MLP over pre-RoPE keys) for `model` over `tokens`.
    fn run_prefill_pred(
        &self,
        model: &str,
        tokens: &[i32],
        length: usize,
        logit_pos: usize,
    ) -> Result<(RawPrefill, TensorF, usize)> {
        let m = self.rt.manifest();
        anyhow::ensure!(
            m.predictor(model).is_some(),
            "no importance predictor for model {model:?} (manifest has no predictors entry)"
        );
        let bucket = m.prefill_bucket(length)?;
        let key = m.graph_key_prefill_pred(model, bucket);
        let inputs = vec![
            Value::vec_i32(pad_to(tokens, bucket)),
            Value::scalar_i32(length as i32),
            Value::scalar_i32(logit_pos as i32),
        ];
        let out = self.rt.execute(&key, None, &inputs)?;
        anyhow::ensure!(out.len() == 6, "predictor graph {key}: {} outputs, want 6", out.len());
        // outputs: k, v, logits, window_scores, h2o_scores, pred_scores
        let mut it = out.into_iter();
        let raw = RawPrefill {
            k: it.next().unwrap().into_f32()?,
            v: it.next().unwrap().into_f32()?,
            logits: it.next().unwrap().into_vec_f32().context("logits")?,
            window_scores: it.next().unwrap().into_f32()?,
            h2o_scores: it.next().unwrap().into_f32()?,
        };
        Ok((raw, it.next().unwrap().into_f32()?, bucket))
    }

    fn run_prefill_lkv(
        &self,
        model: &str,
        variant: &str,
        tokens: &[i32],
        length: usize,
    ) -> Result<(TensorF, TensorF, Vec<f32>, TensorF, usize)> {
        let m = self.rt.manifest();
        let bucket = m.prefill_bucket(length)?;
        let vmeta = m.variant(model, variant)?;
        let key = m.graph_key_prefill_lkv(model, bucket, &vmeta.graph_suffix.clone());
        let inputs =
            vec![Value::vec_i32(pad_to(tokens, bucket)), Value::scalar_i32(length as i32)];
        let out = self.rt.execute(&key, Some((model, variant)), &inputs)?;
        anyhow::ensure!(out.len() == 4, "lkv graph {key}: {} outputs, want 4", out.len());
        // outputs: k, v, logits, lkv_scores
        let mut it = out.into_iter();
        Ok((
            it.next().unwrap().into_f32()?,
            it.next().unwrap().into_f32()?,
            it.next().unwrap().into_vec_f32().context("logits")?,
            it.next().unwrap().into_f32()?,
            bucket,
        ))
    }

    /// Greedily decode `n` draft tokens with `model` starting from
    /// `logits`, using the given cache. Returns the draft token ids.
    /// Shared with the chunked-prefill job (`engine::chunked`).
    pub(crate) fn greedy_draft(
        &self,
        model: &str,
        cache: &mut SeqCache,
        first_logits: &[f32],
        n: usize,
    ) -> Result<Vec<i32>> {
        let mut toks = Vec::with_capacity(n);
        let mut logits = first_logits.to_vec();
        for _ in 0..n {
            let t = argmax(&logits) as i32;
            toks.push(t);
            let step = self.decode_step(model, cache, t)?;
            logits = step.logits;
        }
        Ok(toks)
    }

    /// Assemble the method's prefill output (graph runs + draft loops).
    pub fn prefill_for_method(&self, tokens: &[i32], method: &Method) -> Result<PrefillOutput> {
        let len = tokens.len();
        let m = self.rt.manifest();
        let model = self.cfg.model.clone();
        let obs_w = m.obs_window;
        let mut bd = PrefillBreakdown::default();

        // LookaheadKV family: single lookahead prefill (+ optional base
        // pass for the Table-7 suffix combination).
        if let Some(variant) = method.lkv_variant() {
            let t0 = Instant::now();
            let (k, v, logits, lkv_scores, bucket) =
                self.run_prefill_lkv(&model, variant, tokens, len)?;
            bd.forward_ms = ms(t0);
            let mut bundle = ScoreBundle::empty(len);
            bundle.lkv_scores = Some(lkv_scores);
            if matches!(method, Method::LkvSuffix { .. }) {
                let t1 = Instant::now();
                let (raw, _) = self.run_prefill_base(&model, tokens, len, len - 1)?;
                bundle.window_scores = Some(raw.window_scores);
                bundle.win_start = win_start(len, obs_w, bucket);
                bundle.win_rows = obs_w.min(len);
                bd.rescore_ms = ms(t1);
            }
            return Ok(PrefillOutput { k, v, logits, bundle, bucket, breakdown: bd, blocks: None });
        }

        // Draft-based methods: LAQ / SpecKV.
        if method.needs_draft() {
            let nd = self.cfg.draft_tokens;
            let draft_toks: Vec<i32>;
            let t0 = Instant::now();
            match method {
                Method::Laq => {
                    // Pass 1: cheap SnapKV eviction on the target model,
                    // then decode nd pseudo-response tokens from the
                    // evicted cache (the paper's low-cost draft).
                    let (raw, bucket) = self.run_prefill_base(&model, tokens, len, len - 1)?;
                    bd.forward_ms = ms(t0);
                    let t1 = Instant::now();
                    let mut bundle = ScoreBundle::empty(len);
                    bundle.window_scores = Some(raw.window_scores);
                    bundle.win_start = win_start(len, obs_w, bucket);
                    bundle.win_rows = obs_w.min(len);
                    let sel = Method::SnapKV.select(
                        &self.cfg.eviction,
                        self.n_layers(&model),
                        &bundle,
                    );
                    let cap = m.decode_cap(&model, sel.max_kept() + nd)?;
                    let mut cache =
                        SeqCache::from_selection(&raw.k, &raw.v, &sel.per_layer, len, cap);
                    draft_toks = self.greedy_draft(&model, &mut cache, &raw.logits, nd)?;
                    bd.draft_ms = ms(t1);
                }
                Method::SpecKV => {
                    // Draft model produces the approximate response.
                    let draft = self
                        .cfg
                        .draft_model
                        .clone()
                        .context("SpecKV requires a draft model")?;
                    let (raw, _) = self.run_prefill_base(&draft, tokens, len, len - 1)?;
                    let cap = m.decode_cap(&draft, len + nd)?;
                    let full: Vec<Vec<usize>> =
                        vec![(0..len).collect(); self.n_layers(&draft)];
                    let mut cache = SeqCache::from_selection(&raw.k, &raw.v, &full, len, cap);
                    draft_toks = self.greedy_draft(&draft, &mut cache, &raw.logits, nd)?;
                    bd.draft_ms = ms(t0);
                }
                _ => unreachable!(),
            }
            // Rescore: target prefill over [prompt ; draft], logits pinned
            // to the last *prompt* position so decoding starts correctly.
            let t2 = Instant::now();
            let mut concat = tokens.to_vec();
            concat.extend_from_slice(&draft_toks);
            let (raw, bucket) = self.run_prefill_base(&model, &concat, concat.len(), len - 1)?;
            bd.rescore_ms = ms(t2);
            let mut bundle = ScoreBundle::empty(len);
            bundle.win_start = win_start(concat.len(), obs_w, bucket);
            bundle.win_rows = obs_w.min(concat.len());
            bundle.w_use_override = Some(nd); // aggregate exactly the draft rows
            bundle.window_scores = Some(raw.window_scores);
            bundle.h2o_scores = Some(raw.h2o_scores);
            return Ok(PrefillOutput {
                k: raw.k,
                v: raw.v,
                logits: raw.logits,
                bundle,
                bucket,
                breakdown: bd,
                blocks: None,
            });
        }

        // Learned importance predictor: one predictor-augmented base
        // prefill (the MLP scores stream out of the same forward pass).
        if matches!(method, Method::Predictor) {
            let t0 = Instant::now();
            let (raw, pred_scores, bucket) =
                self.run_prefill_pred(&model, tokens, len, len - 1)?;
            bd.forward_ms = ms(t0);
            let mut bundle = ScoreBundle::empty(len);
            bundle.window_scores = Some(raw.window_scores);
            bundle.h2o_scores = Some(raw.h2o_scores);
            bundle.pred_scores = Some(pred_scores);
            bundle.win_start = win_start(len, obs_w, bucket);
            bundle.win_rows = obs_w.min(len);
            return Ok(PrefillOutput {
                k: raw.k,
                v: raw.v,
                logits: raw.logits,
                bundle,
                bucket,
                breakdown: bd,
                blocks: None,
            });
        }

        // Everything else: one base prefill.
        let t0 = Instant::now();
        let (raw, bucket) = self.run_prefill_base(&model, tokens, len, len - 1)?;
        bd.forward_ms = ms(t0);
        let mut bundle = ScoreBundle::empty(len);
        bundle.window_scores = Some(raw.window_scores);
        bundle.h2o_scores = Some(raw.h2o_scores);
        bundle.win_start = win_start(len, obs_w, bucket);
        bundle.win_rows = obs_w.min(len);
        Ok(PrefillOutput { k: raw.k, v: raw.v, logits: raw.logits, bundle, bucket, breakdown: bd, blocks: None })
    }

    /// One decode step for one sequence; serializes the full cache into
    /// the backend call and replaces it with the returned tensors (the
    /// per-sequence dispatch baseline — see `decode_step_batch`).
    pub fn decode_step(
        &self,
        model: &str,
        cache: &mut SeqCache,
        token: i32,
    ) -> Result<StepOutput> {
        let key = self.rt.manifest().graph_key_decode(model, cache.cap);
        let pos = cache.next_pos;
        let out = {
            let SeqCache { k, v, lens, .. } = &mut *cache;
            let mut seq = DecodeSeq { token, pos, k, v, lens: &lens[..] };
            let exec = |key: &str, inputs: &[Value]| self.rt.execute(key, None, inputs);
            decode_seq_via_execute(&exec, &key, &mut seq)?
        };
        cache.note_insert(pos);
        cache.next_pos += 1;
        Ok(StepOutput { logits: out.logits, probs: out.probs })
    }

    /// Advance every sequence by one decode token in a single backend
    /// call. Caches are updated in place by the backend (no full-cache
    /// serialization round-trip on backends that support it); host-side
    /// slot bookkeeping is applied here.
    pub fn decode_step_batch(
        &self,
        model: &str,
        caches: &mut [&mut SeqCache],
        tokens: &[i32],
    ) -> Result<Vec<StepOutput>> {
        anyhow::ensure!(
            caches.len() == tokens.len(),
            "decode_step_batch: {} caches vs {} tokens",
            caches.len(),
            tokens.len()
        );
        let mut positions = Vec::with_capacity(caches.len());
        let mut seqs: Vec<DecodeSeq<'_>> = Vec::with_capacity(caches.len());
        for (cache, &token) in caches.iter_mut().zip(tokens.iter()) {
            let pos = cache.next_pos;
            positions.push(pos);
            let SeqCache { k, v, lens, .. } = &mut **cache;
            seqs.push(DecodeSeq { token, pos, k, v, lens: &lens[..] });
        }
        let outs = self.rt.decode_batch(model, &mut seqs)?;
        drop(seqs);
        anyhow::ensure!(outs.len() == caches.len(), "decode_batch returned a short batch");
        let mut steps = Vec::with_capacity(outs.len());
        for ((cache, out), pos) in caches.iter_mut().zip(outs).zip(positions) {
            cache.note_insert(pos);
            cache.next_pos += 1;
            steps.push(StepOutput { logits: out.logits, probs: out.probs });
        }
        Ok(steps)
    }

    /// KV geometry of `model` (arena addressing).
    pub fn kv_dims(&self, model: &str) -> Result<KvDims> {
        Ok(KvDims::of(self.rt.manifest().model(model)?))
    }

    /// [`Engine::decode_step_batch`] over *paged* caches: every
    /// sequence advances one token through its arena block table in a
    /// single backend call; host-side slot bookkeeping is applied here.
    /// Callers must have ensured one slot of headroom per sequence
    /// (growing by a block first when needed).
    pub fn decode_step_batch_paged(
        &self,
        model: &str,
        arena: &mut KvArena,
        caches: &mut [&mut PagedSeqCache],
        tokens: &[i32],
    ) -> Result<Vec<StepOutput>> {
        anyhow::ensure!(
            caches.len() == tokens.len(),
            "decode_step_batch_paged: {} caches vs {} tokens",
            caches.len(),
            tokens.len()
        );
        let outs = {
            let seqs: Vec<PagedDecodeSeq<'_>> = caches
                .iter()
                .zip(tokens.iter())
                .map(|(cache, &token)| PagedDecodeSeq {
                    token,
                    pos: cache.next_pos,
                    blocks: &cache.blocks,
                    lens: &cache.lens,
                })
                .collect();
            self.rt.decode_batch_paged(model, arena, &seqs)?
        };
        anyhow::ensure!(outs.len() == caches.len(), "decode_batch_paged returned a short batch");
        let mut steps = Vec::with_capacity(outs.len());
        for (cache, out) in caches.iter_mut().zip(outs) {
            let pos = cache.next_pos;
            cache.note_insert(pos);
            cache.next_pos += 1;
            steps.push(StepOutput { logits: out.logits, probs: out.probs });
        }
        Ok(steps)
    }
}

pub struct StepOutput {
    pub logits: Vec<f32>,
    /// `[L, H, C]` attention over the cache after insertion.
    pub probs: TensorF,
}

/// Absolute row-0 position of the exported window block:
/// clamp(length - W, 0, S - W) — must mirror `model.prefill`.
pub fn win_start(length: usize, window: usize, bucket: usize) -> usize {
    length.saturating_sub(window).min(bucket - window)
}

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

//! End-to-end generation: prefill → evict → compact → decode loop.

use std::time::Instant;

use anyhow::Result;

use super::Engine;
use crate::eviction::spec::PolicyKnobs;
use crate::eviction::Method;
use crate::kvcache::SeqCache;
use crate::model::sampler::Sampler;
use crate::model::tokenizer::{decode_until_eos, EOS_ID};
use crate::util::tensor::TensorF;

/// Why a generation stopped. Surfaced in [`GenResult`], the scheduler's
/// `Reply`, and the HTTP response so cap/pool-driven truncation is
/// observable instead of silent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The model emitted the end-of-sequence token.
    Eos,
    /// `max_new` tokens were generated.
    Length,
    /// The sequence ran out of KV memory mid-decode (dense cache at its
    /// cap, or a paged cache that could not grow — pool exhausted even
    /// after prefix-tree reclamation).
    KvExhausted,
    /// The serving loop shut down with the sequence still active.
    Stopped,
    /// The request failed; see the reply's `error`.
    Error,
    /// The request's `deadline_ms` elapsed before generation finished;
    /// the reply carries whatever tokens were produced in time.
    Deadline,
    /// The client disconnected (or cancelled) mid-generation; the
    /// engine freed the sequence's resources immediately.
    Cancelled,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::Length => "length",
            FinishReason::KvExhausted => "kv_exhausted",
            FinishReason::Stopped => "stopped",
            FinishReason::Error => "error",
            FinishReason::Deadline => "deadline",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

#[derive(Debug, Clone)]
pub struct GenOptions {
    pub budget: usize,
    pub max_new: usize,
    pub temperature: f32,
    pub seed: u64,
    /// Accumulate ground-truth importance from decode attention (Table 8);
    /// only meaningful with `Method::FullKV`.
    pub collect_gt: bool,
    /// Per-request eviction knob overrides (window/kernel/sinks) from a
    /// [`crate::eviction::spec::PolicySpec`]; empty = engine defaults.
    pub knobs: PolicyKnobs,
}

impl GenOptions {
    pub fn new(budget: usize, max_new: usize) -> GenOptions {
        GenOptions {
            budget,
            max_new,
            temperature: 0.0,
            seed: 0,
            collect_gt: false,
            knobs: PolicyKnobs::default(),
        }
    }
}

/// Accumulates mean cross-attention of generated tokens over the prompt —
/// the ground-truth importance scores s_GT of paper Eq. (1).
pub struct GtAccumulator {
    /// [L, H, prompt_len] running sums.
    sums: TensorF,
    steps: usize,
    prompt_len: usize,
}

impl GtAccumulator {
    pub fn new(n_layers: usize, n_heads: usize, prompt_len: usize) -> GtAccumulator {
        GtAccumulator {
            sums: TensorF::zeros(vec![n_layers, n_heads, prompt_len]),
            steps: 0,
            prompt_len,
        }
    }

    /// Fold one decode step's `[L, H, C]` probs, mapping cache slots back
    /// to absolute prompt positions via the cache's slot map.
    pub fn add_step(&mut self, probs: &TensorF, cache: &SeqCache) {
        let (l, h, _c) = (probs.shape[0], probs.shape[1], probs.shape[2]);
        for li in 0..l {
            let slots = &cache.slot_pos[li];
            for hi in 0..h {
                let row = probs.index(&[li, hi]);
                let dst_base = (li * h + hi) * self.prompt_len;
                for (slot, &pos) in slots.iter().enumerate() {
                    if pos < self.prompt_len {
                        self.sums.data[dst_base + pos] += row[slot];
                    }
                }
            }
        }
        self.steps += 1;
    }

    /// Mean over steps: `[L, H, prompt_len]`.
    pub fn finish(mut self) -> TensorF {
        let n = self.steps.max(1) as f32;
        for x in self.sums.data.iter_mut() {
            *x /= n;
        }
        self.sums
    }
}

/// Per-request serving statistics: where this request's time and KV
/// bytes went. Filled by both the offline path ([`Engine::generate`],
/// where queue/spill phases are zero) and the serving loop
/// (`scheduler::batcher`), and surfaced verbatim as the `stats` object
/// of the `POST /generate` response.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestStats {
    /// Submit → popped by the engine loop.
    pub queue_ms: f64,
    /// Pop → first token sampled.
    pub ttft_ms: f64,
    /// Prefill steps run (1 = monolithic).
    pub prefill_chunks: usize,
    /// Decode iterations this request participated in.
    pub decode_iters: usize,
    /// Prompt positions evicted at selection, per layer.
    pub evicted_per_layer: Vec<usize>,
    /// High-water mark of arena blocks held (0 for dense caches).
    pub peak_arena_blocks: usize,
    /// Times this request was preempted to the host spill store.
    pub spills: usize,
    /// Times its spilled blocks were restored.
    pub restores: usize,
    /// Storage dtype of this request's KV blocks (`f32`/`f16`/`u8`;
    /// dense caches are always `f32`).
    pub kv_dtype: String,
    /// Peak resident KV bytes this request held, in the stored
    /// representation (quantized payload + per-block scale/zero-point
    /// for `u8`, not the logical f32 size).
    pub resident_kv_bytes: usize,
}

impl Default for RequestStats {
    fn default() -> RequestStats {
        RequestStats {
            queue_ms: 0.0,
            ttft_ms: 0.0,
            prefill_chunks: 0,
            decode_iters: 0,
            evicted_per_layer: Vec::new(),
            peak_arena_blocks: 0,
            spills: 0,
            restores: 0,
            kv_dtype: "f32".to_string(),
            resident_kv_bytes: 0,
        }
    }
}

impl RequestStats {
    pub fn evicted_total(&self) -> usize {
        self.evicted_per_layer.iter().sum()
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::from_pairs(vec![
            ("queue_ms", self.queue_ms.into()),
            ("ttft_ms", self.ttft_ms.into()),
            ("prefill_chunks", self.prefill_chunks.into()),
            ("decode_iters", self.decode_iters.into()),
            ("evicted_per_layer", self.evicted_per_layer.clone().into()),
            ("evicted_total", self.evicted_total().into()),
            ("peak_arena_blocks", self.peak_arena_blocks.into()),
            ("spills", self.spills.into()),
            ("restores", self.restores.into()),
            ("kv_dtype", self.kv_dtype.clone().into()),
            ("resident_kv_bytes", self.resident_kv_bytes.into()),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct GenResult {
    pub text: String,
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Time to first token (prefill + eviction + compaction + sampling).
    pub ttft_ms: f64,
    /// Forward-pass-only component of TTFT (the paper's baseline).
    pub forward_ms: f64,
    pub eviction_overhead_ms: f64,
    pub decode_ms_total: f64,
    pub n_decode_steps: usize,
    pub kept_per_layer: Vec<usize>,
    pub cache_cap: usize,
    pub finish_reason: FinishReason,
    pub gt_scores: Option<TensorF>,
    /// Per-request serving stats (offline path: queue/spill phases zero).
    pub stats: RequestStats,
    /// What the eviction policy decided, auditable per request.
    pub eviction: Option<crate::eviction::DecisionSummary>,
}

impl GenResult {
    pub fn decode_ms_per_token(&self) -> f64 {
        self.decode_ms_total / self.n_decode_steps.max(1) as f64
    }
}

impl Engine {
    /// Serve one request end-to-end.
    pub fn generate(&self, prompt: &[i32], method: &Method, opts: &GenOptions) -> Result<GenResult> {
        let t_start = Instant::now();
        let model = self.cfg.model.clone();
        let n_layers = self.n_layers(&model);
        let mm = self.rt.manifest().model(&model)?;
        let mheads = mm.n_heads;
        let slot_bytes =
            crate::kvcache::manager::bytes_per_slot(mm.n_layers, mm.n_kv_heads, mm.head_dim);

        // 1-2. prefill + select
        let mut evcfg = self.cfg.eviction;
        evcfg.budget = opts.budget;
        opts.knobs.apply(&mut evcfg);
        let pre = self.prefill_for_method(prompt, method)?;
        let t_sel = Instant::now();
        let sel = method.select(&evcfg, n_layers, &pre.bundle);
        let select_ms = t_sel.elapsed().as_secs_f64() * 1e3;
        let decision = crate::eviction::DecisionSummary::new(method, &evcfg, &sel, &pre.bundle);

        // 3. compact
        let t_cmp = Instant::now();
        let cap = self.rt.manifest().decode_cap(&model, sel.max_kept() + opts.max_new)?;
        let mut cache = SeqCache::from_selection(&pre.k, &pre.v, &sel.per_layer, prompt.len(), cap);
        let compact_ms = t_cmp.elapsed().as_secs_f64() * 1e3;

        // 4. decode
        let mut sampler = if opts.temperature > 0.0 {
            Sampler::with_temperature(opts.temperature, opts.seed)
        } else {
            Sampler::greedy()
        };
        let mut gt = opts
            .collect_gt
            .then(|| GtAccumulator::new(n_layers, mheads, prompt.len()));
        let mut logits = pre.logits.clone();
        let first_token = sampler.sample(&logits);
        let ttft_ms = t_start.elapsed().as_secs_f64() * 1e3;

        let mut tokens = vec![first_token];
        let t_dec = Instant::now();
        let mut token = first_token;
        while tokens.len() < opts.max_new && token != EOS_ID && cache.headroom() > 0 {
            let step = self.decode_step(&model, &mut cache, token)?;
            logits = step.logits;
            if let Some(acc) = gt.as_mut() {
                acc.add_step(&step.probs, &cache);
            }
            token = sampler.sample(&logits);
            tokens.push(token);
        }
        let decode_ms_total = t_dec.elapsed().as_secs_f64() * 1e3;

        let finish_reason = if token == EOS_ID {
            FinishReason::Eos
        } else if tokens.len() >= opts.max_new {
            FinishReason::Length
        } else {
            FinishReason::KvExhausted
        };
        let kept_per_layer: Vec<usize> = sel.per_layer.iter().map(Vec::len).collect();
        let n_decode_steps = tokens.len().saturating_sub(1);
        let stats = RequestStats {
            queue_ms: 0.0,
            ttft_ms,
            prefill_chunks: 1,
            decode_iters: n_decode_steps,
            evicted_per_layer: kept_per_layer
                .iter()
                .map(|&k| prompt.len().saturating_sub(k))
                .collect(),
            peak_arena_blocks: 0,
            spills: 0,
            restores: 0,
            // The offline path decodes through a dense f32 SeqCache.
            kv_dtype: "f32".to_string(),
            resident_kv_bytes: cap * slot_bytes,
        };
        Ok(GenResult {
            text: decode_until_eos(&tokens),
            n_decode_steps,
            tokens,
            prompt_len: prompt.len(),
            ttft_ms,
            forward_ms: pre.breakdown.forward_ms,
            eviction_overhead_ms: pre.breakdown.overhead_ms() + select_ms + compact_ms,
            decode_ms_total,
            kept_per_layer,
            cache_cap: cap,
            finish_reason,
            gt_scores: gt.map(GtAccumulator::finish),
            stats,
            eviction: Some(decision),
        })
    }

    /// Ground-truth importance scores for Table 8: FullKV generation at
    /// `temperature`, returning s_GT `[L, H, prompt_len]`.
    pub fn gt_importance(
        &self,
        prompt: &[i32],
        temperature: f32,
        seed: u64,
        max_new: usize,
    ) -> Result<TensorF> {
        let opts = GenOptions {
            budget: usize::MAX / 2,
            max_new,
            temperature,
            seed,
            collect_gt: true,
            knobs: PolicyKnobs::default(),
        };
        let res = self.generate(prompt, &Method::FullKV, &opts)?;
        Ok(res.gt_scores.expect("collect_gt was set"))
    }
}

//! Incremental (chunked) prefill: the engine-level state machine over the
//! backend's [`ChunkState`] contract.
//!
//! A [`ChunkedPrefill`] job runs one request's prefill in bounded slices
//! so the engine loop can interleave decode steps between chunks (mixed
//! prefill/decode batching) instead of stalling every active sequence for
//! the whole prompt. Eviction is *deferred to the final chunk*: selection
//! only ever sees full-prompt scores, and the finished
//! [`PrefillOutput`] is **bit-identical** to
//! [`Engine::prefill_for_method`] for every policy — including the
//! multi-pass pipelines:
//!
//! * base family (full/random/streaming/snapkv/pyramidkv/h2o/tova): one
//!   chunked base pass;
//! * `lookaheadkv`: one chunked lookahead pass; the Algorithm-2 suffix
//!   scoring runs once at finalize against the full accumulated KV;
//! * `lkv+suffix`: chunked lookahead pass, then a chunked base pass for
//!   the suffix-window scores;
//! * `laq`/`speckv`: chunked pre-draft base pass, a draft step (a handful
//!   of decode-sized calls), then a chunked rescore pass over
//!   `[prompt; draft]`.

use std::time::Instant;

use anyhow::{Context, Result};

use super::prefill::{win_start, PrefillBreakdown, PrefillOutput};
use super::Engine;
use crate::eviction::{Method, ScoreBundle};
use crate::kvcache::prefix::BlockRecord;
use crate::kvcache::{KvArena, KvDims, PagedCtx, SeqCache};
use crate::runtime::{ChunkState, PrefixSeed};
use crate::util::tensor::TensorF;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PassKind {
    /// Base prefill over the prompt (non-draft, non-lookahead methods).
    Base,
    /// Lookahead prefill over the prompt (`lkv`, first pass of
    /// `lkv+suffix`).
    Lkv,
    /// Base pass over the prompt for the suffix-window scores
    /// (`lkv+suffix` second pass).
    SuffixBase,
    /// Base pass over the prompt before drafting (LAQ on the target
    /// model, SpecKV on the draft model).
    PreDraft,
    /// Base pass over `[prompt; draft]` (LAQ/SpecKV rescore).
    Rescore,
}

enum Stage {
    Pass { kind: PassKind, state: ChunkState },
    /// Run the LAQ/SpecKV draft loop, then start the rescore pass.
    Draft,
    Done,
}

/// Prefix-cache integration for one chunked prefill: the block size at
/// which the first pass records its state for the tree, and (on a cache
/// hit) the seed to resume from instead of token 0.
pub struct PrefixPlan {
    pub block_size: usize,
    pub seed: Option<PrefixSeed>,
}

/// Where the first prefill pass of a method runs and how far a cached
/// prefix may seed it (see [`Engine::prefix_pass_info`]).
#[derive(Debug, Clone)]
pub struct PrefixPassInfo {
    /// Model whose tree is matched (the draft model for SpecKV).
    pub model: String,
    /// Base passes need cached H2O sums; lookahead passes only KV.
    pub need_scores: bool,
    /// Deepest token position a seed may cover: `win_start` for base
    /// passes (observation-window rows are never cached), the last
    /// prompt row otherwise (its logits must be recomputed).
    pub resume_cap: usize,
}

/// The first pass's newly computed blocks, handed to
/// [`crate::kvcache::PrefixCache`] after the job completes.
pub struct PrefixRecords {
    pub model: String,
    pub records: Vec<BlockRecord>,
}

/// Captures block-aligned snapshots of the first pass's state as its
/// chunks cross block boundaries (chunks are split *at* the boundaries
/// while recording — chunk geometry never changes results, see
/// `tests/chunked.rs`).
struct Recorder {
    block: usize,
    model: String,
    /// KV geometry of the recorded pass's model (arena reads on the
    /// paged path; matches `state.k.shape` on the dense path).
    dims: KvDims,
    /// Blocks below this offset came from the cache (the seed) and are
    /// not re-recorded.
    upto: usize,
    /// Recording covers only the first pass; `advance` turns this off.
    active: bool,
    records: Vec<BlockRecord>,
}

impl Recorder {
    /// Record the block ending at `end` (a block multiple) from the
    /// pass state: its KV rows plus, for base passes, the *cumulative*
    /// H2O column sums over all rows processed so far. Paged states read
    /// their KV rows out of the arena (`arena` must then be `Some`).
    fn capture(&mut self, state: &ChunkState, arena: Option<&KvArena>, toks: &[i32], end: usize) {
        let b = self.block;
        if end % b != 0 || end <= self.upto {
            return;
        }
        let (l, hkv, dh) = (self.dims.n_layers, self.dims.n_kv_heads, self.dims.head_dim);
        let start = end - b;
        let mut k = TensorF::zeros(vec![l, hkv, b, dh]);
        let mut v = TensorF::zeros(vec![l, hkv, b, dh]);
        match (&state.blocks, arena) {
            (Some(table), Some(ar)) => {
                let bs = ar.block_size();
                for li in 0..l {
                    for g in 0..hkv {
                        let seg = li * hkv + g;
                        for r in 0..b {
                            let slot = start + r;
                            let blk = ar.block_raw(table[slot / bs]).expect("pass block unbound");
                            let dst = ((li * hkv + g) * b + r) * dh;
                            blk.k.decode_row(seg, slot % bs, bs, dh, &mut k.data[dst..dst + dh]);
                            blk.v.decode_row(seg, slot % bs, bs, dh, &mut v.data[dst..dst + dh]);
                        }
                    }
                }
            }
            _ => {
                let bucket = state.k.shape[2];
                debug_assert_eq!(state.k.shape[..], [l, hkv, bucket, dh][..]);
                for li in 0..l {
                    for g in 0..hkv {
                        let src = ((li * hkv + g) * bucket + start) * dh;
                        let dst = ((li * hkv + g) * b) * dh;
                        k.data[dst..dst + b * dh]
                            .copy_from_slice(&state.k.data[src..src + b * dh]);
                        v.data[dst..dst + b * dh]
                            .copy_from_slice(&state.v.data[src..src + b * dh]);
                    }
                }
            }
        }
        let h2o = state.bundle.h2o_scores.as_ref().map(|acc| {
            let (l2, h, s) = (acc.shape[0], acc.shape[1], acc.shape[2]);
            let mut t = TensorF::zeros(vec![l2, h, end]);
            for li in 0..l2 {
                for hi in 0..h {
                    let src = (li * h + hi) * s;
                    let dst = (li * h + hi) * end;
                    t.data[dst..dst + end].copy_from_slice(&acc.data[src..src + end]);
                }
            }
            t
        });
        self.records.push(BlockRecord { start, tokens: toks[start..end].to_vec(), k, v, h2o });
        self.upto = end;
    }
}

/// One request's in-flight incremental prefill.
pub struct ChunkedPrefill {
    method: Method,
    prompt: Vec<i32>,
    chunk: usize,
    bd: PrefillBreakdown,
    stage: Stage,
    /// Finished lookahead pass, kept while the `lkv+suffix` base pass
    /// runs (its k/v/logits/scores are the ones served).
    lkv_pass: Option<ChunkState>,
    /// Finished pre-draft pass, consumed by the draft stage.
    pre_draft: Option<ChunkState>,
    /// `[prompt; draft]` fed to the rescore pass.
    concat: Vec<i32>,
    recorder: Option<Recorder>,
    output: Option<PrefillOutput>,
    /// Paged job: every pass's prompt KV lives in arena blocks charged
    /// to the request; advance with [`ChunkedPrefill::step_paged`].
    paged: bool,
}

impl Engine {
    /// Begin an incremental prefill for `method`; each [`ChunkedPrefill::step`]
    /// advances it by at most `chunk` prompt tokens. Requires a backend
    /// with chunked-prefill support (check
    /// [`crate::runtime::Runtime::supports_chunked_prefill`]).
    pub fn chunked_prefill_begin(
        &self,
        tokens: &[i32],
        method: &Method,
        chunk: usize,
    ) -> Result<ChunkedPrefill> {
        self.chunked_prefill_begin_with_prefix(tokens, method, chunk, None)
    }

    /// [`Engine::chunked_prefill_begin`] with prefix-cache integration:
    /// with a [`PrefixPlan`], the first pass resumes from `plan.seed`
    /// (when present) instead of token 0, and records its newly computed
    /// block-aligned state for tree insertion
    /// ([`ChunkedPrefill::take_prefix_records`]). Only the first pass is
    /// seeded/recorded — it is the one carrying the shared-system-prompt
    /// win; later passes (`lkv+suffix` base, LAQ/SpecKV rescore) always
    /// run cold.
    pub fn chunked_prefill_begin_with_prefix(
        &self,
        tokens: &[i32],
        method: &Method,
        chunk: usize,
        prefix: Option<PrefixPlan>,
    ) -> Result<ChunkedPrefill> {
        self.chunked_prefill_begin_inner(tokens, method, chunk, prefix, None)
    }

    /// [`Engine::chunked_prefill_begin_with_prefix`] with every pass's
    /// prompt KV paged into `ctx`'s arena (blocks charged to
    /// `ctx.owner`). The finished output carries the prompt block table
    /// (`PrefillOutput::blocks`) for gather-compaction; on error the
    /// job's blocks have already been freed.
    pub fn chunked_prefill_begin_paged(
        &self,
        tokens: &[i32],
        method: &Method,
        chunk: usize,
        prefix: Option<PrefixPlan>,
        ctx: &mut PagedCtx<'_>,
    ) -> Result<ChunkedPrefill> {
        anyhow::ensure!(
            self.rt.supports_paged_kv(),
            "backend {} does not support paged KV",
            self.rt.backend_name()
        );
        self.chunked_prefill_begin_inner(tokens, method, chunk, prefix, Some(ctx))
    }

    fn chunked_prefill_begin_inner(
        &self,
        tokens: &[i32],
        method: &Method,
        chunk: usize,
        prefix: Option<PrefixPlan>,
        mut ctx: Option<&mut PagedCtx<'_>>,
    ) -> Result<ChunkedPrefill> {
        anyhow::ensure!(chunk >= 1, "prefill chunk size must be >= 1");
        anyhow::ensure!(!tokens.is_empty(), "empty prompt");
        anyhow::ensure!(
            self.rt.supports_chunked_prefill(),
            "backend {} does not support chunked prefill",
            self.rt.backend_name()
        );
        if let Some(p) = &prefix {
            anyhow::ensure!(p.block_size >= 1, "prefix block size must be >= 1");
            if let Some(s) = &p.seed {
                anyhow::ensure!(
                    s.len % p.block_size == 0,
                    "prefix seed of {} tokens is not block-aligned (block {})",
                    s.len,
                    p.block_size
                );
            }
        }
        let model = self.cfg.model.clone();
        let len = tokens.len();
        let paged = ctx.is_some();
        let seed = prefix.as_ref().and_then(|p| p.seed.as_ref());
        let (kind, pass_model) = if method.lkv_variant().is_some() {
            (PassKind::Lkv, model)
        } else if method.needs_draft() {
            let pass1_model = match method {
                Method::SpecKV => {
                    self.cfg.draft_model.clone().context("SpecKV requires a draft model")?
                }
                _ => model,
            };
            (PassKind::PreDraft, pass1_model)
        } else {
            (PassKind::Base, model)
        };
        let variant = method.lkv_variant();
        let pred = matches!(method, Method::Predictor);
        let state =
            self.new_pass_state(&pass_model, variant, len, len - 1, pred, seed, ctx.as_deref_mut())?;
        let recorder = prefix.map(|p| Recorder {
            block: p.block_size,
            model: pass_model.clone(),
            dims: self.kv_dims(&pass_model).expect("pass model exists"),
            upto: p.seed.as_ref().map(|s| s.len).unwrap_or(0),
            active: true,
            records: Vec::new(),
        });
        Ok(ChunkedPrefill {
            method: method.clone(),
            prompt: tokens.to_vec(),
            chunk,
            bd: PrefillBreakdown::default(),
            stage: Stage::Pass { kind, state },
            lkv_pass: None,
            pre_draft: None,
            concat: Vec::new(),
            recorder,
            output: None,
            paged,
        })
    }

    /// Construct one pass's [`ChunkState`] — dense, or paged with fresh
    /// arena blocks — optionally resumed from a prefix seed. On any
    /// failure after allocation, the pass's blocks are freed before the
    /// error is returned.
    fn new_pass_state(
        &self,
        pass_model: &str,
        variant: Option<&str>,
        len: usize,
        logit_pos: usize,
        pred: bool,
        seed: Option<&PrefixSeed>,
        ctx: Option<&mut PagedCtx<'_>>,
    ) -> Result<ChunkState> {
        let m = self.rt.manifest();
        let Some(ctx) = ctx else {
            return match seed {
                Some(s) => ChunkState::resume(m, pass_model, variant, len, logit_pos, s),
                None => ChunkState::new(m, pass_model, variant, len, logit_pos, pred),
            };
        };
        let dims = self.kv_dims(pass_model)?;
        let blocks = ctx.alloc_blocks(len, &dims)?;
        let bs = ctx.arena.block_size();
        let res = (|| -> Result<ChunkState> {
            let mut st = ChunkState::new_paged(
                m,
                pass_model,
                variant,
                len,
                logit_pos,
                pred,
                blocks.clone(),
                bs,
            )?;
            if let Some(s) = seed {
                st.check_seed(m, s)?;
                ctx.arena.scatter_dense(&dims, &blocks, 0, &s.k, &s.v)?;
                st.apply_seed_scores(m, s)?;
            }
            Ok(st)
        })();
        if res.is_err() {
            ctx.free_blocks(&blocks);
        }
        res
    }

    /// Which model/pass the prefix cache should match for `method`, and
    /// how deep a cached prefix may seed it. Errors for prompts too short
    /// (or too long) to resume at all.
    pub fn prefix_pass_info(&self, len: usize, method: &Method) -> Result<PrefixPassInfo> {
        anyhow::ensure!(len >= 2, "prompt of {len} tokens is too short for prefix reuse");
        anyhow::ensure!(
            !matches!(method, Method::Predictor),
            "predictor prefills do not use the prefix cache (per-key scores are not recorded)"
        );
        if method.lkv_variant().is_some() {
            // Lookahead pass: pure KV accumulation (scores come from the
            // finalize suffix pass); everything but the logits row is
            // resumable.
            return Ok(PrefixPassInfo {
                model: self.cfg.model.clone(),
                need_scores: false,
                resume_cap: len - 1,
            });
        }
        let model = match method {
            Method::SpecKV => {
                self.cfg.draft_model.clone().context("SpecKV requires a draft model")?
            }
            _ => self.cfg.model.clone(),
        };
        let m = self.rt.manifest();
        let bucket = m.prefill_bucket(len)?;
        let cap = win_start(len, m.obs_window, bucket).min(len - 1);
        Ok(PrefixPassInfo { model, need_scores: true, resume_cap: cap })
    }
}

impl ChunkedPrefill {
    /// Advance by one bounded slice of work: one prompt chunk of the
    /// current pass (plus its finalize when it is the last chunk), or the
    /// whole draft loop for LAQ/SpecKV. Returns true once the job is
    /// complete and [`ChunkedPrefill::into_output`] may be called.
    pub fn step(&mut self, engine: &Engine) -> Result<bool> {
        anyhow::ensure!(!self.paged, "paged chunked prefill must be advanced with step_paged");
        self.step_inner(engine, None)
    }

    /// [`ChunkedPrefill::step`] for paged jobs: pass transitions may
    /// allocate/free arena blocks through `ctx`. On error the job's
    /// blocks are *not* freed here — every block is charged to
    /// `ctx.owner`, so the caller cleans up owner-scoped (the scheduler
    /// uses `CacheManager::release(request_id)` before rejecting).
    pub fn step_paged(&mut self, engine: &Engine, ctx: &mut PagedCtx<'_>) -> Result<bool> {
        anyhow::ensure!(self.paged, "dense chunked prefill must be advanced with step");
        self.step_inner(engine, Some(ctx))
    }

    /// Whether this job pages its prompt KV through the arena.
    pub fn is_paged(&self) -> bool {
        self.paged
    }

    fn step_inner(&mut self, engine: &Engine, mut ctx: Option<&mut PagedCtx<'_>>) -> Result<bool> {
        if matches!(self.stage, Stage::Done) {
            return Ok(true);
        }
        if matches!(self.stage, Stage::Draft) {
            let t0 = Instant::now();
            self.run_draft(engine, ctx)?;
            self.bd.draft_ms += ms(t0);
            return Ok(false);
        }
        let t0 = Instant::now();
        let (kind, finished) = {
            let Stage::Pass { kind, state } = &mut self.stage else { unreachable!() };
            let kind = *kind;
            let toks: &[i32] = if kind == PassKind::Rescore {
                &self.concat
            } else {
                &self.prompt
            };
            let lo = state.done;
            let target = (lo + self.chunk).min(state.len);
            let recording = self.recorder.as_ref().is_some_and(|r| r.active);
            // While recording, this step's work is split *at* block
            // boundaries so cumulative score snapshots land exactly on
            // them (chunk geometry never changes results; total work per
            // step stays <= `chunk` tokens either way).
            let mut cur = lo;
            while cur < target {
                let hi = if recording {
                    let b = self.recorder.as_ref().unwrap().block;
                    target.min((cur / b + 1) * b)
                } else {
                    target
                };
                match ctx.as_deref_mut() {
                    Some(c) => engine.rt.prefill_chunk_paged(c.arena, state, &toks[cur..hi])?,
                    None => engine.rt.prefill_chunk(state, &toks[cur..hi])?,
                }
                if recording {
                    let arena = ctx.as_deref().map(|c| &*c.arena);
                    self.recorder.as_mut().unwrap().capture(state, arena, toks, hi);
                }
                cur = hi;
            }
            let finished = state.done == state.len;
            if finished {
                match ctx.as_deref_mut() {
                    Some(c) => engine.rt.prefill_finalize_paged(c.arena, state)?,
                    None => engine.rt.prefill_finalize(state)?,
                }
            }
            (kind, finished)
        };
        let dt = ms(t0);
        // Mirror the monolithic breakdown attribution: SpecKV's pass-1
        // (draft model) counts as draft time; lkv+suffix's base pass and
        // the LAQ/SpecKV rescore count as rescore time.
        match (kind, &self.method) {
            (PassKind::PreDraft, Method::SpecKV) => self.bd.draft_ms += dt,
            (PassKind::Base | PassKind::Lkv | PassKind::PreDraft, _) => self.bd.forward_ms += dt,
            (PassKind::SuffixBase | PassKind::Rescore, _) => self.bd.rescore_ms += dt,
        }
        if finished {
            self.advance(engine, ctx)?;
        }
        Ok(matches!(self.stage, Stage::Done))
    }

    pub fn is_done(&self) -> bool {
        matches!(self.stage, Stage::Done)
    }

    /// Prompt tokens not yet prefilled in the *current* pass.
    pub fn remaining(&self) -> usize {
        match &self.stage {
            Stage::Pass { state, .. } => state.remaining(),
            Stage::Draft => self.prompt.len(), // rescore pass still ahead
            Stage::Done => 0,
        }
    }

    /// The finished prefill artifacts (identical to
    /// [`Engine::prefill_for_method`] for the same prompt and method).
    pub fn into_output(mut self) -> Result<PrefillOutput> {
        let mut out = self.output.take().context("chunked prefill is not finished")?;
        out.breakdown = self.bd.clone();
        Ok(out)
    }

    /// The blocks the first pass recorded for the prefix tree (None when
    /// no [`PrefixPlan`] was given or nothing new was computed). Call
    /// before [`ChunkedPrefill::into_output`].
    pub fn take_prefix_records(&mut self) -> Option<PrefixRecords> {
        let r = self.recorder.take()?;
        if r.records.is_empty() {
            return None;
        }
        Some(PrefixRecords { model: r.model, records: r.records })
    }

    /// Transition after a pass finishes.
    fn advance(&mut self, engine: &Engine, mut ctx: Option<&mut PagedCtx<'_>>) -> Result<()> {
        // Recording covers only the first pass; whatever pass just
        // finished, stop capturing.
        if let Some(r) = self.recorder.as_mut() {
            r.active = false;
        }
        let stage = std::mem::replace(&mut self.stage, Stage::Done);
        let Stage::Pass { kind, state } = stage else {
            anyhow::bail!("advance without a finished pass")
        };
        match kind {
            PassKind::Base => {
                self.output = Some(base_output(state)?);
            }
            PassKind::Lkv => {
                if matches!(self.method, Method::LkvSuffix { .. }) {
                    let next = engine.new_pass_state(
                        &engine.cfg.model,
                        None,
                        self.prompt.len(),
                        self.prompt.len() - 1,
                        false,
                        None,
                        ctx.as_deref_mut(),
                    )?;
                    self.lkv_pass = Some(state);
                    self.stage = Stage::Pass { kind: PassKind::SuffixBase, state: next };
                } else {
                    self.output = Some(base_output(state)?);
                }
            }
            PassKind::SuffixBase => {
                // The suffix pass's own KV was only needed for its
                // attention; the blocks go back to the pool right away.
                let mut state = state;
                if let (Some(c), Some(t)) = (ctx.as_deref_mut(), state.blocks.take()) {
                    c.free_blocks(&t);
                }
                let mut lkv =
                    self.lkv_pass.take().context("suffix pass without a lookahead pass")?;
                let logits = lkv.logits.take().context("lookahead pass captured no logits")?;
                // Table-7 combination bundle, exactly as the monolithic
                // path builds it: lookahead scores + suffix-window rows
                // (no h2o component).
                let mut bundle = ScoreBundle::empty(self.prompt.len());
                bundle.lkv_scores = lkv.bundle.lkv_scores.take();
                bundle.window_scores = state.bundle.window_scores;
                bundle.win_start = state.bundle.win_start;
                bundle.win_rows = state.bundle.win_rows;
                self.output = Some(PrefillOutput {
                    blocks: lkv.blocks.take(),
                    k: lkv.k,
                    v: lkv.v,
                    logits,
                    bundle,
                    bucket: lkv.bucket,
                    breakdown: PrefillBreakdown::default(),
                });
            }
            PassKind::PreDraft => {
                self.pre_draft = Some(state);
                self.stage = Stage::Draft;
            }
            PassKind::Rescore => {
                let mut state = state;
                let nd = self.concat.len() - self.prompt.len();
                let logits = state.logits.take().context("rescore pass captured no logits")?;
                let mut bundle = ScoreBundle::empty(self.prompt.len());
                bundle.win_start = state.bundle.win_start;
                bundle.win_rows = state.bundle.win_rows;
                bundle.w_use_override = Some(nd); // aggregate exactly the draft rows
                bundle.window_scores = state.bundle.window_scores;
                bundle.h2o_scores = state.bundle.h2o_scores;
                self.output = Some(PrefillOutput {
                    blocks: state.blocks.take(),
                    k: state.k,
                    v: state.v,
                    logits,
                    bundle,
                    bucket: state.bucket,
                    breakdown: PrefillBreakdown::default(),
                });
            }
        }
        Ok(())
    }

    /// LAQ/SpecKV draft generation between the pre-draft and rescore
    /// passes — the same cheap-eviction + greedy-decode pipeline as the
    /// monolithic path, so the drafted tokens (and therefore the rescore
    /// pass) match it exactly. On the paged path, the pre-draft pass's
    /// prompt KV is gathered out of the arena for the transient draft
    /// cache and its blocks are freed before the rescore pass allocates
    /// its own.
    fn run_draft(&mut self, engine: &Engine, mut ctx: Option<&mut PagedCtx<'_>>) -> Result<()> {
        let mut state = self.pre_draft.take().context("draft stage without a pre-draft pass")?;
        let logits = state.logits.take().context("pre-draft pass captured no logits")?;
        let nd = engine.cfg.draft_tokens;
        let m = engine.rt.manifest();
        let len = self.prompt.len();
        // Dense view of the pre-draft prompt KV (borrowed for the draft
        // cache's compaction; gathered from the arena on the paged path).
        let gathered: Option<(TensorF, TensorF)> = match (&state.blocks, ctx.as_deref()) {
            (Some(table), Some(c)) => {
                let dims = engine.kv_dims(&state.model)?;
                Some(c.arena.gather_dense(&dims, table, len)?)
            }
            _ => None,
        };
        let (k_full, v_full): (&TensorF, &TensorF) = match &gathered {
            Some((k, v)) => (k, v),
            None => (&state.k, &state.v),
        };
        let draft_toks = match &self.method {
            Method::Laq => {
                let model = engine.cfg.model.clone();
                let mut bundle = ScoreBundle::empty(len);
                bundle.window_scores = state.bundle.window_scores.take();
                bundle.win_start = state.bundle.win_start;
                bundle.win_rows = state.bundle.win_rows;
                let sel =
                    Method::SnapKV.select(&engine.cfg.eviction, engine.n_layers(&model), &bundle);
                let cap = m.decode_cap(&model, sel.max_kept() + nd)?;
                let mut cache =
                    SeqCache::from_selection(k_full, v_full, &sel.per_layer, len, cap);
                engine.greedy_draft(&model, &mut cache, &logits, nd)?
            }
            Method::SpecKV => {
                let draft =
                    engine.cfg.draft_model.clone().context("SpecKV requires a draft model")?;
                let cap = m.decode_cap(&draft, len + nd)?;
                let full: Vec<Vec<usize>> = vec![(0..len).collect(); engine.n_layers(&draft)];
                let mut cache = SeqCache::from_selection(k_full, v_full, &full, len, cap);
                engine.greedy_draft(&draft, &mut cache, &logits, nd)?
            }
            other => anyhow::bail!("method {} has no draft stage", other.name()),
        };
        // The pre-draft pass is fully consumed: free its blocks before
        // the rescore pass allocates over [prompt; draft].
        if let (Some(c), Some(t)) = (ctx.as_deref_mut(), state.blocks.take()) {
            c.free_blocks(&t);
        }
        self.concat = self.prompt.clone();
        self.concat.extend_from_slice(&draft_toks);
        let rescore = engine.new_pass_state(
            &engine.cfg.model,
            None,
            self.concat.len(),
            len - 1,
            false,
            None,
            ctx.as_deref_mut(),
        )?;
        self.stage = Stage::Pass { kind: PassKind::Rescore, state: rescore };
        Ok(())
    }
}

/// Single-pass output: the state's KV (dense tensors or block table),
/// logits and bundle are the final artifacts (base family and plain
/// lookahead methods).
fn base_output(mut state: ChunkState) -> Result<PrefillOutput> {
    let logits = state.logits.take().context("chunked prefill captured no logits")?;
    Ok(PrefillOutput {
        blocks: state.blocks.take(),
        k: state.k,
        v: state.v,
        logits,
        bundle: state.bundle,
        bucket: state.bucket,
        breakdown: PrefillBreakdown::default(),
    })
}

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

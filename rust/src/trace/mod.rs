//! Request-lifecycle tracing: lock-free per-iteration span recording.
//!
//! The engine loop records one [`SpanEvent`] per request phase
//! transition — queue wait, admission, each prefill chunk, eviction
//! selection/compaction, each decode iteration, spill/restore parking,
//! finish — into a fixed-capacity ring of seqlock-guarded slots. The
//! single writer (the engine thread) never blocks and never allocates;
//! concurrent readers (HTTP `GET /trace/<id>`, `--trace-out` export)
//! retry or skip slots that are mid-write, so a scrape can never stall
//! the serving loop.
//!
//! **Span semantics: phases tile the request lifetime.** Every span
//! starts where the previous span of the same request ended, so for any
//! request the recorded spans sum exactly to its wall time (the
//! acceptance test in `tests/trace.rs` and the in-bench assertion in
//! `bench_serve` both lean on this). A decode span therefore measures
//! "time this request spent in decode-iteration cadence", not backend
//! CPU attribution — a prefill chunk interleaved between two of a
//! request's decode steps lands in that request's decode span and in the
//! prefilling request's prefill-chunk span.
//!
//! Export is Chrome trace-event JSON (`ph: "X"` complete events, one
//! `tid` per request), loadable directly in Perfetto / `chrome://tracing`.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Request lifecycle phase of one span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Submit → popped by the engine loop.
    Queue,
    /// Admission bookkeeping: quota charge, prefix-cache lookup, paged
    /// block reservation, chunked-job begin (or, monolithic, the whole
    /// blocking prefill).
    Admission,
    /// One chunked-prefill step (plus interleaved loop work since the
    /// previous chunk — lifecycle tiling, see module docs).
    PrefillChunk,
    /// Eviction selection + gather-compaction + activation.
    Eviction,
    /// One decode iteration.
    Decode,
    /// Preempted: KV parked in the host spill store.
    Spill,
    /// Spilled blocks re-bound into the arena.
    Restore,
    /// Completion: final bookkeeping + reply send.
    Finish,
    /// Write-time quantization into a low-precision KV arena (recorded
    /// only when `--kv-dtype` is not `f32`). Tiled inside the enclosing
    /// lifecycle phase, so it is *informational* — excluded from the
    /// spans-tile-to-wall-time invariant checked by `bench_serve`.
    Quantize,
    /// Dequantize→requantize during gather-compaction: kept rows that
    /// cross block boundaries are decoded to f32 scratch and re-encoded
    /// against the destination block's scale/zero-point.
    Requantize,
    /// Terminal bookkeeping of a *failed* request (error reply + resource
    /// teardown). Replaces the `Finish` span on error exits, so failed
    /// lifecycles still tile.
    Error,
    /// Terminal bookkeeping of a deadline-expired or client-cancelled
    /// request. Replaces the `Finish` span on those exits.
    Cancel,
}

impl Phase {
    pub const ALL: [Phase; 12] = [
        Phase::Queue,
        Phase::Admission,
        Phase::PrefillChunk,
        Phase::Eviction,
        Phase::Decode,
        Phase::Spill,
        Phase::Restore,
        Phase::Finish,
        Phase::Quantize,
        Phase::Requantize,
        Phase::Error,
        Phase::Cancel,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Queue => "queue",
            Phase::Admission => "admission",
            Phase::PrefillChunk => "prefill_chunk",
            Phase::Eviction => "eviction",
            Phase::Decode => "decode",
            Phase::Spill => "spill",
            Phase::Restore => "restore",
            Phase::Finish => "finish",
            Phase::Quantize => "quantize",
            Phase::Requantize => "dequant-requantize",
            Phase::Error => "error",
            Phase::Cancel => "cancel",
        }
    }

    fn from_u64(x: u64) -> Option<Phase> {
        Phase::ALL.get(x as usize).copied()
    }
}

/// One recorded span (snapshot of a ring slot).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanEvent {
    pub request_id: u64,
    pub phase: Phase,
    /// Microseconds since the tracer's epoch.
    pub start_us: u64,
    pub dur_us: u64,
}

/// One ring slot: a per-slot seqlock. `seq` is odd while the writer is
/// mid-update; readers snapshot the fields and discard the read if `seq`
/// changed (or was odd) around it.
struct Slot {
    seq: AtomicU64,
    request_id: AtomicU64,
    phase: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            request_id: AtomicU64::new(0),
            phase: AtomicU64::new(0),
            start_us: AtomicU64::new(0),
            dur_us: AtomicU64::new(0),
        }
    }
}

/// Default ring capacity (events). At one decode span per request per
/// iteration this holds minutes of serving history for small fleets;
/// older events are overwritten, counted in [`Tracer::dropped`].
pub const DEFAULT_CAPACITY: usize = 1 << 16;

pub struct Tracer {
    epoch: Instant,
    slots: Vec<Slot>,
    /// Total events ever recorded; slot index is `head % slots.len()`.
    head: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Tracer {
        Tracer::new()
    }
}

impl Tracer {
    pub fn new() -> Tracer {
        Tracer::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(capacity: usize) -> Tracer {
        let cap = capacity.max(2).next_power_of_two();
        Tracer {
            epoch: Instant::now(),
            slots: (0..cap).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Events recorded since construction.
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Events overwritten by ring wraparound (no longer readable).
    pub fn dropped(&self) -> u64 {
        self.recorded().saturating_sub(self.slots.len() as u64)
    }

    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn instant_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Record one span. Single-writer: only the engine thread calls this
    /// (concurrent writers would need a CAS head claim; the loop is the
    /// sole producer by construction).
    pub fn record(&self, request_id: u64, phase: Phase, start: Instant, end: Instant) {
        let start_us = self.instant_us(start);
        let end_us = self.instant_us(end);
        self.record_us(request_id, phase, start_us, end_us.saturating_sub(start_us));
    }

    pub fn record_us(&self, request_id: u64, phase: Phase, start_us: u64, dur_us: u64) {
        let head = self.head.load(Ordering::Relaxed);
        let slot = &self.slots[(head as usize) & (self.slots.len() - 1)];
        let s = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(s + 1, Ordering::Release); // odd: write in progress
        slot.request_id.store(request_id, Ordering::Relaxed);
        slot.phase.store(phase as u64, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        slot.seq.store(s + 2, Ordering::Release); // even: stable
        self.head.store(head + 1, Ordering::Release);
    }

    fn read_slot(&self, i: usize) -> Option<SpanEvent> {
        let slot = &self.slots[i];
        for _ in 0..4 {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                return None; // never written, or mid-write
            }
            let ev = SpanEvent {
                request_id: slot.request_id.load(Ordering::Relaxed),
                phase: Phase::from_u64(slot.phase.load(Ordering::Relaxed))?,
                start_us: slot.start_us.load(Ordering::Relaxed),
                dur_us: slot.dur_us.load(Ordering::Relaxed),
            };
            if slot.seq.load(Ordering::Acquire) == s1 {
                return Some(ev);
            }
        }
        None // writer lapped us repeatedly; skip the slot
    }

    /// Snapshot every readable span, oldest first.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let first = head.saturating_sub(cap);
        let mut out = Vec::with_capacity((head - first) as usize);
        for i in first..head {
            if let Some(ev) = self.read_slot((i as usize) & (self.slots.len() - 1)) {
                out.push(ev);
            }
        }
        out.sort_by_key(|e| e.start_us);
        out
    }

    /// Every readable span of one request, oldest first.
    pub fn spans_for(&self, request_id: u64) -> Vec<SpanEvent> {
        let mut v = self.snapshot();
        v.retain(|e| e.request_id == request_id);
        v
    }

    /// One request's spans as the `GET /trace/<id>` JSON body.
    pub fn request_json(&self, request_id: u64) -> Json {
        let spans = self.spans_for(request_id);
        let total_us: u64 = spans.iter().map(|s| s.dur_us).sum();
        let arr = spans
            .iter()
            .map(|s| {
                Json::from_pairs(vec![
                    ("phase", s.phase.as_str().into()),
                    ("start_us", (s.start_us as f64).into()),
                    ("dur_us", (s.dur_us as f64).into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("request_id", (request_id as f64).into()),
            ("spans", Json::Arr(arr)),
            ("total_us", (total_us as f64).into()),
        ])
    }

    /// Chrome trace-event JSON (the "JSON Array Format" with a
    /// `traceEvents` wrapper): complete (`ph: "X"`) events, one thread
    /// lane per request id. Loadable in Perfetto / `chrome://tracing`.
    pub fn to_chrome_json(&self) -> Json {
        let events = self
            .snapshot()
            .into_iter()
            .map(|e| {
                Json::from_pairs(vec![
                    ("name", e.phase.as_str().into()),
                    ("cat", "request".into()),
                    ("ph", "X".into()),
                    ("ts", (e.start_us as f64).into()),
                    ("dur", (e.dur_us as f64).into()),
                    ("pid", 1.0.into()),
                    ("tid", (e.request_id as f64).into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("traceEvents", Json::Arr(events)),
            ("displayTimeUnit", "ms".into()),
        ])
    }

    pub fn write_chrome_trace(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_chrome_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn ev(t: &Tracer, req: u64, phase: Phase, start: u64, dur: u64) {
        t.record_us(req, phase, start, dur);
    }

    #[test]
    fn record_and_query_per_request() {
        let t = Tracer::with_capacity(64);
        ev(&t, 1, Phase::Queue, 0, 100);
        ev(&t, 2, Phase::Queue, 50, 25);
        ev(&t, 1, Phase::Admission, 100, 30);
        ev(&t, 1, Phase::Decode, 130, 70);
        let spans = t.spans_for(1);
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].phase, Phase::Queue);
        assert_eq!(spans[2].phase, Phase::Decode);
        assert_eq!(spans.iter().map(|s| s.dur_us).sum::<u64>(), 200);
        assert_eq!(t.spans_for(2).len(), 1);
        assert_eq!(t.spans_for(99).len(), 0);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overwrites_oldest() {
        let t = Tracer::with_capacity(8);
        for i in 0..20 {
            ev(&t, i, Phase::Decode, i * 10, 5);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 8);
        // Only the newest 8 survive.
        assert!(snap.iter().all(|e| e.request_id >= 12));
        assert_eq!(t.dropped(), 12);
    }

    #[test]
    fn spans_tile_with_instant_recording() {
        let t = Tracer::new();
        let t0 = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let t1 = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let t2 = Instant::now();
        t.record(7, Phase::Queue, t0, t1);
        t.record(7, Phase::Decode, t1, t2);
        let spans = t.spans_for(7);
        assert_eq!(spans.len(), 2);
        // Tiling: span 2 starts exactly where span 1 ended.
        assert_eq!(spans[0].start_us + spans[0].dur_us, spans[1].start_us);
        let sum_us = spans.iter().map(|s| s.dur_us).sum::<u64>();
        let wall_us = t2.duration_since(t0).as_micros() as u64;
        assert!(sum_us.abs_diff(wall_us) <= 2, "sum {sum_us} vs wall {wall_us}");
    }

    #[test]
    fn chrome_export_shape() {
        let t = Tracer::with_capacity(16);
        ev(&t, 3, Phase::PrefillChunk, 10, 20);
        ev(&t, 3, Phase::Eviction, 30, 5);
        let j = t.to_chrome_json();
        let events = j.req("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 2);
        for e in events {
            assert_eq!(e.req("ph").as_str(), Some("X"));
            assert_eq!(e.req("cat").as_str(), Some("request"));
            assert_eq!(e.req("tid").as_usize(), Some(3));
            assert!(e.req("ts").as_f64().is_some());
            assert!(e.req("dur").as_f64().is_some());
        }
        assert_eq!(events[0].req("name").as_str(), Some("prefill_chunk"));
        // Round-trips through our own parser (valid JSON).
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req("traceEvents").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn write_trace_file() {
        let t = Tracer::with_capacity(16);
        ev(&t, 1, Phase::Decode, 0, 10);
        let dir = std::env::temp_dir().join("lkv_trace_test");
        let path = dir.join("trace.json");
        t.write_chrome_trace(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(parsed.req("traceEvents").as_arr().unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Readers racing the writer never panic and only ever see complete
    /// events (seqlock torn-read protection).
    #[test]
    fn concurrent_reader_sees_only_complete_events() {
        let t = Arc::new(Tracer::with_capacity(64));
        let stop = Arc::new(AtomicU64::new(0));
        let readers: Vec<_> = (0..2)
            .map(|_| {
                let t = Arc::clone(&t);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut seen = 0usize;
                    while stop.load(Ordering::Relaxed) == 0 {
                        for e in t.snapshot() {
                            // Writer always records dur = start/2 + 1:
                            // a torn read would break the invariant.
                            assert_eq!(e.dur_us, e.start_us / 2 + 1);
                            seen += 1;
                        }
                    }
                    seen
                })
            })
            .collect();
        for i in 0..50_000u64 {
            t.record_us(i % 7, Phase::Decode, i, i / 2 + 1);
        }
        stop.store(1, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(t.recorded(), 50_000);
    }
}

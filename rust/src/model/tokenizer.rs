//! Byte-level tokenizer — the exact mirror of `python/compile/tokenizer.py`.
//!
//! Ids 0..=255 are raw bytes; 256..=259 are PAD/BOS/EOS/SEP. The manifest
//! carries the same constants and the integration tests cross-check them.

pub const PAD_ID: i32 = 256;
pub const BOS_ID: i32 = 257;
pub const EOS_ID: i32 = 258;
pub const SEP_ID: i32 = 259;
pub const VOCAB_SIZE: usize = 320;

/// Stateless tokenizer handle (the constants above are the whole state,
/// but a struct keeps call sites uniform if a BPE variant lands later).
#[derive(Debug, Clone, Copy, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn encode(&self, text: &str, bos: bool, eos: bool) -> Vec<i32> {
        encode(text, bos, eos)
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        decode(ids)
    }
}

pub fn encode(text: &str, bos: bool, eos: bool) -> Vec<i32> {
    let bytes = text.as_bytes();
    let mut ids = Vec::with_capacity(bytes.len() + 2);
    if bos {
        ids.push(BOS_ID);
    }
    ids.extend(bytes.iter().map(|&b| b as i32));
    if eos {
        ids.push(EOS_ID);
    }
    ids
}

/// Decode, dropping special tokens; invalid UTF-8 becomes U+FFFD.
pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids.iter().filter(|&&i| (0..256).contains(&i)).map(|&i| i as u8).collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Decode up to (excluding) the first EOS.
pub fn decode_until_eos(ids: &[i32]) -> String {
    let end = ids.iter().position(|&i| i == EOS_ID).unwrap_or(ids.len());
    decode(&ids[..end])
}

pub fn pad_to(ids: &[i32], len: usize) -> Vec<i32> {
    assert!(ids.len() <= len, "sequence of {} tokens exceeds bucket {len}", ids.len());
    let mut out = ids.to_vec();
    out.resize(len, PAD_ID);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let ids = encode("?K7F=Q2Z;", true, true);
        assert_eq!(ids[0], BOS_ID);
        assert_eq!(*ids.last().unwrap(), EOS_ID);
        assert_eq!(decode(&ids), "?K7F=Q2Z;");
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "héllo→";
        assert_eq!(decode(&encode(s, false, false)), s);
    }

    #[test]
    fn decode_until_eos_stops() {
        let mut ids = encode("abc", false, false);
        ids.push(EOS_ID);
        ids.extend(encode("junk", false, false));
        assert_eq!(decode_until_eos(&ids), "abc");
    }

    #[test]
    fn pad_to_len() {
        let ids = pad_to(&[1, 2], 5);
        assert_eq!(ids, vec![1, 2, PAD_ID, PAD_ID, PAD_ID]);
    }

    #[test]
    #[should_panic]
    fn pad_overflow_panics() {
        pad_to(&[1, 2, 3], 2);
    }
}

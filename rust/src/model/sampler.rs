//! Token sampling over logits: greedy or temperature-scaled categorical.

use crate::util::rng::{argmax, Rng};

#[derive(Debug, Clone)]
pub struct Sampler {
    pub temperature: f32,
    rng: Rng,
}

impl Sampler {
    pub fn greedy() -> Sampler {
        Sampler { temperature: 0.0, rng: Rng::new(0) }
    }

    pub fn with_temperature(temperature: f32, seed: u64) -> Sampler {
        Sampler { temperature, rng: Rng::new(seed) }
    }

    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        if self.temperature <= 0.0 {
            argmax(logits) as i32
        } else {
            self.rng.categorical(logits, self.temperature) as i32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut s = Sampler::greedy();
        assert_eq!(s.sample(&[0.0, 5.0, 1.0]), 1);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut s = Sampler::with_temperature(1.0, 7);
        let logits = [1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[s.sample(&logits) as usize] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }
}

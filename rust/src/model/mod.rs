//! Model-facing types shared across the coordinator: the byte-level
//! tokenizer (mirroring `python/compile/tokenizer.py`) and sampling.

pub mod sampler;
pub mod tokenizer;

pub use sampler::Sampler;
pub use tokenizer::{decode, encode, Tokenizer, BOS_ID, EOS_ID, PAD_ID, SEP_ID};

//! Analytical TTFT cost model (paper §B, after Davies et al. 2025).
//!
//! Reproduces the paper's *theoretical* Table 3 / Table 15 and Fig. 3a for
//! the paper's own configuration — LLaMA3.1-8B on one H100-80GB, batch 1,
//! half precision, KV budget 128, lookahead/window/draft size 32 — since
//! the theoretical analysis is hardware-independent arithmetic we can run
//! anywhere. Each eviction method is decomposed into phases; each phase
//! costs `max(flops / (peak_flops · eff_f), bytes / (bw · eff_m))` and
//! phases are additive (they synchronize on the GPU stream).
//!
//! Calibration notes (documented in EXPERIMENTS.md): with the paper's
//! stated efficiencies (0.7 flops / 0.9 memory, per llm-analysis) the
//! prefill rows match when peak is the H100's dense-BF16 rate; residual
//! differences on the draft methods come from implementation details of
//! their phase accounting that the paper does not fully specify.

pub mod methods;
pub mod profiles;

pub use methods::{method_cost, CostRow, MethodKind};
pub use profiles::{HwProfile, LlmProfile};

/// One phase of work on the accelerator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Phase {
    pub flops: f64,
    pub bytes: f64,
}

impl Phase {
    pub fn seconds(&self, hw: &HwProfile) -> f64 {
        let tc = self.flops / (hw.peak_flops * hw.flops_eff);
        let tm = self.bytes / (hw.mem_bw * hw.mem_eff);
        tc.max(tm)
    }
}

/// Sum of phases with compute/traffic totals.
#[derive(Debug, Clone, Default)]
pub struct Cost {
    pub phases: Vec<Phase>,
}

impl Cost {
    pub fn push(&mut self, p: Phase) {
        self.phases.push(p);
    }

    pub fn tflops(&self) -> f64 {
        self.phases.iter().map(|p| p.flops).sum::<f64>() / 1e12
    }

    pub fn traffic_gb(&self) -> f64 {
        self.phases.iter().map(|p| p.bytes).sum::<f64>() / 1e9
    }

    pub fn ttft_ms(&self, hw: &HwProfile) -> f64 {
        self.phases.iter().map(|p| p.seconds(hw)).sum::<f64>() * 1e3
    }
}

//! Per-method TTFT decomposition (paper §B): forward baseline, LookaheadKV,
//! SnapKV, SpecKV (draft model), and LAQ (two-pass with target-model
//! decode), at the paper's configuration (C=128, window/lookahead/draft=32).

use super::profiles::{HwProfile, LlmProfile};
use super::{Cost, Phase};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodKind {
    ForwardOnly,
    LookaheadKV,
    SnapKV,
    SpecKV,
    Laq,
}

impl MethodKind {
    pub fn label(&self) -> &'static str {
        match self {
            MethodKind::ForwardOnly => "Forward Pass Only",
            MethodKind::LookaheadKV => "LookaheadKV",
            MethodKind::SnapKV => "SnapKV",
            MethodKind::SpecKV => "SpecKV",
            MethodKind::Laq => "LAQ",
        }
    }

    pub fn all() -> [MethodKind; 5] {
        [
            MethodKind::ForwardOnly,
            MethodKind::LookaheadKV,
            MethodKind::SnapKV,
            MethodKind::SpecKV,
            MethodKind::Laq,
        ]
    }
}

/// Knobs matching the paper's theoretical setup.
#[derive(Debug, Clone, Copy)]
pub struct CostConfig {
    pub n_lookahead: f64,
    pub window: f64,
    pub draft_tokens: f64,
    pub budget: f64,
    /// LoRA rank of the lookahead adapters.
    pub lora_rank: f64,
    pub lora_targets: f64, // number of adapted linear layers per block
}

impl Default for CostConfig {
    fn default() -> Self {
        CostConfig {
            n_lookahead: 32.0,
            window: 32.0,
            draft_tokens: 32.0,
            budget: 128.0,
            lora_rank: 8.0,
            lora_targets: 7.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct CostRow {
    pub method: MethodKind,
    pub context: usize,
    pub tflops: f64,
    pub traffic_gb: f64,
    pub ttft_ms: f64,
    pub overhead_ms: f64,
}

fn forward_cost(m: &LlmProfile, s: f64) -> Cost {
    let mut c = Cost::default();
    c.push(Phase {
        flops: m.forward_flops(s),
        bytes: m.weight_bytes() + m.kv_bytes(s),
    });
    c
}

/// Decode `n` tokens, each streaming the weights plus the live KV.
fn decode_cost(m: &LlmProfile, ctx: f64, n: f64) -> Cost {
    let mut c = Cost::default();
    for i in 0..n as usize {
        let cur = ctx + i as f64;
        c.push(Phase {
            flops: m.decode_flops(cur),
            bytes: m.weight_bytes() + m.kv_bytes(cur),
        });
    }
    c
}

/// Cross-attention scoring of `rows` query rows against `s` keys across
/// all layers/heads (the eviction scoring pass over cached KV).
fn rescore_cost(m: &LlmProfile, s: f64, rows: f64) -> Cost {
    let mut c = Cost::default();
    c.push(Phase {
        flops: m.n_layers * 2.0 * rows * s * m.q_dim(),
        bytes: m.kv_bytes(s) / 2.0, // stream keys once
    });
    c
}

pub fn method_cost(
    method: MethodKind,
    target: &LlmProfile,
    draft: &LlmProfile,
    hw: &HwProfile,
    context: usize,
    cfg: &CostConfig,
) -> CostRow {
    let s = context as f64;
    let base = forward_cost(target, s);
    let mut c = Cost::default();
    match method {
        MethodKind::ForwardOnly => c = base.clone(),
        MethodKind::SnapKV => {
            // reuses prefill attention; only the window-row aggregation +
            // top-k, which is O(window·s) score arithmetic — no extra
            // weight traffic at all.
            c = base.clone();
            c.push(Phase { flops: cfg.window * s * target.n_heads * target.n_layers, bytes: 0.0 });
        }
        MethodKind::LookaheadKV => {
            // prefill over s + n_lookahead rows, plus the LoRA delta on
            // the lookahead rows only, plus the Pallas scoring kernel.
            let mut fwd = forward_cost(target, s + cfg.n_lookahead);
            let lora_params = target.n_layers
                * cfg.lora_targets
                * cfg.lora_rank
                * (target.d_model + (target.d_model + target.ff) / 2.0);
            fwd.push(Phase {
                flops: 2.0 * lora_params * cfg.n_lookahead,
                bytes: lora_params * target.bytes_per_param,
            });
            fwd.push(Phase {
                flops: target.n_layers * target.n_heads * 2.0 * cfg.n_lookahead * s * target.head_dim,
                bytes: 0.0,
            });
            c = fwd;
        }
        MethodKind::SpecKV => {
            // draft prefill + draft decode + target prefill over
            // [prompt; draft] + rescore aggregation.
            for p in forward_cost(draft, s).phases {
                c.push(p);
            }
            for p in decode_cost(draft, s, cfg.draft_tokens).phases {
                c.push(p);
            }
            for p in forward_cost(target, s + cfg.draft_tokens).phases {
                c.push(p);
            }
        }
        MethodKind::Laq => {
            // pass 1: target prefill (the baseline forward) + SnapKV evict;
            // pseudo-generation: draft_tokens decode steps on the *target*
            // model with the evicted cache (weight-streaming dominated);
            // pass 2: re-score draft queries against the full prompt KV.
            c = base.clone();
            for p in decode_cost(target, cfg.budget + cfg.window, cfg.draft_tokens).phases {
                c.push(p);
            }
            for p in rescore_cost(target, s, cfg.draft_tokens).phases {
                c.push(p);
            }
        }
    }
    let base_ms = base.ttft_ms(hw);
    let ttft = c.ttft_ms(hw);
    CostRow {
        method,
        context,
        tflops: c.tflops(),
        traffic_gb: c.traffic_gb(),
        ttft_ms: ttft,
        overhead_ms: if method == MethodKind::ForwardOnly { 0.0 } else { ttft - base_ms },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::profiles::{H100, LLAMA31_8B, LLAMA32_1B};

    fn row(m: MethodKind, ctx: usize) -> CostRow {
        method_cost(m, &LLAMA31_8B, &LLAMA32_1B, &H100, ctx, &CostConfig::default())
    }

    #[test]
    fn forward_matches_paper_scale() {
        // paper Table 3 @8K: 136 TFLOPs, 257 ms; @32K: 928 TFLOPs, 1754 ms
        let r8 = row(MethodKind::ForwardOnly, 8192);
        assert!((r8.tflops - 136.0).abs() < 30.0, "{}", r8.tflops);
        assert!((r8.ttft_ms - 257.0).abs() < 70.0, "{}", r8.ttft_ms);
        let r32 = row(MethodKind::ForwardOnly, 32768);
        assert!((r32.tflops - 928.0).abs() < 190.0, "{}", r32.tflops);
        assert!((r32.ttft_ms - 1754.0).abs() < 420.0, "{}", r32.ttft_ms);
    }

    #[test]
    fn ordering_matches_paper() {
        // overhead: SnapKV ~ LKV << SpecKV, LAQ at every context length
        for ctx in [4096, 8192, 16384, 32768] {
            let snap = row(MethodKind::SnapKV, ctx).overhead_ms;
            let lkv = row(MethodKind::LookaheadKV, ctx).overhead_ms;
            let spec = row(MethodKind::SpecKV, ctx).overhead_ms;
            let laq = row(MethodKind::Laq, ctx).overhead_ms;
            assert!(snap < lkv, "snap {snap} < lkv {lkv}");
            assert!(lkv < 0.1 * spec.min(laq), "lkv {lkv} spec {spec} laq {laq}");
            assert!(laq > 100.0, "laq {laq}");
        }
    }

    #[test]
    fn laq_is_memory_dominated() {
        let r = row(MethodKind::Laq, 8192);
        // paper: LAQ traffic ~445 GB vs forward 13 GB
        assert!(r.traffic_gb > 300.0, "{}", r.traffic_gb);
        let f = row(MethodKind::ForwardOnly, 8192);
        assert!(f.traffic_gb < 25.0, "{}", f.traffic_gb);
    }

    #[test]
    fn lkv_overhead_below_paper_bound() {
        // paper headline: <2.16% overhead at 32K
        let f = row(MethodKind::ForwardOnly, 32768);
        let l = row(MethodKind::LookaheadKV, 32768);
        let pct = 100.0 * l.overhead_ms / f.ttft_ms;
        assert!(pct < 2.16, "{pct}%");
    }

    #[test]
    fn headline_cost_reduction_vs_laq() {
        // paper: up to 14.5x eviction-cost reduction at 32K
        let l = row(MethodKind::LookaheadKV, 32768);
        let q = row(MethodKind::Laq, 32768);
        let ratio = q.overhead_ms / l.overhead_ms.max(1e-9);
        assert!(ratio > 10.0, "ratio {ratio}");
    }
}

//! Hardware and model profiles for the analytical cost model.

/// Accelerator profile.
#[derive(Debug, Clone, Copy)]
pub struct HwProfile {
    pub name: &'static str,
    /// Peak dense half-precision FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Achievable fractions (Li 2023, llm-analysis defaults used by the paper).
    pub flops_eff: f64,
    pub mem_eff: f64,
}

/// H100-80GB SXM, dense BF16 tensor-core rate.
pub const H100: HwProfile = HwProfile {
    name: "H100-80GB",
    peak_flops: 756e12,
    mem_bw: 3.35e12,
    flops_eff: 0.7,
    mem_eff: 0.9,
};

/// Transformer shape for analytical FLOPs/bytes (half precision).
#[derive(Debug, Clone, Copy)]
pub struct LlmProfile {
    pub name: &'static str,
    pub n_layers: f64,
    pub d_model: f64,
    pub n_heads: f64,
    pub n_kv_heads: f64,
    pub head_dim: f64,
    pub ff: f64,
    pub vocab: f64,
    pub bytes_per_param: f64,
}

impl LlmProfile {
    pub fn q_dim(&self) -> f64 {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> f64 {
        self.n_kv_heads * self.head_dim
    }

    /// Matmul-visible parameters (dense weights; embedding excluded — its
    /// rows are gathered, not streamed).
    pub fn matmul_params(&self) -> f64 {
        let per_layer = self.d_model * self.q_dim()
            + 2.0 * self.d_model * self.kv_dim()
            + self.q_dim() * self.d_model
            + 3.0 * self.d_model * self.ff;
        self.n_layers * per_layer + self.d_model * self.vocab
    }

    /// Forward FLOPs for `s` tokens attending over a causal prefix of
    /// themselves: 2·P·s for the matmuls + 2·2·(s²/2)·d_q per layer for
    /// QKᵀ and AV.
    pub fn forward_flops(&self, s: f64) -> f64 {
        let matmul = 2.0 * self.matmul_params() * s;
        let attn = self.n_layers * 2.0 * 2.0 * (s * s / 2.0) * self.q_dim();
        matmul + attn
    }

    /// Incremental FLOPs of decoding one token with a KV context of `ctx`.
    pub fn decode_flops(&self, ctx: f64) -> f64 {
        2.0 * self.matmul_params() + self.n_layers * 2.0 * 2.0 * ctx * self.q_dim()
    }

    /// Weight bytes streamed per forward (prefill streams them once).
    pub fn weight_bytes(&self) -> f64 {
        self.matmul_params() * self.bytes_per_param
    }

    /// KV-cache bytes for `s` tokens.
    pub fn kv_bytes(&self, s: f64) -> f64 {
        2.0 * self.n_layers * self.kv_dim() * s * self.bytes_per_param
    }
}

/// LLaMA3.1-8B (the paper's Table-3 target model).
pub const LLAMA31_8B: LlmProfile = LlmProfile {
    name: "LLaMA3.1-8B",
    n_layers: 32.0,
    d_model: 4096.0,
    n_heads: 32.0,
    n_kv_heads: 8.0,
    head_dim: 128.0,
    ff: 14336.0,
    vocab: 128256.0,
    bytes_per_param: 2.0,
};

/// LLaMA3.2-1B (the paper's draft model for SpecKV).
pub const LLAMA32_1B: LlmProfile = LlmProfile {
    name: "LLaMA3.2-1B",
    n_layers: 16.0,
    d_model: 2048.0,
    n_heads: 32.0,
    n_kv_heads: 8.0,
    head_dim: 64.0,
    ff: 8192.0,
    vocab: 128256.0,
    bytes_per_param: 2.0,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama8b_sizes() {
        // matmul params ≈ 7.5B (8.03B minus input embedding)
        let p = LLAMA31_8B.matmul_params();
        assert!((6.9e9..7.8e9).contains(&p), "{p}");
        // weights ≈ 14 GB at bf16 (the paper's ~13 GB forward traffic row)
        let gb = LLAMA31_8B.weight_bytes() / 1e9;
        assert!((13.0..16.0).contains(&gb), "{gb}");
        // GQA KV for 8K tokens ≈ 1 GB (32L x 8 KV heads x 128 dh, bf16)
        let kv = LLAMA31_8B.kv_bytes(8192.0) / 1e9;
        assert!((0.8..1.5).contains(&kv), "{kv}");
    }

    #[test]
    fn forward_flops_order() {
        // paper Table 3: 8K forward ≈ 136 TFLOPs, 32K ≈ 928 TFLOPs.
        // Our accounting lands within ~20% (the paper's exact attention
        // accounting is unspecified); residuals documented in
        // EXPERIMENTS.md §Table 3.
        let t8k = LLAMA31_8B.forward_flops(8192.0) / 1e12;
        assert!((105.0..170.0).contains(&t8k), "{t8k}");
        let t32k = LLAMA31_8B.forward_flops(32768.0) / 1e12;
        assert!((700.0..1100.0).contains(&t32k), "{t32k}");
    }
}

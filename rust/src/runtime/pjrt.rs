//! The PJRT execution backend: lazy graph compilation + cached weights.
//!
//! Threading model: one backend lives on the engine thread (PJRT handles
//! are raw pointers and not `Send`); the scheduler/server communicate with
//! the engine over channels, vLLM-style. Interior mutability is therefore
//! plain `RefCell`.
//!
//! Compiled only under the `pjrt` cargo feature. The default `xla`
//! dependency is an API stub (see `rust/vendor/xla`); swap it for a real
//! binding to execute the AOT HLO-text artifacts.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

use super::artifacts::Manifest;
use super::backend::{Backend, GraphStats, Value};
use super::literal::{literal_f32, literal_i32, tensor_f32, tensor_i32};

pub struct PjrtBackend {
    client: PjRtClient,
    manifest: Manifest,
    graphs: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    weights: RefCell<HashMap<String, Rc<Vec<Literal>>>>,
    stats: RefCell<HashMap<String, GraphStats>>,
}

impl PjrtBackend {
    pub fn new(artifacts_dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "pjrt backend up: platform={} graphs={} models={}",
            client.platform_name(),
            manifest.graphs.len(),
            manifest.models.len()
        );
        Ok(PjrtBackend {
            client,
            manifest,
            graphs: RefCell::new(HashMap::new()),
            weights: RefCell::new(HashMap::new()),
            stats: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (once) and return the executable for a graph key.
    fn graph(&self, key: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.graphs.borrow().get(key) {
            return Ok(Rc::clone(exe));
        }
        let meta = self.manifest.graph(key)?;
        let path = self.manifest.path(&meta.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {key}"))?;
        let dt = t0.elapsed().as_secs_f64() * 1e3;
        self.stats.borrow_mut().entry(key.to_string()).or_default().compile_ms += dt;
        log::info!("compiled {key} in {dt:.0} ms");
        let exe = Rc::new(exe);
        self.graphs.borrow_mut().insert(key.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Load (once) a weights npz in the canonical order of `param_names`.
    fn load_npz_ordered(&self, rel: &str, names: &[String]) -> Result<Rc<Vec<Literal>>> {
        if let Some(w) = self.weights.borrow().get(rel) {
            return Ok(Rc::clone(w));
        }
        let path = self.manifest.path(rel);
        let pairs = Literal::read_npz(&path, &()).with_context(|| format!("reading {path:?}"))?;
        let mut by_name: HashMap<String, Literal> = pairs.into_iter().collect();
        let mut ordered = Vec::with_capacity(names.len());
        for n in names {
            let lit = by_name
                .remove(n)
                .with_context(|| format!("weights file {rel} missing tensor {n:?}"))?;
            ordered.push(lit);
        }
        let rc = Rc::new(ordered);
        self.weights.borrow_mut().insert(rel.to_string(), Rc::clone(&rc));
        Ok(rc)
    }

    fn model_weights(&self, model: &str) -> Result<Rc<Vec<Literal>>> {
        let m = self.manifest.model(model)?;
        let (file, names) = (m.weights_file.clone(), m.param_names.clone());
        self.load_npz_ordered(&file, &names)
    }

    fn variant_weights(&self, model: &str, variant: &str) -> Result<Rc<Vec<Literal>>> {
        let v = self.manifest.variant(model, variant)?;
        let (file, names) = (v.weights_file.clone(), v.param_names.clone());
        self.load_npz_ordered(&file, &names)
    }

    /// Execute a graph: positional args are
    /// `[model weights..] [variant weights..]? [runtime inputs..]`.
    /// Returns the flattened output literals in manifest order.
    fn execute_literals(
        &self,
        key: &str,
        variant: Option<(&str, &str)>,
        inputs: &[Literal],
    ) -> Result<Vec<Literal>> {
        let exe = self.graph(key)?;
        let meta = self.manifest.graph(key)?.clone();
        let weights = self.model_weights(&meta.model)?;
        let vweights = match variant {
            Some((m, v)) => Some(self.variant_weights(m, v)?),
            None => {
                anyhow::ensure!(meta.n_lkv_weight_args == 0, "graph {key} needs a variant");
                None
            }
        };
        let mut args: Vec<&Literal> = Vec::with_capacity(
            weights.len() + vweights.as_ref().map_or(0, |v| v.len()) + inputs.len(),
        );
        args.extend(weights.iter());
        if let Some(v) = &vweights {
            anyhow::ensure!(
                v.len() == meta.n_lkv_weight_args,
                "graph {key}: variant weight count {} != {}",
                v.len(),
                meta.n_lkv_weight_args
            );
            args.extend(v.iter());
        }
        args.extend(inputs.iter());

        let t0 = Instant::now();
        let out =
            exe.execute::<&Literal>(&args).with_context(|| format!("executing {key}"))?;
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = Instant::now();
        let tuple = out[0][0].to_literal_sync().context("fetching result")?;
        let flat = tuple.to_tuple().context("untupling result")?;
        let transfer_ms = t1.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(
            flat.len() == meta.outputs.len(),
            "graph {key}: {} outputs, manifest says {}",
            flat.len(),
            meta.outputs.len()
        );
        let mut stats = self.stats.borrow_mut();
        let e = stats.entry(key.to_string()).or_default();
        e.calls += 1;
        e.exec_ms += exec_ms;
        e.transfer_ms += transfer_ms;
        Ok(flat)
    }
}

fn value_to_literal(v: &Value) -> Result<Literal> {
    match v {
        Value::F32(t) => literal_f32(t),
        Value::I32(t) if t.shape.is_empty() => Ok(Literal::scalar(t.data[0])),
        Value::I32(t) => literal_i32(t),
    }
}

#[allow(unreachable_patterns)] // the stub ElementType has only F32/S32
fn literal_to_value(lit: &Literal) -> Result<Value> {
    match lit.ty().context("output element type")? {
        xla::ElementType::F32 => Ok(Value::F32(tensor_f32(lit)?)),
        xla::ElementType::S32 => Ok(Value::I32(tensor_i32(lit)?)),
        other => anyhow::bail!("unsupported output element type {other:?}"),
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn execute(
        &self,
        key: &str,
        variant: Option<(&str, &str)>,
        inputs: &[Value],
    ) -> Result<Vec<Value>> {
        let lits: Vec<Literal> =
            inputs.iter().map(value_to_literal).collect::<Result<Vec<_>>>()?;
        let out = self.execute_literals(key, variant, &lits)?;
        out.iter().map(literal_to_value).collect()
    }

    fn prepare(&self, key: &str) -> Result<()> {
        self.graph(key).map(|_| ())
    }

    // Chunked prefill is stubbed on this backend: the AOT artifact set
    // has no `prefill_chunk` graph family yet (it would need a KV-cache
    // in/out prefill graph per (bucket, chunk) pair lowered by aot.py).
    // `supports_chunked_prefill` stays false so the engine loop falls
    // back to monolithic prefill, and direct calls error clearly.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    fn prefill_chunk(
        &self,
        _state: &mut super::backend::ChunkState,
        _tokens: &[i32],
    ) -> Result<()> {
        anyhow::bail!(
            "pjrt backend has no chunked-prefill graphs yet; \
             run with LKV_BACKEND=reference or use monolithic prefill"
        )
    }

    fn prefill_finalize(&self, _state: &mut super::backend::ChunkState) -> Result<()> {
        anyhow::bail!(
            "pjrt backend has no chunked-prefill graphs yet; \
             run with LKV_BACKEND=reference or use monolithic prefill"
        )
    }

    fn stats(&self) -> Vec<(String, GraphStats)> {
        let mut v: Vec<(String, GraphStats)> =
            self.stats.borrow().iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        v.sort_by(|a, b| b.1.exec_ms.partial_cmp(&a.1.exec_ms).unwrap());
        v
    }

    fn reset_stats(&self) {
        self.stats.borrow_mut().clear();
    }
}

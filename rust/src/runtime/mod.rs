//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The compile path (`python/compile/aot.py`) lowers every jax graph once
//! to `artifacts/hlo/*.hlo.txt` and records shapes + positional argument
//! contracts in `artifacts/manifest.json`. This module:
//!
//! * parses the manifest ([`artifacts::Manifest`]);
//! * owns the PJRT CPU client and a lazy compile cache
//!   ([`Runtime`]) — each graph is compiled at most once per process;
//! * holds model weights as device-resident [`xla::PjRtBuffer`]s loaded
//!   from `weights/*.npz` once (weights are graph *inputs*, so artifacts
//!   stay small and all LookaheadKV variants share shape-compatible
//!   graphs);
//! * bridges host tensors ([`crate::util::tensor`]) to literals/buffers
//!   ([`literal`]).
//!
//! Python is never involved at runtime; everything here is self-contained
//! given the artifacts directory.

pub mod artifacts;
pub mod literal;
pub mod runtime;

pub use artifacts::{GraphMeta, Manifest, ModelMeta, VariantMeta};
pub use runtime::{GraphHandle, Runtime};

//! Execution runtime: manifest-driven graph execution behind a pluggable
//! [`Backend`] abstraction.
//!
//! The compile path (`python/compile/aot.py`) lowers every jax graph once
//! to `artifacts/hlo/*.hlo.txt` and records shapes + positional argument
//! contracts in `artifacts/manifest.json`. At serve time the engine talks
//! to a [`Runtime`], which dispatches to one of two backends:
//!
//! * [`reference::ReferenceBackend`] (default) — a pure-Rust CPU
//!   implementation of the three graph contracts over
//!   [`crate::util::tensor`] types. Runs offline with no artifacts at
//!   all (weights are synthesized deterministically), so the full
//!   prefill→evict→decode stack is testable and benchable everywhere.
//! * [`pjrt::PjrtBackend`] (`pjrt` feature) — parses the manifest, owns
//!   the PJRT CPU client and a lazy compile cache, and feeds the AOT
//!   graphs their weights from `weights/*.npz`.
//!
//! Python is never involved at runtime; everything here is self-contained
//! given the artifacts directory (or nothing, for the reference backend).

pub mod artifacts;
pub mod backend;
#[cfg(feature = "pjrt")]
pub mod literal;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod runtime;

pub use artifacts::{GraphMeta, Manifest, ModelMeta, VariantMeta};
pub use backend::{
    Backend, ChunkState, DecodeOut, DecodeSeq, GraphStats, KernelStats, PagedDecodeSeq,
    PrefixSeed, Value,
};
pub use reference::{KernelConfig, ReferenceBackend};
pub use runtime::Runtime;
